"""Write-ahead log of source event batches (beyond-paper extension).

Paper section 4.3: "Developing a replay capability to recover the lost
events in the queue is a subject of future work."  This is that future
work: the ingest path appends every source batch (per tick) to a zstd
frame log; after a crash, ``replay`` re-feeds batches from the last
flush frontier.  Associative updaters make replay exactly-once-by-merge
when combined with slate snapshots at flush boundaries (DESIGN.md
section 10).

Offsets are *logical*: every record has a stable byte offset that
survives ``truncate_before`` (the file carries a header recording the
logical offset of its first record), so a flush frontier's
``wal_offset`` stays valid after the log is compacted.  Files written by
older versions (no header) read back with base offset 0.
"""
from __future__ import annotations

import os
import struct
from typing import Dict, Iterator, Optional, Tuple

import msgpack
import numpy as np
from repro.slates import _compress

from repro.core.event import EventBatch

_MAGIC = b"MWAL"
_HDR_MAGIC = b"MWH1"
_HDR_LEN = 12           # magic + u64 logical base offset


def _enc(a):
    a = np.asarray(a)
    return {b"d": a.tobytes(), b"t": a.dtype.str, b"s": list(a.shape)}


def _dec(e):
    return np.frombuffer(e[b"d"], np.dtype(e[b"t"])).reshape(e[b"s"])


class WriteAheadLog:
    """Append-only log of ``(tick, {stream: EventBatch})`` records.

    ``append`` returns the logical end offset after the record — the
    replay point for a frontier recorded *after* that tick.  ``sync=True``
    fsyncs every append (durable against power loss, slower); the default
    flushes to the OS (durable against process crash, the failure model
    of the recovery tests).
    """

    def __init__(self, path: str, *, sync: bool = False,
                 level: Optional[int] = None):
        self.path = path
        self.sync = sync
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        # append sits on the ingest hot path: zstd-1 when available,
        # raw frames under the zlib fallback (zlib-1 alone costs ~15%
        # of a 256-event tick).  Frames are tagged, so a log written at
        # one level replays anywhere.
        if level is None:
            level = 1 if _compress.HAVE_ZSTD else 0
        self._cctx = _compress.Compressor(level=level)
        self._dctx = _compress.Decompressor()
        self._base, self._hdr_len = self._read_header()
        if not os.path.exists(path) or os.path.getsize(path) == 0:
            with open(path, "wb") as f:
                f.write(_HDR_MAGIC + struct.pack("<Q", 0))
            self._base, self._hdr_len = 0, _HDR_LEN
        self._trim_torn_tail()
        self._f = open(path, "ab")
        self._end = self._base + os.path.getsize(path) - self._hdr_len

    # ---- offsets ----
    def _read_header(self) -> Tuple[int, int]:
        """(logical base offset, physical header length)."""
        if not os.path.exists(self.path):
            return 0, 0
        with open(self.path, "rb") as f:
            head = f.read(_HDR_LEN)
        if len(head) >= _HDR_LEN and head[:4] == _HDR_MAGIC:
            return struct.unpack("<Q", head[4:12])[0], _HDR_LEN
        return 0, 0   # legacy headerless file

    def _trim_torn_tail(self):
        """Cut a half-written record left by a crash mid-append, so the
        next append starts on a clean boundary."""
        size = os.path.getsize(self.path)
        with open(self.path, "rb") as f:
            f.seek(self._hdr_len)
            good = self._hdr_len
            while True:
                hdr = f.read(8)
                if len(hdr) < 8 or hdr[:4] != _MAGIC:
                    break
                (n,) = struct.unpack("<I", hdr[4:])
                if f.seek(n, 1) > size or f.tell() > size:
                    break
                good = f.tell()
        if good < size:
            with open(self.path, "r+b") as f:
                f.truncate(good)

    @property
    def offset(self) -> int:
        """Logical end offset (replay point for 'everything from now').
        Tracked incrementally — the append hot path must not stat."""
        return self._end

    # ---- write path ----
    def append(self, tick: int, sources: Dict[str, EventBatch]) -> int:
        payload = {}
        for stream, b in sources.items():
            payload[stream] = {
                "sid": _enc(b.sid), "ts": _enc(b.ts), "key": _enc(b.key),
                "valid": _enc(b.valid),
                "value": {k: _enc(v) for k, v in _flat(b.value)},
            }
        raw = self._cctx.compress(msgpack.packb({"tick": int(tick),
                                                 "src": payload}))
        self._f.write(_MAGIC + struct.pack("<I", len(raw)) + raw)
        self._f.flush()
        if self.sync:
            os.fsync(self._f.fileno())
        self._end += 8 + len(raw)
        return self._end

    def close(self):
        self._f.close()

    # ---- compaction ----
    def truncate_before(self, offset: int):
        """Drop records wholly before logical ``offset`` (typically the
        flush frontier's wal_offset: those events are already reflected
        in flushed slates and will never be replayed).  Logical offsets
        of surviving records are unchanged."""
        if offset <= self._base:
            return
        end = self.offset
        if offset > end:
            raise ValueError(f"truncate offset {offset} beyond log end "
                             f"{end}")
        # frontier offsets come from append(), so they sit on record
        # boundaries; a mid-record offset drops the straddling record
        keep = []
        new_base = self._base
        for rec_off, rec_len, blob in self._iter_raw():
            if rec_off >= offset:
                keep.append(blob)
            else:
                new_base = rec_off + rec_len
        self._f.close()
        tmp = self.path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(_HDR_MAGIC + struct.pack("<Q", new_base))
            for blob in keep:
                f.write(blob)
        os.replace(tmp, self.path)
        self._base, self._hdr_len = new_base, _HDR_LEN
        self._f = open(self.path, "ab")
        self._end = self._base + os.path.getsize(self.path) - _HDR_LEN

    # ---- read path ----
    def _iter_raw(self) -> Iterator[Tuple[int, int, bytes]]:
        """(logical offset, record length, raw record bytes) per record."""
        self._f.flush()
        with open(self.path, "rb") as f:
            f.seek(self._hdr_len)
            off = self._base
            while True:
                hdr = f.read(8)
                if len(hdr) < 8:
                    return
                assert hdr[:4] == _MAGIC, "corrupt WAL"
                (n,) = struct.unpack("<I", hdr[4:])
                body = f.read(n)
                if len(body) < n:
                    return   # torn tail write (crash mid-append): ignore
                yield off, 8 + n, hdr + body
                off += 8 + n

    def replay(self, from_tick: int = 0, *,
               from_offset: Optional[int] = None
               ) -> Iterator[Tuple[int, Dict[str, EventBatch]]]:
        """Yield ``(tick, sources)`` records.

        ``from_offset`` (logical, e.g. a frontier's wal_offset) skips
        records below it without decoding them; ``from_tick`` further
        filters by tick.  An offset below the truncation base starts at
        the first surviving record.
        """
        for off, _, blob in self._iter_raw():
            if from_offset is not None and off < from_offset:
                continue
            rec = msgpack.unpackb(self._dctx.decompress(blob[8:]),
                                  strict_map_key=False)
            if rec["tick"] < from_tick:
                continue
            out = {}
            for stream, b in rec["src"].items():
                sname = stream if isinstance(stream, str) \
                    else stream.decode()
                value = _unflat({(k if isinstance(k, str)
                                  else k.decode()): _dec(v)
                                 for k, v in b["value"].items()})
                out[sname] = EventBatch(
                    sid=_dec(b["sid"]), ts=_dec(b["ts"]),
                    key=_dec(b["key"]), value=value,
                    valid=_dec(b["valid"]))
            yield rec["tick"], out


def _flat(tree, prefix=""):
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree):
            out.extend(_flat(tree[k], f"{prefix}{k}/"))
        return out
    return [(prefix.rstrip("/"), tree)]


def _unflat(flat: Dict[str, np.ndarray]):
    out = {}
    for k, v in flat.items():
        parts = k.split("/")
        cur = out
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = v
    return out
