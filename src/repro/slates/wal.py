"""Write-ahead log of source event batches (beyond-paper extension).

Paper section 4.3: "Developing a replay capability to recover the lost
events in the queue is a subject of future work."  This is that future
work: the ingest path appends every source batch (per tick) to a zstd
frame log; after a crash, ``replay`` re-feeds batches from the last
flushed tick.  Associative updaters make replay idempotent-by-merge when
combined with slate snapshots at flush boundaries.
"""
from __future__ import annotations

import os
import struct
from typing import Dict, Iterator, Tuple

import jax
import msgpack
import numpy as np
from repro.slates import _compress

from repro.core.event import EventBatch

_MAGIC = b"MWAL"


def _enc(a):
    a = np.asarray(a)
    return {b"d": a.tobytes(), b"t": a.dtype.str, b"s": list(a.shape)}


def _dec(e):
    return np.frombuffer(e[b"d"], np.dtype(e[b"t"])).reshape(e[b"s"])


class WriteAheadLog:
    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._cctx = _compress.Compressor(level=1)
        self._dctx = _compress.Decompressor()
        self._f = open(path, "ab")

    def append(self, tick: int, sources: Dict[str, EventBatch]):
        payload = {}
        for stream, b in sources.items():
            payload[stream] = {
                "sid": _enc(b.sid), "ts": _enc(b.ts), "key": _enc(b.key),
                "valid": _enc(b.valid),
                "value": {k: _enc(v) for k, v in _flat(b.value)},
            }
        raw = self._cctx.compress(msgpack.packb({"tick": tick,
                                                 "src": payload}))
        self._f.write(_MAGIC + struct.pack("<I", len(raw)) + raw)
        self._f.flush()

    def close(self):
        self._f.close()

    def replay(self, from_tick: int = 0
               ) -> Iterator[Tuple[int, Dict[str, EventBatch]]]:
        with open(self.path, "rb") as f:
            while True:
                hdr = f.read(8)
                if len(hdr) < 8:
                    return
                assert hdr[:4] == _MAGIC, "corrupt WAL"
                (n,) = struct.unpack("<I", hdr[4:])
                rec = msgpack.unpackb(self._dctx.decompress(f.read(n)),
                                      strict_map_key=False)
                if rec["tick"] < from_tick:
                    continue
                out = {}
                for stream, b in rec["src"].items():
                    sname = stream if isinstance(stream, str) \
                        else stream.decode()
                    value = _unflat({(k if isinstance(k, str)
                                      else k.decode()): _dec(v)
                                     for k, v in b["value"].items()})
                    out[sname] = EventBatch(
                        sid=_dec(b["sid"]), ts=_dec(b["ts"]),
                        key=_dec(b["key"]), value=value,
                        valid=_dec(b["valid"]))
                yield rec["tick"], out


def _flat(tree, prefix=""):
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree):
            out.extend(_flat(tree[k], f"{prefix}{k}/"))
        return out
    return [(prefix.rstrip("/"), tree)]


def _unflat(flat: Dict[str, np.ndarray]):
    out = {}
    for k, v in flat.items():
        parts = k.split("/")
        cur = out
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = v
    return out
