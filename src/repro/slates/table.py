"""Device-resident slate table: a fixed-capacity open-addressing hash map.

One table per (updater, shard) holds that shard's slates — the "slate
cache in the memory of the machine running U" of paper section 4.2, kept
in HBM as struct-of-arrays so the updater hot loop is pure gather /
compute / scatter.

Collision handling is double hashing with a static probe budget; batch
inserts resolve intra-batch slot races with bounded retry rounds.  Keys
that cannot be placed are *dropped and counted* — bounded-resource loss
semantics, exactly how Muppet treats overload (sections 4.3, 5).  TTL and
dirty bits mirror the paper's flush / garbage-collection knobs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.hashing import hash_key

EMPTY = jnp.int32(-1)
PROBES = 8          # static probe budget per lookup
INSERT_ROUNDS = 4   # bounded retry rounds for batch insert


@jax.tree_util.register_dataclass
@dataclass
class SlateTable:
    keys: jnp.ndarray      # int32/int64 [C], EMPTY = free
    ts: jnp.ndarray        # int32 [C] last-update tick (TTL)
    dirty: jnp.ndarray     # bool [C] updated since last flush
    vals: Any              # pytree, leaves [C, ...]
    dropped: jnp.ndarray   # int32 [] lifetime insert-failure count

    @property
    def capacity(self) -> int:
        return int(self.keys.shape[0])

    def occupancy(self):
        return jnp.sum((self.keys != EMPTY).astype(jnp.int32))


def make_table(capacity: int, value_spec: Dict[str, Any],
               key_dtype=jnp.int32) -> SlateTable:
    """value_spec: pytree of (shape_suffix tuple, dtype)."""
    vals = jax.tree.map(
        lambda s: jnp.zeros((capacity,) + tuple(s[0]), s[1]),
        value_spec, is_leaf=_is_spec_leaf)
    return SlateTable(
        keys=jnp.full((capacity,), EMPTY, key_dtype),
        ts=jnp.zeros((capacity,), jnp.int32),
        dirty=jnp.zeros((capacity,), bool),
        vals=vals,
        dropped=jnp.zeros((), jnp.int32),
    )


def _is_spec_leaf(x):
    return isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple)


def _probe_seq(query, capacity: int):
    """[P, B] candidate slots (double hashing)."""
    h1 = hash_key(query, salt=0xA11CE) % jnp.uint32(capacity)
    h2 = hash_key(query, salt=0xB0B) % jnp.uint32(capacity - 1) + jnp.uint32(1)
    steps = jnp.arange(PROBES, dtype=jnp.uint32)[:, None]
    return ((h1[None] + steps * h2[None]) % jnp.uint32(capacity)
            ).astype(jnp.int32)


def lookup(table: SlateTable, query) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """query: int32 [B] -> (slot [B], found [B]).  slot is the matching
    slot if found, else the first empty probe slot (insertion point), else
    -1 (probe budget exhausted)."""
    cand = _probe_seq(query, table.capacity)              # [P,B]
    ck = table.keys[cand]                                 # [P,B]
    hit = ck == query[None]
    free = ck == EMPTY

    def first_true(mask, vals, default):
        # index of first True along axis 0
        any_ = jnp.any(mask, axis=0)
        idx = jnp.argmax(mask, axis=0)
        return jnp.where(any_, jnp.take_along_axis(
            vals, idx[None], axis=0)[0], default), any_

    hit_slot, found = first_true(hit, cand, jnp.int32(-1))
    free_slot, has_free = first_true(free, cand, jnp.int32(-1))
    slot = jnp.where(found, hit_slot,
                     jnp.where(has_free, free_slot, jnp.int32(-1)))
    return slot, found


def insert_or_find(table: SlateTable, query, valid) -> Tuple[
        SlateTable, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Place unique ``query`` keys (masked by ``valid``).

    Returns (table, slot [B], found_existing [B], placed [B]).  New keys
    claim empty slots; intra-batch races on the same empty slot resolve
    over INSERT_ROUNDS retries; stragglers are dropped (counted).
    Caller must guarantee uniqueness of valid keys (dedup upstream).
    """
    keys_arr = table.keys
    slot = jnp.full(query.shape, -1, jnp.int32)
    placed = jnp.zeros(query.shape, bool)
    found = jnp.zeros(query.shape, bool)
    pending = valid

    for _ in range(INSERT_ROUNDS):
        cand_slot, cand_found = _lookup_keys(keys_arr, query,
                                             table.capacity)
        want = pending & (cand_slot >= 0)
        # claim: scatter key ids into candidate slots; later writers win,
        # so read back to see who actually owns the slot
        safe_slot = jnp.where(want & ~cand_found, cand_slot, table.capacity)
        keys_try = keys_arr.at[safe_slot].set(query, mode="drop")
        owner_ok = keys_try[jnp.clip(cand_slot, 0, table.capacity - 1)] == query
        success = want & (cand_found | owner_ok)
        slot = jnp.where(success, cand_slot, slot)
        found = found | (want & cand_found)
        placed = placed | success
        pending = pending & ~success
        keys_arr = keys_try

    dropped = table.dropped + jnp.sum(pending, dtype=jnp.int32)
    new_table = SlateTable(keys=keys_arr, ts=table.ts, dirty=table.dirty,
                           vals=table.vals, dropped=dropped)
    return new_table, slot, found, placed


def _lookup_keys(keys_arr, query, capacity):
    cand = _probe_seq(query, capacity)
    ck = keys_arr[cand]
    hit = ck == query[None]
    free = ck == EMPTY
    stop = hit | free
    any_ = jnp.any(stop, axis=0)
    idx = jnp.argmax(stop, axis=0)
    slot = jnp.where(any_, jnp.take_along_axis(cand, idx[None], axis=0)[0],
                     jnp.int32(-1))
    found = jnp.take_along_axis(hit, idx[None], axis=0)[0] & any_
    return slot, found


def read_slates(table: SlateTable, slot, found, init_fn: Callable):
    """Gather slate values; missing keys get ``init_fn(batch)`` defaults.
    (Paper: 'the update function must set up and initialize the slate on
    first access'.)"""
    gathered = jax.tree.map(
        lambda v: v[jnp.clip(slot, 0, table.capacity - 1)], table.vals)
    fresh = init_fn(slot.shape[0])
    pick = lambda g, f: jnp.where(
        _bshape(found, g), g, f.astype(g.dtype))
    return jax.tree.map(pick, gathered, fresh)


def write_slates(table: SlateTable, slot, ok, new_vals, tick) -> SlateTable:
    safe = jnp.where(ok, slot, table.capacity)
    vals = jax.tree.map(
        lambda tv, nv: tv.at[safe].set(nv.astype(tv.dtype), mode="drop"),
        table.vals, new_vals)
    ts = table.ts.at[safe].set(tick, mode="drop")
    dirty = table.dirty.at[safe].set(True, mode="drop")
    return SlateTable(keys=table.keys, ts=ts, dirty=dirty, vals=vals,
                      dropped=table.dropped)


def expire_ttl(table: SlateTable, now, ttl: int) -> SlateTable:
    """Garbage-collect slates idle for > ttl ticks (paper section 4.2)."""
    dead = (table.keys != EMPTY) & (now - table.ts > ttl)
    keys = jnp.where(dead, jnp.asarray(EMPTY, table.keys.dtype),
                     table.keys)
    dirty = jnp.where(dead, False, table.dirty)
    return SlateTable(keys=keys, ts=table.ts, dirty=dirty, vals=table.vals,
                      dropped=table.dropped)


def _bshape(mask, like):
    return mask.reshape(mask.shape + (1,) * (like.ndim - 1))
