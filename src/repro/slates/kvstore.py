"""Persistent slate store — the role Cassandra plays in paper section 4.2.

Slates are serialized (msgpack) and zstd-compressed ("our applications
often use JSON ... so Muppet compresses each slate before storing it").
The store simulates a replicated cluster: N replica directories, write
quorum W and read quorum R (the paper's ONE / QUORUM / ALL knob), per-write
TTL with garbage collection, and bucketed segment files whose rewrite
stands in for compaction.  Buffered writes flush in the background — the
paper's "devote the store's memory to buffering writes" on SSDs.
"""
from __future__ import annotations

import io
import os
import struct
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Optional, Tuple

import msgpack
import numpy as np
from repro.slates import _compress


def _pack_tree(tree) -> bytes:
    """Serialize a pytree of numpy arrays / scalars."""
    def enc(x):
        a = np.asarray(x)
        return {b"__nd__": True, b"d": a.tobytes(), b"t": a.dtype.str,
                b"s": list(a.shape)}
    flat = _flatten(tree)
    payload = [(k, enc(v)) for k, v in flat]
    return msgpack.packb(payload)


def _unpack_tree(raw: bytes):
    payload = msgpack.unpackb(raw, strict_map_key=False)
    flat = []
    for k, e in payload:
        a = np.frombuffer(e[b"d"], dtype=np.dtype(e[b"t"])).reshape(e[b"s"])
        flat.append((k if isinstance(k, str) else k.decode(), a))
    return _unflatten(flat)


def _flatten(tree, prefix="") -> List[Tuple[str, Any]]:
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree):
            out.extend(_flatten(tree[k], f"{prefix}{k}/"))
        return out
    return [(prefix.rstrip("/"), tree)]


def _unflatten(flat):
    out: Dict[str, Any] = {}
    for k, v in flat:
        parts = k.split("/")
        cur = out
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = v
    if list(out.keys()) == [""]:
        return out[""]
    return out


@dataclass
class Record:
    ts: int          # write tick
    ttl: int         # 0 = forever
    blob: bytes      # compressed slate


class KVStore:
    """Replicated, bucketed, compressed key-value store for slates.

    Layout: root/replica_<i>/<updater>/bucket_<b>.seg — each segment is a
    msgpack map {key: (ts, ttl, blob)}.
    """

    def __init__(self, root: str, *, replicas: int = 3, write_quorum: int = 2,
                 read_quorum: int = 2, buckets: int = 64,
                 flush_buffer: int = 1024):
        assert 1 <= write_quorum <= replicas
        assert 1 <= read_quorum <= replicas
        self.root = root
        self.replicas = replicas
        self.write_quorum = write_quorum
        self.read_quorum = read_quorum
        self.buckets = buckets
        self._cctx = _compress.Compressor(level=3)
        self._dctx = _compress.Decompressor()
        self._lock = threading.Lock()
        self._buffer: Dict[Tuple[str, int], Record] = {}
        self._flush_buffer = flush_buffer
        self._replica_down = [False] * replicas
        os.makedirs(root, exist_ok=True)

    # ---- fault injection (simulated replica failures) ----
    def set_replica_down(self, i: int, down: bool = True):
        self._replica_down[i] = down

    # ---- write path ----
    def put(self, updater: str, key: int, slate, *, ts: int, ttl: int = 0):
        blob = self._cctx.compress(_pack_tree(slate))
        with self._lock:
            self._buffer[(updater, int(key))] = Record(ts=ts, ttl=ttl,
                                                       blob=blob)
            if len(self._buffer) >= self._flush_buffer:
                self._flush_locked()

    def put_many(self, updater: str, items: Iterable[Tuple[int, Any]], *,
                 ts, ttl: int = 0):
        """``ts`` is one write tick for the whole batch or a per-item
        sequence (each slate's own last-update tick, so TTL expiry and
        newest-wins reads stay per-key exact across flushes)."""
        per_item = isinstance(ts, (list, tuple, np.ndarray))
        for i, (key, slate) in enumerate(items):
            self.put(updater, key, slate,
                     ts=int(ts[i]) if per_item else int(ts), ttl=ttl)

    def flush(self):
        with self._lock:
            self._flush_locked()

    def _flush_locked(self):
        if not self._buffer:
            return
        by_seg: Dict[Tuple[str, int], Dict[int, Record]] = {}
        for (upd, key), rec in self._buffer.items():
            b = _bucket_of(key, self.buckets)
            by_seg.setdefault((upd, b), {})[key] = rec
        self._buffer.clear()
        for (upd, b), recs in by_seg.items():
            written = 0
            for i in range(self.replicas):
                if self._replica_down[i]:
                    continue
                self._merge_segment(i, upd, b, recs)
                written += 1
                if written >= self.write_quorum and \
                        written >= self._alive_count():
                    break
            if written < self.write_quorum:
                raise IOError(
                    f"write quorum failed ({written}/{self.write_quorum})")

    def _alive_count(self):
        return sum(1 for d in self._replica_down if not d)

    def _seg_path(self, replica: int, updater: str, bucket: int) -> str:
        d = os.path.join(self.root, f"replica_{replica}", updater)
        os.makedirs(d, exist_ok=True)
        return os.path.join(d, f"bucket_{bucket:04d}.seg")

    def _merge_segment(self, replica: int, updater: str, bucket: int,
                       recs: Dict[int, Record]):
        path = self._seg_path(replica, updater, bucket)
        existing = self._read_segment_file(path)
        for k, r in recs.items():
            old = existing.get(k)
            if old is None or old[0] <= r.ts:
                existing[k] = (r.ts, r.ttl, r.blob)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(msgpack.packb(
                {k: list(v) for k, v in existing.items()}))
        os.replace(tmp, path)  # atomic

    @staticmethod
    def _read_segment_file(path: str) -> Dict[int, Tuple[int, int, bytes]]:
        if not os.path.exists(path):
            return {}
        with open(path, "rb") as f:
            raw = msgpack.unpackb(f.read(), strict_map_key=False)
        return {int(k): (v[0], v[1], v[2]) for k, v in raw.items()}

    # ---- read path ----
    def get(self, updater: str, key: int, *, now: Optional[int] = None):
        """Quorum read: newest ts among read_quorum replicas; expired
        records (TTL) read as missing."""
        self.flush()
        b = _bucket_of(int(key), self.buckets)
        best: Optional[Tuple[int, int, bytes]] = None
        seen = 0
        for i in range(self.replicas):
            if self._replica_down[i]:
                continue
            seg = self._read_segment_file(self._seg_path(i, updater, b))
            rec = seg.get(int(key))
            seen += 1
            if rec is not None and (best is None or rec[0] > best[0]):
                best = rec
            if seen >= self.read_quorum:
                break
        if seen < self.read_quorum:
            raise IOError(f"read quorum failed ({seen}/{self.read_quorum})")
        if best is None:
            return None
        ts, ttl, blob = best
        if ttl and now is not None and now - ts > ttl:
            return None
        return _unpack_tree(self._dctx.decompress(blob))

    def scan(self, updater: str, *, now: Optional[int] = None):
        """Bulk read of every live slate (paper section 5 'bulk reading of
        slates')."""
        return {k: slate
                for k, (_, slate) in self.scan_records(updater,
                                                       now=now).items()}

    def scan_records(self, updater: str, *, now: Optional[int] = None
                     ) -> Dict[int, Tuple[int, Any]]:
        """Like ``scan`` but returns ``{key: (ts, slate)}`` — recovery
        needs each slate's write tick to restore per-slot TTL clocks."""
        self.flush()
        out: Dict[int, bytes] = {}
        meta: Dict[int, int] = {}
        for i in range(self.replicas):
            if self._replica_down[i]:
                continue
            d = os.path.join(self.root, f"replica_{i}", updater)
            if not os.path.isdir(d):
                continue
            for fn in sorted(os.listdir(d)):
                seg = self._read_segment_file(os.path.join(d, fn))
                for k, (ts, ttl, blob) in seg.items():
                    if ttl and now is not None and now - ts > ttl:
                        continue
                    if k not in meta or ts > meta[k]:
                        meta[k] = ts
                        out[k] = blob
        return {k: (meta[k], _unpack_tree(self._dctx.decompress(v)))
                for k, v in out.items()}

    # ---- maintenance ----
    def gc(self, updater: str, *, now: int):
        """Drop expired records (the store-side TTL GC of section 4.2)."""
        removed = 0
        for i in range(self.replicas):
            if self._replica_down[i]:
                continue
            d = os.path.join(self.root, f"replica_{i}", updater)
            if not os.path.isdir(d):
                continue
            for fn in sorted(os.listdir(d)):
                path = os.path.join(d, fn)
                seg = self._read_segment_file(path)
                live = {k: v for k, v in seg.items()
                        if not (v[1] and now - v[0] > v[1])}
                if len(live) != len(seg):
                    removed += len(seg) - len(live)
                    tmp = path + ".tmp"
                    with open(tmp, "wb") as f:
                        f.write(msgpack.packb(
                            {k: list(v) for k, v in live.items()}))
                    os.replace(tmp, path)
        return removed


def _bucket_of(key: int, buckets: int) -> int:
    x = key & 0xFFFFFFFF
    x = (x ^ (x >> 16)) * 0x7FEB352D & 0xFFFFFFFF
    x = (x ^ (x >> 15)) * 0x846CA68B & 0xFFFFFFFF
    return (x ^ (x >> 16)) % buckets
