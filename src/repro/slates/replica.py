"""Read-replica tier + hot-key cache (DESIGN.md section 15).

Muppet's slates are read by "numerous applications" at serving rates
(paper section 4.4).  The engine-attached read path (``read_slate`` /
``read_slates``) answers from the live device tables — up to date, but
every request contends with the stream for the device.  This module
adds the two off-engine tiers:

- :class:`SlateReplica` consumes the *flush stream* the durability
  runtime already produces: at every flush frontier the KV store holds
  a consistent snapshot of all flushed slates, so a replica can
  ``refresh()`` itself from ``store.scan_records`` and serve reads
  without ever touching engine state.  Staleness is bounded — a
  replica knows the frontier tick of its snapshot and refuses reads
  whose ``now`` has drifted more than ``max_staleness_ticks`` past it
  (:class:`StaleReplicaError`), the contract that makes replica reads
  safe to load-balance behind the live tier.

- :class:`HotKeyCache` fronts the *live* read path for the keys the
  count-min telemetry sketch reports as heavy hitters: the driver
  warms the admission set from each window's ``heavy_hitters`` and
  invalidates whole-sale whenever the flush frontier advances (the
  cheapest correct rule: frontier advances are the only boundaries at
  which a replica-vs-live divergence could become user-visible).  A
  bounded LRU with optional wall-clock TTL; only admitted (hot) keys
  are ever stored, so one scan of cold keys cannot evict the working
  set.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.slates.flush import FlushFrontier


class StaleReplicaError(RuntimeError):
    """The replica's snapshot is older than the read's staleness bound."""

    def __init__(self, snapshot_tick: int, now: int, bound: int):
        self.snapshot_tick = snapshot_tick
        self.now = now
        self.bound = bound
        super().__init__(
            f"replica snapshot at tick {snapshot_tick} is "
            f"{now - snapshot_tick} ticks behind now={now} "
            f"(max_staleness_ticks={bound})")


class HotKeyCache:
    """LRU/TTL cache admitting only telemetry-designated hot keys.

    ``warm(keys)`` swaps the admission set (the window's heavy
    hitters); ``put`` silently drops non-admitted keys.  ``get``
    returns ``(hit, value)`` so a cached ``None``-free design stays
    simple: misses and cold keys look identical to the caller, which
    falls through to the live read.  ``invalidate()`` clears entries
    but keeps the admission set (the keys are still hot; their values
    are merely suspect after a frontier advance).  Thread-safe.
    """

    def __init__(self, capacity: int = 256,
                 ttl_s: Optional[float] = None,
                 clock=time.monotonic):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.ttl_s = ttl_s
        self._clock = clock
        self._hot: set = set()
        self._entries: "OrderedDict[Tuple[str, int], Tuple[float, Any]]" \
            = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.invalidations = 0

    def warm(self, keys: Iterable[int]):
        """Replace the admission set with this window's heavy hitters."""
        with self._lock:
            self._hot = {int(k) for k in keys}

    def hot_keys(self) -> List[int]:
        with self._lock:
            return sorted(self._hot)

    def get(self, updater: str, key: int) -> Tuple[bool, Any]:
        k = (updater, int(key))
        with self._lock:
            ent = self._entries.get(k)
            if ent is not None:
                stamp, val = ent
                if self.ttl_s is None or \
                        self._clock() - stamp <= self.ttl_s:
                    self._entries.move_to_end(k)
                    self.hits += 1
                    return True, val
                del self._entries[k]        # TTL-expired
            self.misses += 1
            return False, None

    def put(self, updater: str, key: int, value: Any):
        with self._lock:
            if int(key) not in self._hot:
                return
            self._entries[(updater, int(key))] = (self._clock(), value)
            self._entries.move_to_end((updater, int(key)))
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)

    def invalidate(self):
        """Drop every cached value (flush frontier advanced)."""
        with self._lock:
            if self._entries:
                self.invalidations += 1
            self._entries.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"entries": len(self._entries),
                    "hot_keys": len(self._hot),
                    "hits": self.hits, "misses": self.misses,
                    "invalidations": self.invalidations}


class SlateReplica:
    """Stale-bounded slate reads from flush-frontier snapshots.

    ``workflow`` names the updaters (and their TTLs) to snapshot;
    ``store`` is the KV store the engine's flusher writes.  A replica
    never touches engine device state — it can run in another process
    against the same store directory.  Thread-safe: ``refresh`` swaps
    the snapshot dict atomically under a lock.
    """

    def __init__(self, store, workflow, *,
                 max_staleness_ticks: int = 64, flusher=None):
        if max_staleness_ticks < 0:
            raise ValueError("max_staleness_ticks must be >= 0")
        self.store = store
        self.wf = workflow
        self.max_staleness_ticks = max_staleness_ticks
        # a delta-tracking Flusher: refresh merges its flush stream
        # instead of re-scanning the store (first refresh still scans)
        self.flusher = flusher
        self._snap: Dict[str, Dict[int, tuple]] = {}
        self._tick = -1                      # no snapshot yet
        self._lock = threading.Lock()

    @property
    def snapshot_tick(self) -> int:
        """Frontier tick of the current snapshot (-1 before the first
        ``refresh``)."""
        with self._lock:
            return self._tick

    def refresh(self, frontier: Optional[FlushFrontier] = None, *,
                tick: Optional[int] = None) -> int:
        """Re-snapshot every updater's flushed slates at a frontier.

        Pass the engine's ``FlushFrontier`` (or an explicit ``tick``
        when driving from a raw store).  TTL-bearing updaters are
        scanned with ``now=tick`` so rows the engine would have expired
        never enter the snapshot.  Returns the number of rows held.

        With a delta-tracking ``flusher`` attached, refreshes after the
        first merge the flush stream (``drain_deltas``) into the held
        snapshot — newest write tick wins, TTL-expired rows are pruned
        — instead of re-reading every store segment; byte-for-byte the
        same snapshot a full scan at the frontier would build (the
        store applies the identical newest-wins rule at merge time).
        Call at flush barriers (after ``Flusher.drain``) so the delta
        handoff is complete at the frontier.
        """
        if tick is None:
            tick = int(frontier.tick) if frontier is not None else 0
        deltas = self.flusher.drain_deltas() \
            if self.flusher is not None else {}
        with self._lock:
            base, base_tick = self._snap, self._tick
        snap: Dict[str, Dict[int, tuple]] = {}
        rows = 0
        for up in self.wf.updaters():
            if self.flusher is None or base_tick < 0:
                # cold start (or no flush stream): full store scan;
                # drained deltas are already reflected in the scan
                cur = self.store.scan_records(
                    up.name, now=tick if up.ttl else None)
            else:
                cur = dict(base.get(up.name, {}))
                for k, rec in deltas.get(up.name, {}).items():
                    old = cur.get(k)
                    if old is None or old[0] <= rec[0]:
                        cur[k] = rec
                if up.ttl:
                    cur = {k: rec for k, rec in cur.items()
                           if tick - rec[0] <= up.ttl}
            snap[up.name] = cur
            rows += len(cur)
        with self._lock:
            self._snap = snap
            self._tick = int(tick)
        return rows

    def _check_staleness(self, now: Optional[int], tick: int):
        if tick < 0:
            raise StaleReplicaError(tick, now if now is not None else 0,
                                    self.max_staleness_ticks)
        if now is not None and now - tick > self.max_staleness_ticks:
            raise StaleReplicaError(tick, now, self.max_staleness_ticks)

    def read(self, updater: str, key: int,
             now: Optional[int] = None):
        """One slate from the snapshot; ``now`` (the caller's engine
        tick) enforces the staleness bound — omit it for bound-free
        reads.  Returns ``None`` for missing keys."""
        with self._lock:
            tick, snap = self._tick, self._snap
        self._check_staleness(now, tick)
        rec = snap.get(updater, {}).get(int(key))
        return rec[1] if rec is not None else None

    def read_many(self, updater: str, keys,
                  now: Optional[int] = None) -> List[Any]:
        """Batched snapshot reads, list aligned with ``keys``."""
        with self._lock:
            tick, snap = self._tick, self._snap
        self._check_staleness(now, tick)
        table = snap.get(updater, {})
        out = []
        for k in keys:
            rec = table.get(int(k))
            out.append(rec[1] if rec is not None else None)
        return out

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {"snapshot_tick": self._tick,
                    "max_staleness_ticks": self.max_staleness_ticks,
                    "rows": {u: len(t) for u, t in self._snap.items()}}

    def serve(self, port: int = 0):
        """HTTP server over the replica (same surface as the live
        :class:`~repro.slates.http.SlateServer`)."""
        from repro.slates.http import SlateServer
        return SlateServer(
            read_fn=self.read, stats_fn=self.stats,
            read_many_fn=lambda up, ks: self.read_many(up, ks),
            port=port)
