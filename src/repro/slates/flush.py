"""Slate cache <-> KV store synchronization.

Implements the paper's flush knob ("immediate write-through" ...
"only when evicted from cache"), background-thread flushing (the Muppet
2.0 background-I/O thread, so the update hot loop never blocks on the
store), and read-through restore after a crash.
"""
from __future__ import annotations

import enum
import queue as pyqueue
import threading
from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.slates import table as tbl
from repro.slates.kvstore import KVStore


class FlushPolicy(enum.Enum):
    IMMEDIATE = "immediate"    # write-through every tick
    EVERY_K = "every_k"        # every k ticks
    ON_EVICT = "on_evict"      # only under table pressure / TTL expiry


@dataclass
class FlushConfig:
    policy: FlushPolicy = FlushPolicy.EVERY_K
    every_k: int = 16
    occupancy_evict: float = 0.85   # ON_EVICT pressure threshold


def dirty_snapshot(table: tbl.SlateTable):
    """Host copies of (keys, ts, slates) for dirty slots, and the cleared
    table.  The device->host fetch is the only sync point; serialization
    and disk I/O run on the flusher thread."""
    dirty = np.asarray(jax.device_get(table.dirty))
    keys = np.asarray(jax.device_get(table.keys))
    ts = np.asarray(jax.device_get(table.ts))
    idx = np.nonzero(dirty & (keys != -1))[0]
    vals = jax.tree.map(lambda v: np.asarray(jax.device_get(v))[idx],
                        table.vals)
    cleared = tbl.SlateTable(
        keys=table.keys, ts=table.ts,
        dirty=jnp.zeros_like(table.dirty),
        vals=table.vals, dropped=table.dropped)
    return keys[idx], ts[idx], vals, cleared


def restore_into(table: tbl.SlateTable, keys: np.ndarray, slates,
                 ts: np.ndarray) -> tbl.SlateTable:
    """Re-insert flushed slates after a crash (read-through warm-up)."""
    if len(keys) == 0:
        return table
    k = jnp.asarray(keys, jnp.int32)
    valid = jnp.ones((len(keys),), bool)
    table, slot, found, placed = tbl.insert_or_find(table, k, valid)
    vals = jax.tree.map(jnp.asarray, slates)
    table = tbl.write_slates(table, slot, placed, vals,
                             jnp.asarray(ts, jnp.int32).max())
    # restored slates are clean (they came *from* the store)
    return tbl.SlateTable(keys=table.keys, ts=table.ts,
                          dirty=jnp.zeros_like(table.dirty),
                          vals=table.vals, dropped=table.dropped)


class Flusher:
    """Background flusher thread: consumes dirty snapshots, writes to the
    KV store.  ``flush_tables`` is called from the engine driver per the
    policy; ``drain`` joins outstanding work (tests / shutdown)."""

    def __init__(self, store: KVStore, cfg: Optional[FlushConfig] = None):
        self.store = store
        self.cfg = cfg or FlushConfig()
        self._q: pyqueue.Queue = pyqueue.Queue()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        self.errors: list = []

    def _loop(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            try:
                updater, keys, ts, vals, tick, ttl = item
                rows = _rows_of(vals, len(keys))
                self.store.put_many(updater,
                                    zip(keys.tolist(), rows),
                                    ts=tick, ttl=ttl)
                self.store.flush()
            except Exception as e:  # pragma: no cover
                self.errors.append(e)
            finally:
                self._q.task_done()

    def should_flush(self, tick: int, table: tbl.SlateTable) -> bool:
        p = self.cfg.policy
        if p is FlushPolicy.IMMEDIATE:
            return True
        if p is FlushPolicy.EVERY_K:
            return tick % self.cfg.every_k == 0
        occ = float(jax.device_get(table.occupancy()))
        return occ >= self.cfg.occupancy_evict * table.capacity

    def flush_table(self, updater: str, table: tbl.SlateTable, tick: int,
                    ttl: int = 0) -> tbl.SlateTable:
        keys, ts, vals, cleared = dirty_snapshot(table)
        if len(keys):
            self._q.put((updater, keys, ts, vals, int(tick), ttl))
        return cleared

    def drain(self):
        self._q.join()
        self.store.flush()

    def close(self):
        self.drain()
        self._q.put(None)
        self._thread.join(timeout=5)


def _rows_of(vals, n: int):
    """Split a pytree of [n, ...] arrays into n per-key pytrees."""
    leaves, treedef = jax.tree.flatten(vals)
    return [jax.tree.unflatten(treedef, [lf[i] for lf in leaves])
            for i in range(n)]
