"""Slate cache <-> KV store synchronization.

Implements the paper's flush knob ("immediate write-through" ...
"only when evicted from cache"), background-thread flushing (the Muppet
2.0 background-I/O thread, so the update hot loop never blocks on the
store), read-through restore after a crash, and the *flush frontier*
(DESIGN.md section 10): the durable ``(tick, wal_offset)`` watermark
from which WAL replay resumes after recovery.
"""
from __future__ import annotations

import enum
import json
import os
import queue as pyqueue
import threading
from dataclasses import dataclass
from typing import List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.slates import table as tbl
from repro.slates.kvstore import KVStore


class FlushPolicy(enum.Enum):
    IMMEDIATE = "immediate"    # write-through every tick
    EVERY_K = "every_k"        # every k ticks
    ON_EVICT = "on_evict"      # only under table pressure / TTL expiry


@dataclass
class FlushConfig:
    policy: FlushPolicy = FlushPolicy.EVERY_K
    every_k: int = 16
    occupancy_evict: float = 0.85   # ON_EVICT pressure threshold


class FlushError(RuntimeError):
    """One or more background flush writes failed; ``.errors`` holds the
    underlying exceptions in arrival order."""

    def __init__(self, errors: Sequence[BaseException]):
        self.errors = list(errors)
        super().__init__(
            f"{len(self.errors)} flush write(s) failed: "
            f"{self.errors[0]!r}")


# ---------------------------------------------------------------------------
# flush frontier: the durable replay watermark
# ---------------------------------------------------------------------------

@dataclass
class FlushFrontier:
    """Everything before ``tick`` / ``wal_offset`` is durably reflected
    in the KV store; recovery restores slates and replays the WAL from
    here.  ``wal_offset`` is an int (single shard) or a per-shard list
    (DistributedEngine: one WAL per shard, one barrier tick).  ``meta``
    is an opaque json-serializable driver cursor (e.g. the source index
    at the boundary) that survives even full WAL truncation."""

    tick: int = 0
    wal_offset: Union[int, List[int]] = 0
    meta: Optional[dict] = None

    def save(self, path: str):
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"tick": int(self.tick),
                       "wal_offset": self.wal_offset,
                       "meta": self.meta}, f)
        os.replace(tmp, path)   # atomic: a crash mid-save keeps the old
                                # frontier, replay just covers more ticks

    @staticmethod
    def load(path: str) -> Optional["FlushFrontier"]:
        if not os.path.exists(path):
            return None
        with open(path) as f:
            d = json.load(f)
        return FlushFrontier(tick=int(d["tick"]),
                             wal_offset=d["wal_offset"],
                             meta=d.get("meta"))


def begin_dirty_snapshot(table: tbl.SlateTable):
    """Start the device->host fetch for a flush snapshot.

    Device-side copies are taken first (so the token stays valid after
    the next chunk's donation deletes the table buffers) and their host
    transfer is kicked off asynchronously; :func:`finish_dirty_snapshot`
    resolves the token to host rows whenever the driver is ready —
    typically after the next chunk has been dispatched, so the transfer
    and the serialization behind it overlap device compute.  Returns
    ``(token, cleared_table)``; the cleared table (dirty bits dropped)
    is usable immediately."""
    token = (jnp.copy(table.dirty), jnp.copy(table.keys),
             jnp.copy(table.ts), jax.tree.map(jnp.copy, table.vals))
    for leaf in jax.tree.leaves(token):
        copy_async = getattr(leaf, "copy_to_host_async", None)
        if copy_async is not None:
            copy_async()
    cleared = tbl.SlateTable(
        keys=table.keys, ts=table.ts,
        dirty=jnp.zeros_like(table.dirty),
        vals=table.vals, dropped=table.dropped)
    return token, cleared


def finish_dirty_snapshot(token):
    """Resolve an in-flight snapshot to host ``(keys, ts, vals)`` of the
    dirty occupied slots (the flusher's row format)."""
    dirty_d, keys_d, ts_d, vals_d = token
    dirty = np.asarray(jax.device_get(dirty_d))
    keys = np.asarray(jax.device_get(keys_d))
    ts = np.asarray(jax.device_get(ts_d))
    idx = np.nonzero(dirty & (keys != -1))[0]
    vals = jax.tree.map(lambda v: np.asarray(jax.device_get(v))[idx],
                        vals_d)
    return keys[idx], ts[idx], vals


def dirty_snapshot(table: tbl.SlateTable):
    """Host copies of (keys, ts, slates) for dirty slots, and the cleared
    table — the synchronous begin+finish composition; serialization and
    disk I/O still run on the flusher thread."""
    token, cleared = begin_dirty_snapshot(table)
    keys, ts, vals = finish_dirty_snapshot(token)
    return keys, ts, vals, cleared


def restore_into(table: tbl.SlateTable, keys: np.ndarray, slates,
                 ts: np.ndarray) -> tbl.SlateTable:
    """Re-insert flushed slates after a crash (read-through warm-up).

    ``ts`` is per-key (each slate's last-update tick, as recorded by the
    store): restoring per-slot timestamps keeps TTL eviction after
    recovery identical to the pre-crash schedule.  Idempotent: keys
    already present are overwritten, not merged, so a crash *during*
    recovery just means recovering again from the same frontier.
    """
    if len(keys) == 0:
        return table
    k = jnp.asarray(keys, table.keys.dtype)
    valid = jnp.ones((len(keys),), bool)
    table, slot, found, placed = tbl.insert_or_find(table, k, valid)
    vals = jax.tree.map(jnp.asarray, slates)
    table = tbl.write_slates(table, slot, placed, vals,
                             jnp.asarray(ts, jnp.int32))
    # restored slates are clean (they came *from* the store)
    return tbl.SlateTable(keys=table.keys, ts=table.ts,
                          dirty=jnp.zeros_like(table.dirty),
                          vals=table.vals, dropped=table.dropped)


class Flusher:
    """Background flusher thread: consumes dirty snapshots, writes to the
    KV store.  ``flush_table`` is called from the engine driver per the
    policy; ``drain`` joins outstanding work (flush barriers / shutdown)
    and **re-raises** any write error as :class:`FlushError` — a frontier
    must never advance past a failed store write.

    With ``track_deltas`` the flusher also retains a host-side copy of
    every row it successfully wrote since the last ``drain_deltas()``
    call — the flush *stream* a :class:`~repro.slates.replica.
    SlateReplica` consumes to refresh incrementally instead of
    re-scanning the whole store (DESIGN.md section 15)."""

    def __init__(self, store: KVStore, cfg: Optional[FlushConfig] = None,
                 *, track_deltas: bool = False):
        self.store = store
        self.cfg = cfg or FlushConfig()
        self.track_deltas = track_deltas
        self._deltas: dict = {}          # updater -> {key: (ts, slate)}
        self._dlock = threading.Lock()
        self._q: pyqueue.Queue = pyqueue.Queue()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        self.errors: list = []

    def _loop(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            try:
                updater, keys, ts, vals, ttl = item
                rows = _rows_of(vals, len(keys))
                self.store.put_many(updater,
                                    zip(keys.tolist(), rows),
                                    ts=ts.tolist(), ttl=ttl)
                self.store.flush()
                if self.track_deltas:
                    # recorded only after the write landed: a delta the
                    # replica merges is always durably in the store too
                    with self._dlock:
                        d = self._deltas.setdefault(updater, {})
                        for k, t, row in zip(keys.tolist(), ts.tolist(),
                                             rows):
                            old = d.get(k)
                            if old is None or old[0] <= t:
                                d[k] = (t, row)
            except Exception as e:
                self.errors.append(e)
            finally:
                self._q.task_done()

    def drain_deltas(self) -> dict:
        """Hand off (and clear) the rows written since the last call:
        ``{updater: {key: (ts, slate)}}``, newest write per key.  Call
        after ``drain()`` (a flush barrier) so the handoff covers every
        row at the frontier."""
        with self._dlock:
            d, self._deltas = self._deltas, {}
        return d

    def should_flush(self, tick: int, table: tbl.SlateTable) -> bool:
        p = self.cfg.policy
        if p is FlushPolicy.IMMEDIATE:
            return True
        if p is FlushPolicy.EVERY_K:
            return tick % self.cfg.every_k == 0
        occ = float(jax.device_get(table.occupancy()))
        return occ >= self.cfg.occupancy_evict * table.capacity

    def flush_rows(self, updater: str, keys: np.ndarray, ts: np.ndarray,
                   vals, ttl: int = 0):
        """Enqueue pre-snapshotted host rows (the per-shard flush path of
        ``DistributedEngine`` snapshots all shards in one device_get and
        feeds each shard's rows here).  Store write ticks are the
        per-row ``ts`` (each slate's last-update tick)."""
        if len(keys):
            self._q.put((updater, np.asarray(keys), np.asarray(ts), vals,
                         ttl))

    def flush_table(self, updater: str, table: tbl.SlateTable,
                    ttl: int = 0) -> tbl.SlateTable:
        keys, ts, vals, cleared = dirty_snapshot(table)
        self.flush_rows(updater, keys, ts, vals, ttl)
        return cleared

    def _raise_accumulated(self):
        if self.errors:
            errs, self.errors = self.errors, []
            raise FlushError(errs)

    def drain(self):
        """Join outstanding writes; raises :class:`FlushError` if any
        failed (callers must not record a frontier past the failure)."""
        self._q.join()
        try:
            self.store.flush()
        except Exception as e:
            self.errors.append(e)
        self._raise_accumulated()

    def close(self):
        try:
            self.drain()
        finally:
            self._q.put(None)
            self._thread.join(timeout=5)


def _rows_of(vals, n: int):
    """Split a pytree of [n, ...] arrays into n per-key pytrees.  One
    iteration pass per leaf (``list`` walks the leading axis once)
    instead of n fancy-index calls per leaf."""
    leaves, treedef = jax.tree.flatten(vals)
    if not leaves:
        return [jax.tree.unflatten(treedef, []) for _ in range(n)]
    per_leaf = [list(lf) for lf in leaves]
    return [jax.tree.unflatten(treedef, list(row))
            for row in zip(*per_leaf)]
