"""Slate compression codec with a stdlib fallback.

The paper compresses every slate before it hits the store; we prefer
zstd (fast, high ratio) but a clean checkout without ``zstandard`` must
still run, so fall back to zlib.  Frames are **self-describing**: every
compressed blob starts with a one-byte codec tag, because the WAL and
the KV store outlive the process that wrote them — a log written where
zstd was installed must replay where it is not (and vice versa).
Decompression of a zstd frame without ``zstandard`` installed fails
with an actionable error rather than a codec crash.
"""
from __future__ import annotations

import zlib as _zlib

_ZSTD = b"z"
_ZLIB = b"g"
_RAW = b"r"

try:
    import zstandard as _zstd
    HAVE_ZSTD = True
except ImportError:
    _zstd = None
    HAVE_ZSTD = False


class Compressor:
    """Compresses with the best codec available; output is a tagged
    frame (1 codec byte + payload).  ``level <= 0`` stores raw (still
    tagged): latency-critical writers (the WAL append hot path) opt out
    of compression without changing the frame format."""

    def __init__(self, level: int = 3):
        self._c = None
        if level <= 0:
            self._tag = _RAW
        elif HAVE_ZSTD:
            self._tag = _ZSTD
            self._c = _zstd.ZstdCompressor(level=level)
        else:
            self._tag = _ZLIB
            self._level = min(max(level, 1), 9)

    def compress(self, data: bytes) -> bytes:
        if self._tag == _RAW:
            return self._tag + data
        if self._c is not None:
            return self._tag + self._c.compress(data)
        return self._tag + _zlib.compress(data, self._level)


_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


class Decompressor:
    """Dispatches on the frame's codec tag — independent of which codec
    the local environment would compress with.  Untagged blobs from
    before the tag existed are sniffed by their codec magic (zstd frame
    magic / zlib 0x78 header; neither collides with the tag bytes)."""

    def __init__(self):
        self._zd = _zstd.ZstdDecompressor() if HAVE_ZSTD else None

    def _zstd_decompress(self, payload: bytes) -> bytes:
        if self._zd is None:
            raise RuntimeError(
                "blob was written with zstd but 'zstandard' is not "
                "installed here — pip install -r requirements-dev.txt")
        return self._zd.decompress(payload)

    def decompress(self, data: bytes) -> bytes:
        tag, payload = data[:1], data[1:]
        if tag == _RAW:
            return payload
        if tag == _ZLIB:
            return _zlib.decompress(payload)
        if tag == _ZSTD:
            return self._zstd_decompress(payload)
        if data[:4] == _ZSTD_MAGIC:          # legacy untagged zstd
            return self._zstd_decompress(data)
        if tag == b"\x78":                   # legacy untagged zlib
            return _zlib.decompress(data)
        raise ValueError(f"unknown compression codec tag {tag!r}")
