"""Live slate reads over HTTP (paper section 4.4).

"Muppet provides a small HTTP server on each node for slate fetches...
The fetch retrieves the slate from Muppet's slate cache ... rather than
from the durable key-value store to ensure an up-to-date reply."

GET /slate/<updater>/<key>     -> JSON slate (from the device table)
GET /slates/<updater>?keys=a,b -> batched read: {"slates": {key: slate|null}}
GET /status                    -> engine stats JSON
GET /metrics                   -> Prometheus text exposition (0.0.4)
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Callable, Optional
from urllib.parse import parse_qs, urlparse

import numpy as np


def _jsonable(tree):
    if isinstance(tree, dict):
        return {k: _jsonable(v) for k, v in tree.items()}
    a = np.asarray(tree)
    if a.ndim == 0:
        return a.item()
    return a.tolist()


class SlateServer:
    """Serves reads from a live engine; ``read_fn(updater, key)`` and
    ``stats_fn()`` are bound to the engine + its current state by the
    driver (which swaps the state reference every tick)."""

    def __init__(self, read_fn: Callable[[str, int], Any],
                 stats_fn: Callable[[], Any], port: int = 0,
                 read_many_fn: Optional[Callable[[str, list], list]]
                 = None,
                 metrics_fn: Optional[Callable[[], str]] = None):
        handler = self._make_handler(read_fn, stats_fn, read_many_fn,
                                     metrics_fn)
        self.httpd = ThreadingHTTPServer(("127.0.0.1", port), handler)
        self.port = self.httpd.server_address[1]
        self._thread = threading.Thread(target=self.httpd.serve_forever,
                                        daemon=True)
        self._thread.start()

    @staticmethod
    def _make_handler(read_fn, stats_fn, read_many_fn=None,
                      metrics_fn=None):
        class Handler(BaseHTTPRequestHandler):
            def log_message(self, *a):  # quiet
                pass

            def _send(self, code: int, payload):
                raw = json.dumps(payload).encode()
                self.send_response(code)
                self.send_header("Content-Type", "application/json")
                self.send_header("Content-Length", str(len(raw)))
                self.end_headers()
                self.wfile.write(raw)

            def _send_text(self, code: int, text: str, ctype: str):
                raw = text.encode()
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(raw)))
                self.end_headers()
                self.wfile.write(raw)

            def do_GET(self):
                url = urlparse(self.path)
                parts = [p for p in url.path.split("/") if p]
                try:
                    if parts[:1] == ["status"]:
                        self._send(200, stats_fn())
                    elif parts[:1] == ["metrics"]:
                        if metrics_fn is None:
                            self._send(404,
                                       {"error": "metrics not enabled"})
                        else:
                            self._send_text(
                                200, metrics_fn(),
                                "text/plain; version=0.0.4; "
                                "charset=utf-8")
                    elif len(parts) == 3 and parts[0] == "slate":
                        slate = read_fn(parts[1], int(parts[2]))
                        if slate is None:
                            self._send(404, {"error": "no such slate"})
                        else:
                            self._send(200, _jsonable(slate))
                    elif len(parts) == 2 and parts[0] == "slates":
                        # batched read: one device dispatch for the
                        # whole key vector (the serving-rate path)
                        q = parse_qs(url.query).get("keys", [""])[0]
                        keys = [int(k) for k in q.split(",") if k]
                        if not keys:
                            self._send(400, {"error": "keys= required"})
                            return
                        if read_many_fn is not None:
                            slates = read_many_fn(parts[1], keys)
                        else:       # engines without a batched path
                            slates = [read_fn(parts[1], k) for k in keys]
                        self._send(200, {"slates": {
                            str(k): (None if s is None else _jsonable(s))
                            for k, s in zip(keys, slates)}})
                    else:
                        self._send(404, {"error": "unknown path"})
                except Exception as e:  # pragma: no cover
                    self._send(500, {"error": str(e)})
        return Handler

    def close(self):
        self.httpd.shutdown()
        self.httpd.server_close()
