"""One front door for runtime selection (DESIGN.md section 11.3).

``RuntimeConfig`` subsumes ``EngineConfig`` + ``DistConfig`` +
``DurabilityConfig``: the app author states batch/queue sizes, a shard
count, and (optionally) a durability directory, and ``App.run`` picks
``Engine`` vs ``DistributedEngine`` and the chunked vs durable drive
paths internally.  The underlying configs stay the source of truth —
this is a declarative veneer that compiles down to them.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.core.engine import EngineConfig
from repro.core.queues import OverflowPolicy


@dataclass
class RuntimeConfig:
    batch_size: int = 256
    queue_capacity: int = 0          # 0 = 4 * batch_size
    chunk_size: int = 8              # ticks per device-resident scan
    fused: str = "auto"              # slate-update backend (EngineConfig)
    # key plane width, end-to-end: "int32" (default) or "int64" (needs
    # JAX_ENABLE_X64; widens event keys, slate tables, WAL frames, the
    # sketch sample, and the kernel entry points — DESIGN.md 12.5/17)
    key_dtype: str = "int32"
    overflow: Dict[str, OverflowPolicy] = field(default_factory=dict)
    overflow_stream: Dict[str, str] = field(default_factory=dict)
    default_policy: OverflowPolicy = OverflowPolicy.DROP
    # distribution: shards > 1 (or an explicit mesh) selects
    # DistributedEngine; shards must not exceed len(jax.devices())
    shards: int = 1
    mesh: Optional[object] = None    # jax.sharding.Mesh
    exchange_slack: float = 2.0
    two_choice_threshold: int = 0
    # migration tiering (DESIGN.md section 14): "auto" moves slate rows
    # on device at shape-preserving reconfigures; "off" forces the host
    # remap.  compact_threshold: dead-slot fraction that triggers
    # physical slot compaction on scale-down (0 disables).
    device_migration: str = "auto"
    compact_threshold: float = 0.75
    # durability (DESIGN.md section 10): a directory turns on the WAL +
    # slate flush + crash recovery runtime
    durable_dir: Optional[str] = None
    flush_every: int = 16
    barrier: bool = True
    truncate_wal: bool = False
    # live elasticity (DESIGN.md section 12): an AutoscalePolicy fires
    # reconfigures at declared ticks; a telemetry.LoadAutoscaler closes
    # the loop from windowed load instead (distributed runtimes only)
    autoscale: Optional[object] = None
    # device-side telemetry (DESIGN.md section 13): a TelemetryConfig
    # adds the count-min key-heat sketch to the jitted tick and the
    # windowed metrics registry behind App.telemetry().  Implied by a
    # LoadAutoscaler.
    telemetry: Optional[object] = None   # telemetry.TelemetryConfig

    @property
    def distributed(self) -> bool:
        return self.shards > 1 or self.mesh is not None

    def _queue_capacity(self) -> int:
        return self.queue_capacity or 4 * self.batch_size

    def _durability(self):
        if self.durable_dir is None:
            return None
        from repro.core.durability import DurabilityConfig
        from repro.slates.flush import FlushConfig, FlushPolicy
        return DurabilityConfig(
            dir=self.durable_dir,
            flush=FlushConfig(policy=FlushPolicy.EVERY_K,
                              every_k=self.flush_every),
            barrier=self.barrier,
            truncate_wal=self.truncate_wal)

    def _telemetry(self):
        if self.telemetry is None:
            return None
        from repro.telemetry.metrics import TelemetryConfig
        if not isinstance(self.telemetry, TelemetryConfig):
            raise TypeError(
                f"telemetry must be a TelemetryConfig, got "
                f"{type(self.telemetry).__name__}")
        return self.telemetry

    def engine_config(self) -> EngineConfig:
        if self.autoscale is not None:
            raise ValueError(
                "autoscale needs a distributed runtime: set shards > 1 "
                "(or pass mesh=)")
        return EngineConfig(
            batch_size=self.batch_size,
            queue_capacity=self._queue_capacity(),
            overflow=dict(self.overflow),
            overflow_stream=dict(self.overflow_stream),
            default_policy=self.default_policy,
            fused=self.fused,
            key_dtype=self.key_dtype,
            chunk_size=self.chunk_size,
            durability=self._durability(),
            telemetry=self._telemetry())

    def dist_config(self):
        from repro.core.distributed import AutoscalePolicy, DistConfig
        from repro.telemetry.controller import LoadAutoscaler
        if self.autoscale is not None and \
                not isinstance(self.autoscale,
                               (AutoscalePolicy, LoadAutoscaler)):
            raise TypeError(
                f"autoscale must be an AutoscalePolicy or "
                f"LoadAutoscaler, got {type(self.autoscale).__name__}")
        return DistConfig(
            batch_size=self.batch_size,
            queue_capacity=self._queue_capacity(),
            overflow=dict(self.overflow),
            overflow_stream=dict(self.overflow_stream),
            default_policy=self.default_policy,
            fused=self.fused,
            key_dtype=self.key_dtype,
            chunk_size=self.chunk_size,
            durability=self._durability(),
            exchange_slack=self.exchange_slack,
            two_choice_threshold=self.two_choice_threshold,
            device_migration=self.device_migration,
            compact_threshold=self.compact_threshold,
            autoscale=self.autoscale,
            telemetry=self._telemetry())

    def make_mesh(self):
        if self.mesh is not None:
            return self.mesh
        import jax
        import numpy as np
        from jax.sharding import Mesh
        devs = jax.devices()
        if len(devs) < self.shards:
            raise ValueError(
                f"RuntimeConfig(shards={self.shards}) but only "
                f"{len(devs)} jax device(s) are visible; pass an "
                f"explicit mesh= or lower shards")
        return Mesh(np.asarray(devs[:self.shards]), ("data",))
