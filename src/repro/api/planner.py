"""Graph planner for the declarative builder (DESIGN.md section 11.2).

Three jobs, all at build time (nothing here runs on the data path):

1. **Spec resolution by tracing.**  Function-style operators declare no
   ``in_value_spec`` / ``out_streams`` — the planner propagates value
   specs from the sources through the graph, building each operator
   once all of its input stream specs are known and inferring its
   output specs with ``jax.eval_shape`` (abstract tracing: no FLOPs, no
   device).  Cycles are fine as long as every cycle contains at least
   one stream whose spec is known some other way (a source, a declared
   ``app.stream(name, spec)``, or an operator buildable from outside
   the cycle) — otherwise the planner names the stuck operators and
   streams and asks for an explicit spec.

2. **Validation with actionable errors**: unproduced streams,
   unconsumed sources, producer/subscriber spec disagreement, updater
   fan-in spec disagreement — caught here with operator/stream names
   instead of surfacing as shape errors inside jit.

3. **Mapper fusion.**  A linear mapper chain (M1 -> s -> M2 where s has
   exactly one producer and one subscriber, both mappers) costs one
   queue hop and one pipeline tick per link.  The planner rewrites such
   chains into a single :class:`FusedMapper` stage: same event->event
   function, one queue hop, one tick — lower latency and less per-tick
   dispatch work (measured in BENCH_3 ``mapper_chain3_*``).
"""
from __future__ import annotations

import copy
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.event import (EventBatch, format_spec, is_spec_leaf,
                              spec_matches, spec_of)
from repro.core.operators import (AssociativeUpdater, Mapper, Operator,
                                  SequentialUpdater)
from repro.core.workflow import Workflow


class PlanError(ValueError):
    """Graph construction / validation error (names names)."""


# ----------------------------------------------------------------------
# declarations (recorded by App, consumed here)
# ----------------------------------------------------------------------

@dataclass
class OpDecl:
    kind: str                       # "mapper" | "assoc" | "seq" | "raw"
    name: str
    subscribes: Tuple[str, ...]
    fn: Any = None                  # mapper fn / assoc lift / seq step
    out: Any = None                 # None | str | seq[str] | {name: spec|None}
    slate: Any = None               # updaters: slate value_spec
    merge: Any = "sum"              # assoc: "sum" | merge(slate, delta)
    combine: Any = None             # assoc: combine(d1, d2); None = merge
    emit: Any = None                # assoc: emit(keys, old, new, ts)
    op: Optional[Operator] = None   # raw: prebuilt Operator instance
    table_capacity: int = 4096
    ttl: int = 0
    max_run: int = 32
    sum_mergeable: Optional[bool] = None


@dataclass
class Plan:
    workflow: Workflow
    stream_specs: Dict[str, Any]
    fused_chains: List[Tuple[str, ...]]   # operator names per fused chain


def out_names(out) -> Tuple[str, ...]:
    """Stream names named by an ``out=`` declaration (may be empty when
    the names are left to tracing)."""
    if out is None:
        return ()
    if isinstance(out, str):
        return (out,)
    if isinstance(out, dict):
        return tuple(out)
    return tuple(out)


def _declared_specs(out) -> Dict[str, Any]:
    if isinstance(out, dict):
        return {s: sp for s, sp in out.items() if sp is not None}
    return {}


# ----------------------------------------------------------------------
# abstract tracing
# ----------------------------------------------------------------------

_TRACE_B = 8   # any static capacity works; specs carry no batch dim


def abstract_batch(value_spec, capacity: int = _TRACE_B) -> EventBatch:
    """An EventBatch of ShapeDtypeStructs matching ``value_spec`` — the
    tracer input for spec inference."""
    i32 = jax.ShapeDtypeStruct((capacity,), jnp.int32)
    value = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((capacity,) + tuple(s[0]), s[1]),
        value_spec, is_leaf=is_spec_leaf)
    return EventBatch(sid=i32, ts=i32, key=i32, value=value,
                      valid=jax.ShapeDtypeStruct((capacity,), jnp.bool_))


def _abstract_rows(spec, capacity: Optional[int] = None):
    """Slate pytree of ShapeDtypeStructs; ``capacity=None`` = one row."""
    lead = () if capacity is None else (capacity,)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(lead + tuple(s[0]), s[1]),
        spec, is_leaf=is_spec_leaf)


def _trace(what: str, name: str, fn: Callable, *args):
    try:
        return jax.eval_shape(fn, *args)
    except Exception as e:
        raise PlanError(
            f"{what} {name!r}: spec inference by tracing failed "
            f"({type(e).__name__}: {e}). The function must be "
            f"jax-traceable (jnp ops, no python branches on values); "
            f"otherwise declare out={{'stream': spec}} explicitly."
        ) from e


def _emission_specs(what: str, name: str, res,
                    declared: Tuple[str, ...]) -> Dict[str, Any]:
    """Traced {stream: EventBatch} -> {stream: value_spec}."""
    if not isinstance(res, dict):
        raise PlanError(f"{what} {name!r} must return a dict of "
                        f"stream -> EventBatch, got {type(res).__name__}")
    for s, b in res.items():
        if not isinstance(b, EventBatch):
            raise PlanError(f"{what} {name!r}: emission into {s!r} is "
                            f"{type(b).__name__}, expected EventBatch")
    if declared and set(res) != set(declared):
        raise PlanError(
            f"{what} {name!r}: declared out streams {sorted(declared)} "
            f"but the traced function emits into {sorted(res)}")
    return {s: spec_of(b.value) for s, b in res.items()}


# ----------------------------------------------------------------------
# function-style operator wrappers
# ----------------------------------------------------------------------

def _tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def _tree_max(a, b):
    return jax.tree.map(jnp.maximum, a, b)


class FnMapper(Mapper):
    """A traced map function as an operator.  The function may return a
    single EventBatch (wrapped into its one declared out stream) or a
    dict of stream -> EventBatch."""

    def __init__(self, fn, name, subscribes, in_spec, out_streams,
                 single_out: Optional[str] = None):
        self.fn = fn
        self.name = name
        self.subscribes = tuple(subscribes)
        self.in_value_spec = in_spec
        self.out_streams = dict(out_streams)
        self._single = single_out

    def map_batch(self, batch):
        out = self.fn(batch)
        if isinstance(out, EventBatch):
            if self._single is None:
                raise TypeError(
                    f"mapper {self.name!r} returned a bare EventBatch "
                    f"but declares streams {sorted(self.out_streams)}")
            out = {self._single: out}
        return out


class FnAssociativeUpdater(AssociativeUpdater):
    """lift/combine/merge/emit functions as an AssociativeUpdater."""

    def __init__(self, name, subscribes, in_spec, slate, lift_fn,
                 combine_fn, merge_fn, emit_fn, out_streams, *,
                 table_capacity, ttl, sum_mergeable, monoid=""):
        self.name = name
        self.subscribes = tuple(subscribes)
        self.in_value_spec = in_spec
        self.out_streams = dict(out_streams)
        self._slate = slate
        self._lift = lift_fn
        self._combine = combine_fn
        self._merge = merge_fn
        self._emit = emit_fn
        self.table_capacity = table_capacity
        self.ttl = ttl
        self.sum_mergeable = sum_mergeable
        self.monoid = monoid

    def slate_spec(self):
        return self._slate

    def lift(self, batch):
        return self._lift(batch)

    def combine(self, a, b):
        return self._combine(a, b)

    def merge(self, slate, delta):
        return self._merge(slate, delta)

    def emit(self, keys, old_slate, new_slate, ts):
        if self._emit is None:
            return {}
        return self._emit(keys, old_slate, new_slate, ts)


class FnSequentialUpdater(SequentialUpdater):
    """A per-event step function as a SequentialUpdater."""

    def __init__(self, name, subscribes, in_spec, slate, step_fn,
                 out_streams, *, table_capacity, ttl, max_run):
        self.name = name
        self.subscribes = tuple(subscribes)
        self.in_value_spec = in_spec
        self.out_streams = dict(out_streams)
        self._slate = slate
        self._step = step_fn
        self.table_capacity = table_capacity
        self.ttl = ttl
        self.max_run = max_run

    def slate_spec(self):
        return self._slate

    def step(self, slate_row, ev):
        return self._step(slate_row, ev)


class FusedMapper(Mapper):
    """A linear mapper chain fused into one operator.

    Applies ``head`` then feeds its ``via``-stream output straight into
    ``tail`` — the same validity masking the engine applies between
    hops, minus the queue round-trip.  Event->event semantics are
    unchanged; the chain now traverses in one tick instead of one per
    link (so downstream table ``ts`` stamps land earlier — relevant
    only to TTL accounting, see DESIGN.md section 11.2).
    """

    def __init__(self, head: Mapper, tail: Mapper, via: str):
        self.head, self.tail, self.via = head, tail, via
        self.name = f"{head.name}+{tail.name}"
        self.subscribes = tuple(head.subscribes)
        self.in_value_spec = head.in_value_spec
        self.flop_heavy = (getattr(head, "flop_heavy", False)
                           or getattr(tail, "flop_heavy", False))
        self.out_streams = {
            **{s: sp for s, sp in head.out_streams.items() if s != via},
            **tail.out_streams}

    def chain(self) -> Tuple[str, ...]:
        h = (self.head.chain() if isinstance(self.head, FusedMapper)
             else (self.head.name,))
        t = (self.tail.chain() if isinstance(self.tail, FusedMapper)
             else (self.tail.name,))
        return h + t

    def map_batch(self, batch):
        outs1 = self.head.map_batch(batch)
        mid = outs1[self.via]
        mid = mid.mask(batch.valid & mid.valid)   # the inter-hop mask
        outs = {s: b for s, b in outs1.items() if s != self.via}
        for s, b in self.tail.map_batch(mid).items():
            outs[s] = b.mask(mid.valid & b.valid)
        return outs


# ----------------------------------------------------------------------
# operator construction (one decl -> one Operator, specs resolved)
# ----------------------------------------------------------------------

def _in_spec(decl: OpDecl, specs: Dict[str, Any]):
    sp = specs[decl.subscribes[0]]
    for s in decl.subscribes[1:]:
        if not spec_matches(sp, specs[s]):
            raise PlanError(
                f"operator {decl.name!r} subscribes to streams with "
                f"disagreeing value specs (one input queue needs one "
                f"spec): {decl.subscribes[0]!r}={format_spec(sp)} vs "
                f"{s!r}={format_spec(specs[s])}")
    return sp


def _build_mapper(decl: OpDecl, in_spec) -> FnMapper:
    names = out_names(decl.out)
    declared = _declared_specs(decl.out)
    if names and set(declared) == set(names):
        out_specs = declared          # fully declared: no tracing needed
    else:
        res = _trace("mapper", decl.name, decl.fn, abstract_batch(in_spec))
        if isinstance(res, EventBatch):
            if len(names) != 1:
                raise PlanError(
                    f"mapper {decl.name!r} returns a single EventBatch; "
                    f"declare its stream with out='name'")
            out_specs = {names[0]: spec_of(res.value)}
        else:
            out_specs = _emission_specs("mapper", decl.name, res, names)
        for s, sp in declared.items():
            if not spec_matches(sp, out_specs[s]):
                raise PlanError(
                    f"mapper {decl.name!r}: declared spec for {s!r} "
                    f"({format_spec(sp)}) does not match the traced "
                    f"output ({format_spec(out_specs[s])})")
    single = names[0] if len(names) == 1 else None
    if single is None and len(out_specs) == 1:
        single = next(iter(out_specs))
    return FnMapper(decl.fn, decl.name, decl.subscribes, in_spec,
                    out_specs, single_out=single)


def _build_assoc(decl: OpDecl, in_spec) -> FnAssociativeUpdater:
    if decl.slate is None:
        raise PlanError(f"updater {decl.name!r} needs slate= (a "
                        f"value_spec pytree for one slate)")
    monoid = ""
    if decl.merge == "sum":
        merge_fn = _tree_add
        combine_fn = decl.combine or _tree_add
        auto_sm = decl.combine is None and decl.emit is None
    elif decl.merge == "max":
        # elementwise-max monoid (non-negative leaves, DESIGN.md 16.2):
        # rides the same fused slate_update path as "sum" when no
        # custom combine/emit is attached
        merge_fn = _tree_max
        combine_fn = decl.combine or _tree_max
        auto_sm = False
        if decl.combine is None and decl.emit is None:
            monoid = "max"
    else:
        merge_fn = decl.merge
        combine_fn = decl.combine or _tree_add
        auto_sm = False
    sum_mergeable = (decl.sum_mergeable if decl.sum_mergeable is not None
                     else auto_sm)

    lift_res = _trace("updater", decl.name, decl.fn,
                      abstract_batch(in_spec))
    slate_rows = _abstract_rows(decl.slate, _TRACE_B)
    if (decl.merge in ("sum", "max")
            and jax.tree.structure(lift_res)
            != jax.tree.structure(slate_rows)):
        raise PlanError(
            f"updater {decl.name!r}: with merge={decl.merge!r} the "
            f"lift() pytree must match slate={format_spec(decl.slate)} "
            f"structurally")

    out_specs = _declared_specs(decl.out)
    names = out_names(decl.out)
    if decl.emit is not None:
        i32 = jax.ShapeDtypeStruct((_TRACE_B,), jnp.int32)
        res = _trace("updater-emit", decl.name, decl.emit,
                     i32, slate_rows, slate_rows, i32)
        out_specs = _emission_specs("updater-emit", decl.name, res,
                                    names)
    elif names:
        missing = [s for s in names if s not in out_specs]
        if missing:
            raise PlanError(
                f"updater {decl.name!r} declares out streams {missing} "
                f"but has no emit= function to trace their specs from; "
                f"pass out={{'stream': spec}}")
    return FnAssociativeUpdater(
        decl.name, decl.subscribes, in_spec, decl.slate, decl.fn,
        combine_fn, merge_fn, decl.emit, out_specs,
        table_capacity=decl.table_capacity, ttl=decl.ttl,
        sum_mergeable=sum_mergeable, monoid=monoid)


def _build_seq(decl: OpDecl, in_spec) -> FnSequentialUpdater:
    if decl.slate is None:
        raise PlanError(f"updater {decl.name!r} needs slate= (a "
                        f"value_spec pytree for one slate)")
    slate_row = _abstract_rows(decl.slate)
    i0 = jax.ShapeDtypeStruct((), jnp.int32)
    ev = {"sid": i0, "ts": i0, "key": i0,
          "value": _abstract_rows(in_spec)}
    res = _trace("updater", decl.name, decl.fn, slate_row, ev)
    if not (isinstance(res, tuple) and len(res) == 2):
        raise PlanError(
            f"updater {decl.name!r}: step(slate, ev) must return "
            f"(new_slate, emissions)")
    new_slate, emits = res
    if jax.tree.structure(new_slate) != jax.tree.structure(slate_row):
        raise PlanError(
            f"updater {decl.name!r}: step() returns a slate pytree "
            f"whose structure does not match "
            f"slate={format_spec(decl.slate)}")
    names = out_names(decl.out)
    out_specs = {}
    for s, row in (emits or {}).items():
        if not (isinstance(row, dict) and "value" in row):
            raise PlanError(
                f"updater {decl.name!r}: emission into {s!r} must be "
                f"{{'key': ..., 'value': ..., 'emit': ...}}")
        out_specs[s] = jax.tree.map(
            lambda a: (tuple(a.shape), a.dtype), row["value"])
    if names and set(out_specs) != set(names):
        raise PlanError(
            f"updater {decl.name!r}: declared out streams "
            f"{sorted(names)} but step() emits into {sorted(out_specs)}")
    return FnSequentialUpdater(
        decl.name, decl.subscribes, in_spec, decl.slate, decl.fn,
        out_specs, table_capacity=decl.table_capacity, ttl=decl.ttl,
        max_run=decl.max_run)


def _build_raw(decl: OpDecl, in_spec) -> Operator:
    # shallow-copy so wiring one instance into a graph never rewires
    # the caller's object (an ops.* instance may be reused across apps)
    op = copy.copy(decl.op)
    op.name = decl.name
    # decl.subscribes is authoritative: App.add already chose between
    # the explicit wiring and the instance's own declaration
    op.subscribes = decl.subscribes
    existing = getattr(op, "in_value_spec", None)
    if existing:
        if not spec_matches(existing, in_spec):
            raise PlanError(
                f"operator {decl.name!r} declares "
                f"in_value_spec={format_spec(existing)} but its input "
                f"stream carries {format_spec(in_spec)}")
    else:
        op.in_value_spec = in_spec
    # subclass-API mappers may leave out_streams to tracing (the
    # function-style path above already does this): opt in with
    # ``trace_out_streams = True`` — repro/ml's ModelMapper derives its
    # embedding width from the model config, so its output spec is only
    # cheap to state by eval_shape
    if (isinstance(op, Mapper) and not getattr(op, "out_streams", None)
            and getattr(op, "trace_out_streams", False)):
        res = _trace("mapper", decl.name, op.map_batch,
                     abstract_batch(op.in_value_spec))
        op.out_streams = _emission_specs("mapper", decl.name, res, ())
    return op


def _build_op(decl: OpDecl, specs: Dict[str, Any]) -> Operator:
    in_spec = _in_spec(decl, specs)
    if decl.kind == "mapper":
        return _build_mapper(decl, in_spec)
    if decl.kind == "assoc":
        return _build_assoc(decl, in_spec)
    if decl.kind == "seq":
        return _build_seq(decl, in_spec)
    if decl.kind == "raw":
        return _build_raw(decl, in_spec)
    raise PlanError(f"unknown operator kind {decl.kind!r}")


# ----------------------------------------------------------------------
# mapper fusion
# ----------------------------------------------------------------------

def fuse_mappers(operators: List[Operator], external: set
                 ) -> Tuple[List[Operator], List[Tuple[str, ...]]]:
    """Collapse linear mapper chains into FusedMapper stages.

    A link M1 -s-> M2 fuses iff: both are Mappers, neither is tagged
    ``flop_heavy`` (model-inference stages keep their own queue hop so
    their backpressure stays visible and their latency stays decoupled
    from cheap field maps), s is M1's to-fuse output and M2's *only*
    subscription, s has exactly one producer and exactly one
    subscriber, s is not external, not a self-loop on either operator,
    not part of a cycle back to M1 (fusing a cycle would halve its loop
    latency — only *linear* chains fuse), and fusing would not collide
    two distinct emissions into the same stream name.  Applied to a
    fixpoint, so a 3-link chain becomes one stage.
    """
    ops_list = list(operators)

    def reaches(frm: Operator, to: Operator) -> bool:
        """Is ``to`` reachable from ``frm``'s emissions through the
        stream graph?  (Used to refuse fusing cycle links.)"""
        seen, work = set(), list(frm.out_streams)
        while work:
            s = work.pop()
            if s in seen:
                continue
            seen.add(s)
            for op in ops_list:
                if s in op.subscribes:
                    if op is to:
                        return True
                    work.extend(op.out_streams)
        return False
    changed = True
    while changed:
        changed = False
        for tail in ops_list:
            if not isinstance(tail, Mapper) or len(tail.subscribes) != 1:
                continue
            s = tail.subscribes[0]
            if s in external or s in tail.out_streams:
                continue
            prods = [o for o in ops_list if s in o.out_streams]
            if len(prods) != 1:
                continue
            head = prods[0]
            if head is tail or not isinstance(head, Mapper):
                continue
            if getattr(head, "flop_heavy", False) or \
                    getattr(tail, "flop_heavy", False):
                continue      # FLOP-heavy stage: the queue hop IS the
                #               backpressure/telemetry boundary — fusing
                #               would couple a matmul-bound stage's
                #               latency to a cheap field map
            if s in head.subscribes:
                continue
            subs = [o for o in ops_list if s in o.subscribes]
            if subs != [tail]:
                continue
            head_rest = {k for k in head.out_streams if k != s}
            if head_rest & set(tail.out_streams):
                continue          # emission collision: keep unfused
            if reaches(tail, head):
                continue          # cycle link: keep unfused
            idx = ops_list.index(head)
            ops_list[idx] = FusedMapper(head, tail, s)
            ops_list.remove(tail)
            changed = True
            break
    chains = [op.chain() for op in ops_list
              if isinstance(op, FusedMapper)]
    return ops_list, chains


# ----------------------------------------------------------------------
# the planner entry point
# ----------------------------------------------------------------------

def plan(sources: Dict[str, Any], streams: Dict[str, Any],
         decls: Sequence[OpDecl], *, fuse: bool = True) -> Plan:
    """Resolve specs, build operators, validate, fuse, emit a Workflow.

    ``sources``: external stream name -> value_spec.
    ``streams``: forward-declared stream name -> value_spec or None.
    Operator order in the emitted Workflow is declaration order (with
    fused chains taking the head mapper's slot).
    """
    names = [d.name for d in decls]
    dup = {n for n in names if names.count(n) > 1}
    if dup:
        raise PlanError(f"duplicate operator names: {sorted(dup)}")

    specs: Dict[str, Any] = dict(sources)
    for s, sp in streams.items():
        if sp is not None:
            if s in specs and not spec_matches(specs[s], sp):
                raise PlanError(
                    f"stream {s!r} declared with spec {format_spec(sp)} "
                    f"but already carries {format_spec(specs[s])}")
            specs[s] = sp

    built: Dict[int, Operator] = {}
    pending = list(range(len(decls)))
    while pending:
        progress = False
        for i in list(pending):
            decl = decls[i]
            if not all(s in specs for s in decl.subscribes):
                continue
            op = _build_op(decl, specs)
            for s, sp in op.out_streams.items():
                if s in specs:
                    if not spec_matches(specs[s], sp):
                        raise PlanError(
                            f"stream {s!r}: producer {op.name!r} emits "
                            f"{format_spec(sp)} but the stream already "
                            f"carries {format_spec(specs[s])}")
                else:
                    specs[s] = sp
            built[i] = op
            pending.remove(i)
            progress = True
        if not progress:
            stuck = [decls[i].name for i in pending]
            missing = sorted({s for i in pending
                              for s in decls[i].subscribes
                              if s not in specs})
            raise PlanError(
                f"cannot infer value specs for operator(s) {stuck}: "
                f"stream(s) {missing} have no producer with a known "
                f"spec. Declare one explicitly with "
                f"app.stream(name, spec) (required to break "
                f"spec-inference cycles) or add the missing producer.")

    operators: List[Operator] = [built[i] for i in range(len(decls))]

    produced = set(sources)
    for op in operators:
        produced.update(op.out_streams)
    for s in streams:
        if s not in produced:
            raise PlanError(
                f"stream {s!r} is declared but nothing produces it "
                f"(unreachable); add a producer or remove the "
                f"declaration")
    subscribed = {s for op in operators for s in op.subscribes}
    for s in sources:
        if s not in subscribed:
            raise PlanError(
                f"source {s!r} has no subscribers — its events would "
                f"be dropped on arrival; subscribe an operator or "
                f"remove the source")

    fused_chains: List[Tuple[str, ...]] = []
    if fuse:
        operators, fused_chains = fuse_mappers(operators, set(sources))

    wf = Workflow(operators, external_streams=tuple(sources))
    return Plan(workflow=wf, stream_specs=specs,
                fused_chains=fused_chains)
