"""Prebuilt update combinators for the declarative builder.

Each factory returns an :class:`~repro.core.operators.Updater` instance
with its subscriptions and input spec left blank — ``Stream.update``
(or ``App.add``) wires those in, and the planner fills ``in_value_spec``
from the upstream stream's traced spec.  They are ordinary operators:
the subclass API can use them too by setting ``subscribes`` /
``in_value_spec`` by hand.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.operators import AssociativeUpdater, SequentialUpdater


class Counter(AssociativeUpdater):
    """Count events per key — the paper's Examples 1/4 update function.

    ``sum_mergeable`` by construction (all-adds, zero init), so the
    engine routes it through the fused ``kernels/slate_update`` path
    where that pays off.
    """

    def __init__(self, name: str = "counter", *, table_capacity: int = 4096,
                 ttl: int = 0, sum_mergeable: bool = True):
        self.name = name
        self.table_capacity = table_capacity
        self.ttl = ttl
        self.sum_mergeable = sum_mergeable
        self.subscribes = ()
        self.out_streams = {}

    def slate_spec(self):
        return {"count": ((), jnp.int32)}

    def lift(self, batch):
        return {"count": jnp.ones_like(batch.key)}

    def combine(self, a, b):
        return {"count": a["count"] + b["count"]}

    def merge(self, slate, delta):
        return {"count": slate["count"] + delta["count"]}


class TopK(AssociativeUpdater):
    """Keep the k largest values of ``field`` seen per key.

    Top-k is a commutative monoid (merge two sorted top-k lists, keep
    the k largest), so it rides the associative pre-combine path.
    """

    def __init__(self, k: int, field: str = "x", name: str = "topk", *,
                 table_capacity: int = 4096, ttl: int = 0):
        self.k = k
        self.field = field
        self.name = name
        self.table_capacity = table_capacity
        self.ttl = ttl
        self.subscribes = ()
        self.out_streams = {}

    def slate_spec(self):
        return {"top": ((self.k,), jnp.float32)}

    def init_slate(self, n: int):
        return {"top": jnp.full((n, self.k), -jnp.inf, jnp.float32)}

    def _merge_top(self, a, b):
        cat = jnp.concatenate([a, b], axis=-1)
        return -jnp.sort(-cat, axis=-1)[..., :self.k]

    def lift(self, batch):
        x = batch.value[self.field].astype(jnp.float32)
        pad = jnp.full(x.shape + (self.k - 1,), -jnp.inf, jnp.float32) \
            if self.k > 1 else jnp.zeros(x.shape + (0,), jnp.float32)
        return {"top": jnp.concatenate([x[..., None], pad], axis=-1)}

    def combine(self, a, b):
        return {"top": self._merge_top(a["top"], b["top"])}

    def merge(self, slate, delta):
        return {"top": self._merge_top(slate["top"], delta["top"])}


class Ema(SequentialUpdater):
    """Exponential moving average of ``field`` per key.

    Order-sensitive (the bump depends on the running value), so it runs
    on the strict per-key-timestamp-order padded-run path.
    """

    def __init__(self, alpha: float = 0.1, field: str = "x",
                 name: str = "ema", *, table_capacity: int = 4096,
                 ttl: int = 0, max_run: int = 32):
        self.alpha = float(alpha)
        self.field = field
        self.name = name
        self.table_capacity = table_capacity
        self.ttl = ttl
        self.max_run = max_run
        self.subscribes = ()
        self.out_streams = {}

    def slate_spec(self):
        return {"ema": ((), jnp.float32), "n": ((), jnp.int32)}

    def step(self, slate, ev):
        x = ev["value"][self.field].astype(jnp.float32)
        first = slate["n"] == 0
        new = jnp.where(first, x,
                        (1.0 - self.alpha) * slate["ema"] + self.alpha * x)
        return {"ema": new, "n": slate["n"] + 1}, {}


def counter(name: str = "counter", **kw) -> Counter:
    return Counter(name, **kw)


def topk(k: int, field: str = "x", name: str = "topk", **kw) -> TopK:
    return TopK(k, field, name, **kw)


def ema(alpha: float = 0.1, field: str = "x", name: str = "ema",
        **kw) -> Ema:
    return Ema(alpha, field, name, **kw)


# ---- streaming-ML stages (repro/ml, DESIGN.md section 16) ----
# imported lazily: repro.ml pulls in the model stack, which apps that
# only count and rank plain fields should not pay for

def model_mapper(cfg, params=None, **kw):
    """:class:`repro.ml.ModelMapper` — microbatched model inference as
    a mapper stage (FLOP-heavy tagged, specs inferred by tracing)."""
    from repro.ml.mapper import ModelMapper
    return ModelMapper(cfg, params, **kw)


def semantic_topk(name: str = "semantic_topk", **kw):
    """:class:`repro.ml.SemanticTopK` — per-key top-k by model score on
    the fused elementwise-max slate path."""
    from repro.ml.rankers import SemanticTopK
    return SemanticTopK(name, **kw)


def personalization(name: str = "personalization", **kw):
    """:class:`repro.ml.Personalization` — per-user EMA embedding +
    re-scored candidate slate (sequential path)."""
    from repro.ml.rankers import Personalization
    return Personalization(name, **kw)
