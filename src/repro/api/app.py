"""Declarative MapUpdate application builder (DESIGN.md section 11).

The paper's pitch is that MapUpdate lets developers *quickly write*
fast-data apps; this layer is that surface.  An app is declared as a
graph of named streams and function-style operators, compiled by the
planner (spec inference by tracing, validation, mapper fusion) into the
exact same :class:`~repro.core.workflow.Workflow` the subclass API
builds, and driven through one front door::

    app = App("quickstart")
    checkins = app.source("checkins", {"retailer": ((), jnp.int32)})

    @app.mapper(checkins, out="S2")
    def at_retailer(batch):
        rid = batch.value["retailer"]
        return EventBatch(sid=batch.sid, ts=batch.ts + 1, key=rid,
                          value={"retailer": rid},
                          valid=batch.valid & (rid >= 0))

    at_retailer.update(ops.counter("U1"))
    app.run(source_fn, n_ticks=50,
            runtime=RuntimeConfig(batch_size=512))
    app.read_slate("U1", key)

Cycles are expressed with forward stream references (subscribe to a
stream by name before its producer is declared); the planner resolves
specs at ``build()`` time.  The subclass API keeps working — instances
go in via ``app.add`` / ``stream.update`` and mix freely with
function-style operators.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.api import planner
from repro.api.runtime import RuntimeConfig
from repro.core.engine import Engine, StateHandle
from repro.core.event import format_spec, spec_matches
from repro.core.operators import Operator, Updater
from repro.core.workflow import Workflow


class Stream:
    """Handle to a named stream — the edge currency of the builder."""

    __slots__ = ("app", "name")

    def __init__(self, app: "App", name: str):
        self.app = app
        self.name = name

    def __repr__(self):
        return f"Stream({self.name!r})"

    # fluent sugar: checkins.map(fn).update(ops.counter())
    def map(self, fn: Optional[Callable] = None, *, out=None,
            name: Optional[str] = None):
        if fn is None:
            return lambda f: self.map(f, out=out, name=name)
        return self.app.mapper(self, out=out, name=name)(fn)

    def update(self, updater: Updater, *, name: Optional[str] = None
               ) -> "OpRef":
        """Attach an Updater instance (e.g. ``ops.counter(...)``, or any
        subclass-API updater) to this stream."""
        return self.app.add(updater, subscribes=(self.name,), name=name)

    def updater(self, **kw):
        """Decorator form of :meth:`App.updater` bound to this stream."""
        return self.app.updater(self, **kw)

    def seq_updater(self, **kw):
        """Decorator form of :meth:`App.seq_updater` bound to this
        stream."""
        return self.app.seq_updater(self, **kw)


class OpRef:
    """Handle to a declared operator: its final ``name`` plus access to
    the streams it emits (``.out("S3")``)."""

    __slots__ = ("app", "name")

    def __init__(self, app: "App", name: str):
        self.app = app
        self.name = name

    def __repr__(self):
        return f"OpRef({self.name!r})"

    def out(self, stream_name: str) -> Stream:
        return self.app.stream(stream_name)


class App:
    """A MapUpdate application: declare the graph, then ``run()``."""

    def __init__(self, name: str = "app"):
        self.name = name
        self._sources: Dict[str, Any] = {}
        self._streams: Dict[str, Any] = {}      # forward decls
        self._decls: List[planner.OpDecl] = []
        self._plan: Optional[planner.Plan] = None
        self._plan_fuse: Optional[bool] = None
        self.engine = None                      # Engine | DistributedEngine
        self.handle: Optional[StateHandle] = None
        self._servers: list = []

    # ---- graph declaration ----------------------------------------
    def _mutate(self):
        if self.engine is not None:
            raise RuntimeError(
                f"app {self.name!r} is already running — declare the "
                f"whole graph before start()/run()")
        self._plan = None

    def source(self, name: str, spec) -> Stream:
        """Declare an external stream (fed by ``source_fn``, never
        emitted into by operators)."""
        self._mutate()
        if name in self._sources and not spec_matches(
                self._sources[name], spec):
            raise planner.PlanError(
                f"source {name!r} redeclared with a different spec")
        self._sources[name] = spec
        return Stream(self, name)

    def stream(self, name: str, spec=None) -> Stream:
        """Reference a stream by name — the forward-reference mechanism
        that makes cycles expressible.  ``spec`` is only needed when a
        spec-inference cycle must be broken explicitly."""
        if spec is not None:
            self._mutate()
            known = self._streams.get(name) or self._sources.get(name)
            if known is not None and not spec_matches(known, spec):
                raise planner.PlanError(
                    f"stream {name!r} redeclared with spec "
                    f"{format_spec(spec)}, conflicting with "
                    f"{format_spec(known)}")
            self._streams[name] = spec
        elif name not in self._sources:
            self._streams.setdefault(name, None)
        return Stream(self, name)

    def _subs(self, stream) -> Tuple[str, ...]:
        one = lambda s: s.name if isinstance(s, Stream) else str(s)
        if isinstance(stream, (list, tuple)):
            return tuple(one(s) for s in stream)
        return (one(stream),)

    def _op_name(self, name: Optional[str], fn=None) -> str:
        nm = name or (fn.__name__ if fn is not None else None)
        if not nm:
            raise planner.PlanError("operator needs a name")
        if any(d.name == nm for d in self._decls):
            raise planner.PlanError(
                f"duplicate operator name {nm!r}; pass name= to "
                f"disambiguate")
        return nm

    def _outs_of(self, decl_out, op_name: str):
        names = planner.out_names(decl_out)
        if len(names) == 1:
            return self.stream(names[0])
        if names:
            return tuple(self.stream(n) for n in names)
        return OpRef(self, op_name)

    def mapper(self, stream, *, out=None, name: Optional[str] = None):
        """Decorator: a jax-traceable ``fn(EventBatch) -> EventBatch``
        (with ``out='stream'``) or ``-> {stream: EventBatch}``.  Name,
        subscription, and output value specs are inferred; returns the
        output Stream(s) for chaining."""
        subs = self._subs(stream)

        def deco(fn):
            self._mutate()
            nm = self._op_name(name, fn)
            self._decls.append(planner.OpDecl(
                kind="mapper", name=nm, subscribes=subs, fn=fn, out=out))
            return self._outs_of(out, nm)
        return deco

    def updater(self, stream, *, slate, merge="sum", combine=None,
                emit=None, out=None, name: Optional[str] = None,
                table_capacity: int = 4096, ttl: int = 0,
                sum_mergeable: Optional[bool] = None):
        """Decorator for an associative updater: the decorated function
        is ``lift(EventBatch) -> delta pytree``; ``merge`` is ``"sum"``
        (elementwise adds — the counter family, auto-``sum_mergeable``)
        or ``merge(slate, delta)``; ``combine(d1, d2)`` defaults to
        elementwise add; ``emit(keys, old, new, ts)`` makes it a
        producer (output specs traced from it)."""
        subs = self._subs(stream)

        def deco(lift_fn):
            self._mutate()
            nm = self._op_name(name, lift_fn)
            self._decls.append(planner.OpDecl(
                kind="assoc", name=nm, subscribes=subs, fn=lift_fn,
                out=out, slate=slate, merge=merge, combine=combine,
                emit=emit, table_capacity=table_capacity, ttl=ttl,
                sum_mergeable=sum_mergeable))
            return OpRef(self, nm)
        return deco

    def seq_updater(self, stream, *, slate, out=None,
                    name: Optional[str] = None, table_capacity: int = 4096,
                    ttl: int = 0, max_run: int = 32):
        """Decorator for a sequential updater: the decorated function is
        ``step(slate_row, ev) -> (new_slate_row, emissions)`` with
        strict per-key timestamp order (paper's general update
        function)."""
        subs = self._subs(stream)

        def deco(step_fn):
            self._mutate()
            nm = self._op_name(name, step_fn)
            self._decls.append(planner.OpDecl(
                kind="seq", name=nm, subscribes=subs, fn=step_fn,
                out=out, slate=slate, table_capacity=table_capacity,
                ttl=ttl, max_run=max_run))
            return OpRef(self, nm)
        return deco

    def add(self, *operators: Operator, subscribes=None,
            name: Optional[str] = None):
        """Register prebuilt Operator instances (subclass API or
        ``ops.*`` combinators).  ``subscribes`` overrides/wires the
        subscription; ``in_value_spec`` is inferred when the instance
        leaves it empty."""
        if name is not None and len(operators) != 1:
            raise planner.PlanError("name= applies to a single operator")
        refs = []
        for op in operators:
            self._mutate()
            subs = self._subs(subscribes) if subscribes is not None \
                else tuple(getattr(op, "subscribes", ()) or ())
            if not subs:
                raise planner.PlanError(
                    f"operator {getattr(op, 'name', op)!r} has no "
                    f"subscriptions; attach it via stream.update(...) "
                    f"or pass subscribes=")
            nm = self._op_name(name or getattr(op, "name", None))
            self._decls.append(planner.OpDecl(
                kind="raw", name=nm, subscribes=subs, op=op))
            refs.append(OpRef(self, nm))
        return refs[0] if len(refs) == 1 else refs

    # ---- planning ---------------------------------------------------
    def build(self, fuse: bool = True) -> Workflow:
        """Validate the graph and compile it to a Workflow (cached)."""
        if self._plan is None or self._plan_fuse != fuse:
            self._plan = planner.plan(self._sources, self._streams,
                                      self._decls, fuse=fuse)
            self._plan_fuse = fuse
        return self._plan.workflow

    @property
    def plan(self) -> planner.Plan:
        if self._plan is None:
            self.build()
        return self._plan

    # ---- the front door ---------------------------------------------
    def start(self, runtime: Optional[RuntimeConfig] = None, *,
              recover: bool = False, fuse: bool = True) -> StateHandle:
        """Instantiate the engine (Engine vs DistributedEngine per the
        runtime config) and its initial — or recovered — state.
        Idempotent; returns the live :class:`StateHandle`."""
        if self.handle is not None:
            if runtime is not None:
                raise RuntimeError(
                    f"app {self.name!r} already started; runtime config "
                    f"cannot change mid-flight")
            if recover:
                raise RuntimeError(
                    f"app {self.name!r} already started; recovery must "
                    f"be the first start (recover=True on the initial "
                    f"start()/run())")
            return self.handle
        rt = runtime or RuntimeConfig()
        wf = self.build(fuse=fuse)
        if rt.distributed:
            from repro.core.distributed import DistributedEngine
            self.engine = DistributedEngine(wf, rt.make_mesh(),
                                            rt.dist_config())
        else:
            self.engine = Engine(wf, rt.engine_config())
        state = self.engine.recover() if recover \
            else self.engine.init_state()
        self.handle = StateHandle(self.engine, state)
        return self.handle

    def run(self, source_fn, n_ticks: int, *,
            runtime: Optional[RuntimeConfig] = None, drain=0,
            recover: bool = False, source_offset: int = 0,
            trace_path: Optional[str] = None, **run_kw):
        """Drive the app for ``n_ticks``:
        ``source_fn(tick, max_events) -> {stream: EventBatch}``
        (``[n_shards, B]``-leading batches when distributed).  ``drain``
        runs source-less ticks afterwards until the queues are empty
        (``True`` = up to 64, or an int bound).  Returns the list of
        per-tick output batches; the final state lives on
        ``app.handle`` for ``read_slate``/``stats``/``serve``.

        With ``runtime.autoscale`` set (an
        :class:`~repro.core.distributed.AutoscalePolicy`, distributed
        runtimes only), the drive loop grows/shrinks the active shard
        set and rebalances the weighted ring mid-run — ``source_fn``
        must then size its batches by the live
        ``app.engine.n_shards`` (DESIGN.md section 12).

        ``trace_path`` exports the engine's span trace (Chrome trace
        JSON, Perfetto-loadable) there after the run — needs
        ``TelemetryConfig(trace=True)`` on the runtime (DESIGN.md
        18.3)."""
        h = self.start(runtime, recover=recover)
        outputs: list = []
        if n_ticks:
            if isinstance(self.engine, Engine):
                h.state, outputs = self.engine.run(
                    h.state, source_fn, n_ticks,
                    source_offset=source_offset, handle=h, **run_kw)
            else:
                if run_kw:
                    raise TypeError(
                        f"run() options {sorted(run_kw)} are not "
                        f"supported on the distributed engine")
                h.state, outputs = self.engine.run(
                    h.state, source_fn, n_ticks,
                    start_tick=source_offset, handle=h)
        if drain:
            max_ticks = 64 if drain is True else int(drain)
            h.state, _ = self.engine.drain(h.state, max_ticks=max_ticks)
        if trace_path is not None:
            self.export_trace(trace_path)
        return outputs

    # ---- introspection (state threading owned here) -----------------
    def _live(self) -> StateHandle:
        if self.handle is None:
            raise RuntimeError(
                f"app {self.name!r} has no live state yet — call "
                f"start() or run() first")
        return self.handle

    def read_slate(self, updater: str, key: int):
        return self._live().read_slate(updater, key)

    def stats(self) -> Dict[str, Any]:
        return self._live().stats()

    def telemetry(self):
        """The latest windowed :class:`~repro.telemetry.TelemetryReport`
        (chunk-boundary readings: events/tick EMA, queue pressure,
        heavy-hitter keys from the on-device count-min sketch).  Needs
        ``RuntimeConfig(telemetry=TelemetryConfig(...))`` — or a
        ``LoadAutoscaler``, which implies it.  If no window has been
        observed yet, one reading is taken now."""
        h = self._live()
        reg = getattr(h.engine, "telemetry", None)
        if reg is None:
            raise RuntimeError(
                f"app {self.name!r} runs without telemetry — pass "
                f"RuntimeConfig(telemetry=TelemetryConfig()) or an "
                f"autoscale=LoadAutoscaler(...)")
        return reg.last or reg.observe(h.engine, h.state)

    def export_trace(self, path: str) -> str:
        """Write the engine's span trace to ``path`` as Chrome trace
        JSON (``chrome://tracing`` / Perfetto).  Requires the engine to
        have been started with ``TelemetryConfig(trace=True)``."""
        tracer = getattr(self._live().engine, "tracer", None)
        if tracer is None:
            raise RuntimeError(
                f"app {self.name!r} runs without tracing — pass "
                f"RuntimeConfig(telemetry=TelemetryConfig(trace=True))")
        return tracer.export(path)

    def serve(self, port: int = 0):
        """Start the HTTP slate server (paper section 4.4) bound to the
        app's live state.  Starts the engine with default runtime if
        needed; closed by :meth:`close`."""
        if self.handle is None:
            self.start()
        srv = self.handle.serve(port)
        self._servers.append(srv)
        return srv

    def close(self):
        for srv in self._servers:
            srv.close()
        self._servers.clear()
        if self.engine is not None and hasattr(self.engine, "close"):
            self.engine.close()
