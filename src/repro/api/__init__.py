"""Declarative application layer: builder, planner, combinators, one
``App.run()`` front door (DESIGN.md section 11)."""
from repro.api import ops
from repro.api.app import App, OpRef, Stream
from repro.api.planner import FusedMapper, Plan, PlanError
from repro.api.runtime import RuntimeConfig

__all__ = ["App", "FusedMapper", "OpRef", "Plan", "PlanError",
           "RuntimeConfig", "Stream", "ops"]
