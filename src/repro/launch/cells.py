"""(architecture x input-shape x mesh) cells: abstract inputs + step fns.

Everything here works on ShapeDtypeStructs — no parameter allocation —
so the 110B-parameter cells lower/compile on a CPU host.  The dry-run,
roofline, and perf iterations all consume ``build_cell``.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.distributed import optimizer as adamw
from repro.distributed import sharding as shd
from repro.models import lm
from repro.models.config import (ModelConfig, SHAPE_BY_NAME, ShapeConfig,
                                 cell_is_applicable)
from repro.models.context import Ctx


@dataclass
class Cell:
    cfg: ModelConfig
    shape: ShapeConfig
    mesh: Mesh
    model: Any
    step_fn: Callable
    abstract_args: Tuple[Any, ...]
    donate: Tuple[int, ...] = ()


def _sds_with(sharding, shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def abstract_params(model, mesh, rules):
    shapes, specs = lm.param_specs(model)
    shardings = shd.tree_shardings(specs, shapes, mesh, rules)
    return jax.tree.map(
        lambda s, sh: _sds_with(sh, s.shape, s.dtype), shapes, shardings)


def abstract_opt(params_sds):
    def f32like(s):
        return jax.ShapeDtypeStruct(s.shape, jnp.float32,
                                    sharding=s.sharding)
    return adamw.OptState(
        m=jax.tree.map(f32like, params_sds),
        v=jax.tree.map(f32like, params_sds),
        count=jax.ShapeDtypeStruct((), jnp.int32))


def abstract_batch(cfg, shape, mesh, rules):
    raw = lm.input_specs(cfg, shape)
    shardings = shd.batch_shardings(raw, mesh, rules)
    return jax.tree.map(lambda s, sh: _sds_with(sh, s.shape, s.dtype),
                        raw, shardings)


def abstract_states(model, shape, mesh, rules):
    """Decode caches as ShapeDtypeStructs with shardings."""
    def make_leaf(shp, dtype, logical):
        spec = shd.to_pspec(logical, shp, mesh, rules)
        return _sds_with(NamedSharding(mesh, spec), tuple(shp), dtype)
    return lm.decode_states(model, shape.global_batch, shape.seq_len,
                            make_leaf)


def concrete_states(model, batch: int, cache_len: int, mesh=None,
                    rules=None):
    """Zero-initialized decode caches (host-scale use)."""
    def make_leaf(shp, dtype, logical):
        return jnp.zeros(tuple(shp), dtype)
    return lm.decode_states(model, batch, cache_len, make_leaf)


# --------------------------------------------------------------------------
# step functions
# --------------------------------------------------------------------------

def make_train_step(model, mesh, rules, opt_cfg: adamw.AdamWConfig = None):
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    constrain = shd.make_constrainer(mesh, rules)

    def train_step(params, opt, batch):
        ctx = Ctx(cdtype=jnp.bfloat16, constrain=constrain, mesh=mesh,
                  rules=rules)

        def loss_fn(p):
            return lm.train_loss(model, p, batch, ctx)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt, metrics = adamw.update(params, grads, opt, opt_cfg)
        metrics["loss"] = loss
        return params, opt, metrics

    return train_step


def make_prefill_step(model, mesh, rules, cache_len: int,
                      full_logits: bool = False):
    constrain = shd.make_constrainer(mesh, rules)

    def prefill_step(params, batch):
        ctx = Ctx(cdtype=jnp.bfloat16, constrain=constrain, mesh=mesh,
                  rules=rules)
        return lm.prefill(model, params, batch, ctx, cache_len,
                          full_logits=full_logits)

    return prefill_step


def make_decode_step(model, mesh, rules):
    constrain = shd.make_constrainer(mesh, rules)

    def decode_step(params, token, states, cur_index):
        ctx = Ctx(cdtype=jnp.bfloat16, constrain=constrain, mesh=mesh,
                  rules=rules)
        logits, new_states = lm.decode_step(model, params, token, states,
                                            cur_index, ctx)
        next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_token[:, None], new_states, cur_index + 1

    return decode_step


# --------------------------------------------------------------------------
# cell assembly
# --------------------------------------------------------------------------

def build_cell(arch: str, shape_name: str, mesh: Mesh,
               *, rules=None) -> Cell:
    cfg = get_config(arch)
    shape = SHAPE_BY_NAME[shape_name]
    ok, why = cell_is_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"cell skipped: {why}")
    rules = rules or shd.rules_for(
        mesh, phase=shape.phase,
        long_context=(shape_name == "long_500k"))
    model = lm.build(cfg)
    params_sds = abstract_params(model, mesh, rules)

    if shape.phase == "train":
        batch_sds = abstract_batch(cfg, shape, mesh, rules)
        opt_sds = abstract_opt(params_sds)
        fn = make_train_step(model, mesh, rules)
        return Cell(cfg=cfg, shape=shape, mesh=mesh, model=model,
                    step_fn=fn,
                    abstract_args=(params_sds, opt_sds, batch_sds),
                    donate=(0, 1))
    if shape.phase == "prefill":
        batch_sds = abstract_batch(cfg, shape, mesh, rules)
        fn = make_prefill_step(model, mesh, rules,
                               cache_len=shape.seq_len)
        return Cell(cfg=cfg, shape=shape, mesh=mesh, model=model,
                    step_fn=fn, abstract_args=(params_sds, batch_sds))
    # decode
    batch_sds = abstract_batch(cfg, shape, mesh, rules)
    states_sds = abstract_states(model, shape, mesh, rules)
    fn = make_decode_step(model, mesh, rules)
    return Cell(cfg=cfg, shape=shape, mesh=mesh, model=model,
                step_fn=fn,
                abstract_args=(params_sds, batch_sds["token"], states_sds,
                               batch_sds["cur_index"]),
                donate=(2,))


def lower_cell(cell: Cell):
    jitted = jax.jit(cell.step_fn, donate_argnums=cell.donate)
    with cell.mesh:
        return jitted.lower(*cell.abstract_args)
