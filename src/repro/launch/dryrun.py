import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry run.

For every (architecture x input shape) cell, lower + compile the step on
the production mesh — (16,16) single pod and (2,16,16) multi-pod — and
record memory_analysis / cost_analysis / HLO-walker roofline terms into
``experiments/dryrun/*.json``.  No arrays are allocated: params, optimizer
state, batches and KV caches are ShapeDtypeStructs.

Run one cell:   PYTHONPATH=src python -m repro.launch.dryrun \
                    --arch qwen2-0.5b --shape train_4k --multi-pod
Run everything: PYTHONPATH=src python -m repro.launch.dryrun --all
(``--all`` spawns one subprocess per cell: XLA device-count init is
per-process, and compile memory is reclaimed between cells.)
"""

import argparse
import gc
import json
import subprocess
import sys
import time
import traceback

RESULT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "experiments", "dryrun")

# TPU v5e constants (roofline denominators)
PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # B/s per chip
ICI_BW = 50e9            # B/s per link


def cell_id(arch, shape, multi_pod, tag=""):
    pod = "multipod" if multi_pod else "pod"
    suffix = f"_{tag}" if tag else ""
    return f"{arch}__{shape}__{pod}{suffix}"


def run_one(arch: str, shape_name: str, multi_pod: bool, tag: str = "",
            extra_env=None) -> dict:
    """Executed inside a fresh process (device count locked at import)."""
    import jax
    from repro.analysis.hlo import analyze
    from repro.configs import get_config
    from repro.launch.cells import build_cell, lower_cell
    from repro.launch.mesh import make_production_mesh
    from repro.models.config import SHAPE_BY_NAME, cell_is_applicable

    cfg = get_config(arch)
    shape = SHAPE_BY_NAME[shape_name]
    ok, why = cell_is_applicable(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "(2,16,16) pod,data,model" if multi_pod
        else "(16,16) data,model",
        "multi_pod": multi_pod, "tag": tag,
    }
    if not ok:
        rec["status"] = "skipped"
        rec["skip_reason"] = why
        return rec

    n_chips = 512 if multi_pod else 256
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    cell = build_cell(arch, shape_name, mesh)
    lowered = lower_cell(cell)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    ma = compiled.memory_analysis()
    print(ma)
    ca = compiled.cost_analysis()
    print({k: ca.get(k) for k in ("flops", "bytes accessed")})
    cost = analyze(compiled.as_text())

    # roofline terms (per the brief): seconds per step per chip
    compute_s = cost.flops / PEAK_FLOPS
    memory_s = cost.hbm_bytes / HBM_BW
    collective_s = cost.total_collective_bytes / ICI_BW

    # model flops: 6 N D (train) / 2 N_active D (single forward)
    n_params = cfg.param_count(active_only=False)
    n_active = cfg.param_count(active_only=True)
    if shape.phase == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_active * tokens
    elif shape.phase == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_active * tokens
    else:
        tokens = shape.global_batch  # one token per request
        model_flops = 2.0 * n_active * tokens

    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
    }
    dominant = max(terms, key=terms.get)

    rec.update({
        "status": "ok",
        "n_chips": n_chips,
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "memory_analysis": {
            "argument_bytes_per_device": ma.argument_size_in_bytes,
            "output_bytes_per_device": ma.output_size_in_bytes,
            "temp_bytes_per_device": ma.temp_size_in_bytes,
            "alias_bytes_per_device": ma.alias_size_in_bytes,
            "peak_estimate_bytes_per_device":
                ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes,
        },
        "xla_cost_analysis": {
            "flops_raw": ca.get("flops"),
            "bytes_accessed_raw": ca.get("bytes accessed"),
            "note": "XLA counts while bodies once; see hlo_walker",
        },
        "hlo_walker_per_device": cost.as_dict(),
        "model_flops_total": model_flops,
        "model_flops_per_device": model_flops / n_chips,
        "useful_flops_fraction":
            (model_flops / n_chips) / cost.flops if cost.flops else None,
        "roofline_terms_s": terms,
        "dominant_term": dominant,
        "tokens_per_step": tokens,
    })
    return rec


def cells_to_run(archs=None, shapes=None):
    from repro.configs import ARCHS
    from repro.models.config import SHAPES
    archs = archs or sorted(ARCHS)
    shapes = shapes or [s.name for s in SHAPES]
    for a in archs:
        for s in shapes:
            yield a, s


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(RESULT_DIR, exist_ok=True)

    if args.all:
        failures = []
        for arch, shape in cells_to_run():
            for mp in (False, True):
                cid = cell_id(arch, shape, mp, args.tag)
                path = os.path.join(RESULT_DIR, cid + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip-cached] {cid}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape]
                if mp:
                    cmd.append("--multi-pod")
                if args.tag:
                    cmd += ["--tag", args.tag]
                print(f"[run] {cid}", flush=True)
                r = subprocess.run(cmd, capture_output=True, text=True,
                                   env={**os.environ,
                                        "PYTHONPATH": "src"})
                if r.returncode != 0:
                    failures.append(cid)
                    print(f"[FAIL] {cid}\n{r.stdout[-2000:]}"
                          f"\n{r.stderr[-4000:]}", flush=True)
                else:
                    print(r.stdout.strip().splitlines()[-1], flush=True)
        print(f"\n{'ALL OK' if not failures else 'FAILURES: ' + str(failures)}")
        sys.exit(1 if failures else 0)

    assert args.arch and args.shape, "--arch and --shape required"
    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    for mp in meshes:
        rec = run_one(args.arch, args.shape, mp, args.tag)
        cid = cell_id(args.arch, args.shape, mp, args.tag)
        path = os.path.join(RESULT_DIR, cid + ".json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"[done] {cid}: status={rec['status']} "
              f"dominant={rec.get('dominant_term')} "
              f"compile_s={rec.get('compile_s')}")


if __name__ == "__main__":
    main()
