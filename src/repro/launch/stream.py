"""Durable stream-engine launcher: the counting workflow (paper Examples
1/4) with the DESIGN.md section 10 durability layer, exposing the
``--recover`` path.

Normal run::

    python -m repro.launch.stream --dir /tmp/muppet --ticks 64

Simulated crash (exit mid-run without flushing) then recovery::

    python -m repro.launch.stream --dir /tmp/muppet --ticks 64 --crash-at 40
    python -m repro.launch.stream --dir /tmp/muppet --ticks 64 --recover

The recovered run restores flushed slates from the KV store, replays the
WAL suffix from the frontier, then continues to ``--ticks`` and prints
stats + a few slates, matching what the uninterrupted run would print.
"""
from __future__ import annotations

import argparse
import json

import jax.numpy as jnp
import numpy as np

from repro.core.durability import DurabilityConfig
from repro.core.engine import Engine, EngineConfig
from repro.core.event import EventBatch
from repro.core.operators import AssociativeUpdater, Mapper
from repro.core.workflow import Workflow
from repro.slates.flush import FlushConfig, FlushPolicy

VSPEC = {"x": ((), jnp.float32)}


class SourceMapper(Mapper):
    name = "M1"
    subscribes = ("S1",)
    in_value_spec = VSPEC
    out_streams = {"S2": VSPEC}

    def map_batch(self, batch):
        return {"S2": EventBatch(sid=batch.sid, ts=batch.ts + 1,
                                 key=batch.key, value=batch.value,
                                 valid=batch.valid)}


class CounterUpdater(AssociativeUpdater):
    name = "U1"
    subscribes = ("S2",)
    in_value_spec = VSPEC
    out_streams = {}
    table_capacity = 1 << 14
    sum_mergeable = True

    def slate_spec(self):
        return {"count": ((), jnp.int32), "sum": ((), jnp.float32)}

    def lift(self, batch):
        return {"count": jnp.ones_like(batch.key),
                "sum": batch.value["x"]}

    def combine(self, a, b):
        return {"count": a["count"] + b["count"],
                "sum": a["sum"] + b["sum"]}

    def merge(self, s, d):
        return {"count": s["count"] + d["count"],
                "sum": s["sum"] + d["sum"]}


def make_engine(args) -> Engine:
    wf = Workflow([SourceMapper(), CounterUpdater()],
                  external_streams=("S1",))
    dur = DurabilityConfig(
        dir=args.dir,
        flush=FlushConfig(policy=FlushPolicy.EVERY_K,
                          every_k=args.flush_every),
        truncate_wal=args.truncate_wal)
    return Engine(wf, EngineConfig(batch_size=args.batch,
                                   queue_capacity=args.batch * 4,
                                   chunk_size=args.chunk,
                                   durability=dur))


def source_fn(t, max_events, batch):
    rng = np.random.default_rng(t)           # deterministic per tick:
    n = min(batch, max_events or batch)      # replay == original feed
    keys = rng.integers(0, 10_000, size=n).astype(np.int32)
    return {"S1": EventBatch.of(
        key=keys, value={"x": rng.normal(size=n).astype(np.float32)},
        ts=np.full(n, t, np.int32))}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dir", required=True,
                    help="durability root (wal.log, store/, FRONTIER)")
    ap.add_argument("--ticks", type=int, default=64)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--flush-every", type=int, default=16)
    ap.add_argument("--truncate-wal", action="store_true",
                    help="compact the WAL at each flush frontier")
    ap.add_argument("--crash-at", type=int, default=None,
                    help="hard-exit after this many source ticks "
                         "(simulated machine crash; no final flush)")
    ap.add_argument("--recover", action="store_true",
                    help="restore slates + replay WAL before running")
    args = ap.parse_args(argv)

    eng = make_engine(args)
    done = 0
    if args.recover:
        state = eng.recover()
        # resume the source stream where it left off: the frontier's
        # driver cursor survives even full WAL truncation, and events
        # carry their source tick as ts, so post-frontier WAL records
        # advance it further.  (The engine tick is no substitute — it
        # also counts flush drain ticks.)
        if eng.dur.frontier.meta:
            done = int(eng.dur.frontier.meta.get("source_tick", 0))
        for _, srcs in eng.dur.wal.replay():
            if "S1" in srcs:
                done = max(done, int(np.asarray(srcs["S1"].ts)[0]) + 1)
        print(f"recovered: frontier tick {eng.dur.frontier.tick}, "
              f"engine tick {eng.stats(state)['tick']}, "
              f"resuming at source tick {done}")
    else:
        state = eng.init_state()

    remaining = max(0, args.ticks - done)
    if args.crash_at is not None:
        remaining = min(remaining, args.crash_at - done)
    state, _ = eng.run(
        state, lambda t, mx: source_fn(t, mx, args.batch),
        remaining, source_offset=done)

    if args.crash_at is not None and not args.recover:
        print(f"CRASH at source tick {args.crash_at} (state dropped; "
              f"rerun with --recover)")
        return   # no close(): unflushed slates die with the process

    stats = eng.stats(state)
    print(json.dumps(stats, indent=2))
    for key in (0, 1, 2):
        print(f"slate[{key}] =", eng.read_slate(state, "U1", key))
    eng.close()


if __name__ == "__main__":
    main()
