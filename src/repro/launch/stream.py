"""Durable stream-engine launcher: the counting workflow (paper Examples
1/4) with the DESIGN.md section 10 durability layer, exposing the
``--recover`` path — built on the declarative app layer (section 11).

Normal run::

    python -m repro.launch.stream --dir /tmp/muppet --ticks 64

Simulated crash (exit mid-run without flushing) then recovery::

    python -m repro.launch.stream --dir /tmp/muppet --ticks 64 --crash-at 40
    python -m repro.launch.stream --dir /tmp/muppet --ticks 64 --recover

The recovered run restores flushed slates from the KV store, replays the
WAL suffix from the frontier, then continues to ``--ticks`` and prints
stats + a few slates, matching what the uninterrupted run would print.
``--serve`` starts the live HTTP slate server for the duration of the
run (reads go through the engine's :class:`StateHandle`, republished
every chunk).

Live elasticity demo (DESIGN.md section 12) — needs visible devices,
e.g. ``XLA_FLAGS=--xla_force_host_platform_device_count=16`` on CPU::

    python -m repro.launch.stream --dir /tmp/m --ticks 64 \
        --shards 8 --scale-at 24:16 --scale-at 48:8

Each ``--scale-at TICK:N`` rescales the active shard set live before
source tick TICK, migrating slates and in-flight events loss-free;
``--rebalance-every K`` reweights the ring from the per-shard load
signal every K ticks.

Closed-loop autoscaling (DESIGN.md section 13) replaces the declared
schedule with watermarks on the telemetry pressure signal::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python -m repro.launch.stream --dir /tmp/m --ticks 48 \
        --shards 2 --autoscale load:0.75,0.2

``--autoscale load:HI,LO`` attaches a ``LoadAutoscaler``: the active
shard set grows when windowed per-shard pressure stays above HI and
shrinks back once it stays below LO (hysteresis: dwell + cooldown);
the final telemetry report is printed with the stats.
"""
from __future__ import annotations

import argparse
import json

import jax.numpy as jnp
import numpy as np

from repro import (App, AutoscalePolicy, EventBatch, LoadAutoscaler,
                   RuntimeConfig)


def make_app(args) -> App:
    app = App("stream")
    s1 = app.source("S1", {"x": ((), jnp.float32)})

    @app.mapper(s1, out="S2", name="M1")
    def forward(batch):
        return EventBatch(sid=batch.sid, ts=batch.ts + 1, key=batch.key,
                          value=batch.value, valid=batch.valid)

    @app.updater("S2", name="U1", merge="sum",
                 slate={"count": ((), jnp.int32), "sum": ((), jnp.float32)},
                 table_capacity=1 << 14)
    def lift(batch):
        return {"count": jnp.ones_like(batch.key),
                "sum": batch.value["x"]}

    def on_change(rep):
        print(f"reconfigured: active={len(rep.active)} shards, moved "
              f"{sum(rep.moved_rows.values())} rows + "
              f"{sum(rep.moved_events.values())} queued events "
              f"({'recompiled' if rep.recompiled else 'ring swap only'})")

    autoscale = None
    if args.autoscale is not None:
        hi, lo = args.autoscale
        autoscale = LoadAutoscaler(high=hi, low=lo, window=4, dwell=1,
                                   cooldown=1, on_change=on_change)
    elif args.scale_at or args.rebalance_every:
        autoscale = AutoscalePolicy(
            scale_at=dict(args.scale_at or ()),
            rebalance_every=args.rebalance_every,
            on_change=on_change)
    telemetry = None
    if getattr(args, "trace", None):
        from repro.telemetry import TelemetryConfig
        telemetry = TelemetryConfig(trace=True)
    app.start(RuntimeConfig(batch_size=args.batch,
                            queue_capacity=args.batch * 4,
                            chunk_size=args.chunk,
                            shards=args.shards,
                            autoscale=autoscale,
                            telemetry=telemetry,
                            durable_dir=args.dir,
                            flush_every=args.flush_every,
                            truncate_wal=args.truncate_wal),
              recover=args.recover)
    return app


def source_fn(t, max_events, batch):
    rng = np.random.default_rng(t)           # deterministic per tick:
    n = min(batch, max_events or batch)      # replay == original feed
    keys = rng.integers(0, 10_000, size=n).astype(np.int32)
    return {"S1": EventBatch.of(
        key=keys, value={"x": rng.normal(size=n).astype(np.float32)},
        ts=np.full(n, t, np.int32))}


def source_fn_sharded(t, app, batch):
    """Distributed feed: the same *global* event multiset per tick
    regardless of the current shard count, reshaped to the engine's
    live ``[n_shards, B]`` layout so scale boundaries keep parity.
    Padded with invalid rows up to the next multiple of ``n_shards``
    (truncating would change the multiset when the live shard count
    does not divide ``--batch``)."""
    n = app.engine.n_shards
    b = source_fn(t, None, batch)["S1"].pad_to(-(-batch // n) * n)
    shaped = EventBatch(
        sid=b.sid.reshape(n, -1), ts=b.ts.reshape(n, -1),
        key=b.key.reshape(n, -1),
        value={"x": b.value["x"].reshape(n, -1)},
        valid=b.valid.reshape(n, -1))
    return {"S1": shaped}


def parse_scale_at(spec: str):
    try:
        tick, n = spec.split(":")
        return int(tick), int(n)
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"--scale-at wants TICK:N (e.g. 24:16), got {spec!r}")


def parse_autoscale(spec: str):
    try:
        mode, rest = spec.split(":")
        if mode != "load":
            raise ValueError
        hi, lo = (float(x) for x in rest.split(","))
        return hi, lo
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"--autoscale wants load:HI,LO (e.g. load:0.75,0.2), "
            f"got {spec!r}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dir", required=True,
                    help="durability root (wal.log, store/, FRONTIER)")
    ap.add_argument("--ticks", type=int, default=64)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--flush-every", type=int, default=16)
    ap.add_argument("--truncate-wal", action="store_true",
                    help="compact the WAL at each flush frontier")
    ap.add_argument("--shards", type=int, default=1,
                    help="initial shard count (>1 = DistributedEngine; "
                         "needs that many visible jax devices)")
    ap.add_argument("--scale-at", type=parse_scale_at, action="append",
                    default=None, metavar="TICK:N",
                    help="live-rescale to N active shards before source "
                         "tick TICK (repeatable)")
    ap.add_argument("--rebalance-every", type=int, default=0,
                    help="reweight the ring from the per-shard load "
                         "signal every K source ticks")
    ap.add_argument("--autoscale", type=parse_autoscale, default=None,
                    metavar="load:HI,LO",
                    help="closed-loop autoscaling: grow the active "
                         "shard set when windowed pressure > HI, "
                         "shrink when < LO (DESIGN.md section 13)")
    ap.add_argument("--crash-at", type=int, default=None,
                    help="hard-exit after this many source ticks "
                         "(simulated machine crash; no final flush)")
    ap.add_argument("--recover", action="store_true",
                    help="restore slates + replay WAL before running")
    ap.add_argument("--serve", action="store_true",
                    help="HTTP slate server live during the run")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record engine phase spans and export them as "
                         "Chrome trace JSON (open in Perfetto) after "
                         "the run")
    args = ap.parse_args(argv)
    if args.autoscale is not None and args.shards < 2:
        ap.error("--autoscale needs --shards >= 2 (a distributed "
                 "runtime to scale)")
    if args.autoscale is not None and (args.scale_at
                                       or args.rebalance_every):
        ap.error("--autoscale (closed loop) and --scale-at/"
                 "--rebalance-every (declared schedule) are mutually "
                 "exclusive")

    app = make_app(args)
    eng = app.engine
    done = 0
    if args.recover:
        # resume the source stream where it left off: the frontier's
        # driver cursor survives even full WAL truncation, and events
        # carry their source tick as ts, so post-frontier WAL records
        # advance it further.  (The engine tick is no substitute — it
        # also counts flush drain ticks.)
        if eng.dur.frontier.meta:
            done = int(eng.dur.frontier.meta.get("source_tick", 0))
        for wal in eng.dur.wals:
            for _, srcs in wal.replay():
                if "S1" in srcs:
                    done = max(done,
                               int(np.asarray(srcs["S1"].ts).max()) + 1)
        print(f"recovered: frontier tick {eng.dur.frontier.tick}, "
              f"engine tick {app.stats()['tick']}, "
              f"resuming at source tick {done}")

    if args.serve:
        server = app.serve()
        print(f"slates live at http://127.0.0.1:{server.port}/slate/U1/<k>")

    remaining = max(0, args.ticks - done)
    if args.crash_at is not None:
        remaining = min(remaining, args.crash_at - done)
    if args.shards > 1:
        app.run(lambda t, mx: source_fn_sharded(t, app, args.batch),
                remaining, source_offset=done)
    else:
        app.run(lambda t, mx: source_fn(t, mx, args.batch), remaining,
                source_offset=done)

    if args.crash_at is not None and not args.recover:
        print(f"CRASH at source tick {args.crash_at} (state dropped; "
              f"rerun with --recover)")
        return   # no close(): unflushed slates die with the process

    if args.trace:
        path = app.export_trace(args.trace)
        with open(path) as f:          # verify it round-trips as JSON
            n_spans = len(json.load(f)["traceEvents"])
        print(f"trace: {n_spans} span(s) -> {path} "
              f"(load in Perfetto / chrome://tracing)")

    print(json.dumps(app.stats(), indent=2))
    if args.autoscale is not None:
        rep = app.telemetry()
        print(f"telemetry: active={len(rep.active)} shards, "
              f"pressure={np.round(rep.pressure, 3).tolist()}, "
              f"heavy={rep.heavy_hitters[:3]}")
    for key in (0, 1, 2):
        print(f"slate[{key}] =", app.read_slate("U1", key))
    app.close()


if __name__ == "__main__":
    main()
