"""Streaming training driver.

The training loop is itself a MapUpdate-shaped pipeline: a source stream
(tokens) feeds a stateful step whose "slate" is (params, optimizer
state); the slate-flush machinery is the async checkpointer.  Fault
tolerance: checkpoint every k steps (atomic COMMIT), restart resumes from
the latest committed step, straggler hosts are absorbed by the bounded
skip-ahead prefetcher, and a simulated failure flag exercises the
restart path end-to-end in tests.

CLI (reduced configs run on CPU):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
      --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.data.synthetic import Prefetcher, TokenStream
from repro.distributed import optimizer as adamw
from repro.distributed import sharding as shd
from repro.distributed.checkpoint import Checkpointer
from repro.launch import cells
from repro.launch.mesh import make_host_mesh
from repro.models import lm


class Trainer:
    def __init__(self, cfg, mesh=None, *, opt_cfg=None,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 50):
        self.cfg = cfg
        self.mesh = mesh or make_host_mesh(n_model=1)
        self.rules = shd.rules_for(self.mesh, phase="train")
        self.model = lm.build(cfg)
        self.step_fn = jax.jit(
            cells.make_train_step(self.model, self.mesh, self.rules,
                                  opt_cfg or adamw.AdamWConfig()),
            donate_argnums=(0, 1))
        self.ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self.step = 0
        # straggler monitoring
        self._ema = None
        self.straggler_events = 0

    def init(self, seed: int = 0):
        with self.mesh:
            params, specs = lm.init(self.model, jax.random.PRNGKey(seed))
            shardings = shd.tree_shardings(specs, params, self.mesh,
                                           self.rules)
            params = jax.device_put(params, shardings)
            opt = adamw.init(params)
        return params, opt

    def maybe_restore(self, params, opt):
        if self.ckpt is None:
            return params, opt
        latest = self.ckpt.latest_step()
        if latest is None:
            return params, opt
        state = self.ckpt.restore(latest, {"params": params, "opt": opt})
        self.step = latest
        return state["params"], state["opt"]

    def run(self, params, opt, batches, n_steps: int, *,
            log_every: int = 10, fail_at: Optional[int] = None):
        """``fail_at``: simulate a crash after that step (tests restart)."""
        losses = []
        with self.mesh:
            for batch in batches:
                if self.step >= n_steps:
                    break
                t0 = time.time()
                dev_batch = {k: jnp.asarray(v) for k, v in batch.items()}
                params, opt, metrics = self.step_fn(params, opt, dev_batch)
                loss = float(metrics["loss"])
                losses.append(loss)
                self.step += 1
                dt = time.time() - t0
                self._track_stragglers(dt)
                if self.ckpt and self.step % self.ckpt_every == 0:
                    self.ckpt.save(self.step,
                                   {"params": params, "opt": opt})
                if self.step % log_every == 0:
                    print(f"step {self.step}: loss={loss:.4f} "
                          f"gnorm={float(metrics['grad_norm']):.3f} "
                          f"({dt*1e3:.0f} ms)")
                if fail_at is not None and self.step >= fail_at:
                    raise RuntimeError("simulated node failure")
        return params, opt, losses

    def _track_stragglers(self, dt: float, k: float = 3.0):
        if self._ema is None:
            self._ema = dt
        elif dt > k * self._ema:
            self.straggler_events += 1   # logged; pipeline skip-ahead
        else:
            self._ema = 0.9 * self._ema + 0.1 * dt

    def close(self):
        if self.ckpt:
            self.ckpt.close()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    trainer = Trainer(cfg, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every)
    params, opt = trainer.init(args.seed)
    params, opt = trainer.maybe_restore(params, opt)
    stream = Prefetcher(iter(TokenStream(cfg.vocab_size, args.batch,
                                         args.seq, seed=args.seed)))
    t0 = time.time()
    params, opt, losses = trainer.run(params, opt, stream, args.steps)
    print(f"done: {trainer.step} steps in {time.time()-t0:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"stragglers={trainer.straggler_events}")
    if trainer.ckpt:
        trainer.ckpt.save(trainer.step, {"params": params, "opt": opt},
                          blocking=True)
    trainer.close()
    stream.close()


if __name__ == "__main__":
    main()
