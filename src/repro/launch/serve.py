"""Continuous-batching serving driver — a MapUpdate application.

The paper's mapping (DESIGN.md section 3): each request's decode state
(KV caches / SSM states, write position, last token) is a *slate* keyed by
request id; token events flow through the engine; a bounded admission
queue applies Muppet's overflow policies (drop / throttle) under load;
finished requests expire their slate (TTL).  On a pod, requests hash to
data-axis shards with the same ring as the stream engine — this driver is
the per-shard slot manager.

Tick = (admit up to ``admit_per_tick`` prefills) + (one decode step for
every active slot).  Prefill shapes are bucketed to keep jit cache small.

Durability (DESIGN.md section 10 applied to serving): with a ``journal``
path, every accepted request is appended to a WriteAheadLog before it is
served and a completion record is appended when it finishes.  After a
crash, ``recover_requests`` returns the accepted-but-unfinished requests
for re-submission — at-least-once request processing (a request racing
the crash may decode twice; token streams already sent are re-sent).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.event import EventBatch
from repro.distributed import sharding as shd
from repro.launch import cells
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.models.context import Ctx
from repro.slates.wal import WriteAheadLog
from repro.telemetry.metrics import MetricsRegistry, TelemetryConfig


@dataclass
class Request:
    rid: int
    prompt: np.ndarray           # [P] int32
    max_new: int = 16
    arrived_tick: int = 0
    tokens_out: List[int] = field(default_factory=list)
    done_tick: Optional[int] = None


@dataclass
class ServeConfig:
    n_slots: int = 8             # concurrent decode slots (batch)
    cache_len: int = 256
    prompt_bucket: int = 64      # prefill pad bucket
    admit_per_tick: int = 2
    queue_capacity: int = 64     # admission queue bound (overflow -> shed)
    eos_token: int = -1          # -1 = run to max_new


class ServingEngine:
    def __init__(self, cfg_model, serve_cfg: ServeConfig = None, mesh=None,
                 journal: Optional[str] = None):
        self.journal = WriteAheadLog(journal) if journal else None
        self.scfg = serve_cfg or ServeConfig()
        self.mesh = mesh or make_host_mesh(n_model=1)
        self.rules = shd.rules_for(self.mesh, phase="decode")
        self.model = lm.build(cfg_model)
        self.cfg = cfg_model
        sc = self.scfg

        self._decode = jax.jit(cells.make_decode_step(
            self.model, self.mesh, self.rules), donate_argnums=(2,))
        self._prefill = jax.jit(cells.make_prefill_step(
            self.model, self.mesh, self.rules, cache_len=sc.cache_len,
            full_logits=True))

        # batched decode state over slots = the slate table
        self.states = cells.concrete_states(self.model, sc.n_slots,
                                            sc.cache_len)
        self.cur_index = jnp.zeros((sc.n_slots,), jnp.int32)
        self.last_token = jnp.zeros((sc.n_slots, 1), jnp.int32)
        self.active = np.zeros(sc.n_slots, bool)
        self.slot_req: List[Optional[Request]] = [None] * sc.n_slots

        self.queue: deque = deque()
        self.journal_max_rid = -1          # set by recover_requests
        self.shed = 0                      # overflow drops (paper 4.3)
        self.tick = 0
        self.finished: List[Request] = []
        # windowed serving telemetry (the stream engine's registry via
        # its engine-agnostic observe_raw: events = tokens decoded,
        # queue = admission backlog, drops = shed requests)
        self.telemetry = MetricsRegistry(
            TelemetryConfig(window=8), batch_size=self.scfg.n_slots)
        self._tokens_cum = 0

        self._insert = jax.jit(self._insert_impl, donate_argnums=(0,))

    # ---- admission (the "M0 source mapper") ----
    def submit(self, req: Request, *, journal: bool = True) -> bool:
        if len(self.queue) >= self.scfg.queue_capacity:
            self.shed += 1                 # queue overflow: drop + count
            return False
        if self.journal is not None and journal:
            self.journal.append(req.rid, {"req": EventBatch.of(
                key=np.asarray([req.rid], np.int32),
                value={"prompt": req.prompt[None],
                       "max_new": np.asarray([req.max_new], np.int32)})})
        req.arrived_tick = self.tick
        self.queue.append(req)
        return True

    def _journal_done(self, req: Request):
        if self.journal is not None:
            self.journal.append(req.rid, {"done": EventBatch.of(
                key=np.asarray([req.rid], np.int32),
                value={"n_out": np.asarray([len(req.tokens_out)],
                                           np.int32)})})

    def recover_requests(self) -> List[Request]:
        """Replay the journal: accepted requests with no completion
        record — the work a crashed server owes its clients.  Re-submit
        via ``submit(req, journal=False)`` (already logged) and **check
        the return value**: an overfull admission queue still sheds.
        Also sets ``journal_max_rid`` so new requests can pick rids that
        don't collide with journaled ones (a reused rid would match an
        old completion record and be dropped by the next recovery)."""
        assert self.journal is not None, "no journal configured"
        reqs: Dict[int, Request] = {}
        done = set()
        self.journal_max_rid = -1
        for rid, rec in self.journal.replay():
            self.journal_max_rid = max(self.journal_max_rid, rid)
            if "req" in rec:
                v = rec["req"].value
                reqs[rid] = Request(
                    rid=rid, prompt=np.asarray(v["prompt"][0], np.int32),
                    max_new=int(np.asarray(v["max_new"])[0]))
            if "done" in rec:
                done.add(rid)
        return [r for rid, r in sorted(reqs.items()) if rid not in done]

    @staticmethod
    def _insert_impl(states, new_states, slot, cur_index, cur_value,
                     last_token, tok_value):
        merged = jax.tree.map(
            lambda d, s: d.at[:, slot].set(s[:, 0].astype(d.dtype)),
            states, new_states)
        return (merged, cur_index.at[slot].set(cur_value),
                last_token.at[slot].set(tok_value))

    def _admit(self):
        sc = self.scfg
        admitted = 0
        while (self.queue and admitted < sc.admit_per_tick
               and not self.active.all()):
            req = self.queue.popleft()
            slot = int(np.nonzero(~self.active)[0][0])
            P = len(req.prompt)
            bucket = -(-P // sc.prompt_bucket) * sc.prompt_bucket
            bucket = min(bucket, sc.cache_len)
            toks = np.zeros((1, bucket), np.int32)
            toks[0, :P] = req.prompt[:bucket]
            batch = {"tokens": jnp.asarray(toks)}
            batch.update(self._aux_inputs(1, bucket))
            logits, new_states = self._prefill(lm_params(self), batch)
            # last *real* prompt position; pad rows beyond P sit past the
            # decode frontier (lengths = cur_index+1) and are overwritten
            # as generation advances, so they are never attended.
            tok = int(np.asarray(jnp.argmax(logits[0, min(P, bucket) - 1])))
            self.states, self.cur_index, self.last_token = self._insert(
                self.states, new_states, slot, self.cur_index,
                jnp.int32(min(P, bucket)), self.last_token, jnp.int32(tok))
            req.tokens_out.append(tok)
            self.active[slot] = True
            self.slot_req[slot] = req
            admitted += 1

    def _aux_inputs(self, b, s):
        out = {}
        if self.cfg.encdec:
            out["enc_frames"] = jnp.zeros((b, s, self.cfg.d_model),
                                          jnp.bfloat16)
        if self.cfg.cross_attn_every:
            out["image_embeds"] = jnp.zeros(
                (b, self.cfg.n_image_tokens, self.cfg.d_model),
                jnp.bfloat16)
        return out

    # ---- one engine tick ----
    def step(self):
        self._admit()
        if self.active.any():
            self._tokens_cum += int(self.active.sum())
            tok, self.states, self.cur_index = self._decode(
                lm_params(self), self.last_token, self.states,
                self.cur_index)
            self.last_token = tok
            toks = np.asarray(tok[:, 0])
            for slot in np.nonzero(self.active)[0]:
                req = self.slot_req[slot]
                req.tokens_out.append(int(toks[slot]))
                hit_eos = (self.scfg.eos_token >= 0
                           and int(toks[slot]) == self.scfg.eos_token)
                out_of_budget = len(req.tokens_out) >= req.max_new
                out_of_cache = int(self.cur_index[slot]) >= \
                    self.scfg.cache_len - 1
                if hit_eos or out_of_budget or out_of_cache:
                    req.done_tick = self.tick
                    self.finished.append(req)
                    self._journal_done(req)
                    self.active[slot] = False   # slate TTL expiry
                    self.slot_req[slot] = None
        self.tick += 1
        if self.tick % self.telemetry.cfg.window == 0:
            self._observe()

    def run(self, n_ticks: int):
        for _ in range(n_ticks):
            self.step()

    def _observe(self):
        """One window reading: decode throughput vs slot capacity,
        admission backlog, shed requests — the stream engine's
        TelemetryReport shape, from serving counters."""
        self.telemetry.observe_raw(
            tick=self.tick,
            events=np.asarray([self._tokens_cum]),
            queue_depth=np.asarray([len(self.queue)]),
            queue_peak=np.asarray([len(self.queue)]),
            dropped=np.asarray([self.shed]),
            occupancy=np.asarray([int(self.active.sum())]),
            active=[0], shed=np.asarray([self.shed]))

    def status_server(self, port: int = 0):
        """Live HTTP introspection while serving (the stream engine's
        slate-server pattern applied to decode state): ``GET /status``
        -> stats; ``GET /slate/requests/<rid>`` -> that request's token
        stream so far.  Request state is keyed by rid exactly like a
        slate table, so the same :class:`SlateServer` front end serves
        both engines."""
        from repro.slates.http import SlateServer

        def read_fn(updater: str, rid: int):
            if updater != "requests":
                return None
            # snapshot: the decode loop mutates these on the main
            # thread while HTTP handlers run on server threads
            for r in list(self.finished):
                if r is not None and r.rid == rid:
                    return {"tokens_out": list(r.tokens_out),
                            "done": True}
            for r in list(self.slot_req):
                if r is not None and r.rid == rid:
                    return {"tokens_out": list(r.tokens_out),
                            "done": False}
            for r in list(self.queue):
                if r is not None and r.rid == rid:
                    return {"tokens_out": [], "done": False}
            return None

        return SlateServer(read_fn=read_fn, stats_fn=self.stats,
                           metrics_fn=self.metrics_text, port=port)

    def metrics_text(self) -> str:
        """Prometheus exposition for the serving engine: decode-side
        counters plus the windowed TelemetryReport, same renderer as
        the stream engine's ``/metrics`` (DESIGN.md 18.4)."""
        from repro.telemetry.prom import render_prometheus
        stats = {
            "tick": self.tick,
            "processed": {"decode": self._tokens_cum},
            "queue_dropped": {"admission": self.shed},
            "table_occupancy": {"slots": int(self.active.sum())
                                / max(1, self.scfg.n_slots)},
            "finished": len(self.finished),
            "queued": len(self.queue),
        }
        return render_prometheus(stats=stats, report=self.telemetry.last)

    def stats(self) -> Dict[str, Any]:
        lat = [r.done_tick - r.arrived_tick for r in self.finished
               if r.done_tick is not None]
        out = {
            "tick": self.tick,
            "finished": len(self.finished),
            "active": int(self.active.sum()),
            "queued": len(self.queue),
            "shed": self.shed,
            "mean_latency_ticks": float(np.mean(lat)) if lat else None,
            "tokens_generated": int(sum(len(r.tokens_out)
                                        for r in self.finished)),
        }
        if self.telemetry.last is not None:
            # windowed TelemetryReport on /status (DESIGN.md 13.2)
            out["telemetry"] = self.telemetry.last.to_dict()
        return out


def lm_params(engine: ServingEngine):
    if not hasattr(engine, "_params"):
        with engine.mesh:
            params, specs = lm.init(engine.model, jax.random.PRNGKey(0))
            shardings = shd.tree_shardings(specs, params, engine.mesh,
                                           engine.rules)
            engine._params = jax.device_put(params, shardings)
    return engine._params


def main():
    import argparse
    from repro.configs import reduced_config
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--ticks", type=int, default=64)
    ap.add_argument("--journal", default=None,
                    help="request WAL path (durable at-least-once "
                         "admission)")
    ap.add_argument("--recover", action="store_true",
                    help="re-submit journaled unfinished requests "
                         "before accepting new ones")
    ap.add_argument("--status-port", type=int, default=None,
                    help="serve live /status + /slate/requests/<rid> "
                         "over HTTP while decoding (0 = any free port)")
    args = ap.parse_args()
    cfg = reduced_config(args.arch)
    eng = ServingEngine(cfg, ServeConfig(n_slots=4, cache_len=128,
                                         prompt_bucket=32),
                        journal=args.journal)
    server = None
    if args.status_port is not None:
        server = eng.status_server(args.status_port)
        print(f"status live at http://127.0.0.1:{server.port}/status")
    rid0 = 0
    if args.recover:
        pending = eng.recover_requests()
        rid0 = eng.journal_max_rid + 1   # never reuse journaled rids
        shed = [r.rid for r in pending if not eng.submit(r, journal=False)]
        print(f"recovered {len(pending)} unfinished request(s)"
              + (f"; SHED {shed} (queue full — resubmit later)"
                 if shed else ""))
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(rid=rid0 + i, prompt=rng.integers(
            0, cfg.vocab_size, size=rng.integers(4, 30)).astype(np.int32),
            max_new=8))
    eng.run(args.ticks)
    print(eng.stats())
    if server is not None:
        server.close()


if __name__ == "__main__":
    main()
