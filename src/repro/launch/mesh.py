"""Production meshes.

Single pod: (16, 16) ("data", "model") = 256 chips (TPU v5e pod).
Multi-pod:  (2, 16, 16) ("pod", "data", "model") = 512 chips.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS *before* first jax
init and only then calls this.
"""
from __future__ import annotations

import jax


def _mesh_kwargs(n):
    """``axis_types`` only exists on newer jax; older versions treat all
    axes as auto already, so just omit it there."""
    if hasattr(jax.sharding, "AxisType"):
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n}
    return {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_host_mesh(n_data: int = None, n_model: int = 1,
                   axes=("data", "model")):
    """Small mesh over however many (host) devices exist — tests."""
    n = len(jax.devices())
    n_data = n_data or (n // n_model)
    return jax.make_mesh((n_data, n_model), axes,
                         **_mesh_kwargs(len(axes)))
