"""Model configuration system.

One frozen dataclass covers the ten assigned architectures; families are
expressed through optional sub-configs (MoE, MLA, SSM, enc-dec, VLM) plus a
repeating ``block pattern`` that the scan-based stack (``stack.py``)
compiles into grouped ``lax.scan`` loops.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_routed_experts: int = 64
    n_shared_experts: int = 2
    top_k: int = 6
    d_expert: int = 1408           # fine-grained expert hidden size
    n_dense_layers: int = 1        # leading dense-FFN layers (deepseek style)
    router_aux_coef: float = 0.001
    capacity_factor: float = 1.25  # per-expert buffer slack for dispatch


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0           # 0 = direct q projection (v2-lite)
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64            # N: SSD state size
    head_dim: int = 64             # P: channels per SSD head
    expand: int = 2                # d_inner = expand * d_model
    d_conv: int = 4                # causal conv width
    chunk: int = 256               # chunked-scan block length


@dataclass(frozen=True)
class XLSTMConfig:
    mlstm_expand: int = 2          # mLSTM inner expansion
    slstm_proj: float = 4.0 / 3.0  # sLSTM post-FFN expansion
    conv_width: int = 4
    chunk: int = 256


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False
    act: str = "silu"              # silu -> SwiGLU, gelu -> GeGLU
    rope_theta: float = 10_000.0
    rope_theta_local: float = 0.0  # gemma3: local layers use a different theta
    norm_eps: float = 1e-6
    norm_scale_offset: bool = False  # gemma: RMSNorm applies (1 + w)
    embed_scale: bool = False        # gemma: embeddings scaled by sqrt(D)
    tie_embeddings: bool = True

    # local/global interleave (gemma3: window on 5 of 6 layers)
    sliding_window: int = 0        # 0 -> full attention
    global_every: int = 0          # every k-th layer is global (0 -> none)

    # family sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None

    # hybrid (zamba2): shared attention block applied every k SSM layers
    shared_attn_every: int = 0

    # encoder-decoder (whisper)
    encdec: bool = False
    n_enc_layers: int = 0

    # VLM (llama-3.2-vision): cross-attn layer every k layers
    cross_attn_every: int = 0
    n_image_tokens: int = 0

    dtype: str = "bfloat16"

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (SSM / hybrid / linear-attn)."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter count (for roofline MODEL_FLOPS = 6 N D) ----
    def param_count(self, active_only: bool = False) -> int:
        D = self.d_model
        Dh = self.resolved_head_dim
        H, Hkv = self.n_heads, self.n_kv_heads
        n = 0
        # embeddings (+ untied head)
        n += self.vocab_size * D * (1 if self.tie_embeddings else 2)

        def attn_params() -> int:
            if self.mla is not None:
                m = self.mla
                p = D * (m.kv_lora_rank + m.rope_head_dim)           # down kv
                p += m.kv_lora_rank * H * (m.nope_head_dim + m.v_head_dim)
                qin = m.q_lora_rank or D
                p += (D * m.q_lora_rank if m.q_lora_rank else 0)
                p += qin * H * (m.nope_head_dim + m.rope_head_dim)
                p += H * m.v_head_dim * D                             # o
                return p
            p = D * H * Dh + 2 * D * Hkv * Dh + H * Dh * D
            if self.qkv_bias:
                p += (H + 2 * Hkv) * Dh
            return p

        def ffn_params(dff: int) -> int:
            return 3 * D * dff  # gated (in, gate, out)

        def ssm_params() -> int:
            s = self.ssm or SSMConfig()
            d_in = s.expand * D
            nh = d_in // s.head_dim
            p = D * (2 * d_in + 2 * s.state_dim + nh)  # in_proj(z,x) + B,C + dt
            p += d_in * s.d_conv + d_in * D            # conv + out proj
            return p

        def mlstm_params() -> int:
            x = self.xlstm or XLSTMConfig()
            d_in = x.mlstm_expand * D
            return D * d_in * 2 + d_in * 3 * d_in // x.mlstm_expand + d_in * D

        def slstm_params() -> int:
            x = self.xlstm or XLSTMConfig()
            dp = int(D * x.slstm_proj)
            return 4 * D * D + 4 * D * D + 2 * D * dp  # gates(x) + gates(h) + ffn

        if self.family == "ssm":
            for i in range(self.n_layers):
                n += mlstm_params() if i % 2 == 0 else slstm_params()
        elif self.family == "hybrid":
            n += self.n_layers * ssm_params()
            if self.shared_attn_every:
                n += attn_params() + ffn_params(self.d_ff)  # shared weights, once
        else:
            per_layer_dense = attn_params() + ffn_params(self.d_ff)
            if self.moe is not None:
                m = self.moe
                moe_ffn_total = (
                    m.n_shared_experts * 3 * D * m.d_expert
                    + m.n_routed_experts * 3 * D * m.d_expert
                    + D * m.n_routed_experts  # router
                )
                moe_ffn_active = (
                    m.n_shared_experts * 3 * D * m.d_expert
                    + m.top_k * 3 * D * m.d_expert
                    + D * m.n_routed_experts
                )
                n_moe = self.n_layers - m.n_dense_layers
                n += m.n_dense_layers * per_layer_dense
                n += n_moe * (attn_params()
                              + (moe_ffn_active if active_only else moe_ffn_total))
            else:
                n += self.n_layers * per_layer_dense
            if self.encdec:
                # encoder layers + decoder cross-attn
                n += self.n_enc_layers * (attn_params() + ffn_params(self.d_ff))
                n += self.n_layers * attn_params()  # cross-attn per dec layer
            if self.cross_attn_every:
                n_cross = self.n_layers // self.cross_attn_every
                n += n_cross * (attn_params() + ffn_params(self.d_ff))
        return n


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""
    name: str
    seq_len: int
    global_batch: int
    phase: str  # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def cell_is_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell runs, per the brief's skip rules."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("long_500k needs sub-quadratic attention; "
                       f"{cfg.name} is full-attention (skip per brief)")
    return True, ""
