"""Rotary position embeddings (RoPE), interleaved-pair convention."""
from __future__ import annotations

import jax.numpy as jnp


def _freqs(head_dim: int, theta: float):
    half = head_dim // 2
    exponent = jnp.arange(half, dtype=jnp.float32) / half
    return 1.0 / (theta ** exponent)  # [half]


def apply_rope(x, positions, *, theta: float = 10_000.0):
    """x: [..., S, H, Dh] (or [..., S, Dh]); positions: broadcastable [..., S].

    Uses the split-halves (rotate_half) convention shared by Llama/Qwen/
    Gemma HF implementations.
    """
    head_dim = x.shape[-1]
    inv = _freqs(head_dim, theta)                       # [half]
    ang = positions[..., None].astype(jnp.float32) * inv  # [..., S, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if x.ndim == ang.ndim + 1:                          # heads axis present
        cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
