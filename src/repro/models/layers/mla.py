"""Multi-head Latent Attention (DeepSeek-V2) sublayer.

Caches the *compressed* latent ``c_kv`` (+ the shared rope key), which is
the paper-faithful MLA memory win: cache bytes per token are
``kv_lora_rank + rope_head_dim`` instead of ``2 * H * Dh``.

Prefill/train decompress to per-head K/V and call the flash path ("naive"
MLA).  Decode decompresses from the latent cache on the fly; the absorbed
formulation (folding W_uk into the query) is a recorded perf-iteration
candidate in EXPERIMENTS.md section Perf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.attention import ops as attn_ops
from repro.kernels.decode_attention import ops as dec_ops
from repro.models import init_utils as iu
from repro.models.config import ModelConfig
from repro.models.context import Ctx
from repro.models.layers import norms, rope as rope_mod


def init(key, cfg: ModelConfig):
    m = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    q_in = m.q_lora_rank or D
    pairs = {
        "w_dkv": iu.dense(ks[0], (D, m.kv_lora_rank + m.rope_head_dim),
                          ("fsdp", None)),
        "w_uk": iu.dense(ks[1], (m.kv_lora_rank, H, m.nope_head_dim),
                         (None, "tp", None)),
        "w_uv": iu.dense(ks[2], (m.kv_lora_rank, H, m.v_head_dim),
                         (None, "tp", None)),
        "wq": iu.dense(ks[3], (q_in, H, m.nope_head_dim + m.rope_head_dim),
                       ("fsdp", "tp", None)),
        "wo": iu.dense(ks[4], (H, m.v_head_dim, D), ("tp", None, "fsdp"),
                       scale=1.0 / (H * m.v_head_dim) ** 0.5),
    }
    if m.q_lora_rank:
        pairs["w_dq"] = iu.dense(ks[5], (D, m.q_lora_rank), ("fsdp", None))
    params, specs = iu.split_tree(pairs)
    np_, ns = norms.init(key, m.kv_lora_rank)
    params["kv_norm"], specs["kv_norm"] = np_, ns
    return params, specs


def state_spec(cfg: ModelConfig, batch: int, cache_len: int):
    m = cfg.mla
    return {
        "c_kv": ((batch, cache_len, m.kv_lora_rank), jnp.bfloat16,
                 ("act_batch", "kv_seq", None)),
        "k_rope": ((batch, cache_len, m.rope_head_dim), jnp.bfloat16,
                   ("act_batch", "kv_seq", None)),
    }


def _latent(p, x, ctx, cd):
    m_cfg = p["w_dkv"].shape
    del m_cfg
    dkv = jnp.einsum("bsd,dr->bsr", x.astype(cd), p["w_dkv"].astype(cd))
    lora = p["w_uk"].shape[0]
    c_kv, k_rope = dkv[..., :lora], dkv[..., lora:]
    c_kv = norms.apply(p["kv_norm"], c_kv)
    k_rope = rope_mod.apply_rope(k_rope, ctx.positions)  # [B,S,rope_dim]
    return c_kv, k_rope


def _queries(p, x, ctx, cd, rope_dim):
    q_in = x.astype(cd)
    if "w_dq" in p:
        q_in = jnp.einsum("bsd,dr->bsr", q_in, p["w_dq"].astype(cd))
    q = jnp.einsum("bsr,rhk->bshk", q_in, p["wq"].astype(cd))
    q_nope, q_rope = q[..., :-rope_dim], q[..., -rope_dim:]
    q_rope = rope_mod.apply_rope(q_rope, ctx.positions)
    return jnp.concatenate([q_nope, q_rope], axis=-1)


def _decompress(p, c_kv, k_rope, cd):
    """latents -> per-head K (nope||rope) and V."""
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv.astype(cd), p["w_uk"].astype(cd))
    v = jnp.einsum("bsr,rhk->bshk", c_kv.astype(cd), p["w_uv"].astype(cd))
    H = k_nope.shape[2]
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :].astype(cd),
                                k_nope.shape[:3] + (k_rope.shape[-1],))
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    return k, v


def apply(p, x, state, ctx: Ctx, *, cfg: ModelConfig):
    m = cfg.mla
    cd = ctx.cdtype
    B = x.shape[0]
    q = _queries(p, x, ctx, cd, m.rope_head_dim)

    if ctx.phase == "decode":
        c_new, kr_new = _latent(p, x, ctx, cd)
        b = jnp.arange(B)
        c_cache = state["c_kv"].at[b, ctx.cur_index].set(
            c_new[:, 0].astype(state["c_kv"].dtype))
        kr_cache = state["k_rope"].at[b, ctx.cur_index].set(
            kr_new[:, 0].astype(state["k_rope"].dtype))
        k, v = _decompress(p, c_cache, kr_cache, cd)
        y = dec_ops.decode_attend(q, k, v, ctx.cur_index + 1)
        new_state = {"c_kv": c_cache, "k_rope": kr_cache}
    else:
        c_kv, k_rope = _latent(p, x, ctx, cd)
        k, v = _decompress(p, c_kv, k_rope, cd)
        y = attn_ops.mha(q, k, v, causal=True)
        if ctx.phase == "prefill":
            pad = ctx.cache_len - c_kv.shape[1]
            new_state = {
                "c_kv": jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))
                                ).astype(jnp.bfloat16),
                "k_rope": jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0))
                                  ).astype(jnp.bfloat16),
            }
        else:
            new_state = None

    out = jnp.einsum("bshk,hkd->bsd", y.astype(cd), p["wo"].astype(cd))
    return ctx.constrain(out, ("act_batch", "act_seq", None)), new_state
