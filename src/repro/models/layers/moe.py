"""Fine-grained MoE sublayer (DeepSeekMoE: shared + routed top-k experts).

Dispatch is *sort-based with fixed expert capacity*: tokens are routed to
``top_k`` experts; per-expert buffers have static capacity
``ceil(T*K/E * capacity_factor)`` and tokens beyond capacity are dropped —
deliberately the same bounded-queue overflow semantics the Muppet engine
uses for event routing (DESIGN.md section 2).  Experts are sharded over the
``tp`` ("model") mesh axis (expert parallelism); the token->expert shuffle
lowers to all-to-all style collectives under GSPMD.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import init_utils as iu
from repro.models.config import ModelConfig
from repro.models.context import Ctx
from repro.models.layers import ffn


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def init(key, cfg: ModelConfig):
    m = cfg.moe
    D = cfg.d_model
    ks = jax.random.split(key, 5)
    params, specs = iu.split_tree({
        "router": iu.dense(ks[0], (D, m.n_routed_experts), (None, None),
                           scale=0.02),
        "w_gate": iu.dense(ks[1], (m.n_routed_experts, D, m.d_expert),
                           ("tp", "fsdp", None)),
        "w_in": iu.dense(ks[2], (m.n_routed_experts, D, m.d_expert),
                         ("tp", "fsdp", None)),
        "w_out": iu.dense(ks[3], (m.n_routed_experts, m.d_expert, D),
                          ("tp", None, "fsdp"), scale=1.0 / m.d_expert ** 0.5),
    })
    if m.n_shared_experts:
        sp, ss = ffn.init(ks[4], D, m.n_shared_experts * m.d_expert)
        params["shared"], specs["shared"] = sp, ss
    return params, specs


def apply(p, x, ctx: Ctx, *, cfg: ModelConfig):
    if ctx.mesh is not None and _sharded_ok(cfg, ctx):
        return apply_sharded(p, x, ctx, cfg=cfg)
    return _apply_global(p, x, ctx, cfg=cfg)


def _apply_global(p, x, ctx: Ctx, *, cfg: ModelConfig):
    m = cfg.moe
    cd = ctx.cdtype
    B, S, D = x.shape
    T = B * S
    K, E = m.top_k, m.n_routed_experts
    xt = x.reshape(T, D)

    # ---- routing ----
    logits = xt.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # [T,E]
    gate, expert_ids = jax.lax.top_k(probs, K)                  # [T,K]
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)         # renorm (DS)

    # load-balance aux loss (Switch-style: E * sum_e f_e * p_e)
    assign = jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32)
    frac = jnp.mean(assign, axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    aux = m.router_aux_coef * E * jnp.sum(frac * mean_prob)

    # ---- sort-based dispatch with fixed capacity ----
    cap = min(_round_up(max(int(T * K / E * m.capacity_factor), 1), 8), T * K)
    flat_e = expert_ids.reshape(-1)                             # [T*K]
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_w = gate.reshape(-1)
    order = jnp.argsort(flat_e)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    # rank within each expert run (queue position)
    pos = jnp.arange(T * K) - jnp.searchsorted(se, se, side="left")
    slot = se * cap + pos
    valid = pos < cap                                           # overflow drop
    slot_safe = jnp.where(valid, slot, E * cap)                 # OOB -> dropped

    buf = jnp.zeros((E * cap, D), cd).at[slot_safe].set(
        xt[st].astype(cd), mode="drop")
    buf = buf.reshape(E, cap, D)
    buf = ctx.constrain(buf, ("experts", None, None))

    # ---- expert FFN (gated) ----
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(cd)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["w_in"].astype(cd))
    h = ctx.constrain(h, ("experts", None, None))
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(cd))

    # ---- combine ----
    flat_out = out_e.reshape(E * cap, D)
    contrib = flat_out[jnp.where(valid, slot, 0)]
    contrib = contrib * (sw * valid)[:, None].astype(cd)
    y = jax.ops.segment_sum(contrib, st, num_segments=T)

    if "shared" in p:
        y = y + ffn.apply(p["shared"], xt[None], ctx, act="silu")[0]
    return y.reshape(B, S, D).astype(x.dtype), aux


# --------------------------------------------------------------------------
# explicit expert-parallel dispatch (shard_map)
#
# GSPMD auto-sharding of the global sort-based dispatch degenerates into
# replicated token gathers at pod scale (measured: the deepseek train_4k
# cell was collective-dominated at ~125 s/step, 237 GB/device peak —
# EXPERIMENTS.md section Perf).  This path keeps routing LOCAL to each
# (pod, data, seq) token shard and moves tokens to their expert owners on
# the "model" axis with one all_to_all each way — the same
# bucket-exchange the Muppet engine uses for event routing
# (core/distributed.exchange), applied to MoE tokens.
# --------------------------------------------------------------------------


def _sharded_ok(cfg: ModelConfig, ctx: Ctx) -> bool:
    m = cfg.moe
    rules = ctx.rules or {}
    tp = rules.get("experts", ())
    if tp != ("model",):
        return False
    tp_size = int(ctx.mesh.shape["model"])
    return m.n_routed_experts % tp_size == 0 and ctx.phase != "decode"


def _round_up_i(x: int, mult: int) -> int:
    return (x + mult - 1) // mult * mult


def apply_sharded(p, x, ctx: Ctx, *, cfg: ModelConfig):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    cd = ctx.cdtype
    B, S, D = x.shape
    K, E = m.top_k, m.n_routed_experts
    mesh = ctx.mesh
    rules = ctx.rules
    fsdp = rules.get("act_batch", ())
    seq_ax = rules.get("act_seq", ())
    tp = "model"
    M = int(mesh.shape[tp])
    E_loc = E // M

    b_shard = fsdp if B % max(_ax(mesh, fsdp), 1) == 0 and fsdp else ()
    s_shard = seq_ax if seq_ax and S % _ax(mesh, seq_ax) == 0 else ()
    B_loc = B // max(_ax(mesh, b_shard), 1)
    S_loc = S // max(_ax(mesh, s_shard), 1)
    T_loc = B_loc * S_loc
    cap_send = _round_up_i(max(int(T_loc * K / M * m.capacity_factor), 8),
                           8)
    cap_exp = _round_up_i(max(int(M * cap_send // E_loc), 8), 8)

    def ent(axes):
        return None if not axes else (axes if len(axes) > 1 else axes[0])

    x_spec = P(ent(b_shard), ent(s_shard), None)

    def local_moe(xl, router, wg, wi, wo):
        # xl: [B_loc, S_loc, D]; wg/wi: [E_loc, D_loc, F]; wo: [E_loc, F, D_loc]
        wg_f = jax.lax.all_gather(wg, fsdp, axis=1, tiled=True) \
            if fsdp else wg
        wi_f = jax.lax.all_gather(wi, fsdp, axis=1, tiled=True) \
            if fsdp else wi
        wo_f = jax.lax.all_gather(wo, fsdp, axis=3 - 1, tiled=True) \
            if fsdp else wo

        xt = xl.reshape(T_loc, D)
        logits = xt.astype(jnp.float32) @ router.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, expert_ids = jax.lax.top_k(probs, K)         # [T_loc, K]
        gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

        assign = jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32)
        frac = jnp.mean(assign, axis=0)
        mean_prob = jnp.mean(probs, axis=0)
        aux = m.router_aux_coef * E * jnp.sum(frac * mean_prob)
        aux = jax.lax.pmean(jax.lax.pmean(aux, tp),
                            fsdp) if fsdp else jax.lax.pmean(aux, tp)

        # ---- bucket by destination model-shard (expert owner) ----
        flat_e = expert_ids.reshape(-1)                    # [T_loc*K]
        flat_t = jnp.repeat(jnp.arange(T_loc), K)
        flat_w = gate.reshape(-1).astype(jnp.float32)
        dest = flat_e // E_loc
        order = jnp.argsort(dest, stable=True)
        sdest, se, st, sw = (dest[order], flat_e[order], flat_t[order],
                             flat_w[order])
        pos = jnp.arange(T_loc * K, dtype=jnp.int32) - jnp.searchsorted(
            sdest, sdest, side="left").astype(jnp.int32)
        ok = pos < cap_send
        slot = jnp.where(ok, sdest * cap_send + pos, M * cap_send)

        send_x = jnp.zeros((M * cap_send, D), cd).at[slot].set(
            xt[st].astype(cd), mode="drop")
        send_e = jnp.full((M * cap_send,), -1, jnp.int32).at[slot].set(
            se.astype(jnp.int32) % E_loc, mode="drop")

        def a2a(v):
            return jax.lax.all_to_all(
                v.reshape((M, cap_send) + v.shape[1:]), tp, 0, 0,
                tiled=False).reshape((M * cap_send,) + v.shape[1:])

        recv_x = a2a(send_x)                               # [M*cap, D]
        recv_e = a2a(send_e)

        # ---- local expert FFN (sort by local expert id) ----
        e_sink = jnp.where(recv_e >= 0, recv_e, E_loc)
        order2 = jnp.argsort(e_sink, stable=True)
        re, rx = e_sink[order2], recv_x[order2]
        pos2 = jnp.arange(M * cap_send, dtype=jnp.int32) - \
            jnp.searchsorted(re, re, side="left").astype(jnp.int32)
        ok2 = (re < E_loc) & (pos2 < cap_exp)
        slot2 = jnp.where(ok2, re * cap_exp + pos2, E_loc * cap_exp)
        buf = jnp.zeros((E_loc * cap_exp, D), cd).at[slot2].set(
            rx, mode="drop").reshape(E_loc, cap_exp, D)

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg_f.astype(cd)))
        h = h * jnp.einsum("ecd,edf->ecf", buf, wi_f.astype(cd))
        out_e = jnp.einsum("ecf,efd->ecd", h, wo_f.astype(cd))

        # ---- undo expert sort, a2a back, combine ----
        flat_out = out_e.reshape(E_loc * cap_exp, D)
        back = jnp.zeros((M * cap_send, D), cd).at[order2].set(
            flat_out[jnp.where(ok2, slot2, 0)] *
            ok2[:, None].astype(cd), mode="drop")
        ret = a2a(back)                                    # token order

        contrib = ret[jnp.where(ok, slot, 0)] * \
            (sw * ok).astype(cd)[:, None]
        y = jax.ops.segment_sum(contrib, st, num_segments=T_loc)
        return y.reshape(B_loc, S_loc, D).astype(xl.dtype), aux

    y, aux = shard_map(
        local_moe, mesh=mesh,
        in_specs=(x_spec, P(None, None), P(tp, fsdp or None, None),
                  P(tp, fsdp or None, None), P(tp, None, fsdp or None)),
        out_specs=(x_spec, P()),
        check_rep=False,
    )(x, p["router"], p["w_gate"], p["w_in"], p["w_out"])

    if "shared" in p:
        y = y + ffn.apply(p["shared"], x, ctx, act="silu")
    return y.astype(x.dtype), aux


def _ax(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= int(mesh.shape[a])
    return n
