"""RMSNorm (optionally Gemma-style ``(1 + w)`` scaling)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import init_utils as iu


def init(key, d: int, *, scale_offset: bool = False):
    del key
    if scale_offset:  # gemma stores w and applies (1 + w)
        return iu.split_tree({"scale": iu.zeros((d,), (None,))})
    return iu.split_tree({"scale": iu.ones((d,), (None,))})


def apply(params, x, *, eps: float = 1e-6, scale_offset: bool = False):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + eps)
    w = params["scale"].astype(jnp.float32)
    w = (1.0 + w) if scale_offset else w
    return (xf * w).astype(dt)
