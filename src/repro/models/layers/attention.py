"""GQA self/cross attention sublayer (train / prefill / decode phases).

State protocol (threaded by the scan stack):
  - train:    state None -> None
  - prefill:  state None -> {"k": [B,Smax,Hkv,Dh], "v": ...} (padded caches)
  - decode:   caches in -> caches with the new token written at
              ``ctx.cur_index`` (per-request write index, continuous
              batching: the cache is this request's *slate*).
Cross-attention caches the projected source k/v once (computed at prefill,
reused every decode step).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.attention import ops as attn_ops
from repro.kernels.decode_attention import ops as dec_ops
from repro.models import init_utils as iu
from repro.models.config import ModelConfig
from repro.models.context import Ctx
from repro.models.layers import rope as rope_mod


def init(key, cfg: ModelConfig, *, is_cross: bool = False):
    D = cfg.d_model
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    if is_cross:
        Hkv = H  # cross layers use full-head kv in the assigned archs
    ks = jax.random.split(key, 4)
    pairs = {
        "wq": iu.dense(ks[0], (D, H, Dh), ("fsdp", "tp", None)),
        "wk": iu.dense(ks[1], (D, Hkv, Dh), ("fsdp", "tp", None)),
        "wv": iu.dense(ks[2], (D, Hkv, Dh), ("fsdp", "tp", None)),
        "wo": iu.dense(ks[3], (H, Dh, D), ("tp", None, "fsdp"),
                       scale=1.0 / (H * Dh) ** 0.5),
    }
    if cfg.qkv_bias and not is_cross:
        pairs["bq"] = iu.zeros((H, Dh), ("tp", None))
        pairs["bk"] = iu.zeros((Hkv, Dh), ("tp", None))
        pairs["bv"] = iu.zeros((Hkv, Dh), ("tp", None))
    return iu.split_tree(pairs)


def state_spec(cfg: ModelConfig, batch: int, cache_len: int,
               *, is_cross: bool = False, source_len: int = 0):
    """Pytree of (shape, dtype, logical spec) for the decode-time cache."""
    Hkv = cfg.n_heads if is_cross else cfg.n_kv_heads
    Dh = cfg.resolved_head_dim
    slen = source_len if is_cross else cache_len
    sh = (batch, slen, Hkv, Dh)
    spec = ("act_batch", "kv_seq", "kv_heads", None)
    return {"k": (sh, jnp.bfloat16, spec), "v": (sh, jnp.bfloat16, spec)}


def _proj_qkv(p, x, kv_src, cd):
    q = jnp.einsum("bsd,dhk->bshk", x.astype(cd), p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", kv_src.astype(cd), p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", kv_src.astype(cd), p["wv"].astype(cd))
    if "bq" in p:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    return q, k, v


def _write_cache(cache, new, idx):
    """Write new [B,1,H,D] at per-request position idx [B]."""
    b = jnp.arange(cache.shape[0])
    return cache.at[b, idx].set(new[:, 0].astype(cache.dtype))


def apply(p, x, state, ctx: Ctx, *, cfg: ModelConfig, causal: bool = True,
          window: int = 0, is_cross: bool = False, cross_source: str = "",
          rope_theta: Optional[float] = None):
    cd = ctx.cdtype
    theta = rope_theta if rope_theta is not None else cfg.rope_theta
    B = x.shape[0]

    if is_cross:
        if ctx.is_decode and state is not None:
            q = jnp.einsum("bsd,dhk->bshk", x.astype(cd), p["wq"].astype(cd))
            k, v = state["k"], state["v"]
            src_len = k.shape[1]
            y = dec_ops.decode_attend(
                q, k, v, jnp.full((B,), src_len, jnp.int32))
            new_state = state
        else:
            src = ctx.image_embeds if cross_source == "image" else ctx.enc_memory
            q, k, v = _proj_qkv(p, x, src, cd)
            y = attn_ops.mha(q, k, v, causal=False)
            new_state = {"k": k.astype(jnp.bfloat16),
                         "v": v.astype(jnp.bfloat16)}
        out = jnp.einsum("bshk,hkd->bsd", y.astype(cd), p["wo"].astype(cd))
        out = ctx.constrain(out, ("act_batch", "act_seq", None))
        return out, new_state

    q, k, v = _proj_qkv(p, x, x, cd)
    q = ctx.constrain(q, ("act_batch", None, "heads", None))
    k = ctx.constrain(k, ("act_batch", None, "kv_heads", None))
    positions = ctx.positions
    q = rope_mod.apply_rope(q, positions, theta=theta)
    k = rope_mod.apply_rope(k, positions, theta=theta)

    if ctx.phase == "decode":
        kc = _write_cache(state["k"], k, ctx.cur_index)
        vc = _write_cache(state["v"], v, ctx.cur_index)
        lengths = ctx.cur_index + 1
        y = dec_ops.decode_attend(q, kc, vc, lengths, window=window)
        new_state = {"k": kc, "v": vc}
    else:
        y = attn_ops.mha(q, k, v, causal=causal, window=window)
        if ctx.phase == "prefill":
            pad = ctx.cache_len - k.shape[1]
            padded = lambda t: jnp.pad(
                t, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.bfloat16)
            new_state = {"k": padded(k), "v": padded(v)}
        else:
            new_state = None

    out = jnp.einsum("bshk,hkd->bsd", y.astype(cd), p["wo"].astype(cd))
    out = ctx.constrain(out, ("act_batch", "act_seq", None))
    return out, new_state
