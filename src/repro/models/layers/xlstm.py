"""xLSTM blocks: mLSTM (matrix memory, chunked-parallel) and sLSTM
(scalar memory, strictly recurrent).

mLSTM uses the shared SSD primitive: state C_t = f_t C + i_t k v^T with a
normalizer row folded in as an extra value channel (sigmoid input gate —
the non-stabilized variant used by xLSTM-7B).  sLSTM keeps the exponential
gating + (c, n, m) stabilizer of the paper and runs as a lax.scan over
time (hidden-to-hidden recurrence is not associative).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd import ops as ssd_ops
from repro.models import init_utils as iu
from repro.models.config import ModelConfig
from repro.models.context import Ctx
from repro.models.layers import norms

# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------


def _mdims(cfg: ModelConfig):
    x = cfg.xlstm
    d_inner = x.mlstm_expand * cfg.d_model
    H = cfg.n_heads
    N = d_inner // H
    return x, d_inner, H, N


def mlstm_init(key, cfg: ModelConfig):
    x, d_inner, H, N = _mdims(cfg)
    D = cfg.d_model
    ks = jax.random.split(key, 8)
    params, specs = iu.split_tree({
        "w_up": iu.dense(ks[0], (D, 2 * d_inner), ("fsdp", "tp")),
        "conv_w": iu.dense(ks[1], (x.conv_width, d_inner), (None, "tp"),
                           scale=1.0 / x.conv_width ** 0.5),
        "conv_b": iu.zeros((d_inner,), ("tp",)),
        "w_q": iu.dense(ks[2], (d_inner, H, N), ("tp", None, None)),
        "w_k": iu.dense(ks[3], (d_inner, H, N), ("tp", None, None)),
        "w_v": iu.dense(ks[4], (d_inner, H, N), ("tp", None, None)),
        "w_gates": iu.dense(ks[5], (d_inner, 2 * H), ("tp", None),
                            scale=0.02),
        "gate_bias": iu.ones((2 * H,), (None,)),
        "w_down": iu.dense(ks[6], (d_inner, D), ("tp", "fsdp"),
                           scale=1.0 / d_inner ** 0.5),
    })
    np_, ns = norms.init(ks[7], d_inner)
    params["norm"], specs["norm"] = np_, ns
    return params, specs


def mlstm_state_spec(cfg: ModelConfig, batch: int, cache_len: int):
    x, d_inner, H, N = _mdims(cfg)
    del cache_len
    return {
        "conv": ((batch, x.conv_width - 1, d_inner), jnp.float32,
                 ("act_batch", None, "tp")),
        "mem": ((batch, H, N, N + 1), jnp.float32,
                ("act_batch", "heads", None, None)),
    }


def _conv_causal(xin, w, b):
    W = w.shape[0]
    out = xin * w[W - 1]
    for i in range(1, W):
        shifted = jnp.pad(xin, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * w[W - 1 - i]
    return jax.nn.silu(out + b)


def mlstm_apply(p, x, state, ctx: Ctx, *, cfg: ModelConfig):
    xc_cfg, d_inner, H, N = _mdims(cfg)
    cd = ctx.cdtype
    B, S, _ = x.shape
    up = jnp.einsum("bsd,de->bse", x.astype(cd), p["w_up"].astype(cd))
    xin, z = up[..., :d_inner], up[..., d_inner:]
    w, b = p["conv_w"].astype(cd), p["conv_b"].astype(cd)

    if ctx.is_decode:
        hist = jnp.concatenate([state["conv"].astype(cd), xin], axis=1)
        xcv = jax.nn.silu(jnp.einsum("bwc,wc->bc", hist, w) + b)[:, None]
        new_conv = hist[:, 1:].astype(jnp.float32)
    else:
        xcv = _conv_causal(xin, w, b)
        new_conv = (xin[:, S - (xc_cfg.conv_width - 1):, :]
                    .astype(jnp.float32) if ctx.phase == "prefill" else None)

    q = jnp.einsum("bse,ehn->bshn", xcv, p["w_q"].astype(cd))
    k = jnp.einsum("bse,ehn->bshn", xcv, p["w_k"].astype(cd)) * (N ** -0.5)
    v = jnp.einsum("bse,ehn->bshn", xin, p["w_v"].astype(cd))
    gates = jnp.einsum("bse,eh->bsh", xcv,
                       p["w_gates"].astype(cd)).astype(jnp.float32) \
        + p["gate_bias"].astype(jnp.float32)
    i_gate = jax.nn.sigmoid(gates[..., :H])              # [B,S,H]
    log_f = jax.nn.log_sigmoid(gates[..., H:])           # [B,S,H]

    k_in = k * i_gate[..., None].astype(cd)
    ones = jnp.ones(v.shape[:-1] + (1,), v.dtype)
    v_aug = jnp.concatenate([v, ones], axis=-1)          # normalizer channel

    if ctx.is_decode:
        mem, y_aug = ssd_ops.ssd_step(state["mem"], q[:, 0], k_in[:, 0],
                                      v_aug[:, 0], log_f[:, 0])
        y_aug = y_aug[:, None]
        new_state = {"conv": new_conv, "mem": mem}
    else:
        y_aug, final = ssd_ops.ssd(q, k_in, v_aug, log_f, chunk=xc_cfg.chunk)
        new_state = ({"conv": new_conv, "mem": final}
                     if ctx.phase == "prefill" else None)

    num = y_aug[..., :N].astype(jnp.float32)
    den = y_aug[..., N:].astype(jnp.float32)
    h = num / jnp.maximum(jnp.abs(den), 1.0)
    h = h.reshape(B, -1, d_inner).astype(cd)
    h = norms.apply(p["norm"], h, eps=cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", h.astype(cd), p["w_down"].astype(cd))
    return ctx.constrain(out, ("act_batch", "act_seq", None)), new_state


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------


def slstm_init(key, cfg: ModelConfig):
    x = cfg.xlstm
    D = cfg.d_model
    dp = int(D * x.slstm_proj)
    ks = jax.random.split(key, 5)
    params, specs = iu.split_tree({
        "w_x": iu.dense(ks[0], (D, 4 * D), ("fsdp", "tp")),
        "w_h": iu.dense(ks[1], (D, 4 * D), ("fsdp", "tp")),
        "bias": iu.zeros((4 * D,), ("tp",)),
        "w_ff1": iu.dense(ks[2], (D, dp), ("fsdp", "tp")),
        "w_ff2": iu.dense(ks[3], (dp, D), ("tp", "fsdp"),
                          scale=1.0 / dp ** 0.5),
    })
    np_, ns = norms.init(ks[4], D)
    params["norm"], specs["norm"] = np_, ns
    return params, specs


def slstm_state_spec(cfg: ModelConfig, batch: int, cache_len: int):
    D = cfg.d_model
    del cache_len
    sp = ("act_batch", None)
    return {
        "h": ((batch, D), jnp.float32, sp),
        "c": ((batch, D), jnp.float32, sp),
        "n": ((batch, D), jnp.float32, sp),
        "m": ((batch, D), jnp.float32, sp),
    }


def _slstm_cell_from_gx(w_h, carry, gx_t):
    """One sLSTM step with exponential gating + stabilizer (paper eq. 19).

    ``gx_t = x_t @ w_x + bias`` is precomputed OUTSIDE the scan (one
    parallel matmul over the whole sequence): the recurrence only does
    the h-dependent half, so the per-step HBM traffic is one w_h read
    instead of (w_x + w_h + a sequence-buffer slice) — the dominant
    term of the xlstm train cell in EXPERIMENTS.md section Perf.
    """
    h, c, n, m = carry
    g = gx_t.astype(jnp.float32) + (h @ w_h).astype(jnp.float32)
    i_raw, f_raw, z_raw, o_raw = jnp.split(g, 4, axis=-1)
    log_f = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(log_f + m, i_raw)
    i_p = jnp.exp(i_raw - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    c_new = f_p * c + i_p * jnp.tanh(z_raw)
    n_new = f_p * n + i_p
    h_new = jax.nn.sigmoid(o_raw) * c_new / jnp.maximum(n_new, 1e-6)
    return (h_new.astype(gx_t.dtype), c_new, n_new, m_new)


def slstm_apply(p, x, state, ctx: Ctx, *, cfg: ModelConfig):
    cd = ctx.cdtype
    B, S, D = x.shape
    if state is None:
        zero = jnp.zeros((B, D), jnp.float32)
        carry = (zero, zero, zero, zero)
    else:
        carry = (state["h"], state["c"], state["n"], state["m"])

    xn = norms.apply(p["norm"], x, eps=cfg.norm_eps)
    # x-side gates: one parallel matmul over the whole sequence
    gx = jnp.einsum("bsd,de->bse", xn.astype(cd), p["w_x"].astype(cd)) \
        + p["bias"].astype(cd)
    w_h = p["w_h"].astype(cd)
    # carry h in compute dtype so the per-step matmul stays bf16
    carry = (carry[0].astype(cd),) + carry[1:]

    if ctx.is_decode:
        carry = _slstm_cell_from_gx(w_h, carry, gx[:, 0])
        h_seq = carry[0][:, None]
    else:
        def body(cr, gx_t):
            cr = _slstm_cell_from_gx(w_h, cr, gx_t)
            return cr, cr[0]
        carry, h_seq = jax.lax.scan(body, carry, gx.swapaxes(0, 1))
        h_seq = h_seq.swapaxes(0, 1)                      # [B,S,D]

    new_state = ({"h": carry[0].astype(jnp.float32), "c": carry[1],
                  "n": carry[2], "m": carry[3]}
                 if ctx.phase in ("prefill", "decode") else None)

    h_seq = h_seq.astype(cd)
    ff = jax.nn.gelu(h_seq @ p["w_ff1"].astype(cd), approximate=True)
    out = ff @ p["w_ff2"].astype(cd)
    return ctx.constrain(out, ("act_batch", "act_seq", None)), new_state
