"""Gated feed-forward sublayer (SwiGLU / GeGLU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import init_utils as iu
from repro.models.context import Ctx


def _act(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(f"unknown activation {name!r}")


def init(key, d_model: int, d_ff: int):
    ks = jax.random.split(key, 3)
    return iu.split_tree({
        "w_gate": iu.dense(ks[0], (d_model, d_ff), ("fsdp", "tp")),
        "w_in": iu.dense(ks[1], (d_model, d_ff), ("fsdp", "tp")),
        "w_out": iu.dense(ks[2], (d_ff, d_model), ("tp", "fsdp"),
                          scale=1.0 / d_ff ** 0.5),
    })


def apply(p, x, ctx: Ctx, *, act: str = "silu"):
    cd = ctx.cdtype
    xc = x.astype(cd)
    h = _act(act)(xc @ p["w_gate"].astype(cd)) * (xc @ p["w_in"].astype(cd))
    h = ctx.constrain(h, ("act_batch", None, "ffn"))
    out = h @ p["w_out"].astype(cd)
    return ctx.constrain(out, ("act_batch", "act_seq", None))
