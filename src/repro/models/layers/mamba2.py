"""Mamba-2 (SSD) mixer block.

in_proj -> [z | xBC | dt]; causal depthwise conv over xBC; SSD linear
recurrence via the shared chunked primitive (``kernels/ssd``); gated
RMSNorm; out_proj.  Decode threads (conv_state, ssd_state) — for the
hybrid/SSM archs this *is* the per-request slate in the serving layer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd import ops as ssd_ops
from repro.models import init_utils as iu
from repro.models.config import ModelConfig
from repro.models.context import Ctx
from repro.models.layers import norms


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    d_conv_ch = d_inner + 2 * s.state_dim  # conv runs over [x|B|C]
    return s, d_inner, n_heads, d_conv_ch


def init(key, cfg: ModelConfig):
    s, d_inner, H, conv_ch = _dims(cfg)
    D = cfg.d_model
    ks = jax.random.split(key, 4)
    proj_out = d_inner + conv_ch + H  # z | xBC | dt
    params, specs = iu.split_tree({
        "in_proj": iu.dense(ks[0], (D, proj_out), ("fsdp", "tp")),
        "conv_w": iu.dense(ks[1], (s.d_conv, conv_ch), (None, "tp"),
                           scale=1.0 / s.d_conv ** 0.5),
        "conv_b": iu.zeros((conv_ch,), ("tp",)),
        "dt_bias": iu.zeros((H,), ("tp",)),
        "a_log": iu.ones((H,), ("tp",)),
        "d_skip": iu.ones((H,), ("tp",)),
        "out_proj": iu.dense(ks[2], (d_inner, D), ("tp", "fsdp"),
                             scale=1.0 / d_inner ** 0.5),
    })
    np_, ns = norms.init(ks[3], d_inner)
    params["norm"], specs["norm"] = np_, ns
    return params, specs


def state_spec(cfg: ModelConfig, batch: int, cache_len: int):
    s, d_inner, H, conv_ch = _dims(cfg)
    del cache_len  # SSM state is O(1) in sequence length
    return {
        "conv": ((batch, s.d_conv - 1, conv_ch), jnp.float32,
                 ("act_batch", None, "tp")),
        "ssd": ((batch, H, s.state_dim, s.head_dim), jnp.float32,
                ("act_batch", "heads", None, None)),
    }


def _conv_full(xbc, w, b):
    """Causal depthwise conv, width W, via shifted adds. xbc: [B,S,C]."""
    W = w.shape[0]
    out = xbc * w[W - 1]
    for i in range(1, W):
        shifted = jnp.pad(xbc, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * w[W - 1 - i]
    return jax.nn.silu(out + b)


def _split(cfg, zxd, d_inner, conv_ch):
    z = zxd[..., :d_inner]
    xbc = zxd[..., d_inner:d_inner + conv_ch]
    dt_raw = zxd[..., d_inner + conv_ch:]
    return z, xbc, dt_raw


def apply(p, x, state, ctx: Ctx, *, cfg: ModelConfig):
    s, d_inner, H, conv_ch = _dims(cfg)
    cd = ctx.cdtype
    B, S, _ = x.shape
    N, P = s.state_dim, s.head_dim

    zxd = jnp.einsum("bsd,de->bse", x.astype(cd), p["in_proj"].astype(cd))
    z, xbc, dt_raw = _split(cfg, zxd, d_inner, conv_ch)
    w = p["conv_w"].astype(cd)
    b = p["conv_b"].astype(cd)

    if ctx.is_decode:
        # conv over [conv_state | new token]
        hist = jnp.concatenate([state["conv"].astype(cd), xbc], axis=1)
        xbc_c = jax.nn.silu(jnp.einsum("bwc,wc->bc", hist, w) + b)[:, None]
        new_conv = hist[:, 1:]
    else:
        xbc_c = _conv_full(xbc, w, b)
        new_conv = xbc[:, S - (s.d_conv - 1):, :].astype(jnp.float32) \
            if ctx.phase == "prefill" else None

    xs = xbc_c[..., :d_inner].reshape(B, -1, H, P)
    Bmat = xbc_c[..., d_inner:d_inner + N]                    # [B,S,N]
    Cmat = xbc_c[..., d_inner + N:]                           # [B,S,N]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # [B,S,H]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))              # [H] < 0
    log_a = dt * a                                            # [B,S,H]

    q = jnp.broadcast_to(Cmat[:, :, None, :], (B, Cmat.shape[1], H, N))
    k = jnp.broadcast_to(Bmat[:, :, None, :], (B, Bmat.shape[1], H, N))
    v = xs * dt[..., None].astype(cd)

    if ctx.is_decode:
        ssd_state, y = ssd_ops.ssd_step(
            state["ssd"], q[:, 0], k[:, 0], v[:, 0], log_a[:, 0])
        y = y[:, None]
        new_state = {"conv": new_conv, "ssd": ssd_state}
    else:
        init_state = None
        y, final = ssd_ops.ssd(q, k, v, log_a, chunk=s.chunk,
                               initial_state=init_state)
        new_state = ({"conv": new_conv, "ssd": final}
                     if ctx.phase == "prefill" else None)

    y = y + p["d_skip"].astype(cd)[None, None, :, None] * xs
    y = y.reshape(B, -1, d_inner)
    y = norms.apply(p["norm"], y * jax.nn.silu(z), eps=cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y.astype(cd), p["out_proj"].astype(cd))
    return ctx.constrain(out, ("act_batch", "act_seq", None)), new_state
