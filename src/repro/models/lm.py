"""Model-level API: init / forward / loss / prefill / decode for every
assigned architecture.

``Model`` wraps the per-arch StackPlan(s).  The language-model head uses a
sequence-chunked cross-entropy (lax.scan + checkpoint) so the [B,S,V]
logits tensor is never resident — at qwen1.5-110b train_4k the full-logit
tensor would be ~640 GB in f32.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import init_utils as iu
from repro.models import transformer
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.context import Ctx
from repro.models.layers import norms
from repro.models.stack import (StackPlan, apply_stack, init_stack,
                                init_states, specs_of)


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    plan: StackPlan
    enc_plan: Optional[StackPlan] = None


def build(cfg: ModelConfig) -> Model:
    return Model(cfg=cfg, plan=transformer.build_plan(cfg),
                 enc_plan=transformer.build_encoder_plan(cfg))


# --------------------------------------------------------------------------
# params
# --------------------------------------------------------------------------

def init(model: Model, key) -> tuple:
    cfg = model.cfg
    ks = jax.random.split(key, 6)
    embed, embed_spec = iu.dense(ks[0], (cfg.vocab_size, cfg.d_model),
                                 ("tp", "fsdp"), scale=0.02)
    body, body_specs = init_stack(ks[1], model.plan)
    fn, fns = norms.init(ks[2], cfg.d_model,
                         scale_offset=cfg.norm_scale_offset)
    params = {"embed": embed, "body": body, "final_norm": fn}
    specs = {"embed": embed_spec, "body": body_specs, "final_norm": fns}
    if not cfg.tie_embeddings:
        params["head"], specs["head"] = iu.dense(
            ks[3], (cfg.d_model, cfg.vocab_size), ("fsdp", "tp"), scale=0.02)
    if model.enc_plan is not None:
        params["enc_body"], specs["enc_body"] = init_stack(
            ks[4], model.enc_plan)
        en, ens = norms.init(ks[5], cfg.d_model)
        params["enc_norm"], specs["enc_norm"] = en, ens
    return params, specs


def param_specs(model: Model, key=None):
    """Specs without materializing params (dry run)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    box = {}

    def f(k):
        p, s = init(model, k)
        box["s"] = s
        return p

    shapes = jax.eval_shape(f, key)
    return shapes, box["s"]


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _embed(model: Model, params, tokens, ctx: Ctx):
    cfg = model.cfg
    x = jnp.take(params["embed"], tokens, axis=0).astype(ctx.cdtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, ctx.cdtype)
    return ctx.constrain(x, ("act_batch", "act_seq", None))


def encode(model: Model, params, enc_frames, ctx: Ctx):
    """Whisper encoder over precomputed (stub) frame embeddings."""
    x = enc_frames.astype(ctx.cdtype)
    ectx = ctx.replace(phase="train",
                       positions=_positions(enc_frames.shape[:2]))
    x, _, _ = apply_stack(params["enc_body"], model.enc_plan, x, None, ectx,
                          remat=(ctx.phase == "train"))
    return norms.apply(params["enc_norm"], x, eps=model.cfg.norm_eps)


def _positions(bs):
    b, s = bs
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))


def forward(model: Model, params, tokens, ctx: Ctx, states=None,
            *, remat: bool = True):
    """tokens [B,S] -> (hidden [B,S,D], new_states, aux)."""
    x = _embed(model, params, tokens, ctx)
    x, new_states, aux = apply_stack(params["body"], model.plan, x, states,
                                     ctx, remat=remat)
    x = norms.apply(params["final_norm"], x, eps=model.cfg.norm_eps,
                    scale_offset=model.cfg.norm_scale_offset)
    return x, new_states, aux


def _unembed_matrix(model: Model, params):
    if model.cfg.tie_embeddings:
        return params["embed"].T  # [D, V]
    return params["head"]


def logits_for(model: Model, params, hidden, ctx: Ctx):
    w = _unembed_matrix(model, params).astype(ctx.cdtype)
    logits = jnp.einsum("bsd,dv->bsv", hidden.astype(ctx.cdtype), w)
    return ctx.constrain(logits, ("act_batch", None, "tp"))


# --------------------------------------------------------------------------
# loss (chunked cross-entropy)
# --------------------------------------------------------------------------

def lm_loss(model: Model, params, hidden, labels, ctx: Ctx,
            *, chunk: int = 512):
    """Mean next-token NLL.  hidden [B,S,D], labels [B,S] (already shifted;
    label -100 = masked)."""
    B, S, D = hidden.shape
    w = _unembed_matrix(model, params).astype(ctx.cdtype)
    pad = (-S) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)),
                         constant_values=-100)
    nc = (S + pad) // chunk
    h_blocks = hidden.reshape(B, nc, chunk, D).swapaxes(0, 1)
    y_blocks = labels.reshape(B, nc, chunk).swapaxes(0, 1)

    def body(carry, xs):
        h, y = xs
        lg = jnp.einsum("bsd,dv->bsv", h.astype(ctx.cdtype), w)
        lg = ctx.constrain(lg, ("act_batch", None, "tp"))
        lg = lg.astype(jnp.float32)
        lz = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(
            lg, jnp.maximum(y, 0)[..., None], axis=-1)[..., 0]
        mask = (y >= 0).astype(jnp.float32)
        nll = (lz - gold) * mask
        loss_sum, n_tok = carry
        return (loss_sum + nll.sum(), n_tok + mask.sum()), None

    (loss_sum, n_tok), _ = jax.lax.scan(
        jax.checkpoint(body), (jnp.zeros((), jnp.float32),
                               jnp.zeros((), jnp.float32)),
        (h_blocks, y_blocks))
    return loss_sum / jnp.maximum(n_tok, 1.0)


# --------------------------------------------------------------------------
# phase entry points
# --------------------------------------------------------------------------

def train_loss(model: Model, params, batch: Dict[str, Any], ctx: Ctx):
    """batch: tokens/labels (+ enc_frames / image_embeds)."""
    tokens = batch["tokens"]
    ctx = ctx.replace(phase="train", positions=_positions(tokens.shape))
    if model.enc_plan is not None:
        memory = encode(model, params, batch["enc_frames"], ctx)
        ctx = ctx.replace(enc_memory=memory)
    if model.cfg.cross_attn_every:
        ctx = ctx.replace(image_embeds=batch["image_embeds"]
                          .astype(ctx.cdtype))
    hidden, _, aux = forward(model, params, tokens, ctx, remat=True)
    return lm_loss(model, params, hidden, batch["labels"], ctx) + aux


def prefill(model: Model, params, batch: Dict[str, Any], ctx: Ctx,
            cache_len: int, *, full_logits: bool = False):
    tokens = batch["tokens"]
    ctx = ctx.replace(phase="prefill", positions=_positions(tokens.shape),
                      cache_len=cache_len)
    if model.enc_plan is not None:
        memory = encode(model, params, batch["enc_frames"], ctx)
        ctx = ctx.replace(enc_memory=memory)
    if model.cfg.cross_attn_every:
        ctx = ctx.replace(image_embeds=batch["image_embeds"]
                          .astype(ctx.cdtype))
    hidden, states, _ = forward(model, params, tokens, ctx, remat=False)
    sel = hidden if full_logits else hidden[:, -1:]
    return logits_for(model, params, sel, ctx), states


def decode_step(model: Model, params, token, states, cur_index, ctx: Ctx):
    """token [B,1]; cur_index [B] (write position).  Returns (logits
    [B,1,V], new_states)."""
    ctx = ctx.replace(phase="decode", positions=cur_index[:, None],
                      cur_index=cur_index,
                      cache_len=_states_cache_len(states))
    hidden, new_states, _ = forward(model, params, token, ctx, states,
                                    remat=False)
    return logits_for(model, params, hidden, ctx), new_states


def _states_cache_len(states) -> int:
    leaves = jax.tree.leaves(states)
    for lf in leaves:
        if lf.ndim >= 3:
            return int(lf.shape[2])
    return 0


def decode_states(model: Model, batch: int, cache_len: int, make_leaf):
    return init_states(model.plan, batch, cache_len, make_leaf)


# --------------------------------------------------------------------------
# abstract inputs per (arch x shape) — used by smoke tests and the dry run
# --------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract (shape, dtype) descriptions of every model input for the
    cell; values are jax.ShapeDtypeStruct (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.phase in ("train", "prefill"):
        out = {"tokens": sds((B, S), jnp.int32)}
        if shape.phase == "train":
            out["labels"] = sds((B, S), jnp.int32)
        if cfg.encdec:
            out["enc_frames"] = sds((B, S, cfg.d_model), jnp.bfloat16)
        if cfg.cross_attn_every:
            out["image_embeds"] = sds((B, cfg.n_image_tokens, cfg.d_model),
                                      jnp.bfloat16)
        return out
    # decode: one new token against a cache of S
    return {"token": sds((B, 1), jnp.int32),
            "cur_index": sds((B,), jnp.int32)}
