"""Block builders: assemble layer sublayers into scan-able BlockDefs and
per-architecture StackPlans."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import init_utils as iu
from repro.models.config import ModelConfig
from repro.models.context import Ctx
from repro.models.layers import attention, ffn, mamba2, mla, moe, norms, xlstm
from repro.models.stack import BlockDef, Segment, StackPlan

_F32_ZERO = lambda: jnp.zeros((), jnp.float32)  # noqa: E731


def _norm(cfg, p, x):
    return norms.apply(p, x, eps=cfg.norm_eps,
                       scale_offset=cfg.norm_scale_offset)


# --------------------------------------------------------------------------
# attention (+ optional cross) + ffn/moe blocks
# --------------------------------------------------------------------------

def attn_ffn_block(cfg: ModelConfig, name: str, *, causal: bool = True,
                   window: int = 0, rope_theta: Optional[float] = None,
                   use_moe: bool = False, cross: bool = False,
                   cross_source: str = "", use_extra: bool = False,
                   use_mla: bool = False, source_len: int = 0) -> BlockDef:
    attn_mod = mla if use_mla else attention

    def init(key):
        ks = jax.random.split(key, 4)
        ln1 = norms.init(ks[0], cfg.d_model,
                         scale_offset=cfg.norm_scale_offset)
        if use_mla:
            at = mla.init(ks[1], cfg)
        else:
            at = attention.init(ks[1], cfg, is_cross=cross)
        ln2 = norms.init(ks[2], cfg.d_model,
                         scale_offset=cfg.norm_scale_offset)
        mlp = moe.init(ks[3], cfg) if use_moe else \
            ffn.init(ks[3], cfg.d_model, cfg.d_ff)
        params = {"ln1": ln1[0], "attn": at[0], "ln2": ln2[0], "mlp": mlp[0]}
        specs = {"ln1": ln1[1], "attn": at[1], "ln2": ln2[1], "mlp": mlp[1]}
        return params, specs

    def apply(p, x, state, ctx: Ctx):
        h = _norm(cfg, p["ln1"], x)
        if use_mla:
            h, new_state = mla.apply(p["attn"], h, state, ctx, cfg=cfg)
        else:
            h, new_state = attention.apply(
                p["attn"], h, state, ctx, cfg=cfg, causal=causal,
                window=window, is_cross=cross, cross_source=cross_source,
                rope_theta=rope_theta)
        x = x + h
        h2 = _norm(cfg, p["ln2"], x)
        if use_moe:
            f, aux = moe.apply(p["mlp"], h2, ctx, cfg=cfg)
        else:
            f, aux = ffn.apply(p["mlp"], h2, ctx, act=cfg.act), _F32_ZERO()
        return x + f, new_state, jnp.asarray(aux, jnp.float32)

    def state_spec(batch, cache_len):
        if use_mla:
            return mla.state_spec(cfg, batch, cache_len)
        slen = source_len or cache_len
        return attention.state_spec(cfg, batch, cache_len, is_cross=cross,
                                    source_len=slen if cross else 0)

    return BlockDef(name=name, init=init, apply=apply,
                    state_spec=state_spec, use_extra=use_extra)


def encdec_decoder_block(cfg: ModelConfig, name: str) -> BlockDef:
    """Whisper decoder layer: causal self-attn + cross-attn(memory) + FFN."""

    def init(key):
        ks = jax.random.split(key, 6)
        parts = {
            "ln1": norms.init(ks[0], cfg.d_model),
            "self": attention.init(ks[1], cfg),
            "ln2": norms.init(ks[2], cfg.d_model),
            "cross": attention.init(ks[3], cfg, is_cross=True),
            "ln3": norms.init(ks[4], cfg.d_model),
            "mlp": ffn.init(ks[5], cfg.d_model, cfg.d_ff),
        }
        return ({k: v[0] for k, v in parts.items()},
                {k: v[1] for k, v in parts.items()})

    def apply(p, x, state, ctx: Ctx):
        s_self = state["self"] if state is not None else None
        s_cross = state["cross"] if state is not None else None
        h, ns_self = attention.apply(p["self"], _norm(cfg, p["ln1"], x),
                                     s_self, ctx, cfg=cfg, causal=True)
        x = x + h
        h, ns_cross = attention.apply(p["cross"], _norm(cfg, p["ln2"], x),
                                      s_cross, ctx, cfg=cfg, is_cross=True,
                                      cross_source="memory")
        x = x + h
        x = x + ffn.apply(p["mlp"], _norm(cfg, p["ln3"], x), ctx, act=cfg.act)
        new_state = None
        if ns_self is not None or ns_cross is not None:
            new_state = {"self": ns_self, "cross": ns_cross}
        return x, new_state, _F32_ZERO()

    def state_spec(batch, cache_len):
        return {
            "self": attention.state_spec(cfg, batch, cache_len),
            "cross": attention.state_spec(cfg, batch, cache_len,
                                          is_cross=True,
                                          source_len=cache_len),
        }

    return BlockDef(name=name, init=init, apply=apply, state_spec=state_spec)


def mamba_block(cfg: ModelConfig, name: str) -> BlockDef:
    def init(key):
        ks = jax.random.split(key, 2)
        ln = norms.init(ks[0], cfg.d_model)
        mx = mamba2.init(ks[1], cfg)
        return {"ln": ln[0], "mix": mx[0]}, {"ln": ln[1], "mix": mx[1]}

    def apply(p, x, state, ctx: Ctx):
        h, new_state = mamba2.apply(p["mix"], _norm(cfg, p["ln"], x),
                                    state, ctx, cfg=cfg)
        return x + h, new_state, _F32_ZERO()

    return BlockDef(name=name, init=init, apply=apply,
                    state_spec=lambda b, c: mamba2.state_spec(cfg, b, c))


def mlstm_block(cfg: ModelConfig, name: str) -> BlockDef:
    def init(key):
        ks = jax.random.split(key, 2)
        ln = norms.init(ks[0], cfg.d_model)
        mx = xlstm.mlstm_init(ks[1], cfg)
        return {"ln": ln[0], "mix": mx[0]}, {"ln": ln[1], "mix": mx[1]}

    def apply(p, x, state, ctx: Ctx):
        h, new_state = xlstm.mlstm_apply(p["mix"], _norm(cfg, p["ln"], x),
                                         state, ctx, cfg=cfg)
        return x + h, new_state, _F32_ZERO()

    return BlockDef(name=name, init=init, apply=apply,
                    state_spec=lambda b, c: xlstm.mlstm_state_spec(cfg, b, c))


def slstm_block(cfg: ModelConfig, name: str) -> BlockDef:
    def init(key):
        return xlstm.slstm_init(key, cfg)

    def apply(p, x, state, ctx: Ctx):
        h, new_state = xlstm.slstm_apply(p, x, state, ctx, cfg=cfg)
        return x + h, new_state, _F32_ZERO()

    return BlockDef(name=name, init=init, apply=apply,
                    state_spec=lambda b, c: xlstm.slstm_state_spec(cfg, b, c))


# --------------------------------------------------------------------------
# per-architecture plans
# --------------------------------------------------------------------------


def build_plan(cfg: ModelConfig) -> StackPlan:
    """Backbone (decoder) plan for every assigned architecture."""
    L = cfg.n_layers

    if cfg.family == "ssm":  # xlstm: alternate mLSTM / sLSTM
        assert L % 2 == 0
        return StackPlan(segments=(
            Segment(pattern=(mlstm_block(cfg, "mlstm"),
                             slstm_block(cfg, "slstm")),
                    n_groups=L // 2),))

    if cfg.family == "hybrid":  # zamba2: mamba + shared attn every k
        k = cfg.shared_attn_every
        shared = attn_ffn_block(cfg, "shared_attn", use_extra=True)
        n_groups, tail = divmod(L, k)
        pattern = tuple(mamba_block(cfg, f"mamba{i}") for i in range(k)) \
            + (shared,)
        segs = [Segment(pattern=pattern, n_groups=n_groups)]
        if tail:
            segs.append(Segment(
                pattern=tuple(mamba_block(cfg, f"tail_mamba{i}")
                              for i in range(tail)), n_groups=1))
        return StackPlan(segments=tuple(segs), extra_blocks=(shared,))

    if cfg.moe is not None:  # deepseek family
        use_mla = cfg.mla is not None
        nd = cfg.moe.n_dense_layers
        segs = []
        if nd:
            segs.append(Segment(
                pattern=(attn_ffn_block(cfg, "dense", use_mla=use_mla),),
                n_groups=nd))
        segs.append(Segment(
            pattern=(attn_ffn_block(cfg, "moe", use_moe=True,
                                    use_mla=use_mla),),
            n_groups=L - nd))
        return StackPlan(segments=tuple(segs))

    if cfg.cross_attn_every:  # llama-3.2 vision
        k = cfg.cross_attn_every
        assert L % k == 0
        pattern = tuple(attn_ffn_block(cfg, f"self{i}") for i in range(k - 1))
        pattern += (attn_ffn_block(cfg, "xattn", cross=True,
                                   cross_source="image",
                                   source_len=cfg.n_image_tokens),)
        return StackPlan(segments=(Segment(pattern=pattern,
                                           n_groups=L // k),))

    if cfg.encdec:  # whisper decoder
        return StackPlan(segments=(
            Segment(pattern=(encdec_decoder_block(cfg, "dec"),),
                    n_groups=L),))

    if cfg.global_every:  # gemma3 local:global interleave
        k = cfg.global_every
        theta_local = cfg.rope_theta_local or cfg.rope_theta
        locals_ = tuple(
            attn_ffn_block(cfg, f"local{i}", window=cfg.sliding_window,
                           rope_theta=theta_local)
            for i in range(k - 1))
        pattern = locals_ + (attn_ffn_block(cfg, "global"),)
        n_groups, tail = divmod(L, k)
        segs = [Segment(pattern=pattern, n_groups=n_groups)]
        if tail:
            segs.append(Segment(
                pattern=tuple(
                    attn_ffn_block(cfg, f"tail_local{i}",
                                   window=cfg.sliding_window,
                                   rope_theta=theta_local)
                    for i in range(tail)),
                n_groups=1))
        return StackPlan(segments=tuple(segs))

    # plain dense decoder (qwen2 / qwen1.5-110b / gemma-7b)
    window = cfg.sliding_window
    return StackPlan(segments=(
        Segment(pattern=(attn_ffn_block(cfg, "layer", window=window),),
                n_groups=L),))


def build_encoder_plan(cfg: ModelConfig) -> Optional[StackPlan]:
    if not cfg.encdec:
        return None
    return StackPlan(segments=(
        Segment(pattern=(attn_ffn_block(cfg, "enc", causal=False),),
                n_groups=cfg.n_enc_layers),))
