"""Parameter initialization helpers.

Every ``init`` in the model stack returns ``(params, specs)`` where
``specs`` mirrors ``params`` and holds *logical* partition tuples — e.g.
``("fsdp", "tp")`` — translated to mesh ``PartitionSpec``s by
``distributed.sharding``.  Keeping specs next to shapes at init time makes
2-D (FSDP x TP) sharding explicit and testable without a mesh.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

# Logical axis names used across the model stack:
#   "fsdp"  -> ("pod", "data") mesh axes (parameter/optimizer sharding)
#   "tp"    -> "model" mesh axis (tensor parallel)
#   None    -> replicated
Spec = Tuple[Optional[str], ...]


def dense(key, shape: Sequence[int], spec: Spec, *,
          scale: Optional[float] = None, dtype=jnp.float32):
    """Lecun-normal dense weight with its logical partition spec."""
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    w = jax.random.normal(key, tuple(shape), dtype) * jnp.asarray(std, dtype)
    assert len(spec) == len(shape), (spec, shape)
    return w, spec


def zeros(shape: Sequence[int], spec: Spec, dtype=jnp.float32):
    assert len(spec) == len(shape), (spec, shape)
    return jnp.zeros(tuple(shape), dtype), spec


def ones(shape: Sequence[int], spec: Spec, dtype=jnp.float32):
    assert len(spec) == len(shape), (spec, shape)
    return jnp.ones(tuple(shape), dtype), spec


def split_tree(pairs: dict):
    """{name: (param, spec)} -> (params_dict, specs_dict)."""
    params = {k: v[0] for k, v in pairs.items()}
    specs = {k: v[1] for k, v in pairs.items()}
    return params, specs


def merge(*dicts_pairs):
    """Merge multiple (params, specs) tuples of dicts."""
    params, specs = {}, {}
    for p, s in dicts_pairs:
        params.update(p)
        specs.update(s)
    return params, specs
