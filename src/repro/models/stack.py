"""Scan-based layer stacks.

A model body is a list of ``Segment``s; each segment scans a repeating
``pattern`` of blocks over ``n_groups`` groups (params stacked on a leading
group axis).  Heterogeneous interleaves (gemma3 5:1 local:global, llama
vision cross-attn every 5th, zamba2 shared-attn every 6th, xLSTM
mLSTM/sLSTM alternation) become pattern positions, keeping HLO size
O(pattern) instead of O(layers) — essential for 80-cell dry-run compiles.

Blocks with ``use_extra=True`` read their params from a shared (unscanned)
dict — zamba2's shared attention block — while their *state* (KV cache)
remains per-group.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.models.context import Ctx


@dataclass(frozen=True)
class BlockDef:
    name: str
    init: Callable                      # key -> (params, specs)
    apply: Callable                     # (params, x, state, ctx) -> (x, state, aux)
    state_spec: Optional[Callable] = None  # (batch, cache_len) -> pytree of (shape, dtype, spec)
    use_extra: bool = False             # params live in the shared dict


@dataclass(frozen=True)
class Segment:
    pattern: Sequence[BlockDef]
    n_groups: int


@dataclass(frozen=True)
class StackPlan:
    segments: Sequence[Segment]
    extra_blocks: Sequence[BlockDef] = field(default_factory=tuple)

    @property
    def n_layers(self) -> int:
        return sum(len(s.pattern) * s.n_groups for s in self.segments)


def specs_of(init_fn: Callable, key):
    """Trace ``init_fn`` abstractly; return (shape-pytree, static specs)."""
    box = {}

    def f(k):
        p, s = init_fn(k)
        box["s"] = s
        return p

    shapes = jax.eval_shape(f, key)
    return shapes, box["s"]


def _prepend_none(spec_tree):
    return jax.tree.map(lambda s: (None,) + tuple(s), spec_tree,
                        is_leaf=lambda s: isinstance(s, tuple))


def init_stack(key, plan: StackPlan):
    """Returns (params, specs).  params['segments'][i][j] has leaves with a
    leading n_groups axis; params['extra'][name] is unstacked."""
    params = {"segments": [], "extra": {}}
    specs = {"segments": [], "extra": {}}
    for si, seg in enumerate(plan.segments):
        seg_params, seg_specs = [], []
        for j, blk in enumerate(seg.pattern):
            if blk.use_extra:
                seg_params.append(None)
                seg_specs.append(None)
                continue
            kseg = jax.random.fold_in(key, si * 131 + j)
            _, sp = specs_of(blk.init, kseg)
            keys = jax.random.split(kseg, seg.n_groups)
            stacked = jax.vmap(lambda k, b=blk: b.init(k)[0])(keys)
            seg_params.append(stacked)
            seg_specs.append(_prepend_none(sp))
        params["segments"].append(seg_params)
        specs["segments"].append(seg_specs)
    for bi, blk in enumerate(plan.extra_blocks):
        kextra = jax.random.fold_in(key, 10_000 + bi)
        p, sp = blk.init(kextra)
        params["extra"][blk.name] = p
        specs["extra"][blk.name] = sp
    return params, specs


def init_states(plan: StackPlan, batch: int, cache_len: int,
                make_leaf: Callable):
    """Build the decode-state pytree.  ``make_leaf(shape, dtype, spec)``
    returns either concrete zeros or ShapeDtypeStructs (dry run)."""
    out = []
    for seg in plan.segments:
        seg_states = []
        for blk in seg.pattern:
            if blk.state_spec is None:
                seg_states.append(None)
                continue
            spec = blk.state_spec(batch, cache_len)
            leaf = jax.tree.map(
                lambda s: make_leaf(((seg.n_groups,) + tuple(s[0])), s[1],
                                    (None,) + tuple(s[2])),
                spec, is_leaf=lambda s: isinstance(s, tuple) and len(s) == 3
                and isinstance(s[0], tuple))
            seg_states.append(leaf)
        out.append(tuple(seg_states))
    return out


def apply_stack(params, plan: StackPlan, x, states, ctx: Ctx, *,
                remat: bool = True, remat_policy=None):
    """Returns (x, new_states, aux_sum).

    Decode threads the (large, mostly-unchanged) KV/SSM states through
    the scan CARRY with per-group dynamic-slice / dynamic-update-slice:
    while-loop carries alias in place, so each step writes only the new
    token's window instead of re-emitting the full per-layer cache as a
    scan ``ys`` (measured 2x full-cache write traffic on the gemma-7b
    decode cell — EXPERIMENTS.md section Perf).
    """
    if states is not None and ctx.is_decode:
        return _apply_stack_carry(params, plan, x, states, ctx)

    extra = params["extra"]
    aux_total = jnp.zeros((), jnp.float32)
    new_states_all = []
    for si, seg in enumerate(plan.segments):
        seg_params = params["segments"][si]
        seg_states = states[si] if states is not None else \
            tuple(None for _ in seg.pattern)

        def body(carry, xs, _seg=seg, _extra=extra):
            xc, aux = carry
            p_list, s_list = xs
            new_s = []
            for j, blk in enumerate(_seg.pattern):
                pj = _extra[blk.name] if blk.use_extra else p_list[j]
                xc, st, a = blk.apply(pj, xc, s_list[j], ctx)
                new_s.append(st)
                aux = aux + a
            return (xc, aux), tuple(new_s)

        if remat:
            body = jax.checkpoint(
                body, policy=remat_policy
                or jax.checkpoint_policies.nothing_saveable)
        (x, aux_total), new_seg_states = jax.lax.scan(
            body, (x, aux_total), (tuple(seg_params), seg_states))
        new_states_all.append(new_seg_states)
    return x, new_states_all, aux_total


def _index_tree(tree, i):
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
        tree)


def _update_tree(tree, new, i):
    return jax.tree.map(
        lambda a, n: jax.lax.dynamic_update_index_in_dim(
            a, n.astype(a.dtype), i, 0), tree, new)


def _apply_stack_carry(params, plan: StackPlan, x, states, ctx: Ctx):
    extra = params["extra"]
    aux_total = jnp.zeros((), jnp.float32)
    new_states_all = []
    for si, seg in enumerate(plan.segments):
        seg_params = params["segments"][si]
        seg_states = states[si]

        def body(carry, xs, _seg=seg, _extra=extra):
            xc, aux, st_stacked = carry
            p_list, i = xs
            new_stacked = []
            for j, blk in enumerate(_seg.pattern):
                pj = _extra[blk.name] if blk.use_extra else p_list[j]
                sj = _index_tree(st_stacked[j], i) \
                    if st_stacked[j] is not None else None
                xc, st, a = blk.apply(pj, xc, sj, ctx)
                new_stacked.append(
                    _update_tree(st_stacked[j], st, i)
                    if st is not None else st_stacked[j])
                aux = aux + a
            return (xc, aux, tuple(new_stacked)), None

        (x, aux_total, seg_states), _ = jax.lax.scan(
            body, (x, aux_total, seg_states),
            (tuple(seg_params), jnp.arange(seg.n_groups, dtype=jnp.int32)))
        new_states_all.append(seg_states)
    return x, new_states_all, aux_total
