"""Apply-time context threaded through model blocks.

Carries phase (train/prefill/decode), positions, sharding-constraint hook
and auxiliary memories (encoder output, image embeddings).  Blocks never
import mesh machinery directly; ``constrain`` is injected by the launcher
(`distributed.sharding.make_constrainer`) and is the identity on CPU tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax.numpy as jnp

Array = Any


def _identity_constrain(x, _spec):
    return x


@dataclass(frozen=True)
class Ctx:
    phase: str = "train"                 # train | prefill | decode
    positions: Optional[Array] = None    # [B, S] absolute positions
    cache_len: int = 0                   # static max cache length (decode)
    cur_index: Optional[Array] = None    # [B] per-request write index (decode)
    enc_memory: Optional[Array] = None   # [B, S_enc, D] (whisper decoder)
    image_embeds: Optional[Array] = None # [B, n_img, D] (vlm cross-attn)
    cdtype: Any = jnp.bfloat16           # compute dtype
    deterministic: bool = True
    # constrain(x, logical_spec_tuple) -> x ; logical axes: "batch", "seq",
    # "heads", "kv_seq", "ffn", "vocab", "experts", None
    constrain: Callable = _identity_constrain
    rngs: Optional[Any] = None
    # mesh + logical->axes rules, set by the launcher; layers may use
    # them for explicit shard_map regions (e.g. MoE expert-parallel
    # dispatch).  None on single-host test paths.
    mesh: Optional[Any] = None
    rules: Optional[Any] = None

    @property
    def is_decode(self) -> bool:
        return self.phase == "decode"

    def replace(self, **kw) -> "Ctx":
        return dataclasses.replace(self, **kw)
