"""Windowed load metrics from chunk-boundary device reads.

The hot path stays sync-free: every signal here is derived from the
single ``device_get`` per chunk/segment boundary the drivers already
pay for (the throttle trace on ``Engine.run``, the stats reads on the
distributed drive loop) — ``MetricsRegistry.observe`` batches one
small aggregate tree into that same transfer slot and diffs it against
the previous window.  Readings are therefore *window* quantities
(deltas over the ticks since the last observe), smoothed into EMAs;
cumulative engine counters never leave the device between boundaries.

``observe_raw`` is the engine-agnostic core (the LM serving driver
feeds it its own counters); ``observe`` adapts a stream engine
(``Engine`` or ``DistributedEngine``) and, when the state carries a
count-min sketch, attaches heavy-hitter estimates.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.telemetry import latency as lat_mod
from repro.telemetry import sketch as sk_mod


@dataclass
class TelemetryConfig:
    """Knobs for the device sketch + the metrics window."""

    # depth 2 x width 2048 trades hash rows for row width: the scatter
    # cost in the tick is depth*B updates, while heavy-hitter *ranking*
    # (telemetry's job, unlike a tight frequency oracle) only needs the
    # error bound e*N/width to stay far under the skew threshold.
    # Raise depth for tighter per-key estimates.
    depth: int = 2            # count-min hash rows
    width: int = 2048         # counters per row (lane-aligned on TPU)
    sample: int = 128         # key-sample ring size (heavy-hitter cands)
    impl: str = "auto"        # countmin backend (kernels/countmin/ops)
    window: int = 8           # source ticks per metrics/decision window
    # sketch aging per window.  0 (default) hard-resets: counters hold
    # exactly one window, so heavy-hitter shares are exact.  >0 keeps a
    # decayed residue (steady state ~1/(1-decay) windows) for smoother
    # estimates — shares are normalized by that factor.
    decay: float = 0.0
    alpha: float = 0.5        # EMA smoothing of windowed readings
    top_k: int = 8            # heavy hitters reported per window
    seed: int = 0x7E1E        # sketch salt seed
    # latency observability (DESIGN.md 18): power-of-two event-latency
    # buckets per updater arc, updated inside the jitted tick.  0
    # disables the histogram state entirely.
    latency_buckets: int = 32
    trace: bool = False       # host-side span tracer on the drive loop
    control_log: Optional[str] = None  # autoscaler decision JSONL path


@dataclass
class TelemetryReport:
    """One window's view of the running engine (all arrays [n_shards];
    the single-shard engine reports shape [1])."""

    tick: int                     # engine tick at the snapshot
    ticks: int                    # ticks covered by this window
    n_shards: int
    active: List[int]             # active shard ids
    events: np.ndarray            # events processed this window
    events_per_tick: np.ndarray   # EMA of events/tick
    queue_depth: np.ndarray       # backlog right now (sum over operators)
    queue_peak_delta: np.ndarray  # high-water growth this window
    dropped_delta: np.ndarray     # drops this window (queues + exchange)
    occupancy: np.ndarray         # slate rows resident (sum over tables)
    pressure: np.ndarray          # EMA normalized load (see `observe_raw`)
    heavy_hitters: List[Tuple[int, int, float]]  # (key, est, share)
    migration_pause_s: float      # EMA of reconfigure pause seconds
    # trailing fields default so older constructors stay valid
    window_s: float = 0.0         # wall seconds since the last observe
    migration_bytes_moved: float = 0.0  # EMA of bytes per reconfigure
    # overload visibility (DESIGN.md section 16): shed = ingest dropped
    # at admission (throttle hits / shed requests), deferred = run tails
    # re-queued by sequential hotspot backpressure — both this window
    shed_delta: Any = 0.0         # [n_shards] when the engine reports it
    deferred_delta: Any = 0.0
    # end-to-end latency (DESIGN.md section 18): quantiles interpolated
    # from the windowed device-histogram deltas, pooled over arcs; the
    # per-arc p99 keeps the queue-delay breakdown ("which arc's queue
    # is eating the latency").  All in source ticks.
    event_latency_p50: float = 0.0
    event_latency_p90: float = 0.0
    event_latency_p99: float = 0.0
    queue_delay_p99: Any = field(default_factory=dict)
    recovery_replay_s: float = 0.0  # last recover() restore+replay secs

    def to_dict(self) -> Dict[str, Any]:
        """JSON-safe form (the HTTP status surface)."""
        out = {}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            out[f.name] = v.tolist() if isinstance(v, np.ndarray) else v
        return out


class MetricsRegistry:
    """EMA windows over boundary readings for one engine.

    Shape-agnostic: per-shard array sizes are taken from each reading,
    and a shape change (physical grow) or an explicit :meth:`rebase`
    restarts the window marks — deltas never span a migration, whose
    counter resets would otherwise read as negative load.
    """

    def __init__(self, cfg: TelemetryConfig, *, batch_size: int):
        self.cfg = cfg
        self.batch_size = max(1, batch_size)
        self.salts = sk_mod.make_salts(cfg.depth, cfg.seed)
        self.last: Optional[TelemetryReport] = None
        self._mark: Optional[Dict[str, Any]] = None
        self._ema_ev: Optional[np.ndarray] = None
        self._ema_pressure: Optional[np.ndarray] = None
        self._pause_ema = 0.0
        self._bytes_ema = 0.0
        self._obs_t: Optional[float] = None
        self._recovery_s = 0.0
        # cumulative per-arc latency histograms from the last boundary
        # read (arc -> {"counts", "sum"}) — the /metrics exposition
        # renders these as native Prometheus _bucket/_sum/_count series
        self.hist_cum: Dict[str, Any] = {}

    # ---- engine-agnostic core ---------------------------------------
    def observe_raw(self, *, tick: int, events: np.ndarray,
                    queue_depth: np.ndarray, queue_peak: np.ndarray,
                    dropped: np.ndarray, occupancy: np.ndarray,
                    active: Sequence[int],
                    heavy: List[Tuple[int, int]] = (),
                    shed: Optional[np.ndarray] = None,
                    deferred: Optional[np.ndarray] = None,
                    hist: Optional[Dict[str, Any]] = None
                    ) -> TelemetryReport:
        """Fold one boundary reading (cumulative counters) into the
        window state and return the report.  ``events`` / ``queue_peak``
        / ``dropped`` — and, when given, ``shed`` / ``deferred`` — are
        lifetime counters; this diffs them against the previous
        reading."""
        events = np.asarray(events, np.float64)
        queue_depth = np.asarray(queue_depth, np.float64)
        queue_peak = np.asarray(queue_peak, np.float64)
        dropped = np.asarray(dropped, np.float64)
        occupancy = np.asarray(occupancy, np.float64)
        shed = np.zeros_like(events) if shed is None \
            else np.asarray(shed, np.float64)
        deferred = np.zeros_like(events) if deferred is None \
            else np.asarray(deferred, np.float64)
        n = events.shape[0]
        m = self._mark
        if m is None or m["events"].shape != events.shape:
            m = {"tick": tick, "events": events, "peak": queue_peak,
                 "dropped": dropped, "shed": shed, "deferred": deferred,
                 "hist": hist}
        if self._ema_ev is None or self._ema_ev.shape != events.shape:
            # EMAs survive a same-shape rebase: only the *window marks*
            # restart at migrations — zeroing smoothed pressure there
            # would feed artificially low readings into the controller's
            # streaks right when hysteresis matters most
            self._ema_ev = np.zeros(n)
            self._ema_pressure = np.zeros(n)
        dt = max(1, tick - m["tick"])
        ev_d = np.clip(events - m["events"], 0.0, None)
        peak_d = np.clip(queue_peak - m["peak"], 0.0, None)
        drop_d = np.clip(dropped - m["dropped"], 0.0, None)
        shed_d = np.clip(shed - m.get("shed", shed), 0.0, None)
        def_d = np.clip(deferred - m.get("deferred", deferred), 0.0, None)
        # normalized load: throughput share of batch capacity, plus
        # standing backlog and (heavily weighted) drops — a shard at
        # pressure ~1 is saturated, >1 is shedding
        pressure = (ev_d / dt + queue_depth + 4.0 * drop_d) \
            / self.batch_size
        a = self.cfg.alpha
        self._ema_ev = a * (ev_d / dt) + (1 - a) * self._ema_ev
        self._ema_pressure = a * pressure + (1 - a) * self._ema_pressure
        total = float(ev_d.sum())
        # a decaying sketch holds ~1/(1-decay) windows of counts at
        # steady state while `total` covers one window — normalize so
        # the skew threshold compares like with like
        norm = total / max(1e-9, 1.0 - self.cfg.decay) \
            if 0.0 < self.cfg.decay < 1.0 else total
        hh = [(k, est, min(1.0, est / norm) if norm else 0.0)
              for k, est in heavy]
        # latency quantiles from windowed histogram deltas: pooled over
        # arcs for the end-to-end figure, per-arc for queue-delay p99
        nb = self.cfg.latency_buckets
        lat_p = [0.0, 0.0, 0.0]
        arc_p99: Dict[str, float] = {}
        if hist and nb > 0:
            mh = m.get("hist") or {}
            pooled = None
            for a, h in hist.items():
                cum = np.asarray(h["counts"], np.float64)
                prev = mh.get(a)
                d = np.clip(cum - np.asarray(prev["counts"],
                                             np.float64), 0.0, None) \
                    if prev is not None \
                    and np.shape(prev["counts"]) == cum.shape \
                    else np.zeros_like(cum)
                arc_p99[a] = lat_mod.quantile(d, 0.99, n_buckets=nb)
                pooled = d if pooled is None else pooled + d
            if pooled is not None:
                lat_p = lat_mod.quantiles(pooled, (0.5, 0.9, 0.99),
                                          n_buckets=nb)
            self.hist_cum = hist
        self._mark = {"tick": tick, "events": events, "peak": queue_peak,
                      "dropped": dropped, "shed": shed,
                      "deferred": deferred, "hist": hist}
        now = time.perf_counter()
        window_s = (now - self._obs_t) if self._obs_t is not None else 0.0
        self._obs_t = now
        self.last = TelemetryReport(
            tick=tick, ticks=dt, n_shards=n, active=list(active),
            events=ev_d, events_per_tick=self._ema_ev.copy(),
            queue_depth=queue_depth, queue_peak_delta=peak_d,
            dropped_delta=drop_d, occupancy=occupancy,
            pressure=self._ema_pressure.copy(), heavy_hitters=hh,
            migration_pause_s=self._pause_ema,
            window_s=window_s,
            migration_bytes_moved=self._bytes_ema,
            shed_delta=shed_d, deferred_delta=def_d,
            event_latency_p50=lat_p[0], event_latency_p90=lat_p[1],
            event_latency_p99=lat_p[2], queue_delay_p99=arc_p99,
            recovery_replay_s=self._recovery_s)
        return self.last

    # ---- stream-engine adapter --------------------------------------
    def observe(self, engine, state) -> TelemetryReport:
        """One boundary reading of a stream engine: a single
        ``device_get`` of the aggregate tree (the piggyback transfer),
        then ``observe_raw``.  Heavy hitters are estimated from the
        state's sketch when present (summed over shards)."""
        return self.finish_observe(self.begin_observe(engine, state))

    def begin_observe(self, engine, state):
        """Phase 1 of the double-buffered boundary reading: assemble the
        aggregate tree, copy it out of the soon-to-be-donated state
        buffers, and start the device->host transfer.  Returns a pending
        token; the driver resolves it with :meth:`finish_observe` after
        the *next* chunk is dispatched so the transfer overlaps device
        compute (one-chunk report lag)."""
        tree = self._tree(engine, state, with_heavy=True)
        # device-side copies escape the donation of `state` by the next
        # chunk dispatch; the async copy then drains in the background
        tree = jax.tree.map(jnp.copy, tree)
        for leaf in jax.tree.leaves(tree):
            copy_async = getattr(leaf, "copy_to_host_async", None)
            if copy_async is not None:
                copy_async()
        return (engine, tree)

    def finish_observe(self, pending) -> TelemetryReport:
        """Phase 2: resolve the transfer and fold the reading into the
        window state (the ``observe_raw`` path)."""
        engine, tree = pending
        host = jax.device_get(tree)
        (tick, events, qsize, qpeak, dropped, occ, heavy,
         active, shed, deferred, hist) = self._post(engine, host,
                                                    with_heavy=True)
        return self.observe_raw(
            tick=tick, events=events, queue_depth=qsize,
            queue_peak=qpeak, dropped=dropped, occupancy=occ,
            active=active, heavy=heavy, shed=shed, deferred=deferred,
            hist=hist)

    def _tree(self, engine, state, *, with_heavy: bool):
        upd = {u.name for u in engine.wf.updaters()}
        tree = {
            "tick": state["tick"],
            "proc": {k: v for k, v in state["processed"].items()
                     if k in upd},
            "qsize": {k: q.size for k, q in state["queues"].items()},
            "qpeak": {k: q.peak for k, q in state["queues"].items()},
            "qdrop": {k: q.dropped for k, q in state["queues"].items()},
            # per-shard row counts (table.occupancy() sums across the
            # shard dim too; the report promises [n_shards] arrays)
            "occ": {k: (t.keys != -1).sum(axis=-1)
                    for k, t in state["tables"].items()},
        }
        if "exchange_dropped" in state:
            tree["exdrop"] = state["exchange_dropped"]
        if "throttle_hits" in state:
            tree["shed"] = state["throttle_hits"]
        if "deferred" in state:
            tree["deferred"] = state["deferred"]
        if with_heavy and "sketch" in state:
            tree["sk"] = state["sketch"]
        if "lat_hist" in state:
            tree["hist"] = state["lat_hist"]
        return tree

    def _read(self, engine, state, *, with_heavy: bool):
        tree = self._tree(engine, state, with_heavy=with_heavy)
        host = jax.device_get(tree)            # the one boundary sync
        return self._post(engine, host, with_heavy=with_heavy)

    def _post(self, engine, host, *, with_heavy: bool):
        def shards(x):
            return np.atleast_1d(np.asarray(x, np.float64))

        def summed(d):
            out = None
            for v in d.values():
                v = shards(v)
                out = v if out is None else out + v
            return out if out is not None else np.zeros(1)

        tick = int(np.max(np.asarray(host["tick"])))
        events = summed(host["proc"])
        dropped = summed(host["qdrop"])
        if "exdrop" in host:
            dropped = dropped + shards(host["exdrop"])
        heavy = []
        if "sk" in host:
            sk = host["sk"]
            counts = np.asarray(sk["counts"])
            sample = np.asarray(sk["sample"])
            if counts.ndim == 2:               # single-shard engine
                counts, sample = counts[None], sample[None]
            n_tot = np.atleast_1d(np.asarray(sk["sample_n"]))
            agg = counts.sum(axis=0)           # global heat across shards
            cand = np.unique(np.concatenate(
                [sk_mod.candidates(sample[s], int(n_tot[s]))
                 for s in range(sample.shape[0])]) if sample.shape[0]
                else np.zeros(0, np.int32))
            if len(cand):
                est = sk_mod.estimate(agg, cand, self.salts)
                order = np.argsort(-est, kind="stable")[:self.cfg.top_k]
                heavy = [(int(cand[i]), int(est[i])) for i in order]
        active = getattr(engine, "active_shards", None)
        if active is None:
            active = list(range(events.shape[0]))
        shed = shards(host["shed"]) if "shed" in host else None
        deferred = shards(host["deferred"]) if "deferred" in host \
            else None
        hist = None
        if "hist" in host:
            # per-arc [1, W] rows (leading shard dim on the distributed
            # engine) -> one global [W] row + total latency sum per arc
            hist = {}
            for a, h in host["hist"].items():
                c = np.asarray(h["counts"])
                w = c.shape[-1]
                hist[a] = {"counts": c.reshape(-1, w).sum(axis=0),
                           "sum": float(np.asarray(h["sum"]).sum())}
        return (tick, events, summed(host["qsize"]),
                summed(host["qpeak"]), dropped, summed(host["occ"]),
                heavy, active, shed, deferred, hist)

    # ---- window management ------------------------------------------
    def rebase(self, engine, state):
        """Restart the window marks after a migration (queue peaks and
        shard shapes may have changed): a fresh counter snapshot only —
        no report, no heavy-hitter estimation, and the EMAs are left
        untouched (folding an artificial post-drain zero reading into
        them would bias the controller toward premature scale-down)."""
        (tick, events, _, qpeak, dropped, _, _, _, shed, deferred,
         hist) = self._read(engine, state, with_heavy=False)
        z = np.zeros_like(events)
        self._mark = {"tick": tick, "events": events, "peak": qpeak,
                      "dropped": dropped,
                      "shed": z if shed is None else shed,
                      "deferred": z if deferred is None else deferred,
                      "hist": hist}

    def note_recovery(self, seconds: float):
        """Record the last ``recover()`` wall time (restore + WAL
        replay across shards) — surfaced as ``recovery_replay_s`` on
        the report; the migration path's ``pause_s`` equivalent for
        the crash-recovery path."""
        self._recovery_s = float(seconds)

    def note_pause(self, seconds: float, bytes_moved: int = 0):
        """Record a reconfigure pause and the payload it re-homed
        (EMAs; surfaced on the report — the controller sizes its
        cooldown from the pause, relative to the observed wall-clock
        window, instead of a fixed constant)."""
        a = self.cfg.alpha
        self._pause_ema = a * float(seconds) + (1 - a) * self._pause_ema
        self._bytes_ema = a * float(bytes_moved) \
            + (1 - a) * self._bytes_ema
