"""Prometheus text-format exposition (version 0.0.4).

Renders the engine's lifetime counters (``stats()``), the latest
``TelemetryReport`` window gauges, and the cumulative device latency
histograms as native ``_bucket``/``_sum``/``_count`` series.  The
power-of-two device buckets map directly onto Prometheus cumulative
``le`` buckets (upper edge ``2^b`` ticks, top bucket ``+Inf``), so a
standard ``histogram_quantile()`` over the scraped series agrees with
the report's interpolated ``event_latency_p*``.

Everything here renders from snapshots the engine already holds
(``MetricsRegistry.last`` / ``hist_cum``) — a scrape never touches
device state.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import numpy as np

from repro.telemetry import latency as lat_mod

_PREFIX = "muppet"


def _esc(v: Any) -> str:
    return str(v).replace("\\", r"\\").replace('"', r'\"') \
        .replace("\n", r"\n")


def _labels(d: Optional[Dict[str, Any]]) -> str:
    if not d:
        return ""
    inner = ",".join(f'{k}="{_esc(v)}"' for k, v in d.items())
    return "{" + inner + "}"


def _num(v: Any) -> str:
    f = float(v)
    if f == float("inf"):
        return "+Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


class _Doc:
    """Accumulates samples grouped by metric family (HELP/TYPE once)."""

    def __init__(self):
        self._fam: Dict[str, Dict[str, Any]] = {}
        self._order: List[str] = []

    def add(self, name: str, kind: str, help_: str, value: Any,
            labels: Optional[Dict[str, Any]] = None,
            suffix: str = ""):
        name = f"{_PREFIX}_{name}"
        if name not in self._fam:
            self._fam[name] = {"kind": kind, "help": help_,
                               "samples": []}
            self._order.append(name)
        self._fam[name]["samples"].append(
            (name + suffix + _labels(labels), _num(value)))

    def render(self) -> str:
        out = []
        for name in self._order:
            fam = self._fam[name]
            out.append(f"# HELP {name} {fam['help']}")
            out.append(f"# TYPE {name} {fam['kind']}")
            for series, value in fam["samples"]:
                out.append(f"{series} {value}")
        return "\n".join(out) + "\n"


def render_prometheus(*, stats: Optional[Dict[str, Any]] = None,
                      report: Any = None,
                      hist: Optional[Dict[str, Any]] = None,
                      n_buckets: int = lat_mod.N_BUCKETS) -> str:
    """Render a /metrics payload.

    ``stats``: engine lifetime counters (``Engine.stats`` shape);
    ``report``: the latest ``TelemetryReport`` (or None before the
    first window); ``hist``: cumulative per-arc latency histograms
    (``MetricsRegistry.hist_cum`` shape: arc -> {"counts", "sum"}).
    """
    doc = _Doc()
    if stats:
        _render_stats(doc, stats)
    if report is not None:
        _render_report(doc, report)
    if hist:
        _render_hist(doc, hist, n_buckets)
    return doc.render()


def _render_stats(doc: _Doc, stats: Dict[str, Any]):
    counters = {"exchange_dropped": "events dropped at shard exchange",
                "throttle_hits": "events shed at admission",
                "deferred": "run tails re-queued by hotspot backpressure",
                "shed_requests": "requests shed at admission",
                "completed": "requests completed"}
    if "tick" in stats:
        doc.add("tick", "gauge", "engine tick at last read",
                stats["tick"])
    for k, v in (stats.get("processed") or {}).items():
        doc.add("processed_total", "counter",
                "events processed per operator", v, {"op": k})
    for k, v in (stats.get("queue_dropped") or {}).items():
        doc.add("queue_dropped_total", "counter",
                "events dropped per queue", v, {"queue": k})
    for k, v in (stats.get("table_occupancy") or {}).items():
        doc.add("table_rows", "gauge",
                "slate rows resident per updater", v, {"updater": k})
    for k, v in stats.items():
        if k in ("tick", "processed", "queue_dropped",
                 "table_occupancy"):
            continue
        if isinstance(v, (bool,)) or not isinstance(v, (int, float)):
            continue
        kind = "counter" if k in counters else "gauge"
        doc.add(f"{k}{'_total' if kind == 'counter' else ''}", kind,
                counters.get(k, f"engine stat {k}"), v)


def _render_report(doc: _Doc, report: Any):
    per_shard = {"pressure": "EMA normalized load per shard",
                 "events_per_tick": "EMA events per tick per shard",
                 "queue_depth": "standing backlog per shard",
                 "events": "events processed this window per shard",
                 "dropped_delta": "drops this window per shard",
                 "occupancy": "slate rows resident per shard"}
    active = list(getattr(report, "active", []) or [])
    for name, help_ in per_shard.items():
        v = np.atleast_1d(np.asarray(getattr(report, name, []),
                                     np.float64))
        for i, x in enumerate(v):
            shard = active[i] if i < len(active) else i
            doc.add(f"window_{name}", "gauge", help_, x,
                    {"shard": shard})
    gauges = {"window_s": "wall seconds covered by the window",
              "ticks": "source ticks covered by the window",
              "migration_pause_s": "EMA reconfigure pause seconds",
              "migration_bytes_moved": "EMA bytes moved per reconfigure",
              "recovery_replay_s": "last recovery restore+replay secs"}
    for name, help_ in gauges.items():
        if hasattr(report, name):
            doc.add(name, "gauge", help_, getattr(report, name))
    for q, name in ((0.5, "event_latency_p50"),
                    (0.9, "event_latency_p90"),
                    (0.99, "event_latency_p99")):
        if hasattr(report, name):
            doc.add("event_latency_ticks", "gauge",
                    "windowed event latency quantile (ticks)",
                    getattr(report, name), {"quantile": q})
    for arc, p99 in (getattr(report, "queue_delay_p99", None)
                     or {}).items():
        doc.add("queue_delay_p99_ticks", "gauge",
                "windowed per-arc queue-delay p99 (ticks)", p99,
                {"arc": arc})


def _render_hist(doc: _Doc, hist: Dict[str, Any], n_buckets: int):
    for arc, h in hist.items():
        counts = np.asarray(h["counts"], np.float64).ravel()[:n_buckets]
        cum = 0.0
        for b, c in enumerate(counts):
            cum += c
            # inclusive integer upper edge: bucket b holds latencies
            # in [2^(b-1), 2^b), i.e. up to 2^b - 1 ticks
            le = ("+Inf" if b >= n_buckets - 1
                  else lat_mod.bucket_hi(b) - 1)
            doc.add("event_latency_ticks_hist", "histogram",
                    "event latency at updater dequeue (ticks)", cum,
                    {"arc": arc, "le": le}, suffix="_bucket")
        doc.add("event_latency_ticks_hist", "histogram",
                "event latency at updater dequeue (ticks)",
                float(h.get("sum", 0)), {"arc": arc}, suffix="_sum")
        doc.add("event_latency_ticks_hist", "histogram",
                "event latency at updater dequeue (ticks)",
                float(counts.sum()), {"arc": arc}, suffix="_count")
