"""Host-side span tracing + the control-plane JSONL log.

``Tracer`` wraps the phases the drive loops already split — chunk
dispatch, WAL fence, flush begin/commit, telemetry observe,
reconfigure/migration, recovery restore/replay — into Chrome
trace-event JSON (``ph: "X"`` complete events).  ``Tracer.export``
writes a file that loads directly in Perfetto / ``chrome://tracing``.
The buffer is a bounded ring so tracing can stay on for long runs;
everything here is host wall-clock around calls the drivers make
anyway — no device syncs, no effect on the jitted tick.

``ControlLog`` is the autoscaler's flight recorder: one JSON line per
observe→decide→act cycle (report summary, decision + reason, applied
action outcome), append-only so post-hoc analysis can replay exactly
what the controller saw and did.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Any, Dict, List, Optional

import numpy as np


def json_safe(v: Any) -> Any:
    """Best-effort conversion to JSON-serializable values."""
    if isinstance(v, (str, bool, int, float)) or v is None:
        return v
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, np.generic):
        return v.item()
    if isinstance(v, dict):
        return {str(k): json_safe(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [json_safe(x) for x in v]
    if dataclasses.is_dataclass(v) and not isinstance(v, type):
        return json_safe(dataclasses.asdict(v))
    try:                                   # 0-d device arrays etc.
        return v.item()
    except Exception:
        return str(v)


class Tracer:
    """Ring-buffered Chrome-trace span recorder (thread-safe)."""

    def __init__(self, capacity: int = 4096):
        self.capacity = capacity
        self._events: deque = deque(maxlen=capacity)
        self._t0 = time.perf_counter()
        self._lock = threading.Lock()

    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    @contextmanager
    def span(self, name: str, cat: str = "engine", **args):
        """Record a complete ("X") event around the block.  Yields the
        mutable args dict so outcomes measured inside the span (e.g. a
        migration's ``pause_s``) land on the span itself."""
        t0 = self._now_us()
        a: Dict[str, Any] = dict(args)
        try:
            yield a
        finally:
            self._push({"name": name, "cat": cat, "ph": "X",
                        "ts": t0, "dur": self._now_us() - t0,
                        "pid": 0,
                        "tid": threading.get_ident() % 100000,
                        "args": json_safe(a)})

    def instant(self, name: str, cat: str = "engine", **args):
        self._push({"name": name, "cat": cat, "ph": "i", "s": "t",
                    "ts": self._now_us(), "pid": 0,
                    "tid": threading.get_ident() % 100000,
                    "args": json_safe(args)})

    def _push(self, ev: Dict[str, Any]):
        with self._lock:
            self._events.append(ev)

    def events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._events)

    def spans(self, name: str) -> List[Dict[str, Any]]:
        """All recorded spans with the given name, oldest first."""
        return [e for e in self.events() if e["name"] == name]

    def export(self, path: str) -> str:
        """Write Chrome trace-event JSON (opens in Perfetto)."""
        doc = {"traceEvents": self.events(), "displayTimeUnit": "ms"}
        with open(path, "w") as f:
            json.dump(doc, f)
        return path


def null_span(**args):
    """Stand-in for ``Tracer.span`` when tracing is off: yields the
    same mutable args dict, records nothing."""
    return _null_span(args)


@contextmanager
def _null_span(args):
    yield args


class ControlLog:
    """Append-only JSONL log of controller cycles (thread-safe)."""

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "a")
        self._lock = threading.Lock()

    def log(self, record: Dict[str, Any]):
        line = json.dumps(json_safe(record))
        with self._lock:
            self._f.write(line + "\n")
            self._f.flush()

    def close(self):
        with self._lock:
            if not self._f.closed:
                self._f.close()
