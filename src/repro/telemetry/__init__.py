"""Device-side telemetry + the closed control loop (DESIGN.md 13).

Three layers, observe -> decide -> act:

- ``telemetry.sketch``: a count-min sketch of routed event keys,
  updated *inside* the jitted tick (``kernels/countmin``), with a
  key-sample ring so heavy hitters can be enumerated host-side;
- ``telemetry.metrics``: a windowed metrics registry that turns the
  chunk-boundary device reads the drivers already pay for into EMA load
  signals (``TelemetryReport``) — no new syncs on the hot path;
- ``telemetry.controller``: ``LoadAutoscaler``, a hysteresis controller
  choosing among the PR-4 actuators (``scale`` / ``rebalance`` /
  ``split_keys``) from those signals.
"""
from repro.telemetry.controller import Action, LoadAutoscaler
from repro.telemetry.metrics import (MetricsRegistry, TelemetryConfig,
                                     TelemetryReport)
from repro.telemetry.prom import render_prometheus
from repro.telemetry.trace import ControlLog, Tracer

__all__ = ["Action", "ControlLog", "LoadAutoscaler", "MetricsRegistry",
           "TelemetryConfig", "TelemetryReport", "Tracer",
           "render_prometheus"]
