"""Closed-loop autoscaling: watermarks + hysteresis over telemetry.

``LoadAutoscaler`` replaces the tick-scheduled
:class:`~repro.core.distributed.AutoscalePolicy`: instead of declaring
*when* to scale, the app declares *what load means* (high/low
watermarks on the normalized per-shard pressure signal) and the
controller decides at every metrics window.  The decision function is
deliberately boring (DESIGN.md 13.3) — boring is what keeps a control
loop from oscillating:

- **dwell**: a watermark must hold for ``dwell`` consecutive windows
  before any action fires (a one-window spike is noise);
- **cooldown**: after an action, ``cooldown`` windows pass before the
  next (the migrated system needs time to show its new steady state);
- **priority**: heavy-hitter *skew* (one key dominating the window)
  is checked first — scaling out cannot relieve a single-key hotspot,
  so it triggers ``split_keys``; then scale up, scale down, and last
  the ring ``rebalance`` for diffuse imbalance.

``decide`` is a pure-ish function of the report plus the controller's
own streak counters, so hysteresis is unit-testable without an engine;
``DistributedEngine`` interprets the returned :class:`Action`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional, Tuple

import numpy as np

from repro.telemetry.metrics import TelemetryConfig, TelemetryReport


@dataclass
class Action:
    """One controller decision, interpreted by the engine's drive loop."""

    kind: str                  # "scale" | "rebalance" | "split"
    target: int = 0            # active shard count ("scale")
    keys: Tuple[int, ...] = () # heavy-hitter keys ("split")
    reason: str = ""


@dataclass
class LoadAutoscaler:
    """Watermark controller over :class:`TelemetryReport` pressure.

    ``pressure`` ~ events/tick/batch_size + backlog + weighted drops,
    per shard (see ``MetricsRegistry.observe_raw``): ~1.0 means a shard
    consumes its full batch every tick; >1 means it is falling behind.
    """

    high: float = 0.75          # mean pressure above -> scale up
    low: float = 0.25           # mean pressure below -> scale down
    window: int = 8             # source ticks per decision window
    dwell: int = 2              # consecutive windows past a watermark
    cooldown: int = 2           # windows to sit out after any action
    # adaptive cooldown: >0 stretches the post-action cooldown to at
    # least pause_factor * (observed migration pause / window wall
    # time) windows — a migration that stalls the stream for many
    # windows' worth of time earns a proportionally longer sit-out,
    # while the device-path's millisecond pauses keep the floor above.
    # 0 keeps the fixed constant.
    pause_factor: float = 0.0
    min_shards: int = 1
    max_shards: int = 0         # 0 = bounded by visible devices
    scale_factor: int = 2       # grow/shrink multiplier per action
    skew: float = 0.0           # top-key share threshold (0 = no splits)
    # latency watermark (DESIGN.md 18): >0 drives the *scale-up* streak
    # from ``report.event_latency_p99`` (source ticks) instead of mean
    # pressure — a fast-data service is operated off its tail latency,
    # and the tail can breach an SLO while mean backlog still looks
    # healthy.  Scale-down keeps the pressure watermark (a quiet p99
    # says nothing about how much headroom the fleet has).
    p99_high: float = 0.0
    rebalance_ratio: float = 0.0  # max/mean pressure ratio (0 = off)
    gain: float = 0.5           # heat -> weight damping for rebalance
    drain_max: int = 64         # drain-barrier bound per action
    on_change: Optional[Any] = None   # callback(MigrationReport)
    telemetry: Optional[TelemetryConfig] = None  # engine default override

    # hysteresis state (not config)
    _cool: int = field(default=0, repr=False)
    _hi: int = field(default=0, repr=False)
    _lo: int = field(default=0, repr=False)
    _next_cool: int = field(default=0, repr=False)

    def reset(self):
        self._cool = self._hi = self._lo = self._next_cool = 0

    def decide(self, report: TelemetryReport, *, n_active: int,
               limit: int, can_split: bool = True,
               already_split: Tuple[int, ...] = ()) -> Optional[Action]:
        """One window's decision.  ``limit`` is the physical ceiling
        (visible devices / ``max_shards``); ``can_split=False`` (e.g.
        durable runs, where partials are not store-mergeable) skips the
        skew branch *before* it consumes streaks or cooldown, so the
        scale path still fires on a persistent heavy hitter.
        ``already_split`` keys are likewise skipped — splitting is
        idempotent on the engine, so re-firing it would burn cooldown
        on a no-op forever while overload persists.  Returns None to
        hold."""
        act = [s for s in report.active if s < report.pressure.shape[0]]
        p = report.pressure[act] if act else report.pressure
        mean = float(p.mean()) if p.size else 0.0
        self._next_cool = self.cooldown
        if (self.pause_factor > 0.0 and report.migration_pause_s > 0.0
                and report.window_s > 0.0):
            self._next_cool = max(self.cooldown, int(np.ceil(
                self.pause_factor * report.migration_pause_s
                / report.window_s)))
        # streaks accumulate even during cooldown — a persistent
        # condition should fire the moment the cooldown expires
        p99 = float(getattr(report, "event_latency_p99", 0.0) or 0.0)
        hi_cond = p99 > self.p99_high if self.p99_high > 0.0 \
            else mean > self.high
        self._hi = self._hi + 1 if hi_cond else 0
        self._lo = self._lo + 1 if mean < self.low else 0
        if self._cool > 0:
            self._cool -= 1
            return None
        if self.max_shards:
            limit = min(limit, self.max_shards)

        # single-key skew: more shards won't help; split the key
        if (can_split and self.skew > 0.0 and self._hi >= self.dwell
                and report.heavy_hitters and n_active > 1):
            for key, est, share in report.heavy_hitters:
                if share < self.skew:
                    break                    # ranked: rest are cooler
                if key in already_split:
                    continue
                return self._fire(Action(
                    kind="split", keys=(key,),
                    reason=f"key {key} holds {share:.0%} of window "
                           f"events (skew >= {self.skew:.0%})"))
        if self._hi >= self.dwell:
            target = min(limit, n_active * self.scale_factor)
            if target > n_active:
                why = (f"p99 latency {p99:.0f} ticks > {self.p99_high:g}"
                       if self.p99_high > 0.0
                       else f"pressure {mean:.2f} > high {self.high}")
                return self._fire(Action(
                    kind="scale", target=target,
                    reason=f"{why} for {self._hi} windows"))
        if self._lo >= self.dwell:
            target = max(self.min_shards, n_active // self.scale_factor)
            if target < n_active:
                return self._fire(Action(
                    kind="scale", target=target,
                    reason=f"pressure {mean:.2f} < low {self.low} "
                           f"for {self._lo} windows"))
        if (self.rebalance_ratio > 0.0 and p.size and mean > 0.0
                and float(p.max()) / mean >= self.rebalance_ratio):
            return self._fire(Action(
                kind="rebalance",
                reason=f"imbalance {float(p.max()) / mean:.2f}x >= "
                       f"{self.rebalance_ratio}x"))
        return None

    def _fire(self, action: Action) -> Action:
        self._cool = self._next_cool or self.cooldown
        self._hi = self._lo = 0
        return action

    def heat_weights(self, report: TelemetryReport, owners=None,
                     ) -> np.ndarray:
        """Sketch-informed ring weights: shards hot from *diffuse* key
        heat shed arcs; the share attributable to a single heavy hitter
        is subtracted first (moving that key's arc merely relocates the
        hotspot — ``split`` is its remedy, not reweighting).  ``owners``
        maps candidate keys to their shard(s): [K] for a single owner
        arc, or [n_updaters, K] (``engine.heat_owners``) when routing
        is salted per destination updater — the sketch counted each key
        once per subscribing updater's dequeue, so a hitter's mass is
        split evenly across its per-updater rows."""
        heat = np.asarray(report.events, np.float64).copy()
        if owners is not None and report.heavy_hitters:
            keys = np.asarray([k for k, _, _ in report.heavy_hitters],
                              np.int32)
            own = np.atleast_2d(np.asarray(owners(keys)))
            for row in own:
                for (key, est, _), s in zip(report.heavy_hitters, row):
                    if 0 <= s < heat.shape[0]:
                        heat[s] = max(0.0, heat[s] - est / own.shape[0])
        act = [s for s in report.active if s < heat.shape[0]]
        mean = float(heat[act].mean()) if act else 0.0
        if mean <= 0.0:
            return np.ones_like(heat)
        return np.power((mean + 1.0) / (heat + 1.0), self.gain)
