"""Count-min key-heat sketch: device state + host readout.

The sketch answers "how hot is key k?" without per-key state: ``depth``
hash rows of ``width`` counters; an event increments one counter per
row; ``estimate`` reads the min over rows — an upper bound on the true
count that is exact when no collision survives all rows (error <=
e*N/width with prob 1 - e^-depth, the classic Cormode-Muthukrishnan
bound).  A count-min sketch cannot *enumerate* keys, so the device
state carries a small key-sample ring updated alongside the counters;
``heavy_hitters`` estimates the sampled candidates and ranks them.

Device side (``sketch_update``) runs inside the jitted tick on the
*routed* keys each updater dequeues — per-shard sketches therefore
measure per-arc heat, the signal the rebalance weights want.  Host
side (``estimate`` / ``heavy_hitters``) operates on a ``device_get``
snapshot taken at chunk boundaries only (DESIGN.md 13.2).  ``decay``
ages the counters at window boundaries so heat is recent, not
lifetime; the ``total`` event counter stays monotone (the metrics
window diffs it).
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.hashing import _mix32_np, fold_u32, fold_u32_np, mix32
from repro.kernels.countmin import countmin_update


def make_salts(depth: int, seed: int = 0x7E1E) -> np.ndarray:
    """Per-row hash salts (uint32), deterministic in (depth, seed)."""
    rows = np.arange(depth, dtype=np.uint32)
    return _mix32_np(rows * np.uint32(0x85EBCA6B) + np.uint32(seed))


def make_sketch(depth: int, width: int, sample: int,
                key_dtype=jnp.int32) -> Dict[str, Any]:
    """Fresh sketch state (no leading shard dim; engines broadcast).
    The sample ring carries raw keys, so it shares the key dtype."""
    return {
        "counts": jnp.zeros((depth, width), jnp.int32),
        "total": jnp.zeros((), jnp.int32),
        "sample": jnp.zeros((sample,), key_dtype),
        "sample_n": jnp.zeros((), jnp.int32),
    }


def columns(keys, salts: np.ndarray, width: int):
    """[B] integer keys -> [depth, B] int32 hashed columns (jit-safe;
    one broadcast avalanche over all rows at once — bitwise the same
    as per-row ``hash_key(keys, salt)``, which ``estimate`` uses;
    64-bit keys enter through the same xor-fold)."""
    h = mix32(fold_u32(keys)[None, :]
              ^ jnp.asarray(salts, jnp.uint32)[:, None])
    return (h % jnp.uint32(width)).astype(jnp.int32)


def sketch_update(sk, keys, valid, salts: np.ndarray, *,
                  impl: str = "auto"):
    """Fold one batch of (keys, valid) into the sketch — called inside
    the jitted tick; everything here is shape-static and sync-free.

    The sample ring update is an elementwise select, not a scatter:
    batch row ``i`` overwrites ring slot ``i`` when valid.  That makes
    the ring *positional best-effort* — a key only enters via the first
    ``S`` batch rows — which is exactly enough for its job (candidate
    discovery for heavy hitters: a hot key hits every row range across
    ticks) at a fraction of a scatter's cost; the count-min counters
    remain the exact part."""
    width = sk["counts"].shape[1]
    add = valid.astype(jnp.int32)
    counts = countmin_update(sk["counts"], columns(keys, salts, width),
                             add, impl=impl)
    S = sk["sample"].shape[0]
    B = keys.shape[0]
    k, v = (keys[:S], valid[:S]) if B >= S else \
        (jnp.pad(keys, (0, S - B)), jnp.pad(valid, (0, S - B)))
    n = jnp.sum(add)
    return {
        "counts": counts,
        "total": sk["total"] + n,
        "sample": jnp.where(v, k, sk["sample"]),
        "sample_n": sk["sample_n"] + n,
    }


def decay(sk, factor: float):
    """Age the counters at a window boundary (host-driven, off the hot
    path): ``factor`` in (0, 1) scales heat down, 0 hard-resets.  The
    monotone ``total`` / sample ring are left alone — the metrics
    window diffs ``total`` and the ring is already time-local."""
    counts = sk["counts"]
    if factor <= 0.0:
        counts = jnp.zeros_like(counts)
    else:
        counts = jnp.floor(counts.astype(jnp.float32) * factor) \
            .astype(counts.dtype)
    return {**sk, "counts": counts}


# ---- host-side readout (chunk-boundary snapshots) --------------------

def estimate(counts: np.ndarray, keys, salts: np.ndarray) -> np.ndarray:
    """Point estimates for ``keys`` from a host snapshot of one sketch:
    min over rows — always >= the true (decayed) count.  Pure numpy
    (``_mix32_np`` is bitwise ``hash_key``): the readout path must not
    add device dispatches beyond the boundary snapshot itself."""
    counts = np.asarray(counts)
    # arrays keep their key width (the fold matches the device path);
    # bare sequences default to int32
    if not (isinstance(keys, np.ndarray) and keys.dtype.kind in "iu"):
        keys = np.asarray(keys, np.int32)
    keys = np.atleast_1d(keys)
    width = counts.shape[1]
    ests = []
    for d, s in enumerate(salts):
        cols = _mix32_np(fold_u32_np(keys) ^ np.uint32(s))
        ests.append(counts[d, cols % np.uint32(width)])
    return np.min(np.stack(ests), axis=0)


def candidates(sample: np.ndarray, sample_n: int) -> np.ndarray:
    """Distinct keys currently resident in the sample ring."""
    sample = np.asarray(sample)
    n = min(int(sample_n), sample.shape[0])
    return np.unique(sample[:n]) if n else np.zeros(0, sample.dtype)


def heavy_hitters(counts: np.ndarray, sample: np.ndarray, sample_n: int,
                  salts: np.ndarray, k: int = 8
                  ) -> List[Tuple[int, int]]:
    """Top-k ``(key, estimated_count)`` among the sampled candidates,
    hottest first.  Candidates come from the sample ring; a key that
    never landed in the ring during the window cannot be reported — by
    construction such a key received few recent events."""
    cand = candidates(sample, sample_n)
    if not len(cand):
        return []
    est = estimate(counts, cand, salts)
    order = np.argsort(-est, kind="stable")[:k]
    return [(int(cand[i]), int(est[i])) for i in order]
