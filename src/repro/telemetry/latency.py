"""Per-arc latency histograms: device state + host readout.

The device side answers "how old is each event when an updater dequeues
it?" without any per-event host traffic: one power-of-two-bucket
histogram per updater arc, updated inside the jitted tick from
``engine_tick - event.ts`` (``kernels/histogram``).  Bucket ``b`` holds
latencies in ``[2^(b-1), 2^b)`` (bucket 0 is exactly {0}); the binning
is the integer bit-length ``32 - clz(lat)``, so bucket edges are exact
— no float log2 jitter at powers of two — and the top bucket saturates.

For a source-fed updater the reading is queue delay; for the terminal
updater of a map/update chain it is the paper's end-to-end
event-time-to-slate-visibility.  The report pools all arcs for the
``event_latency_p*`` quantiles and keeps per-arc ``queue_delay_p99``.

Host side (``quantile``) interpolates percentiles from *windowed*
bucket-count deltas at chunk boundaries only — the counters ride the
same ``begin_observe``/``finish_observe`` device_get the drivers
already pay for (DESIGN.md 18), so the hot path gains zero syncs.
"""
from __future__ import annotations

from typing import Any, Dict, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.histogram import histogram_update

# Logical power-of-two buckets; 32 covers the full int32 latency range
# (bucket 31 holds >= 2^30 ticks).  The device row is padded up to the
# TPU lane width so the Pallas one-hot kernel stays engaged.
N_BUCKETS = 32
LANE = 128


def pad_width(n_buckets: int) -> int:
    """Device row width: logical buckets padded to a lane multiple.
    The padded tail is never hit (bucketize saturates below it)."""
    return ((max(1, n_buckets) + LANE - 1) // LANE) * LANE


def make_hist(arcs: Sequence[str], n_buckets: int) -> Dict[str, Any]:
    """Fresh histogram state, one row per updater arc (no leading
    shard dim; engines broadcast).  ``sum`` accumulates total latency
    ticks for the Prometheus ``_sum`` series — int32, pinned so x64
    mode cannot widen the scan carry."""
    w = pad_width(n_buckets)
    return {a: {"counts": jnp.zeros((1, w), jnp.int32),
                "sum": jnp.zeros((), jnp.int32)}
            for a in arcs}


def bucketize(lat, n_buckets: int):
    """[B] int32 latencies -> [B] int32 bucket indices (jit-safe).

    Integer bit-length binning: 0 -> 0, 1 -> 1, [2,4) -> 2, [4,8) -> 3,
    ... [2^(b-1), 2^b) -> b, clamped to the saturating top bucket.
    ``clz`` keeps the edges bitwise exact — float ``log2`` misplaces
    counts at large powers of two."""
    lat = jnp.maximum(lat, 0).astype(jnp.int32)
    b = jnp.int32(32) - jax.lax.clz(lat)
    return jnp.minimum(b, jnp.int32(n_buckets - 1))


def hist_update(h, tick, ts, valid, *, n_buckets: int,
                impl: str = "auto"):
    """Fold one dequeued batch into one arc's histogram — called inside
    the jitted tick; shape-static and sync-free.  ``tick - ts`` is the
    event's age in source ticks at dequeue (clamped at 0 for
    future-stamped events)."""
    lat = jnp.maximum(tick - ts, 0).astype(jnp.int32)
    cols = bucketize(lat, n_buckets)[None, :]          # [1, B]
    add = valid.astype(jnp.int32)
    return {
        "counts": histogram_update(h["counts"], cols, add, impl=impl),
        "sum": h["sum"] + jnp.sum(jnp.where(valid, lat, 0),
                                  dtype=jnp.int32),
    }


# ---- host-side readout (chunk-boundary snapshots) --------------------

def bucket_lo(b: int) -> int:
    """Inclusive lower edge of bucket b (in ticks)."""
    return 0 if b <= 0 else 1 << (b - 1)


def bucket_hi(b: int) -> int:
    """Exclusive upper edge of bucket b (in ticks)."""
    return 1 << b


def quantile(counts: np.ndarray, q: float, *, n_buckets: int) -> float:
    """Interpolated quantile from (windowed) bucket counts.

    Standard histogram interpolation: find the bucket holding rank
    ``q * N`` and place the quantile linearly within its ``[lo, hi)``
    edge interval.  The saturating top bucket has no finite upper edge,
    so mass landing there reports the bucket's lower edge (the
    Prometheus ``histogram_quantile`` convention for +Inf)."""
    counts = np.asarray(counts, np.float64).ravel()[:n_buckets]
    total = counts.sum()
    if total <= 0:
        return 0.0
    target = q * total
    cum = 0.0
    for b, c in enumerate(counts):
        if c <= 0:
            continue
        if cum + c >= target:
            if b >= n_buckets - 1:
                return float(bucket_lo(b))
            lo, hi = bucket_lo(b), bucket_hi(b)
            frac = min(1.0, max(0.0, (target - cum) / c))
            return float(lo + (hi - lo) * frac)
        cum += c
    return float(bucket_lo(n_buckets - 1))


def quantiles(counts: np.ndarray, qs: Sequence[float], *,
              n_buckets: int):
    return [quantile(counts, q, n_buckets=n_buckets) for q in qs]
