"""Workflow graph: operators wired by streams (paper section 3, Figure 1).

A MapUpdate application is a directed graph (cycles allowed) whose nodes
are map/update functions and edges are streams.  The engine executes one
*tick* per step: every operator consumes from its input queue, produced
events land on subscriber queues for the next tick (pipelined, so
end-to-end latency = graph depth x tick time — the paper's pipeline).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence

from repro.core.operators import Mapper, Operator, SequentialUpdater, Updater


@dataclass
class Workflow:
    operators: Sequence[Operator]
    external_streams: Sequence[str] = ()   # fed by sources (never emitted
                                           # into by operators: throttle-safe)

    def __post_init__(self):
        names = [op.name for op in self.operators]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate operator names: {names}")
        self.by_name: Dict[str, Operator] = {op.name: op
                                             for op in self.operators}
        # stream -> subscriber operator names
        self.subscribers: Dict[str, List[str]] = {}
        for op in self.operators:
            for s in op.subscribes:
                self.subscribers.setdefault(s, []).append(op.name)
        self._validate()

    def _validate(self):
        produced = set(self.external_streams)
        for op in self.operators:
            produced.update(op.out_streams)
        for op in self.operators:
            for s in op.subscribes:
                if s not in produced:
                    raise ValueError(
                        f"operator {op.name!r} subscribes to stream {s!r} "
                        f"that nothing produces")
        for s in self.external_streams:
            for op in self.operators:
                if s in op.out_streams:
                    raise ValueError(
                        f"{op.name!r} emits into external stream {s!r}; "
                        "the paper forbids this (source-throttling "
                        "deadlock analysis, section 5)")

    # ---- helpers ----
    def updaters(self) -> List[Updater]:
        return [op for op in self.operators if isinstance(op, Updater)]

    def mappers(self) -> List[Mapper]:
        return [op for op in self.operators if isinstance(op, Mapper)]

    def dests_of(self, stream: str) -> List[str]:
        return self.subscribers.get(stream, [])

    def op_index(self, name: str) -> int:
        return [op.name for op in self.operators].index(name)
