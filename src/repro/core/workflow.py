"""Workflow graph: operators wired by streams (paper section 3, Figure 1).

A MapUpdate application is a directed graph (cycles allowed) whose nodes
are map/update functions and edges are streams.  The engine executes one
*tick* per step: every operator consumes from its input queue, produced
events land on subscriber queues for the next tick (pipelined, so
end-to-end latency = graph depth x tick time — the paper's pipeline).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence

from repro.core.event import format_spec, spec_matches
from repro.core.operators import Mapper, Operator, SequentialUpdater, Updater


@dataclass
class Workflow:
    operators: Sequence[Operator]
    external_streams: Sequence[str] = ()   # fed by sources (never emitted
                                           # into by operators: throttle-safe)

    def __post_init__(self):
        names = [op.name for op in self.operators]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate operator names: {names}")
        self.by_name: Dict[str, Operator] = {op.name: op
                                             for op in self.operators}
        # stream -> subscriber operator names
        self.subscribers: Dict[str, List[str]] = {}
        for op in self.operators:
            for s in op.subscribes:
                self.subscribers.setdefault(s, []).append(op.name)
        self._validate()

    def _validate(self):
        produced = set(self.external_streams)
        for op in self.operators:
            produced.update(op.out_streams)
        for op in self.operators:
            for s in op.subscribes:
                if s not in produced:
                    raise ValueError(
                        f"operator {op.name!r} subscribes to stream {s!r} "
                        f"that nothing produces")
        for s in self.external_streams:
            for op in self.operators:
                if s in op.out_streams:
                    raise ValueError(
                        f"{op.name!r} emits into external stream {s!r}; "
                        "the paper forbids this (source-throttling "
                        "deadlock analysis, section 5)")
        # producer/subscriber spec agreement: a mismatch here would
        # otherwise surface as an opaque shape/dtype error inside jit
        # (enqueue of a batch into a queue preallocated with the
        # subscriber's in_value_spec).  External streams carry no
        # declared spec — the subscriber's spec is authoritative there.
        for prod in self.operators:
            for s, out_spec in prod.out_streams.items():
                for sub_name in self.subscribers.get(s, []):
                    sub = self.by_name[sub_name]
                    if not spec_matches(out_spec, sub.in_value_spec):
                        raise ValueError(
                            f"stream {s!r}: producer {prod.name!r} emits "
                            f"value_spec {format_spec(out_spec)} but "
                            f"subscriber {sub_name!r} expects "
                            f"{format_spec(sub.in_value_spec)} "
                            f"(in_value_spec)")

    # ---- helpers ----
    def updaters(self) -> List[Updater]:
        return [op for op in self.operators if isinstance(op, Updater)]

    def mappers(self) -> List[Mapper]:
        return [op for op in self.operators if isinstance(op, Mapper)]

    def dests_of(self, stream: str) -> List[str]:
        return self.subscribers.get(stream, [])

    def op_index(self, name: str) -> int:
        return [op.name for op in self.operators].index(name)
