"""Events and event batches.

The paper's event is ``<sid, ts, k, v>`` processed one at a time; the TPU
adaptation processes *microbatches*: a struct-of-arrays ``EventBatch`` with
a validity mask (fixed capacity, SPMD-friendly).  ``v`` is a pytree of
arrays with leading dim B — schema-free blobs live host-side in the KV
store; on device we carry their encoded features (DESIGN.md section 9).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclass
class EventBatch:
    sid: jnp.ndarray      # int32 [B] stream id
    ts: jnp.ndarray       # int32 [B] timestamp ticks (global across streams)
    key: jnp.ndarray      # int32 [B] event key (hashed key space)
    value: Any            # pytree, leaves [B, ...]
    valid: jnp.ndarray    # bool  [B]

    @property
    def capacity(self) -> int:
        return int(self.key.shape[0])

    def count(self):
        # pinned accumulator: jnp.sum widens int32 under x64, which
        # would leak int64 into the scan carry (queue/table counters)
        return jnp.sum(self.valid, dtype=jnp.int32)

    # ---- constructors ----
    @staticmethod
    def empty(capacity: int, value_spec: Dict[str, Any],
              key_dtype=jnp.int32) -> "EventBatch":
        """value_spec: pytree of (shape_suffix, dtype)."""
        value = jax.tree.map(
            lambda s: jnp.zeros((capacity,) + tuple(s[0]), s[1]),
            value_spec, is_leaf=_is_spec_leaf)
        z = jnp.zeros((capacity,), jnp.int32)
        return EventBatch(sid=z, ts=z,
                          key=jnp.zeros((capacity,), key_dtype),
                          value=value,
                          valid=jnp.zeros((capacity,), bool))

    @staticmethod
    def of(key, value, *, ts=None, sid=None, valid=None,
           key_dtype=None) -> "EventBatch":
        if key_dtype is None:
            # arrays keep their key width; bare sequences default to
            # int32 (stable even when jax_enable_x64 widens literals)
            kd = getattr(key, "dtype", None)
            key_dtype = kd if kd is not None \
                and np.dtype(kd).kind in "iu" else jnp.int32
        key = jnp.asarray(key, key_dtype)
        b = key.shape[0]
        # scalars broadcast to the batch (ts=3 means "whole batch at
        # tick 3", not a 0-d array that breaks take())
        full = lambda v, dt: jnp.broadcast_to(jnp.asarray(v, dt), (b,))
        return EventBatch(
            sid=jnp.zeros((b,), jnp.int32) if sid is None
            else full(sid, jnp.int32),
            ts=jnp.arange(b, dtype=jnp.int32) if ts is None
            else full(ts, jnp.int32),
            key=key,
            value=jax.tree.map(jnp.asarray, value),
            valid=jnp.ones((b,), bool) if valid is None
            else full(valid, bool),
        )

    # ---- transforms (all shape-static) ----
    def with_value(self, value) -> "EventBatch":
        return EventBatch(self.sid, self.ts, self.key, value, self.valid)

    def mask(self, keep) -> "EventBatch":
        return EventBatch(self.sid, self.ts, self.key, self.value,
                          self.valid & keep)

    def take(self, idx) -> "EventBatch":
        g = lambda a: a[idx]
        return EventBatch(g(self.sid), g(self.ts), g(self.key),
                          jax.tree.map(g, self.value), g(self.valid))

    def pad_to(self, capacity: int) -> "EventBatch":
        b = self.capacity
        if b == capacity:
            return self
        assert capacity > b
        pad = lambda a: jnp.pad(
            a, [(0, capacity - b)] + [(0, 0)] * (a.ndim - 1))
        return EventBatch(pad(self.sid), pad(self.ts), pad(self.key),
                          jax.tree.map(pad, self.value), pad(self.valid))

    def sort_by_key_ts(self) -> "EventBatch":
        """Deterministic (key, ts) order; invalid rows sink to the end.
        This realizes the paper's 'events fed in increasing timestamp
        order with deterministic tie-breaking' per updater.  Stable
        passes give a lexicographic (key, ts) sort without widening the
        key.  The middle pass pushes invalid rows behind valid ones
        *within* the sink key group too, so a genuine event at the key
        dtype's max (the sink value) keeps its valid run contiguous —
        the updater paths write a run's total at its last valid row."""
        sink = jnp.asarray(jnp.iinfo(self.key.dtype).max, self.key.dtype)
        by_ts = self.take(jnp.argsort(self.ts, stable=True))
        by_val = by_ts.take(jnp.argsort(~by_ts.valid, stable=True))
        invalid_key = jnp.where(by_val.valid, by_val.key, sink)
        out = by_val.take(jnp.argsort(invalid_key, stable=True))
        # rewrite invalid rows' keys to the sink value so the key array is
        # truly sorted (downstream run detection relies on it)
        skey = jnp.where(out.valid, out.key, sink)
        return EventBatch(out.sid, out.ts, skey, out.value, out.valid)

    # ---- host-side helpers ----
    def to_host(self):
        n = int(np.asarray(self.count()))
        v = np.asarray(self.valid)
        sel = np.nonzero(v)[0][:n]
        return {
            "sid": np.asarray(self.sid)[sel],
            "ts": np.asarray(self.ts)[sel],
            "key": np.asarray(self.key)[sel],
            "value": jax.tree.map(lambda a: np.asarray(a)[sel], self.value),
        }


def _is_spec_leaf(x):
    return (isinstance(x, tuple) and len(x) == 2
            and isinstance(x[0], tuple))


# ---- value-spec utilities (shared by workflow validation + the
#      declarative api layer) ----

def is_spec_leaf(x) -> bool:
    """A value_spec leaf is ``(shape_suffix_tuple, dtype)``."""
    return _is_spec_leaf(x)


def spec_of(value) -> Any:
    """value pytree with leading batch dim -> value_spec pytree."""
    return jax.tree.map(lambda a: (tuple(a.shape[1:]), a.dtype), value)


def spec_matches(a, b) -> bool:
    """Structural equality of two value_specs: same pytree shape, same
    shape suffixes, same dtypes (dtype aliases normalized)."""
    la, ta = jax.tree.flatten(a, is_leaf=_is_spec_leaf)
    lb, tb = jax.tree.flatten(b, is_leaf=_is_spec_leaf)
    if ta != tb:
        return False
    for x, y in zip(la, lb):
        if not (_is_spec_leaf(x) and _is_spec_leaf(y)):
            return False
        if tuple(x[0]) != tuple(y[0]) or np.dtype(x[1]) != np.dtype(y[1]):
            return False
    return True


def format_spec(spec) -> str:
    """Compact human-readable value_spec (for validation errors)."""
    def leaf(s):
        return f"{np.dtype(s[1]).name}{list(s[0])}"
    return str(jax.tree.map(leaf, spec, is_leaf=_is_spec_leaf))


def concat(batches) -> EventBatch:
    cat = lambda *xs: jnp.concatenate(xs, axis=0)
    return EventBatch(
        sid=cat(*[b.sid for b in batches]),
        ts=cat(*[b.ts for b in batches]),
        key=cat(*[b.key for b in batches]),
        value=jax.tree.map(cat, *[b.value for b in batches]),
        valid=cat(*[b.valid for b in batches]),
    )


def compact(batch: EventBatch) -> EventBatch:
    """Move valid events to the front (stable)."""
    order = jnp.argsort(~batch.valid, stable=True)
    return batch.take(order)
