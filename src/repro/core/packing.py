"""Slate pytree <-> lane-aligned flat buffer for the fused update path.

The ``slate_update`` Pallas kernel (``kernels/slate_update``) operates on
a single ``[C, D]`` f32 table; real updaters declare slates as pytrees of
mixed-dtype leaves.  This layer gives each updater a static *pack spec*:
leaves are flattened in pytree order, each contributing
``prod(shape_suffix)`` f32 columns, and D is padded up to a multiple of
``LANE_ALIGN`` so the kernel's ``supported()`` check always holds.

Pack/unpack are pure reshape/concat/cast ops, so under jit XLA fuses
them into the surrounding gather/scatter — the kernel's
``input_output_aliases`` donation chain stays intact through the tick.

Contract (``AssociativeUpdater.sum_mergeable`` / ``monoid``): the packed
representation is only sound when ``combine`` and ``merge`` are the same
elementwise monoid on every leaf and a fresh slate is all zeros — the
monoid's identity.  For "sum" a segmented sum of packed deltas
scatter-added into the packed table is exactly
``merge(slate, combine(...))``; for "max" (non-negative leaves only, so
zero *is* the identity — including the zero pad columns this layer
appends) a segmented max scatter-maxed in is exact *and* bitwise
order-independent.  Integer leaves (e.g. counters, packed score|id
words from repro/ml) ride in f32 lanes — exact up to 2**24, the same
bound a float32 "sum" column already has.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Tuple

import jax
import jax.numpy as jnp

LANE_ALIGN = 8   # kernels/slate_update/kernel.supported(): D % 8 == 0


def _is_spec_leaf(x):
    return isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple)


@dataclass(frozen=True)
class PackSpec:
    """Static layout: one (shape_suffix, dtype, width) per pytree leaf,
    in ``jax.tree.leaves`` order, plus the padded row width D."""
    leaves: Tuple[Tuple[Tuple[int, ...], Any, int], ...]
    treedef: Any
    width: int          # sum of leaf widths (unpadded)
    padded_width: int   # D, multiple of LANE_ALIGN

    @property
    def d(self) -> int:
        return self.padded_width


def pack_spec(slate_spec) -> PackSpec:
    """Build the layout from an updater's ``slate_spec()`` pytree of
    ((shape_suffix), dtype) leaves."""
    leaves, treedef = jax.tree.flatten(slate_spec, is_leaf=_is_spec_leaf)
    rows = []
    width = 0
    for shape, dtype in leaves:
        dt = jnp.dtype(dtype)
        if dt.itemsize > 4:
            raise TypeError(
                f"pack_spec: 64-bit slate leaf {dt} cannot ride the "
                f"fused path's f32 lanes exactly; keep slate values at "
                f"<= 32 bits (only *keys* widen under key_dtype=int64)")
        w = 1
        for s in shape:
            w *= int(s)
        rows.append((tuple(int(s) for s in shape), dt, w))
        width += w
    padded = max(LANE_ALIGN,
                 -(-width // LANE_ALIGN) * LANE_ALIGN)
    return PackSpec(leaves=tuple(rows), treedef=treedef, width=width,
                    padded_width=padded)


def pack(tree, spec: PackSpec, *, pad: bool = True) -> jnp.ndarray:
    """[N, ...] pytree -> [N, D] f32.  ``pad`` zero-fills the tail
    columns up to the lane-aligned width the kernel needs; jnp backends
    can skip it and work at the exact width."""
    leaves = jax.tree.leaves(tree)
    assert len(leaves) == len(spec.leaves), (len(leaves), spec)
    n = leaves[0].shape[0]
    cols = [l.reshape(n, w).astype(jnp.float32)
            for l, (_, _, w) in zip(leaves, spec.leaves)]
    if pad and spec.padded_width > spec.width:
        cols.append(jnp.zeros((n, spec.padded_width - spec.width),
                              jnp.float32))
    return cols[0] if len(cols) == 1 else jnp.concatenate(cols, axis=1)


def unpack(buf: jnp.ndarray, spec: PackSpec):
    """[N, D] f32 -> [N, ...] pytree with the original leaf dtypes."""
    n = buf.shape[0]
    leaves: List[jnp.ndarray] = []
    off = 0
    for shape, dtype, w in spec.leaves:
        col = buf[:, off:off + w].reshape((n,) + shape)
        leaves.append(col.astype(dtype))
        off += w
    return jax.tree.unflatten(spec.treedef, leaves)
