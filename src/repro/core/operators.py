"""MapUpdate operators.

The paper's ``map(event) -> event*`` and ``update(event, slate) -> event*``
become *vectorized* operators over EventBatches.  Updaters come in two
flavors matching the two execution paths the engine offers (DESIGN.md
section 2):

- ``AssociativeUpdater``: declares ``lift / combine / merge`` so the engine
  can pre-combine same-key events with a segmented associative scan (the
  TPU analogue of Example 6's key-splitting trick is built on this);
- ``SequentialUpdater``: declares ``step(slate, event)`` with strict
  per-key timestamp order, executed as a padded-run scan (vmap over keys,
  scan over run positions).

Emissions are shape-static: each input event may emit at most one event
per declared output stream, masked by validity (multi-emission is
expressed by chaining a mapper that fans out).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.event import EventBatch


class Operator:
    """Base: every operator subscribes to streams and has a unique name."""
    name: str = "op"
    subscribes: Sequence[str] = ()

    # value_spec of events this operator consumes: pytree of
    # ((shape_suffix, dtype)) leaves — needed to preallocate queues.
    in_value_spec: Dict[str, Any] = {}

    # stream -> value_spec this operator can emit to
    out_streams: Dict[str, Any] = {}


class Mapper(Operator):
    """Stateless. ``map_batch`` must be jax-traceable and respect
    ``batch.valid`` (emitted batches carry their own validity masks)."""

    # FLOP-heavy stages (model inference, repro/ml) set this so the
    # planner's fusion pass keeps them behind their own queue hop: fusing
    # a matmul-bound stage into a neighboring field map would hide its
    # backpressure from telemetry and couple its latency to cheap stages.
    flop_heavy: bool = False

    def map_batch(self, batch: EventBatch) -> Dict[str, EventBatch]:
        raise NotImplementedError


class Updater(Operator):
    """Stateful: owns one slate per (updater, key) — paper section 3."""

    ttl: int = 0          # ticks; 0 = forever (paper's default)
    table_capacity: int = 4096   # per-shard slate-table capacity

    def slate_spec(self) -> Dict[str, Any]:
        """pytree of (shape_suffix, dtype) describing one slate."""
        raise NotImplementedError

    def init_slate(self, n: int):
        """Fresh slates for first-seen keys: pytree with leading dim n."""
        return jax.tree.map(
            lambda s: jnp.zeros((n,) + tuple(s[0]), s[1]),
            self.slate_spec(), is_leaf=_is_spec_leaf)


class AssociativeUpdater(Updater):
    """update is a commutative monoid over per-event deltas.

    Engine contract:
      total_k = combine(lift(e_1), ..., lift(e_m))   for key k's events
      slate_k' = merge(slate_k, total_k)
      emit(keys, old, new, ts) -> optional events (<=1 per key per stream)

    ``sum_mergeable`` (DESIGN.md section 2.3): declare True iff
      - ``combine(a, b)`` and ``merge(s, d)`` are both elementwise
        additions of every slate/delta leaf, and
      - a fresh slate (``init_slate``) is all zeros, and
      - leaf values stay exact in f32 lanes (|v| < 2**24 for ints).
    Counter-style updaters (paper Examples 1/2/4/5) all qualify.  The
    engine then routes this updater through the fused
    ``kernels/slate_update`` path: pack deltas -> segmented-sum combine
    -> in-place scatter-add into the packed table, skipping the generic
    gather/merge/scatter.  Declaring it for a non-additive updater is a
    correctness bug, not a slowdown.  Updaters that emit downstream
    events keep the generic path (emissions need old/new slates).

    ``monoid`` generalizes the same contract to other elementwise
    monoids the fused path implements.  Currently:
      - "sum": identical to ``sum_mergeable=True``
      - "max": combine/merge are elementwise ``maximum`` of every leaf,
        all leaf values are **non-negative** (so the zero ``init_slate``
        and zeroed padding rows are the identity), and values stay exact
        in f32 lanes.  Max is idempotent, which buys exactness under
        at-least-once replay for free (repro/ml's ``semantic_topk`` is
        built on this).
    Leave it "" for updaters with a general combine.
    """

    sum_mergeable: bool = False
    monoid: str = ""

    def lift(self, batch: EventBatch):
        """EventBatch -> delta pytree with leading dim B."""
        raise NotImplementedError

    def combine(self, d1, d2):
        """Elementwise-batched associative combine of two delta pytrees."""
        raise NotImplementedError

    def merge(self, slate, delta):
        """Fold combined delta into slate (batched over keys)."""
        raise NotImplementedError

    def emit(self, keys, old_slate, new_slate, ts
             ) -> Dict[str, EventBatch]:
        return {}


class SequentialUpdater(Updater):
    """General update function: strict per-key arrival order.

    ``step(slate_row, ev)`` consumes one event for one key; ``ev`` is a
    dict(sid, ts, key, value) of single rows; must be vmap-able.
    Returns (new_slate_row, emissions) where emissions is
    {stream: (value_row, emit_flag)}.
    """

    max_run: int = 32     # static per-key events per tick (hotspot bound)

    def step(self, slate_row, ev) -> Tuple[Any, Dict[str, Any]]:
        raise NotImplementedError


def _is_spec_leaf(x):
    return isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple)
