"""Updater execution paths: the TPU-native updater hot loop.

- ``apply_associative``: sort by key -> segmented associative scan
  pre-combines every key's events into one delta -> single slate
  gather/merge/scatter.  O(B log B) with batch-wide parallelism; this is
  the path the ``slate_update`` Pallas kernel accelerates.

- ``apply_sequential``: sort by (key, ts) -> padded-run scan preserving
  the paper's strict per-key timestamp order: vmap over key runs, scan
  over run positions.  Run length is statically bounded (``max_run``);
  events beyond the bound are *deferred* back to the caller (re-queued
  next tick), which is how a hotspot manifests here — and what the
  two-choice + key-splitting mitigations relieve.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.event import EventBatch
from repro.core.operators import AssociativeUpdater, SequentialUpdater
from repro.slates import table as tbl


def _bshape(mask, like):
    return mask.reshape(mask.shape + (1,) * (like.ndim - 1))


def _segmented_combine(updater, deltas, boundary):
    """Inclusive segmented scan: each row ends up holding the combine of
    its run's prefix; run-last rows hold run totals."""

    def op(a, b):
        fa, va = a
        fb, vb = b
        combined = updater.combine(va, vb)
        v = jax.tree.map(
            lambda c, y: jnp.where(_bshape(fb, y), y, c), combined, vb)
        return (fa | fb, v)

    _, scanned = jax.lax.associative_scan(op, (boundary, deltas))
    return scanned


def apply_associative(updater: AssociativeUpdater, table: tbl.SlateTable,
                      batch: EventBatch, tick
                      ) -> Tuple[tbl.SlateTable, Dict[str, EventBatch],
                                 jnp.ndarray]:
    """Returns (table, emissions, n_processed)."""
    batch = batch.sort_by_key_ts()
    B = batch.capacity
    key = batch.key
    prev_key = jnp.concatenate([jnp.full((1,), -2, jnp.int32), key[:-1]])
    boundary = key != prev_key                       # run starts
    next_key = jnp.concatenate([key[1:], jnp.full((1,), -3, jnp.int32)])
    run_last = key != next_key                       # run totals live here

    deltas = updater.lift(batch)
    scanned = _segmented_combine(updater, deltas, boundary)

    unique = run_last & batch.valid
    table, slot, found, placed = tbl.insert_or_find(table, key, unique)
    ok = unique & placed
    old = tbl.read_slates(table, slot, found & ok, updater.init_slate)
    new = updater.merge(old, scanned)
    table = tbl.write_slates(table, slot, ok, new, tick)

    emissions = updater.emit(key, old, new, batch.ts)
    emissions = {s: eb.mask(ok) for s, eb in emissions.items()}
    return table, emissions, batch.count()


def apply_sequential(updater: SequentialUpdater, table: tbl.SlateTable,
                     batch: EventBatch, tick
                     ) -> Tuple[tbl.SlateTable, Dict[str, EventBatch],
                                EventBatch, jnp.ndarray]:
    """Returns (table, emissions, deferred_events, n_processed).

    Deferred = valid events whose per-key run exceeded ``max_run`` this
    tick (hotspot backpressure); the engine re-queues them.
    """
    batch = batch.sort_by_key_ts()
    B = batch.capacity
    key, valid = batch.key, batch.valid
    first_idx = jnp.searchsorted(key, key, side="left").astype(jnp.int32)
    pos = jnp.arange(B, dtype=jnp.int32) - first_idx
    run_start = (pos == 0) & valid
    in_budget = pos < updater.max_run
    deferred = batch.mask(valid & ~in_budget)

    table, slot, found, placed = tbl.insert_or_find(table, key, run_start)
    ok = run_start & placed
    slates = tbl.read_slates(table, slot, found & ok, updater.init_slate)

    # emission accumulators at sorted-row granularity
    out_specs = updater.out_streams
    em_vals = {s: jax.tree.map(
        lambda sp: jnp.zeros((B,) + tuple(sp[0]), sp[1]), spec,
        is_leaf=_is_spec_leaf) for s, spec in out_specs.items()}
    em_keys = {s: jnp.zeros((B,), jnp.int32) for s in out_specs}
    em_flag = {s: jnp.zeros((B,), bool) for s in out_specs}

    idx_all = jnp.arange(B, dtype=jnp.int32)

    def body(carry, j):
        slates_c, em_vals_c, em_keys_c, em_flag_c = carry
        idx = jnp.clip(idx_all + j, 0, B - 1)
        active = (ok & (idx_all + j < B) & (key[idx] == key)
                  & valid[idx] & (j < updater.max_run))
        ev = {
            "sid": batch.sid[idx], "ts": batch.ts[idx], "key": key[idx],
            "value": jax.tree.map(lambda a: a[idx], batch.value),
        }
        new_slates, emits = jax.vmap(updater.step)(slates_c, ev)
        slates_c = jax.tree.map(
            lambda n, o: jnp.where(_bshape(active, n), n, o),
            new_slates, slates_c)
        for s in out_specs:
            if s not in emits:
                continue
            row = emits[s]
            flag = row["emit"] & active
            safe = jnp.where(flag, idx, B)
            em_vals_c = dict(em_vals_c)
            em_vals_c[s] = jax.tree.map(
                lambda acc, v: acc.at[safe].set(v.astype(acc.dtype),
                                                mode="drop"),
                em_vals_c[s], row["value"])
            em_keys_c = dict(em_keys_c)
            em_keys_c[s] = em_keys_c[s].at[safe].set(
                row["key"].astype(jnp.int32), mode="drop")
            em_flag_c = dict(em_flag_c)
            em_flag_c[s] = em_flag_c[s].at[safe].set(True, mode="drop")
        return (slates_c, em_vals_c, em_keys_c, em_flag_c), None

    carry = (slates, em_vals, em_keys, em_flag)
    (slates, em_vals, em_keys, em_flag), _ = jax.lax.scan(
        body, carry, jnp.arange(updater.max_run, dtype=jnp.int32))

    table = tbl.write_slates(table, slot, ok, slates, tick)

    emissions = {}
    for s in out_specs:
        emissions[s] = EventBatch(
            sid=jnp.zeros((B,), jnp.int32),
            ts=batch.ts + 1,
            key=em_keys[s],
            value=em_vals[s],
            valid=em_flag[s],
        )
    n_proc = jnp.sum((valid & in_budget).astype(jnp.int32))
    return table, emissions, deferred, n_proc


def _is_spec_leaf(x):
    return isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple)
