"""Updater execution paths: the TPU-native updater hot loop.

- ``apply_associative``: sort by key -> segmented associative scan
  pre-combines every key's events into one delta -> single slate
  gather/merge/scatter.  O(B log B) with batch-wide parallelism.
  Updaters declaring ``sum_mergeable`` (and no output streams) skip the
  generic scan entirely: their deltas and slate table are packed into
  lane-aligned [B, D] / [C, D] f32 buffers (``core/packing.py``) and the
  whole combine+scatter runs as one fused ``kernels/slate_update`` call
  (Pallas on TPU, segment-sum oracle elsewhere), in-place via
  ``input_output_aliases``.

- ``apply_sequential``: sort by (key, ts) -> padded-run scan preserving
  the paper's strict per-key timestamp order: vmap over key runs, scan
  over run positions.  Run length is statically bounded (``max_run``);
  events beyond the bound are *deferred* back to the caller (re-queued
  next tick), which is how a hotspot manifests here — and what the
  two-choice + key-splitting mitigations relieve.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.event import EventBatch
from repro.core.operators import AssociativeUpdater, SequentialUpdater
from repro.kernels.slate_update import ops as slate_ops
from repro.kernels.slate_update import ref as slate_ref
from repro.slates import table as tbl


def _bshape(mask, like):
    return mask.reshape(mask.shape + (1,) * (like.ndim - 1))


def _last_valid_of_run(key, valid):
    """Per-key write point: the last *valid* row of each sorted run.

    Invalid rows are rewritten to the sink key 2**31-1 by
    ``sort_by_key_ts`` and ordered behind valid rows; a genuine event
    with that key shares the sink run, so the run's write point must be
    its last valid row — marking the run's final row would either drop
    the key (final row invalid) or leak invalid rows' lift deltas into
    its slate."""
    next_key = jnp.concatenate([key[1:], jnp.full((1,), -3, key.dtype)])
    next_valid = jnp.concatenate([valid[1:], jnp.zeros((1,), bool)])
    return (key != next_key) | (valid & ~next_valid)


def _segmented_combine(updater, deltas, boundary):
    """Inclusive segmented scan: each row ends up holding the combine of
    its run's prefix; run-last rows hold run totals."""

    def op(a, b):
        fa, va = a
        fb, vb = b
        combined = updater.combine(va, vb)
        v = jax.tree.map(
            lambda c, y: jnp.where(_bshape(fb, y), y, c), combined, vb)
        return (fa | fb, v)

    _, scanned = jax.lax.associative_scan(op, (boundary, deltas))
    return scanned


def merge_monoid(updater: AssociativeUpdater) -> str:
    """The elementwise monoid the fused path may run this updater under:
    "sum" (``sum_mergeable`` or ``monoid="sum"``), "max"
    (``monoid="max"``, non-negative leaves), or "" (generic combine —
    fused path ineligible)."""
    if getattr(updater, "sum_mergeable", False):
        return "sum"
    return getattr(updater, "monoid", "") or ""


def fused_eligible(updater: AssociativeUpdater) -> bool:
    """The fused slate_update path handles updaters whose combine/merge
    are an elementwise monoid the kernel implements (sum or non-negative
    max) and that emit nothing (the packed path never materializes
    old/new slates per key)."""
    return (merge_monoid(updater) in ("sum", "max")
            and not updater.out_streams)


def apply_associative(updater: AssociativeUpdater, table: tbl.SlateTable,
                      batch: EventBatch, tick, *, impl: str = "auto"
                      ) -> Tuple[tbl.SlateTable, Dict[str, EventBatch],
                                 jnp.ndarray]:
    """Returns (table, emissions, n_processed).

    ``impl`` selects the backend for ``fused_eligible`` updaters:
      - "off":  always the generic scan/gather/merge/scatter below
      - "auto": Pallas kernel on TPU (where the in-place [C, D] alias
                pays off); the generic path elsewhere
      - "pallas" / "interpret": force the kernel (packed [C, D] table,
        in-place via input_output_aliases; interpret runs on CPU)
      - "jnp":  packed segment-sum + direct scatter-add, no table pack —
        the portable fused fallback
      - "ref": force the packed-table jnp oracle
        (``kernels/slate_update/ref``) — exercises the same [C, D]
        buffer layout as the kernel without Pallas
    """
    if impl != "off" and fused_eligible(updater):
        if impl != "auto" or jax.default_backend() == "tpu":
            return _apply_associative_fused(updater, table, batch, tick,
                                            impl=impl)
    batch = batch.sort_by_key_ts()
    B = batch.capacity
    key = batch.key
    prev_key = jnp.concatenate([jnp.full((1,), -2, key.dtype), key[:-1]])
    boundary = key != prev_key                       # run starts
    run_last = _last_valid_of_run(key, batch.valid)  # run totals live here

    deltas = updater.lift(batch)
    scanned = _segmented_combine(updater, deltas, boundary)

    unique = run_last & batch.valid
    table, slot, found, placed = tbl.insert_or_find(table, key, unique)
    ok = unique & placed
    old = tbl.read_slates(table, slot, found & ok, updater.init_slate)
    new = updater.merge(old, scanned)
    table = tbl.write_slates(table, slot, ok, new, tick)

    emissions = updater.emit(key, old, new, batch.ts)
    emissions = {s: eb.mask(ok) for s, eb in emissions.items()}
    return table, emissions, batch.count()


def _apply_associative_fused(updater: AssociativeUpdater,
                             table: tbl.SlateTable, batch: EventBatch,
                             tick, *, impl: str
                             ) -> Tuple[tbl.SlateTable,
                                        Dict[str, EventBatch],
                                        jnp.ndarray]:
    """Counter-style hot path: pack deltas/table to [B,D]/[C,D] f32 and
    run the fused segmented-combine + in-place scatter.  Requires
    ``fused_eligible(updater)`` — an elementwise sum or non-negative max
    combine/merge, zero init slates, no emissions — so skipping the
    generic gather/merge/scatter is exact (modulo f32 summation on the
    sum monoid, which the generic "sum" leaf already uses; max is
    order-independent and therefore bitwise-identical)."""
    op = merge_monoid(updater)
    batch = batch.sort_by_key_ts()
    key = batch.key                       # invalid rows sorted to sink
    run_last = _last_valid_of_run(key, batch.valid)
    unique = run_last & batch.valid

    spec = packing.pack_spec(updater.slate_spec())
    deltas = updater.lift(batch)
    # segment totals combine whole runs; invalid rows sharing the sink
    # run with a genuine key 2**31-1 must contribute the identity — zero
    # for sum, and zero again for max thanks to the non-negative contract
    deltas = jax.tree.map(
        lambda d: jnp.where(_bshape(batch.valid, d), d,
                            jnp.zeros_like(d)), deltas)
    if (jax.tree.structure(deltas)
            != jax.tree.structure(updater.slate_spec(),
                                  is_leaf=_is_spec_leaf)):
        raise TypeError(
            f"sum_mergeable updater {updater.name!r}: lift() pytree must "
            "match slate_spec() structure for the packed path")
    table, slot, found, placed = tbl.insert_or_find(table, key, unique)
    ok = unique & placed
    slots = jnp.where(ok, slot, jnp.int32(-1))            # -1 = no write
    safe = jnp.where(ok, slot, table.capacity)

    # Newly placed keys may land in a slot freed by expire_ttl /
    # fail_shard, which clear the key but keep the dead occupant's vals;
    # the generic path masks them out via read_slates' init_slate
    # substitution, the additive path must zero them before the add.
    safe_fresh = jnp.where(ok & ~found, slot, table.capacity)
    base_vals = jax.tree.map(
        lambda tv: tv.at[safe_fresh].set(0, mode="drop"), table.vals)

    backend = impl
    if backend == "auto":
        backend = ("pallas" if jax.default_backend() == "tpu"
                   else "jnp")
    if backend == "jnp":
        # combine via one segment reduce, then scatter run totals into
        # the slate leaves directly — no [C, D] table pack and no lane
        # padding on this side, so the CPU/GPU fallback touches only B
        # rows at the exact slate width.
        packed_deltas = packing.pack(deltas, spec, pad=False)
        totals = slate_ref.run_totals(key, packed_deltas, op=op)  # [B, D]
        total_tree = packing.unpack(totals, spec)          # [B, ...]
        if op == "max":
            vals = jax.tree.map(
                lambda tv, dv: tv.at[safe].max(dv.astype(tv.dtype),
                                               mode="drop"),
                base_vals, total_tree)
        else:
            vals = jax.tree.map(
                lambda tv, dv: tv.at[safe].add(dv.astype(tv.dtype),
                                               mode="drop"),
                base_vals, total_tree)
    else:
        packed_deltas = packing.pack(deltas, spec)        # [B, D] aligned
        packed_vals = packing.pack(base_vals, spec)       # [C, D]
        packed_vals = slate_ops.slate_update(key, packed_deltas, slots,
                                             packed_vals, impl=backend,
                                             op=op)
        vals = packing.unpack(packed_vals, spec)

    # bookkeeping scatter (ts / dirty), same slots write_slates would hit
    ts = table.ts.at[safe].set(tick, mode="drop")
    dirty = table.dirty.at[safe].set(True, mode="drop")
    table = tbl.SlateTable(keys=table.keys, ts=ts, dirty=dirty, vals=vals,
                           dropped=table.dropped)
    return table, {}, batch.count()


def apply_sequential(updater: SequentialUpdater, table: tbl.SlateTable,
                     batch: EventBatch, tick
                     ) -> Tuple[tbl.SlateTable, Dict[str, EventBatch],
                                EventBatch, jnp.ndarray]:
    """Returns (table, emissions, deferred_events, n_processed).

    Deferred = valid events whose per-key run exceeded ``max_run`` this
    tick (hotspot backpressure); the engine re-queues them.
    """
    batch = batch.sort_by_key_ts()
    B = batch.capacity
    key, valid = batch.key, batch.valid
    first_idx = jnp.searchsorted(key, key, side="left").astype(jnp.int32)
    pos = jnp.arange(B, dtype=jnp.int32) - first_idx
    run_start = (pos == 0) & valid
    in_budget = pos < updater.max_run
    deferred = batch.mask(valid & ~in_budget)

    table, slot, found, placed = tbl.insert_or_find(table, key, run_start)
    ok = run_start & placed
    slates = tbl.read_slates(table, slot, found & ok, updater.init_slate)

    # emission accumulators at sorted-row granularity
    out_specs = updater.out_streams
    em_vals = {s: jax.tree.map(
        lambda sp: jnp.zeros((B,) + tuple(sp[0]), sp[1]), spec,
        is_leaf=_is_spec_leaf) for s, spec in out_specs.items()}
    em_keys = {s: jnp.zeros((B,), key.dtype) for s in out_specs}
    em_flag = {s: jnp.zeros((B,), bool) for s in out_specs}

    idx_all = jnp.arange(B, dtype=jnp.int32)

    def body(carry, j):
        slates_c, em_vals_c, em_keys_c, em_flag_c = carry
        idx = jnp.clip(idx_all + j, 0, B - 1)
        active = (ok & (idx_all + j < B) & (key[idx] == key)
                  & valid[idx] & (j < updater.max_run))
        ev = {
            "sid": batch.sid[idx], "ts": batch.ts[idx], "key": key[idx],
            "value": jax.tree.map(lambda a: a[idx], batch.value),
        }
        new_slates, emits = jax.vmap(updater.step)(slates_c, ev)
        slates_c = jax.tree.map(
            lambda n, o: jnp.where(_bshape(active, n), n, o),
            new_slates, slates_c)
        for s in out_specs:
            if s not in emits:
                continue
            row = emits[s]
            flag = row["emit"] & active
            safe = jnp.where(flag, idx, B)
            em_vals_c = dict(em_vals_c)
            em_vals_c[s] = jax.tree.map(
                lambda acc, v: acc.at[safe].set(v.astype(acc.dtype),
                                                mode="drop"),
                em_vals_c[s], row["value"])
            em_keys_c = dict(em_keys_c)
            em_keys_c[s] = em_keys_c[s].at[safe].set(
                row["key"].astype(key.dtype), mode="drop")
            em_flag_c = dict(em_flag_c)
            em_flag_c[s] = em_flag_c[s].at[safe].set(True, mode="drop")
        return (slates_c, em_vals_c, em_keys_c, em_flag_c), None

    carry = (slates, em_vals, em_keys, em_flag)
    (slates, em_vals, em_keys, em_flag), _ = jax.lax.scan(
        body, carry, jnp.arange(updater.max_run, dtype=jnp.int32))

    table = tbl.write_slates(table, slot, ok, slates, tick)

    emissions = {}
    for s in out_specs:
        emissions[s] = EventBatch(
            sid=jnp.zeros((B,), jnp.int32),
            ts=batch.ts + 1,
            key=em_keys[s],
            value=em_vals[s],
            valid=em_flag[s],
        )
    n_proc = jnp.sum(valid & in_budget, dtype=jnp.int32)
    return table, emissions, deferred, n_proc


def _is_spec_leaf(x):
    return isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple)
