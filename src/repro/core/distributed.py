"""Distributed MapUpdate engine: the single-shard tick under shard_map.

Muppet's data path — workers hash events to peers and write directly into
their queues — becomes one ``all_to_all`` per workflow hop: each shard
buckets its outgoing events by destination shard (ring lookup), the
collective delivers every bucket, and the receiving shard enqueues.  No
master is on the data path; the ring is a runtime *array* input with a
fixed shape, so failure re-routes and elastic joins/leaves/reweights
swap ring contents without recompiling — ``scale`` / ``add_shards`` /
``remove_shards`` / ``rebalance`` migrate slates and in-flight events
loss-free at a drain barrier (DESIGN.md section 12); only changing the
physical slot count (grow, or compaction shrink) recompiles.

Migration itself is tiered (DESIGN.md section 14): shape-preserving
reconfigures re-home slate rows *on device* — ``exchange_rows`` packs
each table's moving rows by their new ring owner and delivers them with
one ``all_to_all``, the same collective the event path uses — while
shape changes (physical grow, slot compaction) fall back to the host
remap.  Both paths produce bitwise-identical slates (the PR-4 parity
contract).

Two-choice dispatch (Muppet 2.0 dual queues): for associative updaters,
per-key load beyond ``two_choice_threshold`` in a tick spills to the
key's secondary shard; each shard then holds a *partial* aggregate and
``read_slate`` merges the (at most two) partials — the same <=2-contender
bound the paper proves acceptable in production.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field, replace
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import apply as apply_mod
from repro.core import queues as q_mod
from repro.core.durability import (DurabilityConfig, EngineDurability,
                                   merge_replay_ticks)
from repro.core.engine import EngineConfig, resolve_key_dtype
from repro.core.event import EventBatch, concat
from repro.core.hashing import HashRing, route, route_secondary
from repro.core.operators import (AssociativeUpdater, Mapper,
                                  SequentialUpdater, Updater)
from repro.core.queues import OverflowPolicy
from repro.core.workflow import Workflow
from repro.slates import flush as flush_mod
from repro.slates import table as tbl
from repro.telemetry import latency as lat_mod
from repro.telemetry import sketch as sk_mod
from repro.telemetry.controller import LoadAutoscaler
from repro.telemetry.metrics import MetricsRegistry, TelemetryConfig
from repro.telemetry.trace import ControlLog, Tracer, null_span


def _axis_size(axis_names) -> int:
    """Static size of the (possibly multi-) mesh axis we're mapped over.
    ``jax.lax.axis_size`` only exists on newer jax; ``psum(1, axes)``
    constant-folds to a python int on every version we support."""
    if hasattr(jax.lax, "axis_size"):
        return int(jax.lax.axis_size(axis_names))
    return int(jax.lax.psum(1, axis_names))


def _linear_shard_index(axis_names):
    """This shard's linearized id over the (possibly multi-) mesh axes —
    the shard-dim index of the global state arrays, matching
    ``np.prod``-order linearization (trailing axis fastest)."""
    idx = None
    for a in axis_names:
        i = jax.lax.axis_index(a)
        idx = i if idx is None else idx * _axis_size(a) + i
    return idx


def _salt(name: str) -> int:
    h = 2166136261
    for c in name.encode():
        h = ((h ^ c) * 16777619) & 0xFFFFFFFF
    return h


def exchange(batch: EventBatch, dest, axis_names, cap_per_dest: int
             ) -> Tuple[EventBatch, jnp.ndarray]:
    """Route events to destination shards with one all_to_all.

    Per-destination buckets have static capacity; excess events are
    dropped and counted (bounded queues, paper section 4.3).  Returns the
    received local batch [n*cap] and the local overflow count.
    """
    n = _axis_size(axis_names)
    B = batch.capacity
    dest = jnp.where(batch.valid, dest, n)              # invalid -> sink
    order = jnp.argsort(dest, stable=True)
    sb = batch.take(order)
    sdest = dest[order]
    pos = jnp.arange(B, dtype=jnp.int32) - jnp.searchsorted(
        sdest, sdest, side="left").astype(jnp.int32)
    ok = sb.valid & (sdest < n) & (pos < cap_per_dest)
    slot = jnp.where(ok, sdest * cap_per_dest + pos, n * cap_per_dest)
    dropped = jnp.sum((sb.valid & (sdest < n) & ~ok).astype(jnp.int32))

    buckets = EventBatch.empty(
        n * cap_per_dest,
        jax.tree.map(lambda a: (a.shape[1:], a.dtype), sb.value),
        key_dtype=sb.key.dtype)

    def put(dst, src):
        return dst.at[slot].set(src, mode="drop")

    buckets = EventBatch(
        sid=put(buckets.sid, sb.sid), ts=put(buckets.ts, sb.ts),
        key=put(buckets.key, sb.key),
        value=jax.tree.map(put, buckets.value, sb.value),
        valid=put(buckets.valid, ok))

    def a2a(x):
        return jax.lax.all_to_all(
            x.reshape((n, cap_per_dest) + x.shape[1:]), axis_names,
            split_axis=0, concat_axis=0).reshape((n * cap_per_dest,)
                                                 + x.shape[1:])

    received = EventBatch(
        sid=a2a(buckets.sid), ts=a2a(buckets.ts), key=a2a(buckets.key),
        value=jax.tree.map(a2a, buckets.value), valid=a2a(buckets.valid))
    return received, dropped


def exchange_rows(t: tbl.SlateTable, dest_salt: int, ring_hashes,
                  ring_shards, axis_names, cap_per_dest: int, combine
                  ) -> Tuple[tbl.SlateTable, jnp.ndarray]:
    """Slate-row migration as one all_to_all (DESIGN.md section 14.1):
    the table-row generalization of :func:`exchange`, run under
    shard_map on every shard at a reconfigure boundary.

    Each shard routes its rows through the *new* ring, packs movers
    ``(key, value, ts, dirty)`` into per-destination buckets, trades
    buckets with the collective, and rebuilds its table from stayers +
    arrivals.  Duplicate keys converging on one shard (two-choice /
    hot-split partials) fold via the updater's ``combine`` (else
    last-ts-wins), exactly like the host rebuild: folded rows are
    dirty, the fold is timestamp-monotone, and rows that do not fit
    (bucket overflow, full table) are dropped and counted.  ``combine``
    must be associative and — for bitwise parity with the host path's
    first-encountered fold order — commutative, which every partial-
    producing dispatch mode already requires.

    ``cap_per_dest`` bounds rows moved per (src, dest) pair; the caller
    sizes it from an exact on-device count (``_migrate_device``), so
    nothing is lost in practice.  Returns ``(new_table, moved_out)``.
    """
    n = _axis_size(axis_names)
    me = _linear_shard_index(axis_names)
    C = t.capacity
    valid = t.keys != tbl.EMPTY
    owner = route(t.keys, dest_salt, ring_hashes, ring_shards)
    mover = valid & (owner != me)
    moved_out = jnp.sum(mover.astype(jnp.int32))

    # pack movers into per-destination buckets (the exchange() layout)
    dest = jnp.where(mover, owner, n)                   # stayers -> sink
    order = jnp.argsort(dest, stable=True)
    sdest = dest[order]
    pos = jnp.arange(C, dtype=jnp.int32) - jnp.searchsorted(
        sdest, sdest, side="left").astype(jnp.int32)
    ok = (sdest < n) & (pos < cap_per_dest)
    slot = jnp.where(ok, sdest * cap_per_dest + pos, n * cap_per_dest)
    lost = jnp.sum(((sdest < n) & ~ok).astype(jnp.int32))

    def bucket(src, fill):
        buf = jnp.full((n * cap_per_dest,) + src.shape[1:], fill,
                       src.dtype)
        return buf.at[slot].set(src[order], mode="drop")

    def a2a(x):
        return jax.lax.all_to_all(
            x.reshape((n, cap_per_dest) + x.shape[1:]), axis_names,
            split_axis=0, concat_axis=0).reshape((n * cap_per_dest,)
                                                 + x.shape[1:])

    rvalid = a2a(jnp.zeros((n * cap_per_dest,), bool)
                 .at[slot].set(ok, mode="drop"))
    rkeys = a2a(bucket(t.keys, tbl.EMPTY))
    rts = a2a(bucket(t.ts, 0))
    rdirty = a2a(bucket(t.dirty, False))
    rvals = jax.tree.map(lambda v: a2a(bucket(v, 0)), t.vals)

    # candidates = stayers ∪ arrivals; sort valid-first, key-ascending
    # (two stable passes — no 64-bit composite key needed) so duplicate
    # keys are adjacent and segment folding is a single scan
    stay = valid & (owner == me)
    ckeys = jnp.concatenate([t.keys, rkeys])
    cvalid = jnp.concatenate([stay, rvalid])
    cts = jnp.concatenate([t.ts, rts])
    cdirty = jnp.concatenate([t.dirty, rdirty])
    cvals = jax.tree.map(lambda a, b: jnp.concatenate([a, b]),
                         t.vals, rvals)
    o1 = jnp.argsort(ckeys, stable=True)
    o2 = jnp.argsort(jnp.where(cvalid[o1], 0, 1).astype(jnp.int32),
                     stable=True)
    order2 = o1[o2]
    ks, vs = ckeys[order2], cvalid[order2]
    ts_s, dt_s = cts[order2], cdirty[order2]
    vals_s = jax.tree.map(lambda v: v[order2], cvals)

    prev_same = jnp.concatenate(
        [jnp.zeros((1,), bool), (ks[1:] == ks[:-1]) & vs[1:] & vs[:-1]])
    seg_start = ~prev_same

    def _b(mask, like):
        return mask.reshape(mask.shape + (1,) * (like.ndim - 1))

    def fold(a, b):
        fa, va, ta, da = a
        fb, vb, tb, db = b
        if combine is not None:
            merged = combine(va, vb)
        else:
            merged = jax.tree.map(
                lambda x, y: jnp.where(_b(tb >= ta, x), y, x), va, vb)
        v = jax.tree.map(lambda m, y: jnp.where(_b(fb, m), y, m),
                         merged, vb)
        return (fa | fb, v,
                jnp.where(fb, tb, jnp.maximum(ta, tb)),
                jnp.where(fb, db, jnp.ones_like(db)))

    _, fvals, fts, fdirty = jax.lax.associative_scan(
        fold, (seg_start, vals_s, ts_s, dt_s))

    # one representative per key: the last row of its sorted run holds
    # the full fold; singleton runs keep their original ts/dirty
    rep = vs & ~jnp.concatenate([prev_same[1:], jnp.zeros((1,), bool)])

    fresh = tbl.SlateTable(
        keys=jnp.full((C,), tbl.EMPTY, t.keys.dtype),
        ts=jnp.zeros((C,), jnp.int32),
        dirty=jnp.zeros((C,), bool),
        vals=jax.tree.map(jnp.zeros_like, t.vals),
        dropped=t.dropped + lost)
    fresh, slot2, _, placed = tbl.insert_or_find(fresh, ks, rep)
    safe = jnp.where(placed, slot2, C)
    new_vals = jax.tree.map(
        lambda dst, src: dst.at[safe].set(src.astype(dst.dtype),
                                          mode="drop"),
        fresh.vals, fvals)
    new = tbl.SlateTable(
        keys=fresh.keys,
        ts=fresh.ts.at[safe].set(fts, mode="drop"),
        dirty=fresh.dirty.at[safe].set(fdirty, mode="drop"),
        vals=new_vals,
        dropped=fresh.dropped + jnp.sum((rep & ~placed).astype(jnp.int32)))
    return new, moved_out


def exchange_queue(q: q_mod.QueueState, dest_salt: int, ring_hashes,
                   ring_shards, axis_names, cap_per_dest: int
                   ) -> Tuple[q_mod.QueueState, jnp.ndarray]:
    """Queued-event re-homing as one all_to_all: the queue counterpart
    of :func:`exchange_rows`, so a planned leave with backlog
    (``drain_max=0``, or a drain barrier that could not retire the
    queues) stays on the device migration path instead of falling back
    to the host remap.

    Mirrors ``_migrate_queues_host`` exactly: every in-``size`` slot is
    scanned in dequeue order, routed by its key's *primary* owner on
    the new ring (validity flags ride along as payload, like the host
    scan), and each destination rebuilds its queue compacted at head 0
    in (source shard asc, dequeue order) — the host path's
    shard-ascending concat.  ``dropped`` carries plus any overflow
    (bucket or destination-capacity); ``peak`` restarts at the
    post-migration backlog, the rebalance window's load signal.
    Returns ``(new_queue, moved_out)``.
    """
    n = _axis_size(axis_names)
    me = _linear_shard_index(axis_names)
    buf = q.buf
    C = buf.capacity
    pos = (q.head + jnp.arange(C, dtype=jnp.int32)) % C
    live = jnp.arange(C, dtype=jnp.int32) < q.size
    sid, ts, key = buf.sid[pos], buf.ts[pos], buf.key[pos]
    vflag = buf.valid[pos]
    vals = jax.tree.map(lambda v: v[pos], buf.value)
    owner = route(key, dest_salt, ring_hashes, ring_shards)
    moved_out = jnp.sum((live & (owner != me)).astype(jnp.int32))

    # all live events (stayers included) go through the buckets so the
    # rebuild's arrival order is purely (src, dequeue) — host parity
    dest = jnp.where(live, owner, n)                    # dead -> sink
    order = jnp.argsort(dest, stable=True)
    sdest = dest[order]
    bpos = jnp.arange(C, dtype=jnp.int32) - jnp.searchsorted(
        sdest, sdest, side="left").astype(jnp.int32)
    ok = (sdest < n) & (bpos < cap_per_dest)
    slot = jnp.where(ok, sdest * cap_per_dest + bpos, n * cap_per_dest)
    lost = jnp.sum(((sdest < n) & ~ok).astype(jnp.int32))

    def bucket(src, fill):
        b = jnp.full((n * cap_per_dest,) + src.shape[1:], fill,
                     src.dtype)
        return b.at[slot].set(src[order], mode="drop")

    def a2a(x):
        return jax.lax.all_to_all(
            x.reshape((n, cap_per_dest) + x.shape[1:]), axis_names,
            split_axis=0, concat_axis=0).reshape((n * cap_per_dest,)
                                                 + x.shape[1:])

    rlive = a2a(jnp.zeros((n * cap_per_dest,), bool)
                .at[slot].set(ok, mode="drop"))
    rsid, rts, rkey = a2a(bucket(sid, 0)), a2a(bucket(ts, 0)), \
        a2a(bucket(key, 0))
    rvflag = a2a(bucket(vflag, False))
    rvals = jax.tree.map(lambda v: a2a(bucket(v, 0)), vals)

    # compact arrivals at head 0; received layout is already src-major
    # with dequeue order within each source, so rank order == the host
    # rebuild's FIFO order
    rank = jnp.cumsum(rlive.astype(jnp.int32)) - 1
    fits = rlive & (rank < C)
    size = jnp.sum(fits.astype(jnp.int32))
    tgt = jnp.where(fits, rank, C)

    def scat(src, fill):
        b = jnp.full((C,) + src.shape[1:], fill, src.dtype)
        return b.at[tgt].set(src, mode="drop")

    nbuf = EventBatch(
        sid=scat(rsid, 0), ts=scat(rts, 0), key=scat(rkey, 0),
        value=jax.tree.map(lambda v: scat(v, 0), rvals),
        valid=scat(rvflag, False))
    drops = lost + jnp.sum((rlive & ~fits).astype(jnp.int32))
    return q_mod.QueueState(
        buf=nbuf, head=jnp.zeros_like(q.head), size=size,
        dropped=q.dropped + drops, peak=size), moved_out


@dataclass
class AutoscalePolicy:
    """Declarative elasticity for ``DistributedEngine.run`` (DESIGN.md
    section 12): scale the active shard set at given source ticks and/or
    rebalance the weighted ring from the per-shard load signal every k
    source ticks.  Exposed through the front door as
    ``RuntimeConfig(autoscale=AutoscalePolicy(...))``."""

    scale_at: Dict[int, int] = field(default_factory=dict)
    # source tick -> target active shard count (fires before that tick)
    rebalance_every: int = 0     # source ticks between reweights; 0 = off
    drain_max: int = 64          # drain-barrier bound per reconfigure
    on_change: Optional[Any] = None  # callback(MigrationReport), e.g. log


@dataclass
class MigrationReport:
    """What a live reconfigure moved (scale / rebalance / leave)."""

    n_shards: int                # physical shard slots after
    active: List[int]            # active shard ids after
    drain_ticks: int             # barrier ticks run before migration
    moved_rows: Dict[str, int]   # slate rows re-homed, per updater
    moved_events: Dict[str, int]  # queued events re-homed, per operator
    recompiled: bool             # physical shape change (grow/compact)
    pause_s: float = 0.0         # wall seconds the stream stood still
    bytes_moved: int = 0         # payload re-homed (rows + events)
    path: str = "host"           # "device" (all_to_all) or "host" remap


@dataclass
class DistConfig(EngineConfig):
    exchange_slack: float = 2.0   # per-dest bucket capacity multiplier
    two_choice_threshold: int = 0  # 0 = off; else per-key spill point
    axis_names: Tuple[str, ...] = ("data",)
    # tick-scheduled AutoscalePolicy, or a closed-loop LoadAutoscaler
    # driven by the telemetry subsystem (DESIGN.md 13.3)
    autoscale: Optional[Any] = None
    # hot-key split set capacity (fixed shape).  0 = the split routing
    # path is not compiled into the tick at all (no per-event secondary
    # route); >0 opts in, and a LoadAutoscaler with skew > 0 implies 8.
    # Needs cfg.telemetry and no durability.  See split_keys.
    hot_key_capacity: int = 0
    # migration tier selection (DESIGN.md 14.1).  "auto": reconfigures
    # that keep physical shapes re-home slate rows AND any queued
    # backlog on device (all_to_all row + event exchange — a drained
    # queue set is no longer required); "off" forces the host remap
    # everywhere (debug / parity baseline).
    device_migration: str = "auto"
    # physical slot compaction (DESIGN.md 14.2): when a deactivation
    # leaves >= this fraction of slots dead, shrink the mesh to the
    # active set and free the parked slots' HBM (shape change — the
    # tick recompiles, like grow).  0 disables; compact() forces it.
    compact_threshold: float = 0.75


class DistributedEngine:
    """Global state lives sharded on dim 0 (= shard axis) of every leaf."""

    def __init__(self, workflow: Workflow, mesh: Mesh,
                 config: Optional[DistConfig] = None):
        self.wf = workflow
        self.mesh = mesh
        self.cfg = config or DistConfig()
        self.key_dtype = resolve_key_dtype(self.cfg.key_dtype)
        self.axes = self.cfg.axis_names
        self.n_shards = int(np.prod([mesh.shape[a] for a in self.axes]))
        self.ring = HashRing(self.n_shards)
        self._sharding = NamedSharding(mesh, P(self.axes))
        self._replicated = NamedSharding(mesh, P())
        cap = int(self.cfg.batch_size * self.cfg.exchange_slack
                  / self.n_shards)
        self.cap_per_dest = max(8, cap)
        self._step = None
        self._chunk = None
        self._empty_step = None
        self._plan_fn = None       # device-migration owner-count jit
        self._migrate_fns = {}     # (row_cap, ev_cap) -> jitted exchange
        self._read_fns = {}        # (updater, with_sec) -> batched read
        # serializes slate readers against in-flight reconfigures and
        # the donating step dispatches: a read racing either would see a
        # half-swapped ring or donated (deleted) buffers.  RLock so
        # read_split_slate can hold it across its sub-key loop while
        # read_slate re-acquires.  Drivers that publish a StateHandle
        # republish it *inside* the critical section (_live_handle).
        self.read_lock = threading.RLock()
        self._live_handle = None
        self._load_mark = np.zeros(self.n_shards)  # rebalance window base
        self.tick_cursor = 0      # post-run() *source* cursor
        self.dur: Optional[EngineDurability] = None
        if self.cfg.durability is not None:
            self.attach_durability(self.cfg.durability)
        # telemetry (DESIGN.md 13): a per-shard count-min sketch in the
        # jitted tick + the windowed registry; a closed-loop controller
        # implies it even when cfg.telemetry is unset
        tele = self.cfg.telemetry
        if tele is None and isinstance(self.cfg.autoscale,
                                       LoadAutoscaler):
            tele = self.cfg.autoscale.telemetry or TelemetryConfig()
        self.tele_cfg = tele
        self.telemetry: Optional[MetricsRegistry] = None
        self.tracer: Optional[Tracer] = None
        self._ctl_log: Optional[ControlLog] = None
        if tele is not None:
            self.telemetry = MetricsRegistry(
                tele, batch_size=self.cfg.batch_size)
            self._salts = self.telemetry.salts
            if tele.trace:
                self.tracer = Tracer()
            if tele.control_log:
                self._ctl_log = ControlLog(tele.control_log)
        # hot-key split set: fixed-shape runtime input of the tick, so
        # split/unsplit swap contents without recompiling (ring-style).
        # Opt-in (explicit capacity, or a skew-enabled controller):
        # compiling it in costs every associative delivery a secondary
        # ring route, so plain-telemetry runs skip it entirely.
        hot_cap = self.cfg.hot_key_capacity
        if (hot_cap == 0 and isinstance(self.cfg.autoscale,
                                        LoadAutoscaler)
                and self.cfg.autoscale.skew > 0.0):
            hot_cap = 8
        self._hot_capacity = (hot_cap if tele is not None
                              and self.cfg.durability is None else 0)
        self._hot_keys = np.zeros(max(1, self._hot_capacity),
                                  self.key_dtype)
        self._hot_valid = np.zeros(max(1, self._hot_capacity), bool)

    @property
    def key_bits(self) -> int:
        return int(self.key_dtype.itemsize) * 8

    def _span(self, name: str, **args):
        """Tracer span when tracing is on, else a free no-op."""
        return self.tracer.span(name, **args) if self.tracer \
            else null_span(**args)

    # ---- state ----
    def init_state(self):
        def per_shard(make):
            one = make()
            return jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x[None], (self.n_shards,) + x.shape).copy(), one)

        kd = self.key_dtype
        queues = {op.name: per_shard(partial(
            q_mod.make_queue, self.cfg.queue_capacity, op.in_value_spec,
            key_dtype=kd))
            for op in self.wf.operators}
        tables = {up.name: per_shard(partial(
            tbl.make_table, up.table_capacity, up.slate_spec(),
            key_dtype=kd))
            for up in self.wf.updaters()}
        z = lambda: jnp.zeros((self.n_shards,), jnp.int32)
        state = {
            "queues": queues, "tables": tables,
            "tick": z(),
            "exchange_dropped": z(),
            "throttle_hits": z(),
            "deferred": z(),
            "processed": {op.name: z() for op in self.wf.operators},
        }
        if self.tele_cfg is not None:
            tc = self.tele_cfg
            state["sketch"] = per_shard(partial(
                sk_mod.make_sketch, tc.depth, tc.width, tc.sample,
                key_dtype=kd))
            if tc.latency_buckets > 0:
                state["lat_hist"] = per_shard(partial(
                    lat_mod.make_hist,
                    [u.name for u in self.wf.updaters()],
                    tc.latency_buckets))
        state = jax.tree.map(lambda x: jnp.array(x, copy=True), state)
        return jax.device_put(state, self._shard_tree(state))

    def _shard_tree(self, state):
        def spec(path_unused, leaf):
            if leaf.ndim >= 1 and leaf.shape[0] == self.n_shards:
                return self._sharding
            return self._replicated
        return jax.tree_util.tree_map_with_path(spec, state)

    # ---- the per-shard tick ----
    def _local_tick(self, state, sources, ring_hashes, ring_shards,
                    hot_keys, hot_valid):
        cfg, wf = self.cfg, self.wf
        queues = {k: jax.tree.map(lambda x: x[0], v)
                  for k, v in state["queues"].items()}
        tables = {k: jax.tree.map(lambda x: x[0], v)
                  for k, v in state["tables"].items()}
        processed = {k: v[0] for k, v in state["processed"].items()}
        exchange_dropped = state["exchange_dropped"][0]
        throttle_hits = state["throttle_hits"][0]
        deferred_total = state["deferred"][0]
        tick = state["tick"][0]
        sketch = None
        if "sketch" in state:
            sketch = {k: v[0] for k, v in state["sketch"].items()}
        lat_hist = None
        if "lat_hist" in state:
            lat_hist = {k: jax.tree.map(lambda x: x[0], v)
                        for k, v in state["lat_hist"].items()}
        sources = {k: jax.tree.map(lambda x: x[0], v)
                   for k, v in sources.items()}
        outputs: Dict[str, List[EventBatch]] = {}

        def deliver_all(items):
            nonlocal throttle_hits, exchange_dropped
            work = deque(items)
            for _ in range(len(work) + 64):
                if not work:
                    return
                stream, batch = work.popleft()
                subs = wf.dests_of(stream)
                if not subs:
                    outputs.setdefault(stream, []).append(batch)
                    continue
                for dest_op in subs:
                    op = wf.by_name[dest_op]
                    dshard = route(batch.key, _salt(dest_op), ring_hashes,
                                   ring_shards)
                    if (cfg.two_choice_threshold
                            and isinstance(op, AssociativeUpdater)):
                        dshard = self._two_choice(batch, dshard, dest_op,
                                                  ring_hashes, ring_shards)
                    elif (self._hot_capacity
                            and isinstance(op, AssociativeUpdater)):
                        dshard = self._hot_split(
                            batch, dshard, dest_op, ring_hashes,
                            ring_shards, hot_keys, hot_valid, tick)
                    recv, dropped = exchange(batch, dshard, self.axes,
                                             self.cap_per_dest)
                    exchange_dropped = exchange_dropped + dropped
                    nq, ovf = q_mod.enqueue(queues[dest_op], recv)
                    pol = cfg.policy_for(dest_op)
                    if pol is OverflowPolicy.DROP:
                        nq = q_mod.count_drop(nq, ovf)
                    elif pol is OverflowPolicy.OVERFLOW_STREAM:
                        work.append((cfg.overflow_stream[dest_op], ovf))
                    elif pol is OverflowPolicy.THROTTLE:
                        throttle_hits = throttle_hits + ovf.count()
                        nq = q_mod.count_drop(nq, ovf)
                    queues[dest_op] = nq
            raise RuntimeError("overflow-stream routing did not converge")

        deliver_all(list(sources.items()))
        emitted_now: List[Tuple[str, EventBatch]] = []

        for op in wf.operators:
            queues[op.name], batch = q_mod.dequeue(queues[op.name],
                                                   cfg.batch_size)
            if sketch is not None and isinstance(op, Updater):
                # per-shard key heat from the *routed* keys this shard's
                # updaters dequeue — the per-arc signal rebalance wants.
                # Pure extra state; the tick never reads it (parity).
                sketch = sk_mod.sketch_update(
                    sketch, batch.key, batch.valid, self._salts,
                    impl=self.tele_cfg.impl)
            if lat_hist is not None and isinstance(op, Updater):
                # per-shard event age at dequeue (DESIGN.md 18): for a
                # terminal updater this is end-to-end event-time-to-
                # slate-visibility — same parity contract as the sketch
                lat_hist[op.name] = lat_mod.hist_update(
                    lat_hist[op.name], tick, batch.ts, batch.valid,
                    n_buckets=self.tele_cfg.latency_buckets,
                    impl=self.tele_cfg.impl)
            if isinstance(op, Mapper):
                outs = op.map_batch(batch)
                for s, b in outs.items():
                    emitted_now.append((s, b.mask(batch.valid & b.valid)))
                processed[op.name] = processed[op.name] + batch.count()
            elif isinstance(op, AssociativeUpdater):
                tables[op.name], ems, n = apply_mod.apply_associative(
                    op, tables[op.name], batch, tick, impl=cfg.fused)
                emitted_now.extend(ems.items())
                processed[op.name] = processed[op.name] + n
            elif isinstance(op, SequentialUpdater):
                tables[op.name], ems, deferred, n = \
                    apply_mod.apply_sequential(op, tables[op.name], batch,
                                               tick)
                emitted_now.extend(ems.items())
                deferred_total = deferred_total + deferred.count()
                nq, ovf = q_mod.enqueue(queues[op.name], deferred)
                queues[op.name] = q_mod.count_drop(nq, ovf)
                processed[op.name] = processed[op.name] + n

        for up in wf.updaters():
            if up.ttl:
                tables[up.name] = tbl.expire_ttl(tables[up.name], tick,
                                                 up.ttl)

        deliver_all(emitted_now)

        out_batches = {s: concat(bs) if len(bs) > 1 else bs[0]
                       for s, bs in outputs.items()}
        lift = lambda t: jax.tree.map(lambda x: x[None], t)
        new_state = {
            "queues": {k: lift(v) for k, v in queues.items()},
            "tables": {k: lift(v) for k, v in tables.items()},
            "tick": (tick + 1)[None],
            "exchange_dropped": exchange_dropped[None],
            "throttle_hits": throttle_hits[None],
            "deferred": deferred_total[None],
            "processed": {k: v[None] for k, v in processed.items()},
        }
        if sketch is not None:
            new_state["sketch"] = {k: v[None] for k, v in sketch.items()}
        if lat_hist is not None:
            new_state["lat_hist"] = {k: lift(v)
                                     for k, v in lat_hist.items()}
        return new_state, {k: lift(v) for k, v in out_batches.items()}

    def _two_choice(self, batch, primary, dest_op, ring_hashes,
                    ring_shards):
        """Spill a key's per-tick excess to its secondary shard."""
        secondary = route_secondary(batch.key, _salt(dest_op), ring_hashes,
                                    ring_shards)
        key_sink = jnp.where(
            batch.valid, batch.key,
            jnp.asarray(jnp.iinfo(batch.key.dtype).max, batch.key.dtype))
        order = jnp.argsort(key_sink, stable=True)
        sk = key_sink[order]
        rank_sorted = jnp.arange(batch.capacity, dtype=jnp.int32) - \
            jnp.searchsorted(sk, sk, side="left").astype(jnp.int32)
        rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)
        spill = rank >= self.cfg.two_choice_threshold
        return jnp.where(spill, secondary, primary)

    def _hot_split(self, batch, primary, dest_op, ring_hashes,
                   ring_shards, hot_keys, hot_valid, tick):
        """Runtime hot-key relief (DESIGN.md 13.4): events whose key is
        in the (fixed-shape) hot set alternate between the key's
        primary and secondary ring shard — two-choice dispatch, but
        targeted at controller-identified heavy hitters instead of a
        per-tick rank threshold.  The row-index/tick parity flip sends
        ~half of each tick's hot events to each shard and flips halves
        every tick.  An empty set leaves routing bit-identical."""
        secondary = route_secondary(batch.key, _salt(dest_op),
                                    ring_hashes, ring_shards)
        is_hot = jnp.any((batch.key[:, None] == hot_keys[None, :])
                         & hot_valid[None, :], axis=1)
        flip = ((jnp.arange(batch.capacity, dtype=jnp.int32) ^ tick)
                & 1) == 1
        return jnp.where(is_hot & flip & batch.valid, secondary, primary)

    def _hot_table(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """The hot-key split set as runtime tick inputs (ring-style:
        contents swap, shape never does)."""
        return jnp.asarray(self._hot_keys), jnp.asarray(self._hot_valid)

    # ---- jit plumbing ----
    def _spec_like(self, tree):
        """Leading-dim-n_shards leaves are sharded, the rest replicated."""
        sharded, rep = P(self.axes), P()
        return jax.tree.map(
            lambda x: sharded
            if (hasattr(x, "ndim") and x.ndim >= 1
                and x.shape[0] == self.n_shards) else rep, tree)

    def step(self, state, sources: Dict[str, EventBatch]):
        """sources: global batches with leading dim n_shards*B_loc or
        [n_shards, B_loc] — pass [n_shards, B_loc] (leading shard axis)."""
        from jax.experimental.shard_map import shard_map
        if self._step is None:
            sharded, rep = P(self.axes), P()
            state_specs = self._spec_like(state)
            src_specs = jax.tree.map(lambda _: sharded, sources)

            def run(st, src, rh, rs, hk, hv):
                fn = shard_map(self._local_tick, mesh=self.mesh,
                               in_specs=(state_specs, src_specs, rep, rep,
                                         rep, rep),
                               out_specs=sharded,
                               check_rep=False)
                return fn(st, src, rh, rs, hk, hv)

            self._step = jax.jit(run, donate_argnums=(0,))
        rh, rs = self.ring.table()
        hk, hv = self._hot_table()
        return self._step(state, sources, rh, rs, hk, hv)

    def run_chunk(self, state, stacked_sources: Dict[str, EventBatch]):
        """T device-resident ticks in one dispatch (DESIGN.md 2.2).

        ``stacked_sources`` leaves are [T, n_shards, B, ...] — tick axis
        leading (scanned), shard axis second (split by shard_map).
        Returns ``(state, stacked_outputs, info)``; output leaves are
        [T, n_shards, ...] and ``info['throttle_hits']`` is the
        [T, n_shards] on-device per-tick trace, so the host syncs once
        per chunk for the backpressure signal.
        """
        from jax.experimental.shard_map import shard_map
        if self._chunk is None:
            stacked = P(None, self.axes)
            rep = P()
            state_specs = self._spec_like(state)
            src_specs = jax.tree.map(lambda _: stacked, stacked_sources)

            def local_chunk(st, src, rh, rs, hk, hv):
                def body(s, x):
                    s2, outs = self._local_tick(s, x, rh, rs, hk, hv)
                    return s2, (outs, s2["throttle_hits"])
                final, (outs, hits) = jax.lax.scan(body, st, src)
                return final, outs, hits

            def run(st, src, rh, rs, hk, hv):
                fn = shard_map(local_chunk, mesh=self.mesh,
                               in_specs=(state_specs, src_specs, rep, rep,
                                         rep, rep),
                               out_specs=(state_specs, stacked, stacked),
                               check_rep=False)
                return fn(st, src, rh, rs, hk, hv)

            self._chunk = jax.jit(run, donate_argnums=(0,))
        rh, rs = self.ring.table()
        hk, hv = self._hot_table()
        state, outs, hits = self._chunk(state, stacked_sources, rh, rs,
                                        hk, hv)
        return state, outs, {"throttle_hits": hits}

    # ---- durability (DESIGN.md section 10): per-shard WAL + frontier --
    def attach_durability(self, cfg: DurabilityConfig):
        """One WAL per shard (on durable storage, the role Cassandra's
        commit log plays), one shared slate store, one barrier frontier.
        Incompatible with two-choice dispatch: partial aggregates of the
        same key on two shards would clobber each other in the store."""
        if self.cfg.two_choice_threshold:
            raise ValueError("durability requires two_choice_threshold=0 "
                             "(per-key partials are not store-mergeable)")
        self.dur = EngineDurability(cfg, self.wf,
                                    self.cfg.queue_capacity,
                                    self.cfg.batch_size,
                                    n_shards=self.n_shards)

    def append_sources(self, tick: int, sources: Dict[str, EventBatch]):
        """Write-ahead: log each shard's slice of the [n_shards, B]
        source batches to that shard's WAL (call before ``step``).

        The device_get and the per-shard slicing run as one deferred
        thunk on the durability writer thread: the dispatch path only
        pays the enqueue.  Step/chunk dispatches never donate source
        buffers, so the captured device arrays stay valid until the
        thunk resolves; the frontier fence orders the thunk before any
        frontier that must cover this tick."""
        n_shards, dur = self.n_shards, self.dur

        def _log():
            host = {s: jax.tree.map(
                lambda x: np.asarray(jax.device_get(x)), b)
                for s, b in sources.items()}
            for sh in range(n_shards):
                sl = {s: EventBatch(sid=b.sid[sh], ts=b.ts[sh],
                                    key=b.key[sh],
                                    value=jax.tree.map(lambda x: x[sh],
                                                       b.value),
                                    valid=b.valid[sh])
                      for s, b in host.items()}
                sl = {s: b for s, b in sl.items() if b.valid.any()}
                dur._do_append(int(tick), sl, sh)

        dur.append_deferred(_log)

    def _step_empty(self, state):
        """One source-less tick (drain barriers, replay gap ticks)."""
        from jax.experimental.shard_map import shard_map
        if self._empty_step is None:
            sharded, rep = P(self.axes), P()
            state_specs = self._spec_like(state)

            def run(st, rh, rs, hk, hv):
                fn = shard_map(
                    lambda s, h, r, k, v: self._local_tick(s, {}, h, r,
                                                           k, v),
                    mesh=self.mesh,
                    in_specs=(state_specs, rep, rep, rep, rep),
                    out_specs=sharded, check_rep=False)
                return fn(st, rh, rs, hk, hv)

            self._empty_step = jax.jit(run, donate_argnums=(0,))
        rh, rs = self.ring.table()
        hk, hv = self._hot_table()
        state, _ = self._empty_step(state, rh, rs, hk, hv)
        return state

    def _drain_queues(self, state, max_ticks: int):
        d = 0
        while d < max_ticks:
            sizes = jax.device_get({k: q.size
                                    for k, q in state["queues"].items()})
            if all(int(v.sum()) == 0 for v in sizes.values()):
                break
            state = self._step_empty(state)
            d += 1
        return state, d

    def _flush_boundary(self, state, tick: int, meta=None):
        """Barrier-drain, flush every shard's dirty slates (one
        device_get per table), record the frontier.  ``meta`` is the
        driver cursor stored with the frontier (``_run_span`` records
        the source index, mirroring ``Engine.run``)."""
        dur = self.dur
        if dur.cfg.barrier:
            state, d = self._drain_queues(state, dur.cfg.drain_ticks_max)
            tick += d
        new_tables = {}
        for up in self.wf.updaters():
            t = state["tables"][up.name]
            dirty = np.asarray(jax.device_get(t.dirty))
            keys = np.asarray(jax.device_get(t.keys))
            ts = np.asarray(jax.device_get(t.ts))
            vals = jax.tree.map(lambda v: np.asarray(jax.device_get(v)),
                                t.vals)
            for sh in range(self.n_shards):
                idx = np.nonzero(dirty[sh] & (keys[sh] != -1))[0]
                dur.flusher.flush_rows(
                    up.name, keys[sh][idx], ts[sh][idx],
                    jax.tree.map(lambda v: v[sh][idx], vals), up.ttl)
            new_tables[up.name] = tbl.SlateTable(
                keys=t.keys, ts=t.ts, dirty=jnp.zeros_like(t.dirty),
                vals=t.vals, dropped=t.dropped)
        state = dict(state)
        state["tables"] = new_tables
        dur.record_frontier(tick, meta=meta)
        return state, tick

    def run(self, state, source_fn, n_ticks: int, *, start_tick: int = 0,
            handle=None):
        """Uniform host driver (same shape as ``Engine.run``):
        ``source_fn(tick, max_events) -> dict[stream, EventBatch]`` with
        [n_shards, B]-leading batches; ``max_events`` is always ``None``
        here (per-shard backpressure is the exchange/queue bound, not a
        host-side ingest limit).  With durability attached, sources are
        write-ahead logged per shard and flush boundaries fire per the
        flush policy — the ``run_durable`` path.  ``handle`` (a
        :class:`~repro.core.engine.StateHandle`) is republished every
        tick.  Returns ``(state, outputs)`` with one output dict per
        source tick; the post-run tick cursor (drain ticks included) is
        left on ``self.tick_cursor`` for durable drivers that resume.

        With ``cfg.autoscale`` set to an :class:`AutoscalePolicy`, the
        drive loop fires live reconfigures at the policy's source-tick
        boundaries: ``scale_at[t]`` rescales the active shard set
        before tick ``t`` runs, and every ``rebalance_every`` ticks the
        weighted ring is rebuilt from the per-shard load signal.  With
        a :class:`~repro.telemetry.LoadAutoscaler` the loop closes
        instead: every decision window the telemetry registry reads the
        boundary signals and the controller picks scale / rebalance /
        split (DESIGN.md 13.3).  Either way ``source_fn`` must size its
        batches by the *current* ``self.n_shards``.

        Source index and engine tick are decoupled (the ``Engine.run``
        split ported here): ``source_fn`` sees consecutive indices
        ``start_tick .. start_tick + n_ticks`` regardless of flush or
        reconfigure drain ticks — WAL records are keyed by the engine
        tick, the frontier meta records the source cursor."""
        pol = self.cfg.autoscale
        self._live_handle = handle
        if pol is None:
            return self._run_span(state, source_fn, n_ticks,
                                  start_tick=start_tick, handle=handle)
        if isinstance(pol, LoadAutoscaler):
            return self._run_closed_loop(state, source_fn, n_ticks, pol,
                                         start_tick=start_tick,
                                         handle=handle)
        end = start_tick + n_ticks
        marks = {t for t in pol.scale_at if start_tick <= t < end}
        if pol.rebalance_every:
            marks |= {t for t in range(start_tick, end)
                      if t > start_tick
                      and (t - start_tick) % pol.rebalance_every == 0}
        outputs: List[Dict[str, Any]] = []
        t = start_tick
        self.tick_cursor = t
        for boundary in sorted(marks) + [end]:
            if boundary > t:
                state, outs = self._run_span(state, source_fn,
                                             boundary - t, start_tick=t,
                                             handle=handle)
                outputs.extend(outs)
                t = boundary
            if boundary < end:          # fire before tick `boundary` runs
                if boundary in pol.scale_at:
                    state, rep = self.scale(state, pol.scale_at[boundary],
                                            drain_max=pol.drain_max)
                else:
                    state, rep = self.rebalance(state,
                                                drain_max=pol.drain_max)
                if rep is not None and pol.on_change is not None:
                    pol.on_change(rep)
                if handle is not None:
                    handle.state = state
        self.tick_cursor = max(t, self.tick_cursor)
        return state, outputs

    def _run_closed_loop(self, state, source_fn, n_ticks: int, pol, *,
                         start_tick: int = 0, handle=None):
        """Observe -> decide -> act (DESIGN.md 13.3): run one decision
        window of source ticks, take the boundary telemetry reading,
        and let the :class:`LoadAutoscaler` choose an actuator.  The
        sketch ages at every window so heat stays recent."""
        assert self.telemetry is not None
        outputs: List[Dict[str, Any]] = []
        t = start_tick
        end = start_tick + n_ticks
        limit = pol.max_shards or len(jax.devices())
        lead = self._lead_axis_size()
        if lead > 1:
            # multi-axis meshes grow along their trailing axis, so the
            # reachable ceiling is the largest multiple of the leading
            # axes' product (never below the current physical size)
            limit = max(self.n_shards, (limit // lead) * lead)
        while t < end:
            n = min(pol.window - (t - start_tick) % pol.window, end - t)
            state, outs = self._run_span(state, source_fn, n,
                                         start_tick=t, handle=handle)
            outputs.extend(outs)
            t += n
            with self._span("telemetry_observe", tick=t):
                report = self.telemetry.observe(self, state)
            if "sketch" in state:
                state = dict(state)
                state["sketch"] = sk_mod.decay(state["sketch"],
                                               self.tele_cfg.decay)
            action = pol.decide(
                report, n_active=len(self.active_shards), limit=limit,
                can_split=(self.dur is None and self._hot_capacity > 0),
                already_split=tuple(self.split_key_set()))
            rep = None
            if action is not None and t < end:
                t0 = time.perf_counter()
                if action.kind == "scale":
                    state, rep = self.scale(state, action.target,
                                            drain_max=pol.drain_max)
                elif action.kind == "rebalance":
                    w = pol.heat_weights(report, owners=self.heat_owners)
                    state, rep = self.rebalance(state, weights=w,
                                                drain_max=pol.drain_max)
                elif action.kind == "split":
                    state, rep = self.split_keys(state, action.keys)
                self.telemetry.note_pause(
                    rep.pause_s if rep is not None
                    else time.perf_counter() - t0,
                    bytes_moved=rep.bytes_moved if rep is not None
                    else 0)
                self.telemetry.rebase(self, state)
                if rep is not None and pol.on_change is not None:
                    pol.on_change(rep)
                if handle is not None:
                    handle.state = state
            if self._ctl_log is not None:
                self._ctl_log.log({
                    "tick": t,
                    "pressure": [float(x) for x in
                                 np.asarray(report.pressure).ravel()],
                    "event_latency_p99": report.event_latency_p99,
                    "queue_depth": float(
                        np.asarray(report.queue_depth).sum()),
                    "n_active": len(self.active_shards),
                    "action": None if action is None else {
                        "kind": action.kind, "target": action.target,
                        "keys": [int(k) for k in action.keys],
                        "reason": action.reason},
                    "applied": None if rep is None else {
                        "path": rep.path, "pause_s": rep.pause_s,
                        "moved_rows": rep.moved_rows,
                        "bytes_moved": rep.bytes_moved},
                })
        self.tick_cursor = t
        return state, outputs

    def _run_span(self, state, source_fn, n_ticks: int, *,
                  start_tick: int = 0, handle=None):
        """The inner drive loop.  Source index (``source_fn``'s ``t``)
        and engine tick (the WAL key, which also counts drain ticks)
        are tracked separately — the single-shard ``eng_tick`` +
        frontier ``meta.source_tick`` split ported from ``Engine.run``
        — so durable flush drains never consume source indices and
        ``source_fn`` is invoked exactly ``n_ticks`` times with
        consecutive indices, even across mid-run reconfigures."""
        outputs = []
        src_t = start_tick
        self._live_handle = handle
        eng_tick = int(np.asarray(jax.device_get(state["tick"])).max()) \
            if self.dur is not None else 0
        # without a closed-loop controller (which observes at its own
        # decision windows), this span keeps App.telemetry() fresh by
        # reading at every cfg window boundary
        observe = (self.telemetry is not None
                   and not isinstance(self.cfg.autoscale,
                                      LoadAutoscaler))
        obs_mark = start_tick
        for _ in range(n_ticks):
            srcs = source_fn(src_t, None)
            if self.dur is not None:
                self.append_sources(eng_tick, srcs)
            # step donates (deletes) the buffers a handle reader may be
            # holding: lock from dispatch until the fresh state is
            # republished
            with self.read_lock:
                state, outs = self.step(state, srcs)
                outputs.append(outs)
                src_t += 1
                eng_tick += 1
                if self.dur is not None and self.dur.due(
                        eng_tick, state["tables"]):
                    with self._span("flush_boundary", tick=eng_tick,
                                    source_tick=src_t):
                        state, eng_tick = self._flush_boundary(
                            state, eng_tick, meta={"source_tick": src_t})
                    if handle is not None:
                        handle.on_frontier_advance()
                if observe and src_t - obs_mark >= self.tele_cfg.window:
                    with self._span("telemetry_observe", tick=src_t):
                        report = self.telemetry.observe(self, state)
                    if handle is not None:
                        handle.on_telemetry(report)
                    state = dict(state)
                    state["sketch"] = sk_mod.decay(state["sketch"],
                                                   self.tele_cfg.decay)
                    obs_mark = src_t
                if handle is not None:
                    handle.state = state
        self.tick_cursor = src_t
        return state, outputs

    def drain(self, state, max_ticks: int = 64):
        """Run source-less ticks until every shard's queues are empty
        (or ``max_ticks``).  Returns ``(state, ticks_run)``."""
        return self._drain_queues(state, max_ticks)

    def run_durable(self, state, source_fn, n_ticks: int, *,
                    start_tick: int = 0):
        """Host driver: per-tick step with write-ahead logging and
        policy-driven flush boundaries.  ``source_fn(tick)`` returns
        [n_shards, B]-leading source batches.  Returns
        ``(state, next_source_tick)`` — the source cursor, which flush
        drain ticks no longer consume.  Thin wrapper over :meth:`run`
        — one durable drive loop to maintain."""
        assert self.dur is not None, "attach_durability first"
        state, _ = self.run(state, lambda t, _mx: source_fn(t), n_ticks,
                            start_tick=start_tick)
        return state, self.tick_cursor

    def recover(self, *, frontier=None):
        """Rebuild sharded state after losing any subset of machines:
        flushed slates are re-inserted on whatever shard the *current*
        ring routes them to (so a dead shard's keys land on survivors —
        the elastic-restore move of ``distributed/checkpoint.py``:
        host rows -> ``device_put`` with the target sharding), then each
        shard's WAL suffix replays through the shard_map tick, which
        re-routes every replayed event with the current ring."""
        dur = self.dur
        assert dur is not None, "attach_durability first"
        t_recover = time.perf_counter()
        frontier = frontier or dur.frontier
        f_tick = int(frontier.tick)
        offs = list(frontier.wal_offset) \
            if isinstance(frontier.wal_offset, (list, tuple)) \
            else [frontier.wal_offset] * self.n_shards
        if len(offs) < self.n_shards:   # frontier predates a scale-up:
            offs += [0] * (self.n_shards - len(offs))  # replay new WALs
                                                       # from the start
        # frontier from a *larger* pre-crash shard set (scaled up, then
        # restarted smaller): the extra shards' WAL suffixes must replay
        # too — their events re-route by the current ring anyway
        extra_wals = []
        if len(offs) > len(dur.wals):
            from repro.slates.wal import WriteAheadLog
            extra_wals = [WriteAheadLog(dur.cfg.wal_path(s),
                                        sync=dur.cfg.sync_wal)
                          for s in range(len(dur.wals), len(offs))]

        state = jax.device_get(self.init_state())
        state["tick"] = np.full((self.n_shards,), f_tick, np.int32)
        rh, rs = self.ring.table()
        with self._span("recover_restore", frontier=f_tick):
            for up in self.wf.updaters():
                recs = dur.store.scan_records(
                    up.name, now=f_tick if up.ttl else None)
                if not recs:
                    continue
                ks = np.asarray(sorted(recs), self.key_dtype)
                shard_of = np.asarray(jax.device_get(
                    route(jnp.asarray(ks), _salt(up.name), rh, rs)))
                t = state["tables"][up.name]
                per_shard = []
                for sh in range(self.n_shards):
                    local = jax.tree.map(lambda x: jnp.asarray(x[sh]), t)
                    sel = np.nonzero(shard_of == sh)[0]
                    if len(sel):
                        ts = np.asarray(
                            [recs[int(k)][0] for k in ks[sel]], np.int32)
                        slates = jax.tree.map(
                            lambda *r: np.stack(r),
                            *[recs[int(k)][1] for k in ks[sel]])
                        local = flush_mod.restore_into(local, ks[sel],
                                                       slates, ts)
                    per_shard.append(jax.device_get(local))
                state["tables"][up.name] = jax.tree.map(
                    lambda *xs: np.stack(xs), *per_shard)
            state = jax.tree.map(
                jnp.asarray, state,
                is_leaf=lambda x: isinstance(x, np.ndarray))
            state = jax.device_put(state, self._shard_tree(state))

        cur = f_tick
        with self._span("recover_replay", frontier=f_tick) as sp:
            try:
                for tk, by_shard in merge_replay_ticks(
                        list(dur.wals) + extra_wals, offs):
                    if tk < f_tick:
                        continue
                    if len(offs) > self.n_shards:
                        by_shard = self._fold_shard_sources(by_shard)
                    while cur < tk:
                        state = self._step_empty(state)
                        cur += 1
                    state, _ = self.step(state, self._stack_shard_sources(
                        by_shard))
                    cur += 1
            finally:
                for w in extra_wals:
                    w.close()
            sp["replayed_ticks"] = cur - f_tick
        if self.telemetry is not None:
            self.telemetry.note_recovery(
                time.perf_counter() - t_recover)
        return state

    def _fold_shard_sources(self, by_shard: Dict[int, Dict[str, Any]]
                            ) -> Dict[int, Dict[str, Any]]:
        """Fold replay records from shard slots beyond the current
        physical size onto live slots (source slot is irrelevant — the
        tick re-routes every event by key through the current ring)."""
        folded: Dict[int, Dict[str, Any]] = {}
        for sh, src in sorted(by_shard.items()):
            tgt = sh % self.n_shards
            cur = folded.setdefault(tgt, {})
            for s, b in src.items():
                cur[s] = b if s not in cur else concat(
                    [jax.tree.map(jnp.asarray, cur[s]),
                     jax.tree.map(jnp.asarray, b)])
        return folded

    def _stack_shard_sources(self, by_shard: Dict[int, Dict[str, Any]]
                             ) -> Dict[str, EventBatch]:
        """Per-shard replay records -> [n_shards, B] source batches
        (missing shards/streams become all-invalid rows)."""
        caps: Dict[str, int] = {}
        tmpl: Dict[str, EventBatch] = {}
        for src in by_shard.values():
            for s, b in src.items():
                if s not in caps or b.capacity > caps[s]:
                    caps[s], tmpl[s] = b.capacity, b

        def one(sh, s):
            b = by_shard.get(sh, {}).get(s)
            if b is None:
                t = tmpl[s]
                return EventBatch.empty(
                    caps[s], jax.tree.map(
                        lambda a: (a.shape[1:], a.dtype), t.value),
                    key_dtype=t.key.dtype)
            return EventBatch(sid=jnp.asarray(b.sid),
                              ts=jnp.asarray(b.ts),
                              key=jnp.asarray(b.key),
                              value=jax.tree.map(jnp.asarray, b.value),
                              valid=jnp.asarray(b.valid)).pad_to(caps[s])

        return {s: jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[one(sh, s) for sh in range(self.n_shards)])
            for s in tmpl}

    def close(self):
        if self.dur is not None:
            self.dur.close()
        if self._ctl_log is not None:
            self._ctl_log.close()

    # ---- failure / elasticity (host side; master of section 4.3) ----
    def fail_shard(self, state, shard: int):
        """Machine crash: re-route ring; the dead shard's unflushed slates
        and queued events are lost (paper semantics).  The ring table is
        shape-stable (padded), so the swap needs no recompilation —
        contrast :meth:`scale` / :meth:`remove_shards`, whose planned
        membership changes migrate state loss-free first."""
        self.ring.fail(shard)

        def zap(leaf):
            if hasattr(leaf, "ndim") and leaf.ndim >= 1 and \
                    leaf.shape[0] == self.n_shards:
                return leaf.at[shard].set(jnp.zeros_like(leaf[shard]))
            return leaf

        state = dict(state)
        state["queues"] = jax.tree.map(zap, state["queues"])
        # tables: mark every slot empty on the dead shard
        new_tables = {}
        for name, t in state["tables"].items():
            keys = t.keys.at[shard].set(
                jnp.full_like(t.keys[shard], tbl.EMPTY))
            dirty = t.dirty.at[shard].set(
                jnp.zeros_like(t.dirty[shard]))
            new_tables[name] = tbl.SlateTable(
                keys=keys, ts=t.ts, dirty=dirty, vals=t.vals,
                dropped=t.dropped)
        state["tables"] = new_tables
        return state

    # ---- live elasticity (DESIGN.md section 12) ---------------------
    @property
    def active_shards(self) -> List[int]:
        return [int(s) for s in np.nonzero(self.ring.alive)[0]]

    def scale(self, state, new_n_shards: int, *, drain_max: int = 64):
        """Live resize to ``new_n_shards`` *active* shards, loss-free.

        Scale-up reactivates dead slots first (content-only ring swap,
        no recompilation), then grows the physical slot count / mesh if
        needed (the one move that recompiles).  Scale-down deactivates
        the highest-numbered active shards and migrates everything off
        them.  Returns ``(state, MigrationReport)``.
        """
        if new_n_shards < 1:
            raise ValueError("need at least one active shard")
        active = self.active_shards
        if new_n_shards == len(active):
            return state, self._report(0, {}, {}, recompiled=False)
        if new_n_shards < len(active):
            return self.remove_shards(state, active[new_n_shards:],
                                      drain_max=drain_max)
        dead = [s for s in range(self.n_shards) if not self.ring.alive[s]]
        activate = dead[:new_n_shards - len(active)]
        grow_to = new_n_shards if len(active) + len(activate) \
            < new_n_shards else None
        return self._reconfigure(state, grow_to=grow_to,
                                 activate=activate, drain_max=drain_max)

    def add_shards(self, state, k: int, *, drain_max: int = 64):
        """Grow the active shard set by ``k`` (elastic join)."""
        return self.scale(state, len(self.active_shards) + k,
                          drain_max=drain_max)

    def remove_shards(self, state, shards, *, drain_max: int = 64):
        """Planned leave: migrate the given shards' slates and queued
        events to the survivors, then deactivate them — loss-free,
        unlike :meth:`fail_shard`.  Content-only ring swap (the slots
        stay allocated; rejoin them later via :meth:`scale`)."""
        shards = [int(s) for s in np.atleast_1d(shards)]
        for s in shards:
            if s >= self.n_shards or not self.ring.alive[s]:
                raise ValueError(f"shard {s} is not active")
        if len(self.active_shards) - len(shards) < 1:
            raise ValueError("cannot remove every active shard")
        return self._reconfigure(state, deactivate=shards,
                                 drain_max=drain_max)

    def shard_load(self, state) -> np.ndarray:
        """Per-shard pressure signal from the queue stats: high-water
        marks + backlog, with drops weighted heavier (a dropping shard
        is past saturation)."""
        load = np.zeros(self.n_shards)
        for q in state["queues"].values():
            g = lambda x: np.asarray(jax.device_get(x), np.float64)
            load += g(q.peak) + g(q.size) + 4.0 * g(q.dropped)
        return load

    def _rebase_load_window(self, state, load: Optional[np.ndarray] = None):
        """Restart the rebalance load window at the current pressure.

        Shared by ``rebalance()``'s no-op exits and ``_reconfigure``:
        the next window's delta must measure only load accrued *after*
        this point.  Queue peaks restart at migrations, and a
        controller invoking ``rebalance()`` back-to-back (outside the
        cadence path) must see an empty window — not recycled history
        that would reweight twice for the same pressure."""
        self._load_mark = self.shard_load(state) if load is None else load

    def rebalance(self, state, *, gain: float = 0.5, floor: float = 0.25,
                  cap: float = 4.0, drain_max: int = 64, weights=None):
        """Load-aware ring reweighting: shards whose queues ran hot
        since the last rebalance shed vnode arcs (key ranges) to cold
        shards.  Content-only ring swap + row migration — no
        recompilation.  Returns ``(state, report_or_None)``.

        ``weights``: explicit per-shard target weights (e.g. the
        sketch-informed heat weights of
        :meth:`~repro.telemetry.LoadAutoscaler.heat_weights`) instead
        of the queue-delta heuristic; they are clipped to
        ``[floor, cap]`` and no-op reweights are still skipped."""
        alive = self.ring.alive
        if weights is not None:
            w = np.clip(np.asarray(weights, np.float64), floor, cap)
            target = np.where(alive, w, self.ring.weights)
        else:
            load = self.shard_load(state)
            if load.shape != self._load_mark.shape:
                self._load_mark = np.zeros_like(load)
            delta = np.clip(load - self._load_mark, 0.0, None)
            mean = float(delta[alive].mean()) if alive.any() else 0.0
            if mean <= 0.0:
                self._rebase_load_window(state, load)
                return state, None
            # cold shards (delta < mean) gain weight, hot shards lose
            # it; gain damps the step, floor/cap bound the skew.  Dead
            # slots keep their stored weight — their zero load is
            # absence, not coldness, and must not compound toward cap
            # across windows
            ratio = (mean + 1.0) / (delta + 1.0)
            target = self.ring.weights * np.power(ratio, gain)
            target = np.clip(target / target[alive].mean(), floor, cap)
            target = np.where(alive, target, self.ring.weights)
        if np.array_equal(self.ring.vnode_counts(),
                          self.ring.counts_for(target)):
            # balanced load: the reweight would not move a single vnode
            # — skip the drain barrier + host remap entirely
            self._rebase_load_window(state)
            return state, None
        return self._reconfigure(state, weights=target,
                                 drain_max=drain_max)

    # ---- runtime hot-key splitting (DESIGN.md 13.4) -----------------
    def split_keys(self, state, keys):
        """Live hotspot relief for heavy-hitter keys (paper Example 6
        made runtime): register ``keys`` in the hot set so their events
        spread across the key's primary *and* secondary ring shard;
        ``read_slate`` merges the (<= 2) partials with the updater's
        combine — the same contender bound the paper accepts for
        two-choice dispatch.  Content-only swap of a fixed-shape array:
        no recompilation, no migration, takes effect next tick.
        Returns ``(state, None)``; undo with :meth:`clear_split`."""
        if self._hot_capacity == 0:
            raise ValueError(
                "split_keys needs the hot-key split path compiled in: "
                "set DistConfig.hot_key_capacity > 0 (or use a "
                "LoadAutoscaler with skew > 0) together with "
                "cfg.telemetry, durability off")
        if self.dur is not None:
            raise ValueError(
                "split_keys requires durability off: per-key partials "
                "are not store-mergeable (the two_choice_threshold "
                "constraint)")
        if len(self.active_shards) < 2:
            return state, None
        cur = [int(k) for k, v in zip(self._hot_keys, self._hot_valid)
               if v]
        for k in keys:
            if int(k) not in cur:
                cur.append(int(k))
        # active splits keep priority: evicting one would strand its
        # partials (read_slate stops merging the secondary) — new keys
        # beyond capacity wait for clear_split
        cur = cur[:self._hot_capacity]
        hk = np.zeros_like(self._hot_keys)
        hv = np.zeros_like(self._hot_valid)
        hk[:len(cur)] = cur
        hv[:len(cur)] = True
        self._hot_keys, self._hot_valid = hk, hv
        return state, None

    def clear_split(self, state, *, drain_max: int = 64):
        """Deactivate every hot-key split and converge the partials:
        one same-ring reconfigure whose table rebuild folds duplicate
        keys via the updater's combine, so each formerly-split key ends
        up whole on its owner shard again."""
        if not self._hot_valid.any():
            return state, None
        self._hot_valid = np.zeros_like(self._hot_valid)
        return self._reconfigure(state, drain_max=drain_max)

    def split_key_set(self) -> List[int]:
        """Currently split (hot) keys."""
        return [int(k) for k, v in zip(self._hot_keys, self._hot_valid)
                if v]

    def heat_owners(self, keys) -> np.ndarray:
        """Ring owner per key *per updater* — [n_updaters, K], one row
        per updater salt, the heavy-hitter -> arc attribution used by
        :meth:`~repro.telemetry.LoadAutoscaler.heat_weights`.  Routing
        is salted by destination, so a key heavy for two updaters heats
        two (generally different) shards; the sketch counts the key once
        per subscribing updater's dequeue, and ``heat_weights`` splits a
        hitter's estimated mass evenly across these rows."""
        ups = list(self.wf.updaters())
        ks = np.asarray(keys, self.key_dtype)
        if not ups:
            return np.zeros((1, len(ks)), np.int32)
        return np.stack([self.ring.owners(ks, _salt(u.name))
                         for u in ups])

    def _report(self, drain_ticks, moved_rows, moved_events, *,
                recompiled: bool, pause_s: float = 0.0,
                bytes_moved: int = 0, path: str = "host"
                ) -> MigrationReport:
        return MigrationReport(
            n_shards=self.n_shards, active=self.active_shards,
            drain_ticks=drain_ticks, moved_rows=moved_rows,
            moved_events=moved_events, recompiled=recompiled,
            pause_s=pause_s, bytes_moved=bytes_moved, path=path)

    def _reconfigure(self, state, *, grow_to: Optional[int] = None,
                     activate=(), deactivate=(), weights=None,
                     drain_max: int = 64, force_compact: bool = False):
        """The migration kernel behind scale/remove/rebalance:

        1. drain-barrier the queues (and flush, with durability);
        2. swap in the new ring (membership / weights / physical size);
        3. re-home slate rows to their new owners — on device when the
           physical shapes are unchanged and the barrier emptied the
           queues (``exchange_rows`` under shard_map: no host round
           trip), else the host remap + ``device_put`` fallback (the
           elastic-restore move of ``distributed/checkpoint.py``),
           which also re-homes any leftover queued events;
        4. resume on the swapped ring — recompilation only if the
           physical slot count changed (grow, or compaction shrink).

        Both tiers yield bitwise-identical slates (DESIGN.md 14.3).

        Runs under ``read_lock``: concurrent slate readers must observe
        either the pre-migration state (old ring, rows in place) or the
        post-migration state — never a half-swapped ring over mid-
        exchange rows, and never the deleted buffers the drain steps
        donate.  The live :class:`StateHandle` (when a driver published
        one) is re-pointed at the migrated state *before* the lock is
        released, so a reader waking on the lock can never see a handle
        still bound to pre-migration (freed) state.
        """
        with self.read_lock:
            with self._span("reconfigure") as sp:
                state, report = self._reconfigure_impl(
                    state, grow_to=grow_to, activate=activate,
                    deactivate=deactivate, weights=weights,
                    drain_max=drain_max, force_compact=force_compact)
                # reconcile the report's measured pause with the traced
                # span: pause_s was clocked inside the impl, so the
                # span's dur (same region plus handle repoint) must
                # bound it from above — a cheap invariant the trace
                # tests assert
                sp["pause_s"] = report.pause_s
                sp["path"] = report.path
                sp["n_shards"] = report.n_shards
                sp["drain_ticks"] = report.drain_ticks
            if self._live_handle is not None:
                self._live_handle.state = state
        return state, report

    def _reconfigure_impl(self, state, *, grow_to=None, activate=(),
                          deactivate=(), weights=None, drain_max=64,
                          force_compact=False):
        t_start = time.perf_counter()
        state, drained = self._drain_queues(state, drain_max)
        if self.dur is not None:
            tick = int(np.asarray(jax.device_get(state["tick"])).max())
            # the barrier retired every source fed so far, so the
            # frontier's driver cursor advances to the current source
            # cursor (monotone: a reconfigure on a freshly-recovered
            # engine must not regress a prior run's recorded cursor) —
            # with truncate_wal, a stale cursor would re-feed
            # already-flushed source ticks after a crash
            prev = (self.dur.frontier.meta or {}).get("source_tick", 0)
            meta = {"source_tick": max(int(prev),
                                       int(self.tick_cursor))}
            state, _ = self._flush_boundary(state, tick, meta=meta)
        old_n = self.n_shards

        grew = grow_to is not None and grow_to > old_n
        if grew:
            self._grow_physical(grow_to)
        for s in activate:
            self.ring.join(int(s))
        for s in deactivate:
            self.ring.fail(int(s))
        if weights is not None:
            self.ring.set_weights(weights)

        compacting = False
        if not grew:
            n_active = len(self.active_shards)
            dead_frac = 1.0 - n_active / self.n_shards
            want = force_compact or (
                self.cfg.compact_threshold > 0.0
                and dead_frac >= self.cfg.compact_threshold)
            if want and n_active < self.n_shards:
                lead = self._lead_axis_size()
                if n_active % lead == 0:
                    compacting = True
                elif force_compact:
                    raise ValueError(
                        f"cannot compact to {n_active} shards on a "
                        f"multi-axis mesh: the active count must be a "
                        f"multiple of the leading axes' product {lead}")

        use_device = (not grew and not compacting
                      and self.cfg.device_migration != "off")
        if use_device:
            # a non-empty backlog (planned leave with drain_max=0, or a
            # barrier that could not retire the queues) stays on this
            # path too: exchange_queue re-homes queued events with the
            # same all_to_all and rebases peaks at the new backlog
            state, moved_rows, moved_events, bytes_moved = \
                self._migrate_device(state)
            path = "device"
        else:
            host = jax.device_get(state)
            slot_map = None
            if grew:
                host = self._host_grow(host, old_n)
            if compacting:
                host, slot_map = self._compact_physical(host)
            moved_rows = self._migrate_tables_host(host["tables"],
                                                   slot_map=slot_map)
            moved_events = self._migrate_queues_host(host["queues"],
                                                     slot_map=slot_map)
            bytes_moved = self._bytes_of(moved_rows, moved_events)
            state = jax.tree.map(
                jnp.asarray, host,
                is_leaf=lambda x: isinstance(x, np.ndarray))
            state = jax.device_put(state, self._shard_tree(state))
            path = "host"
        if self.dur is not None:
            self.dur.resize(self.n_shards)
        # queue peak counters restarted at migration: rebase the
        # rebalance window on the post-migration load, or the next
        # window's delta would subtract peaks that no longer exist
        jax.block_until_ready(state["tables"])
        self._rebase_load_window(state)
        return state, self._report(
            drained, moved_rows, moved_events,
            recompiled=grew or compacting,
            pause_s=time.perf_counter() - t_start,
            bytes_moved=bytes_moved, path=path)

    def _queues_empty(self, state) -> bool:
        sizes = jax.device_get({k: q.size
                                for k, q in state["queues"].items()})
        return all(int(np.asarray(v).sum()) == 0
                   for v in sizes.values())

    def _reset_queue_peaks(self, state):
        """Rebase every queue's high-water mark at its current backlog
        (the host migrator's ``peak=new_sizes``) so the next rebalance
        window measures post-migration load only."""
        state = dict(state)
        state["queues"] = {
            name: q_mod.QueueState(
                buf=q.buf, head=q.head, size=q.size, dropped=q.dropped,
                peak=jax.device_put(jnp.copy(q.size),
                                    self._sharding))
            for name, q in state["queues"].items()}
        return state

    def _lead_axis_size(self) -> int:
        """Product of every mesh axis size except the trailing one —
        the granularity physical grow/compact must respect."""
        return int(np.prod([self.mesh.shape[a]
                            for a in self.axes[:-1]], dtype=np.int64)) \
            if len(self.axes) > 1 else 1

    def _row_bytes(self, up) -> int:
        n = self.key_dtype.itemsize + 4 + 1   # key + ts + dirty
        for leaf in jax.tree.leaves(up.slate_spec(),
                                    is_leaf=tbl._is_spec_leaf):
            shp, dt = leaf
            n += int(np.prod(shp, dtype=np.int64)) * np.dtype(dt).itemsize
        return n

    def _event_bytes(self, op) -> int:
        n = 4 * 2 + self.key_dtype.itemsize + 1  # sid + ts + key + valid
        for leaf in jax.tree.leaves(op.in_value_spec,
                                    is_leaf=tbl._is_spec_leaf):
            shp, dt = leaf
            n += int(np.prod(shp, dtype=np.int64)) * np.dtype(dt).itemsize
        return n

    def _bytes_of(self, moved_rows, moved_events) -> int:
        total = sum(moved_rows.get(up.name, 0) * self._row_bytes(up)
                    for up in self.wf.updaters())
        total += sum(moved_events.get(op.name, 0) * self._event_bytes(op)
                     for op in self.wf.operators)
        return total

    def _migrate_device(self, state):
        """The device migration tier (DESIGN.md 14.1): count row movers
        AND queued-event movers per (src, dest) with a tiny jitted
        plan, pick pow2 bucket capacities (bounding the jit cache),
        then run ``exchange_rows`` for every updater table and
        ``exchange_queue`` for every backlogged operator queue in one
        shard_map dispatch.  Slates and events never leave the device.
        Returns ``(state, moved_rows, moved_events, bytes_moved)``."""
        from jax.experimental.shard_map import shard_map
        updaters = list(self.wf.updaters())
        rh, rs = self.ring.table()
        tables, queues = state["tables"], state["queues"]
        if self._plan_fn is None:
            sharded, rep = P(self.axes), P()
            specs = (self._spec_like(tables), self._spec_like(queues))
            n = self.n_shards
            operators = list(self.wf.operators)

            def plan_local(tb, qs, rh_, rs_):
                me = _linear_shard_index(self.axes)
                rows = {}
                for up in updaters:
                    t = jax.tree.map(lambda x: x[0], tb[up.name])
                    owner = route(t.keys, _salt(up.name), rh_, rs_)
                    mover = (t.keys != tbl.EMPTY) & (owner != me)
                    rows[up.name] = jnp.zeros((n,), jnp.int32).at[
                        jnp.where(mover, owner, n)].add(
                            1, mode="drop")[None]
                evs = {}
                for op in operators:
                    q = jax.tree.map(lambda x: x[0], qs[op.name])
                    C = q.buf.capacity
                    pos = (q.head
                           + jnp.arange(C, dtype=jnp.int32)) % C
                    live = jnp.arange(C, dtype=jnp.int32) < q.size
                    owner = route(q.buf.key[pos], _salt(op.name),
                                  rh_, rs_)
                    # count *all* live events per dest (stayers too):
                    # exchange_queue routes everything through the
                    # buckets, so the cap must cover to-self traffic
                    evs[op.name] = jnp.zeros((n,), jnp.int32).at[
                        jnp.where(live, owner, n)].add(
                            1, mode="drop")[None]
                return {"rows": rows, "events": evs}

            def plan(tb, qs, rh_, rs_):
                return shard_map(plan_local, mesh=self.mesh,
                                 in_specs=specs + (rep, rep),
                                 out_specs=sharded,
                                 check_rep=False)(tb, qs, rh_, rs_)
            self._plan_fn = jax.jit(plan)
        plan = jax.device_get(self._plan_fn(tables, queues, rh, rs))
        moved = {name: int(np.asarray(c).sum())
                 for name, c in plan["rows"].items()}
        # event movers exclude the diagonal (stayers route to-self)
        moved_ev = {name: int(np.asarray(c).sum()
                              - np.trace(np.asarray(c)))
                    for name, c in plan["events"].items()}
        maxc = max((int(np.asarray(c).max())
                    for c in plan["rows"].values()), default=0)
        ev_maxc = max((int(np.asarray(c).max())
                       for c in plan["events"].values()), default=0)
        bytes_moved = self._bytes_of(moved, moved_ev)
        if maxc == 0 and sum(moved_ev.values()) == 0:
            # nothing re-homes: tables and queues stand (the caller
            # rebases queue peaks at the standing backlog)
            return self._reset_queue_peaks(state), moved, moved_ev, 0

        def pow2(c):
            cap = 8
            while cap < c:
                cap *= 2
            return cap
        cap_rows = pow2(maxc) if maxc else 0
        cap_ev = pow2(ev_maxc) if ev_maxc else 0
        fn = self._migrate_fns.get((cap_rows, cap_ev))
        if fn is None:
            fn = self._make_migrate_fn(tables, updaters,
                                       cap_rows, cap_ev)
            self._migrate_fns[(cap_rows, cap_ev)] = fn
        state = dict(state)
        state["tables"], qs = fn(tables, queues, rh, rs)
        # peak is rebased to the backlog (= size) inside the jit, so
        # the two leaves come back aliased to one buffer — copy so the
        # next donating step dispatch doesn't donate it twice
        state["queues"] = {
            name: q_mod.QueueState(buf=q.buf, head=q.head, size=q.size,
                                   dropped=q.dropped,
                                   peak=jnp.copy(q.peak))
            for name, q in qs.items()}
        return state, moved, moved_ev, bytes_moved

    def _make_migrate_fn(self, tables, updaters, cap_rows: int,
                         cap_ev: int):
        from jax.experimental.shard_map import shard_map
        sharded, rep = P(self.axes), P()
        specs = self._spec_like(tables)
        operators = list(self.wf.operators)

        def mig_local(tb, qs, rh_, rs_):
            out_t = {}
            for up in updaters:
                t = jax.tree.map(lambda x: x[0], tb[up.name])
                if cap_rows:
                    t, _ = exchange_rows(
                        t, _salt(up.name), rh_, rs_, self.axes,
                        cap_rows, getattr(up, "combine", None))
                out_t[up.name] = jax.tree.map(lambda x: x[None], t)
            out_q = {}
            for op in operators:
                q = jax.tree.map(lambda x: x[0], qs[op.name])
                if cap_ev:
                    q, _ = exchange_queue(q, _salt(op.name), rh_, rs_,
                                          self.axes, cap_ev)
                else:   # no backlog anywhere: rebase peak in place
                    q = q_mod.QueueState(buf=q.buf, head=q.head,
                                         size=q.size,
                                         dropped=q.dropped,
                                         peak=q.size)
                out_q[op.name] = jax.tree.map(lambda x: x[None], q)
            return out_t, out_q

        def run(tb, qs, rh_, rs_):
            qspecs = self._spec_like(qs)
            return shard_map(mig_local, mesh=self.mesh,
                             in_specs=(specs, qspecs, rep, rep),
                             out_specs=(sharded, sharded),
                             check_rep=False)(tb, qs, rh_, rs_)
        return jax.jit(run, donate_argnums=(0, 1))

    def compact(self, state, *, drain_max: int = 64):
        """Force physical slot compaction (DESIGN.md 14.2): shrink the
        mesh/state to the current active shard set, freeing the parked
        slots' HBM, regardless of ``compact_threshold``.  No-op when
        every slot is active.  Returns ``(state, MigrationReport)``."""
        if len(self.active_shards) == self.n_shards:
            return state, self._report(0, {}, {}, recompiled=False,
                                       path="none")
        return self._reconfigure(state, drain_max=drain_max,
                                 force_compact=True)

    def _grow_physical(self, new_n: int):
        """More shard slots: bigger mesh over more devices, bigger
        state arrays — shapes change, jit caches reset.  Multi-axis
        meshes grow along their trailing axis (``('pod','data')`` keeps
        the pod count and widens each pod), so ``new_n`` must be a
        multiple of the leading axes' product."""
        lead = self._lead_axis_size()
        if new_n % lead:
            raise ValueError(
                f"multi-axis mesh {dict(self.mesh.shape)} grows along "
                f"its trailing axis {self.axes[-1]!r}: target {new_n} "
                f"must be a multiple of {lead}")
        devs = jax.devices()
        if len(devs) < new_n:
            raise ValueError(
                f"scale to {new_n} shards needs {new_n} devices; only "
                f"{len(devs)} visible")
        shape = tuple(int(self.mesh.shape[a])
                      for a in self.axes[:-1]) + (new_n // lead,)
        self.mesh = Mesh(np.asarray(devs[:new_n]).reshape(shape),
                         self.axes)
        self.n_shards = new_n
        self.ring.grow(new_n)
        self._reset_for_new_shape()

    def _reset_for_new_shape(self):
        """Shared tail of grow/compact: rebind shardings and bucket
        capacity to the new physical size, invalidate every jit."""
        self._sharding = NamedSharding(self.mesh, P(self.axes))
        self._replicated = NamedSharding(self.mesh, P())
        cap = int(self.cfg.batch_size * self.cfg.exchange_slack
                  / self.n_shards)
        self.cap_per_dest = max(8, cap)
        self._step = self._chunk = self._empty_step = None
        self._plan_fn = None
        self._migrate_fns = {}
        self._read_fns = {}

    def _compact_physical(self, host):
        """Physical slot compaction (DESIGN.md 14.2): renumber the
        active shards onto a smaller mesh — the inverse of
        ``_host_grow``, and the move that actually frees parked HBM
        (deactivation alone keeps the full-size arrays allocated).
        The ring is rebuilt at the new size (weights carried).

        Tables and queues are left at the *old* physical size here:
        dead slots may still hold slate rows (deactivation re-homes
        ownership, not residency, on the device path), so the host
        migrators the caller runs next scan every old slice and rebuild
        at the new shard count.  Per-slot *lifetime* counters — the
        count-min sketch's counts/total/sample_n, ``processed``,
        ``exchange_dropped``, ``throttle_hits``, and the table/queue
        ``dropped`` tallies — are folded from the dead slots into the
        first survivor before slicing, so ``TelemetryReport`` lifetime
        counts stay exact across a compaction (the sketch key-sample
        ring is positional, not a counter: it is sliced, not summed).
        Returns ``(host, slot_map)`` where ``slot_map[d]`` is the old
        slot renumbered to new slot ``d``; durability shrinks its WAL
        set via ``resize`` after the flush barrier that preceded us."""
        actives = self.active_shards
        k, old_n = len(actives), self.n_shards
        lead = self._lead_axis_size()
        shape = tuple(int(self.mesh.shape[a])
                      for a in self.axes[:-1]) + (k // lead,)
        self.mesh = Mesh(np.asarray(jax.devices()[:k]).reshape(shape),
                         self.axes)
        self.n_shards = k
        self.ring = HashRing(k, vnodes=self.ring.vnodes,
                             weights=self.ring.weights[actives],
                             seed=self.ring.seed)
        self._reset_for_new_shape()
        idx = np.asarray(actives, np.int64)
        dead = np.asarray(sorted(set(range(old_n)) - set(
            int(a) for a in actives)), np.int64)

        def sel(leaf):
            if hasattr(leaf, "ndim") and leaf.ndim >= 1 \
                    and leaf.shape[0] == old_n:
                return np.asarray(leaf)[idx]
            return leaf

        def fold(leaf):
            """Dead slots' tallies accumulate into survivor 0, then
            slice — lifetime sums are invariant under compaction."""
            a = np.asarray(leaf).copy()
            if dead.size and a.ndim >= 1 and a.shape[0] == old_n:
                a[idx[0]] += a[dead].sum(axis=0).astype(a.dtype)
            return a[idx] if a.ndim >= 1 and a.shape[0] == old_n \
                else leaf

        counters = {"exchange_dropped", "throttle_hits", "deferred",
                    "processed"}
        out = {}
        for key, val in host.items():
            if key in ("tables", "queues"):
                out[key] = val
            elif key in counters:
                out[key] = jax.tree.map(fold, val)
            elif key == "sketch":
                out[key] = {nm: (fold(lf) if nm != "sample" else
                                 sel(lf))
                            for nm, lf in val.items()}
            else:
                out[key] = jax.tree.map(sel, val)
        # table/queue drop tallies stay at the old size for the host
        # migrators, which inherit ``dropped[slot_map[d]]`` — park the
        # dead slots' counts on the first survivor so they carry
        if dead.size:
            for name, t in host["tables"].items():
                drop = np.asarray(t.dropped).copy()
                drop[idx[0]] += drop[dead].sum(axis=0).astype(drop.dtype)
                drop[dead] = 0
                out["tables"][name] = tbl.SlateTable(
                    keys=t.keys, ts=t.ts, dirty=t.dirty, vals=t.vals,
                    dropped=drop)
            for name, q in host["queues"].items():
                drop = np.asarray(q.dropped).copy()
                drop[idx[0]] += drop[dead].sum(axis=0).astype(drop.dtype)
                drop[dead] = 0
                out["queues"][name] = q_mod.QueueState(
                    buf=q.buf, head=q.head, size=q.size, dropped=drop,
                    peak=q.peak)
        tick = int(np.asarray(host["tick"]).max())
        out["tick"] = np.full((k,), tick, np.int32)
        return out, [int(a) for a in actives]

    def _host_grow(self, host, old_n: int):
        """Pad every [old_n, ...] leaf to the new physical size: fresh
        queues/tables/counters for the new slots, tick carried over."""
        pad_n = self.n_shards - old_n

        def pad(leaf, fill=0):
            if not (hasattr(leaf, "ndim") and leaf.ndim >= 1
                    and leaf.shape[0] == old_n):
                return leaf
            ext = np.full((pad_n,) + leaf.shape[1:], fill, leaf.dtype)
            return np.concatenate([np.asarray(leaf), ext])

        out = jax.tree.map(pad, host)
        tick = int(np.asarray(host["tick"]).max())
        out["tick"] = pad(host["tick"], fill=tick)
        new_tables = {}
        for name, t in out["tables"].items():
            keys = np.asarray(t.keys)
            keys[old_n:] = -1                   # new slots start empty
            new_tables[name] = tbl.SlateTable(
                keys=keys, ts=t.ts, dirty=t.dirty, vals=t.vals,
                dropped=t.dropped)
        out["tables"] = new_tables
        return out

    def _migrate_tables_host(self, tables,
                             slot_map=None) -> Dict[str, int]:
        """Re-home slate rows whose ring owner changed (host-side).

        Every shard's table is rebuilt from scratch rather than patched
        in place: deleting moved-out rows from an open-addressing table
        would punch holes in probe chains, making rows behind a freed
        slot invisible to later lookups.  Row *values* move bit-exactly;
        ``ts``/``dirty`` are preserved; same-key rows converging on one
        shard (two-choice partials) merge via the updater's combine
        (else last-ts-wins).  Rows a destination table cannot place are
        dropped and counted — the paper's bounded-resource semantics.

        The input table's leading dim may exceed ``self.n_shards``
        (slot compaction): all old slices are scanned, the rebuild is
        stacked at the new count, and ``slot_map[d]`` names the old
        slot whose ``dropped`` tally new slot ``d`` inherits."""
        moved: Dict[str, int] = {}
        n = self.n_shards
        for up in self.wf.updaters():
            t = tables[up.name]
            keys = np.array(t.keys)
            smap = np.asarray(slot_map if slot_map is not None
                              else range(n), np.int64)
            old2new = np.full(keys.shape[0], -1, np.int64)
            old2new[smap] = np.arange(n)
            sh, slot = np.nonzero(keys != -1)
            moved[up.name] = 0
            drop = np.array(t.dropped)
            if len(sh) == 0:
                if keys.shape[0] != n:
                    out = []
                    for d in range(n):
                        loc = tbl.make_table(up.table_capacity,
                                             up.slate_spec(),
                                             key_dtype=self.key_dtype)
                        out.append(jax.device_get(tbl.SlateTable(
                            keys=loc.keys, ts=loc.ts, dirty=loc.dirty,
                            vals=loc.vals,
                            dropped=jnp.asarray(int(drop[smap[d]]),
                                                jnp.int32))))
                    tables[up.name] = jax.tree.map(
                        lambda *xs: np.stack(xs), *out)
                continue
            ts = np.asarray(t.ts)[sh, slot]
            dirty = np.asarray(t.dirty)[sh, slot]
            vals = jax.tree.map(lambda v: np.asarray(v)[sh, slot],
                                t.vals)
            rkeys = keys[sh, slot]
            owner = self.ring.owners(rkeys, _salt(up.name))
            moved[up.name] = int((owner != old2new[sh]).sum())
            out = [None] * n
            for d in range(n):
                pick = np.nonzero(owner == d)[0]
                loc = self._build_local_table(
                    up, int(drop[smap[d]]), rkeys[pick], ts[pick],
                    dirty[pick],
                    jax.tree.map(lambda v: v[pick], vals))
                out[d] = jax.device_get(loc)
            tables[up.name] = jax.tree.map(
                lambda *xs: np.stack(xs), *out)
        return moved

    def _build_local_table(self, up, dropped0: int, in_keys, in_ts,
                           in_dirty, in_vals) -> tbl.SlateTable:
        """One shard's fresh table from migrated rows (dup keys folded
        with the updater's combine, clean rows stay clean)."""
        combine = getattr(up, "combine", None)
        # fold duplicate keys (two-choice partials converging here)
        first: Dict[int, int] = {}
        in_ts = np.array(in_ts)
        in_dirty = np.array(in_dirty)
        in_vals = jax.tree.map(np.array, in_vals)
        for i, k in enumerate(in_keys.tolist()):
            if k in first:
                j = first[k]
                a = jax.tree.map(lambda v: v[j], in_vals)
                b = jax.tree.map(lambda v: v[i], in_vals)
                row = combine(a, b) if combine is not None else \
                    (b if in_ts[i] >= in_ts[j] else a)
                for lf, rw in zip(jax.tree.leaves(in_vals),
                                  jax.tree.leaves(row)):
                    lf[j] = np.asarray(rw)
                in_ts[j] = max(in_ts[j], in_ts[i])
                in_dirty[j] = True
            else:
                first[k] = i
        uniq = np.asarray(sorted(first.values()), np.int64)
        in_keys = np.asarray(in_keys)[uniq]
        in_ts, in_dirty = in_ts[uniq], in_dirty[uniq]
        in_vals = jax.tree.map(lambda v: v[uniq], in_vals)

        local = tbl.make_table(up.table_capacity, up.slate_spec(),
                               key_dtype=self.key_dtype)
        drops = 0
        for i in range(0, len(in_keys), 256):
            k = jnp.asarray(in_keys[i:i + 256], self.key_dtype)
            valid = jnp.ones(k.shape, bool)
            local, slot, _, placed = tbl.insert_or_find(local, k, valid)
            local = tbl.write_slates(
                local, slot, placed,
                jax.tree.map(lambda v: jnp.asarray(v[i:i + 256]),
                             in_vals),
                jnp.asarray(in_ts[i:i + 256], jnp.int32))
            # write_slates marks landed rows dirty; rows flushed before
            # the move stay clean (they still match the store)
            keep_clean = jnp.asarray(~in_dirty[i:i + 256]) & placed
            safe = jnp.where(keep_clean, slot, local.capacity)
            local = tbl.SlateTable(
                keys=local.keys, ts=local.ts,
                dirty=local.dirty.at[safe].set(False, mode="drop"),
                vals=local.vals, dropped=local.dropped)
            drops += int(jax.device_get((~placed).sum()))
        return tbl.SlateTable(
            keys=local.keys, ts=local.ts, dirty=local.dirty,
            vals=local.vals,
            dropped=jnp.asarray(dropped0 + drops, jnp.int32))

    def _migrate_queues_host(self, queues,
                             slot_map=None) -> Dict[str, int]:
        """Re-home in-flight queued events (anything the drain barrier
        could not retire) through the new ring, rebuilding each queue
        compacted at head 0.  ``dropped`` counters carry; ``peak``
        restarts at the post-migration backlog (it is the rebalance
        window's load signal).  Like the table migrator, the input may
        have more slices than ``self.n_shards`` (compaction): every old
        slice is scanned and the rebuild is stacked at the new count,
        with ``slot_map`` naming the old slot each new ``dropped``
        tally carries from."""
        moved: Dict[str, int] = {}
        n = self.n_shards
        for op in self.wf.operators:
            q = queues[op.name]
            sizes = np.asarray(q.size)
            heads = np.asarray(q.head)
            cap = q.buf.key.shape[1]
            moved[op.name] = 0
            total = int(sizes.sum())
            smap = np.asarray(slot_map if slot_map is not None
                              else range(n), np.int64)
            old2new = np.full(len(sizes), -1, np.int64)
            old2new[smap] = np.arange(n)
            new_sizes = np.zeros(n, np.int32)
            new_drop = np.asarray(q.dropped)[smap].copy()
            if total == 0:
                queues[op.name] = q_mod.QueueState(
                    buf=jax.tree.map(lambda x: np.asarray(x)[smap],
                                     q.buf),
                    head=np.zeros(n, np.int32),
                    size=new_sizes, dropped=new_drop,
                    peak=np.zeros(n, np.int32))
                continue
            ev = {"sid": [], "ts": [], "key": [], "valid": [], "src": []}
            leaves, treedef = jax.tree.flatten(
                jax.tree.map(np.asarray, q.buf.value))
            ev_leaves: List[list] = [[] for _ in leaves]
            for s in range(len(sizes)):
                idx = (heads[s] + np.arange(sizes[s])) % cap
                ev["sid"].append(np.asarray(q.buf.sid)[s][idx])
                ev["ts"].append(np.asarray(q.buf.ts)[s][idx])
                ev["key"].append(np.asarray(q.buf.key)[s][idx])
                ev["valid"].append(np.asarray(q.buf.valid)[s][idx])
                ev["src"].append(np.full(len(idx), s, np.int32))
                for li, lf in enumerate(leaves):
                    ev_leaves[li].append(lf[s][idx])
            cat = {k: np.concatenate(v) for k, v in ev.items()}
            cat_leaves = [np.concatenate(v) for v in ev_leaves]
            dest = self.ring.owners(cat["key"], _salt(op.name))
            moved[op.name] = int((dest != old2new[cat["src"]]).sum())
            # rebuild each destination queue: stayers + movers, FIFO
            buf_sid = np.zeros((n, cap), np.int32)
            buf_ts = np.zeros((n, cap), np.int32)
            buf_key = np.zeros((n, cap), self.key_dtype)
            buf_valid = np.zeros((n, cap), bool)
            buf_leaves = [np.zeros((n, cap) + lf.shape[2:], lf.dtype)
                          for lf in leaves]
            for d in range(n):
                pick = np.nonzero(dest == d)[0]
                k = len(pick)
                if k > cap:
                    new_drop[d] += k - cap
                    pick = pick[:cap]
                    k = cap
                buf_sid[d, :k] = cat["sid"][pick]
                buf_ts[d, :k] = cat["ts"][pick]
                buf_key[d, :k] = cat["key"][pick]
                buf_valid[d, :k] = cat["valid"][pick]
                for bl, cl in zip(buf_leaves, cat_leaves):
                    bl[d, :k] = cl[pick]
                new_sizes[d] = k
            value = jax.tree.unflatten(treedef, buf_leaves)
            queues[op.name] = q_mod.QueueState(
                buf=EventBatch(sid=buf_sid, ts=buf_ts, key=buf_key,
                               value=value, valid=buf_valid),
                head=np.zeros(n, np.int32), size=new_sizes,
                dropped=new_drop, peak=new_sizes.copy())
        return moved

    def stats(self, state):
        g = lambda x: np.asarray(jax.device_get(x))
        return {
            "tick": int(g(state["tick"]).max()),
            "exchange_dropped": int(g(state["exchange_dropped"]).sum()),
            "throttle_hits": int(g(state["throttle_hits"]).sum()),
            "deferred": int(g(state["deferred"]).sum()),
            "processed": {k: int(g(v).sum())
                          for k, v in state["processed"].items()},
            "queue_dropped": {k: int(g(q.dropped).sum())
                              for k, q in state["queues"].items()},
            "table_occupancy": {k: int(g(t.occupancy()).sum())
                                for k, t in state["tables"].items()},
        }

    def read_slate(self, state, updater: str, key: int, *, merge=None):
        """Read a slate by key; with two-choice enabled — or the key in
        the live hot-key split set — merges the (<=2) partial
        aggregates (primary + secondary shard).  Holds ``read_lock`` so
        the ring/table pair is a consistent pre- or post-migration
        snapshot."""
        with self.read_lock:
            rh, rs = self.ring.table()
            karr = jnp.asarray([key], self.key_dtype)
            shards = [int(route(karr, _salt(updater), rh, rs)[0])]
            is_hot = bool(np.any(self._hot_valid
                                 & (self._hot_keys == key)))
            if self.cfg.two_choice_threshold or is_hot:
                shards.append(int(route_secondary(karr, _salt(updater),
                                                  rh, rs)[0]))
            vals = []
            t = state["tables"][updater]
            for s in dict.fromkeys(shards):
                local = jax.tree.map(lambda x: x[s], t)
                slot, found = tbl.lookup(local, karr)
                if bool(found[0]):
                    vals.append(jax.tree.map(
                        lambda v: jax.device_get(v[int(slot[0])]),
                        local.vals))
        if not vals:
            return None
        if len(vals) == 1:
            return vals[0]
        # merge the two partial aggregates via the updater's combine
        op = self.wf.by_name[updater]
        combine = merge or op.combine
        out = vals[0]
        for v in vals[1:]:
            out = combine(jax.tree.map(np.asarray, out),
                          jax.tree.map(np.asarray, v))
        return out

    def _make_read_fn(self, tables, updater: str, with_sec: bool,
                      impl: str):
        """Compile the batched distributed read (DESIGN.md 15): every
        shard runs the device lookup over its local table for the whole
        [Q] key vector, tags each hit with the ring roles it owns
        (bitmask: 1 = primary, 2 = effective secondary), and one
        ``all_gather`` ships the per-shard partials — role mask + local
        rows, gathered *once* — back replicated; the host selects the
        owning shard's row per (key, role).  Replaces the former
        psum-per-role select (two masked psum sweeps over every value
        leaf): the rows cross the interconnect once instead of twice,
        and the select is an O(Q) host argmax instead of a summed
        zero-masked reduction.  Result parity with the psum path is
        exact — at most one shard contributes per (key, role), so
        sum-of-masked equals select-of-owner (asserted in tests against
        the per-key ``read_slate`` loop).  Returns replicated
        ``(role_mask [n_shards, Q], rows [n_shards, Q, ...])``."""
        from jax.experimental.shard_map import shard_map
        from repro.kernels.slate_lookup import ops as lk_ops
        rep = P()
        tspec = self._spec_like(tables)
        salt = _salt(updater)
        two = bool(self.cfg.two_choice_threshold)
        axes = self.axes

        def local(tb, karr, rh_, rs_, hk_, hv_):
            me = _linear_shard_index(axes)
            t = jax.tree.map(lambda x: x[0], tb)
            found, rows = lk_ops.lookup_tree(t.keys, t.vals, karr,
                                             impl=impl)
            prim = route(karr, salt, rh_, rs_)
            mask = (found & (prim == me)).astype(jnp.int32)
            if with_sec:
                sec = route_secondary(karr, salt, rh_, rs_)
                is_hot = jnp.any((karr[:, None] == hk_[None, :])
                                 & hv_[None, :], axis=1)
                use_sec = (jnp.bool_(two) | is_hot) & (sec != prim)
                sec_eff = jnp.where(use_sec, sec, jnp.int32(-1))
                mask = mask | (
                    (found & (sec_eff == me)).astype(jnp.int32) << 1)

            def gath(x):
                return jax.lax.all_gather(x, axes, tiled=False)

            return gath(mask), jax.tree.map(gath, rows)

        def run(tb, karr, rh_, rs_, hk_, hv_):
            fn = shard_map(local, mesh=self.mesh,
                           in_specs=(tspec, rep, rep, rep, rep, rep),
                           out_specs=(rep, rep), check_rep=False)
            return fn(tb, karr, rh_, rs_, hk_, hv_)

        return jax.jit(run)

    def read_slates(self, state, updater: str, keys, *,
                    impl: str = "auto"):
        """Batched point reads through the ring: one sharded device
        dispatch + one host sync for a [Q] key vector, bitwise identical
        to Q ``read_slate`` calls (two-choice / hot-split partials merge
        primary-then-secondary via the updater's combine).  Returns a
        list aligned with ``keys`` (``None`` for missing)."""
        keys_np = np.asarray(keys, self.key_dtype).reshape(-1)
        if keys_np.size == 0:
            return []
        with self.read_lock:
            with_sec = (bool(self.cfg.two_choice_threshold)
                        or bool(self._hot_valid.any()))
            cache_key = (updater, with_sec, impl)
            fn = self._read_fns.get(cache_key)
            if fn is None:
                fn = self._make_read_fn(state["tables"][updater],
                                        updater, with_sec, impl)
                self._read_fns[cache_key] = fn
            rh, rs = self.ring.table()
            hk, hv = self._hot_table()
            res = jax.device_get(fn(state["tables"][updater],
                                    jnp.asarray(keys_np), rh, rs, hk, hv))
        # host select over the gathered partials: at most one shard's
        # mask bit is set per (key, role), so argmax IS the owner
        mask, rows = np.asarray(res[0]), res[1]
        q = np.arange(keys_np.size)
        pm = (mask & 1).astype(bool)                    # [n_shards, Q]
        pf, psh = pm.any(axis=0), pm.argmax(axis=0)
        pr = jax.tree.map(lambda v: np.asarray(v)[psh, q], rows)
        if with_sec:
            sm = (mask & 2).astype(bool)
            sf, ssh = sm.any(axis=0), sm.argmax(axis=0)
            sr = jax.tree.map(lambda v: np.asarray(v)[ssh, q], rows)
        else:
            sf, sr = np.zeros_like(pf), None
        op = self.wf.by_name[updater]
        combine = getattr(op, "combine", None)
        out = []
        for i in range(keys_np.size):
            a = (jax.tree.map(lambda v: v[i], pr) if pf[i] else None)
            b = (jax.tree.map(lambda v: v[i], sr)
                 if sr is not None and sf[i] else None)
            if a is not None and b is not None:
                out.append(combine(a, b))
            elif a is not None:
                out.append(a)
            else:
                out.append(b)
        return out
