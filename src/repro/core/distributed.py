"""Distributed MapUpdate engine: the single-shard tick under shard_map.

Muppet's data path — workers hash events to peers and write directly into
their queues — becomes one ``all_to_all`` per workflow hop: each shard
buckets its outgoing events by destination shard (ring lookup), the
collective delivers every bucket, and the receiving shard enqueues.  No
master is on the data path; the ring is a runtime *array* input, so
failure re-routes and elastic joins swap rings without recompiling.

Two-choice dispatch (Muppet 2.0 dual queues): for associative updaters,
per-key load beyond ``two_choice_threshold`` in a tick spills to the
key's secondary shard; each shard then holds a *partial* aggregate and
``read_slate`` merges the (at most two) partials — the same <=2-contender
bound the paper proves acceptable in production.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field, replace
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import apply as apply_mod
from repro.core import queues as q_mod
from repro.core.durability import (DurabilityConfig, EngineDurability,
                                   merge_replay_ticks)
from repro.core.engine import EngineConfig
from repro.core.event import EventBatch, concat
from repro.core.hashing import HashRing, route, route_secondary
from repro.core.operators import (AssociativeUpdater, Mapper,
                                  SequentialUpdater, Updater)
from repro.core.queues import OverflowPolicy
from repro.core.workflow import Workflow
from repro.slates import flush as flush_mod
from repro.slates import table as tbl


def _axis_size(axis_names) -> int:
    """Static size of the (possibly multi-) mesh axis we're mapped over.
    ``jax.lax.axis_size`` only exists on newer jax; ``psum(1, axes)``
    constant-folds to a python int on every version we support."""
    if hasattr(jax.lax, "axis_size"):
        return int(jax.lax.axis_size(axis_names))
    return int(jax.lax.psum(1, axis_names))


def _salt(name: str) -> int:
    h = 2166136261
    for c in name.encode():
        h = ((h ^ c) * 16777619) & 0xFFFFFFFF
    return h


def exchange(batch: EventBatch, dest, axis_names, cap_per_dest: int
             ) -> Tuple[EventBatch, jnp.ndarray]:
    """Route events to destination shards with one all_to_all.

    Per-destination buckets have static capacity; excess events are
    dropped and counted (bounded queues, paper section 4.3).  Returns the
    received local batch [n*cap] and the local overflow count.
    """
    n = _axis_size(axis_names)
    B = batch.capacity
    dest = jnp.where(batch.valid, dest, n)              # invalid -> sink
    order = jnp.argsort(dest, stable=True)
    sb = batch.take(order)
    sdest = dest[order]
    pos = jnp.arange(B, dtype=jnp.int32) - jnp.searchsorted(
        sdest, sdest, side="left").astype(jnp.int32)
    ok = sb.valid & (sdest < n) & (pos < cap_per_dest)
    slot = jnp.where(ok, sdest * cap_per_dest + pos, n * cap_per_dest)
    dropped = jnp.sum((sb.valid & (sdest < n) & ~ok).astype(jnp.int32))

    buckets = EventBatch.empty(
        n * cap_per_dest,
        jax.tree.map(lambda a: (a.shape[1:], a.dtype), sb.value))

    def put(dst, src):
        return dst.at[slot].set(src, mode="drop")

    buckets = EventBatch(
        sid=put(buckets.sid, sb.sid), ts=put(buckets.ts, sb.ts),
        key=put(buckets.key, sb.key),
        value=jax.tree.map(put, buckets.value, sb.value),
        valid=put(buckets.valid, ok))

    def a2a(x):
        return jax.lax.all_to_all(
            x.reshape((n, cap_per_dest) + x.shape[1:]), axis_names,
            split_axis=0, concat_axis=0).reshape((n * cap_per_dest,)
                                                 + x.shape[1:])

    received = EventBatch(
        sid=a2a(buckets.sid), ts=a2a(buckets.ts), key=a2a(buckets.key),
        value=jax.tree.map(a2a, buckets.value), valid=a2a(buckets.valid))
    return received, dropped


@dataclass
class DistConfig(EngineConfig):
    exchange_slack: float = 2.0   # per-dest bucket capacity multiplier
    two_choice_threshold: int = 0  # 0 = off; else per-key spill point
    axis_names: Tuple[str, ...] = ("data",)


class DistributedEngine:
    """Global state lives sharded on dim 0 (= shard axis) of every leaf."""

    def __init__(self, workflow: Workflow, mesh: Mesh,
                 config: Optional[DistConfig] = None):
        self.wf = workflow
        self.mesh = mesh
        self.cfg = config or DistConfig()
        self.axes = self.cfg.axis_names
        self.n_shards = int(np.prod([mesh.shape[a] for a in self.axes]))
        self.ring = HashRing(self.n_shards)
        self._sharding = NamedSharding(mesh, P(self.axes))
        self._replicated = NamedSharding(mesh, P())
        cap = int(self.cfg.batch_size * self.cfg.exchange_slack
                  / self.n_shards)
        self.cap_per_dest = max(8, cap)
        self._step = None
        self._chunk = None
        self._empty_step = None
        self.tick_cursor = 0      # post-run() tick (drains included)
        self.dur: Optional[EngineDurability] = None
        if self.cfg.durability is not None:
            self.attach_durability(self.cfg.durability)

    # ---- state ----
    def init_state(self):
        def per_shard(make):
            one = make()
            return jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x[None], (self.n_shards,) + x.shape).copy(), one)

        queues = {op.name: per_shard(partial(
            q_mod.make_queue, self.cfg.queue_capacity, op.in_value_spec))
            for op in self.wf.operators}
        tables = {up.name: per_shard(partial(
            tbl.make_table, up.table_capacity, up.slate_spec()))
            for up in self.wf.updaters()}
        z = lambda: jnp.zeros((self.n_shards,), jnp.int32)
        state = {
            "queues": queues, "tables": tables,
            "tick": z(),
            "exchange_dropped": z(),
            "throttle_hits": z(),
            "processed": {op.name: z() for op in self.wf.operators},
        }
        state = jax.tree.map(lambda x: jnp.array(x, copy=True), state)
        return jax.device_put(state, self._shard_tree(state))

    def _shard_tree(self, state):
        def spec(path_unused, leaf):
            if leaf.ndim >= 1 and leaf.shape[0] == self.n_shards:
                return self._sharding
            return self._replicated
        return jax.tree_util.tree_map_with_path(spec, state)

    # ---- the per-shard tick ----
    def _local_tick(self, state, sources, ring_hashes, ring_shards):
        cfg, wf = self.cfg, self.wf
        queues = {k: jax.tree.map(lambda x: x[0], v)
                  for k, v in state["queues"].items()}
        tables = {k: jax.tree.map(lambda x: x[0], v)
                  for k, v in state["tables"].items()}
        processed = {k: v[0] for k, v in state["processed"].items()}
        exchange_dropped = state["exchange_dropped"][0]
        throttle_hits = state["throttle_hits"][0]
        tick = state["tick"][0]
        sources = {k: jax.tree.map(lambda x: x[0], v)
                   for k, v in sources.items()}
        outputs: Dict[str, List[EventBatch]] = {}

        def deliver_all(items):
            nonlocal throttle_hits, exchange_dropped
            work = deque(items)
            for _ in range(len(work) + 64):
                if not work:
                    return
                stream, batch = work.popleft()
                subs = wf.dests_of(stream)
                if not subs:
                    outputs.setdefault(stream, []).append(batch)
                    continue
                for dest_op in subs:
                    op = wf.by_name[dest_op]
                    dshard = route(batch.key, _salt(dest_op), ring_hashes,
                                   ring_shards)
                    if (cfg.two_choice_threshold
                            and isinstance(op, AssociativeUpdater)):
                        dshard = self._two_choice(batch, dshard, dest_op,
                                                  ring_hashes, ring_shards)
                    recv, dropped = exchange(batch, dshard, self.axes,
                                             self.cap_per_dest)
                    exchange_dropped = exchange_dropped + dropped
                    nq, ovf = q_mod.enqueue(queues[dest_op], recv)
                    pol = cfg.policy_for(dest_op)
                    if pol is OverflowPolicy.DROP:
                        nq = q_mod.count_drop(nq, ovf)
                    elif pol is OverflowPolicy.OVERFLOW_STREAM:
                        work.append((cfg.overflow_stream[dest_op], ovf))
                    elif pol is OverflowPolicy.THROTTLE:
                        throttle_hits = throttle_hits + ovf.count()
                        nq = q_mod.count_drop(nq, ovf)
                    queues[dest_op] = nq
            raise RuntimeError("overflow-stream routing did not converge")

        deliver_all(list(sources.items()))
        emitted_now: List[Tuple[str, EventBatch]] = []

        for op in wf.operators:
            queues[op.name], batch = q_mod.dequeue(queues[op.name],
                                                   cfg.batch_size)
            if isinstance(op, Mapper):
                outs = op.map_batch(batch)
                for s, b in outs.items():
                    emitted_now.append((s, b.mask(batch.valid & b.valid)))
                processed[op.name] = processed[op.name] + batch.count()
            elif isinstance(op, AssociativeUpdater):
                tables[op.name], ems, n = apply_mod.apply_associative(
                    op, tables[op.name], batch, tick, impl=cfg.fused)
                emitted_now.extend(ems.items())
                processed[op.name] = processed[op.name] + n
            elif isinstance(op, SequentialUpdater):
                tables[op.name], ems, deferred, n = \
                    apply_mod.apply_sequential(op, tables[op.name], batch,
                                               tick)
                emitted_now.extend(ems.items())
                nq, ovf = q_mod.enqueue(queues[op.name], deferred)
                queues[op.name] = q_mod.count_drop(nq, ovf)
                processed[op.name] = processed[op.name] + n

        for up in wf.updaters():
            if up.ttl:
                tables[up.name] = tbl.expire_ttl(tables[up.name], tick,
                                                 up.ttl)

        deliver_all(emitted_now)

        out_batches = {s: concat(bs) if len(bs) > 1 else bs[0]
                       for s, bs in outputs.items()}
        lift = lambda t: jax.tree.map(lambda x: x[None], t)
        new_state = {
            "queues": {k: lift(v) for k, v in queues.items()},
            "tables": {k: lift(v) for k, v in tables.items()},
            "tick": (tick + 1)[None],
            "exchange_dropped": exchange_dropped[None],
            "throttle_hits": throttle_hits[None],
            "processed": {k: v[None] for k, v in processed.items()},
        }
        return new_state, {k: lift(v) for k, v in out_batches.items()}

    def _two_choice(self, batch, primary, dest_op, ring_hashes,
                    ring_shards):
        """Spill a key's per-tick excess to its secondary shard."""
        secondary = route_secondary(batch.key, _salt(dest_op), ring_hashes,
                                    ring_shards)
        key_sink = jnp.where(batch.valid, batch.key, jnp.int32(2**31 - 1))
        order = jnp.argsort(key_sink, stable=True)
        sk = key_sink[order]
        rank_sorted = jnp.arange(batch.capacity, dtype=jnp.int32) - \
            jnp.searchsorted(sk, sk, side="left").astype(jnp.int32)
        rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)
        spill = rank >= self.cfg.two_choice_threshold
        return jnp.where(spill, secondary, primary)

    # ---- jit plumbing ----
    def _spec_like(self, tree):
        """Leading-dim-n_shards leaves are sharded, the rest replicated."""
        sharded, rep = P(self.axes), P()
        return jax.tree.map(
            lambda x: sharded
            if (hasattr(x, "ndim") and x.ndim >= 1
                and x.shape[0] == self.n_shards) else rep, tree)

    def step(self, state, sources: Dict[str, EventBatch]):
        """sources: global batches with leading dim n_shards*B_loc or
        [n_shards, B_loc] — pass [n_shards, B_loc] (leading shard axis)."""
        from jax.experimental.shard_map import shard_map
        if self._step is None:
            sharded, rep = P(self.axes), P()
            state_specs = self._spec_like(state)
            src_specs = jax.tree.map(lambda _: sharded, sources)

            def run(st, src, rh, rs):
                fn = shard_map(self._local_tick, mesh=self.mesh,
                               in_specs=(state_specs, src_specs, rep, rep),
                               out_specs=sharded,
                               check_rep=False)
                return fn(st, src, rh, rs)

            self._step = jax.jit(run, donate_argnums=(0,))
        rh, rs = self.ring.table()
        return self._step(state, sources, rh, rs)

    def run_chunk(self, state, stacked_sources: Dict[str, EventBatch]):
        """T device-resident ticks in one dispatch (DESIGN.md 2.2).

        ``stacked_sources`` leaves are [T, n_shards, B, ...] — tick axis
        leading (scanned), shard axis second (split by shard_map).
        Returns ``(state, stacked_outputs, info)``; output leaves are
        [T, n_shards, ...] and ``info['throttle_hits']`` is the
        [T, n_shards] on-device per-tick trace, so the host syncs once
        per chunk for the backpressure signal.
        """
        from jax.experimental.shard_map import shard_map
        if self._chunk is None:
            stacked = P(None, self.axes)
            rep = P()
            state_specs = self._spec_like(state)
            src_specs = jax.tree.map(lambda _: stacked, stacked_sources)

            def local_chunk(st, src, rh, rs):
                def body(s, x):
                    s2, outs = self._local_tick(s, x, rh, rs)
                    return s2, (outs, s2["throttle_hits"])
                final, (outs, hits) = jax.lax.scan(body, st, src)
                return final, outs, hits

            def run(st, src, rh, rs):
                fn = shard_map(local_chunk, mesh=self.mesh,
                               in_specs=(state_specs, src_specs, rep, rep),
                               out_specs=(state_specs, stacked, stacked),
                               check_rep=False)
                return fn(st, src, rh, rs)

            self._chunk = jax.jit(run, donate_argnums=(0,))
        rh, rs = self.ring.table()
        state, outs, hits = self._chunk(state, stacked_sources, rh, rs)
        return state, outs, {"throttle_hits": hits}

    # ---- durability (DESIGN.md section 10): per-shard WAL + frontier --
    def attach_durability(self, cfg: DurabilityConfig):
        """One WAL per shard (on durable storage, the role Cassandra's
        commit log plays), one shared slate store, one barrier frontier.
        Incompatible with two-choice dispatch: partial aggregates of the
        same key on two shards would clobber each other in the store."""
        if self.cfg.two_choice_threshold:
            raise ValueError("durability requires two_choice_threshold=0 "
                             "(per-key partials are not store-mergeable)")
        self.dur = EngineDurability(cfg, self.wf,
                                    self.cfg.queue_capacity,
                                    self.cfg.batch_size,
                                    n_shards=self.n_shards)

    def append_sources(self, tick: int, sources: Dict[str, EventBatch]):
        """Write-ahead: log each shard's slice of the [n_shards, B]
        source batches to that shard's WAL (call before ``step``)."""
        host = {s: jax.tree.map(lambda x: np.asarray(jax.device_get(x)), b)
                for s, b in sources.items()}
        for sh in range(self.n_shards):
            sl = {s: EventBatch(sid=b.sid[sh], ts=b.ts[sh], key=b.key[sh],
                                value=jax.tree.map(lambda x: x[sh],
                                                   b.value),
                                valid=b.valid[sh])
                  for s, b in host.items()}
            sl = {s: b for s, b in sl.items() if b.valid.any()}
            self.dur.append(tick, sl, shard=sh)

    def _step_empty(self, state):
        """One source-less tick (drain barriers, replay gap ticks)."""
        from jax.experimental.shard_map import shard_map
        if self._empty_step is None:
            sharded, rep = P(self.axes), P()
            state_specs = self._spec_like(state)

            def run(st, rh, rs):
                fn = shard_map(
                    lambda s, h, r: self._local_tick(s, {}, h, r),
                    mesh=self.mesh,
                    in_specs=(state_specs, rep, rep),
                    out_specs=sharded, check_rep=False)
                return fn(st, rh, rs)

            self._empty_step = jax.jit(run, donate_argnums=(0,))
        rh, rs = self.ring.table()
        state, _ = self._empty_step(state, rh, rs)
        return state

    def _drain_queues(self, state, max_ticks: int):
        d = 0
        while d < max_ticks:
            sizes = jax.device_get({k: q.size
                                    for k, q in state["queues"].items()})
            if all(int(v.sum()) == 0 for v in sizes.values()):
                break
            state = self._step_empty(state)
            d += 1
        return state, d

    def _flush_boundary(self, state, tick: int):
        """Barrier-drain, flush every shard's dirty slates (one
        device_get per table), record the frontier."""
        dur = self.dur
        if dur.cfg.barrier:
            state, d = self._drain_queues(state, dur.cfg.drain_ticks_max)
            tick += d
        new_tables = {}
        for up in self.wf.updaters():
            t = state["tables"][up.name]
            dirty = np.asarray(jax.device_get(t.dirty))
            keys = np.asarray(jax.device_get(t.keys))
            ts = np.asarray(jax.device_get(t.ts))
            vals = jax.tree.map(lambda v: np.asarray(jax.device_get(v)),
                                t.vals)
            for sh in range(self.n_shards):
                idx = np.nonzero(dirty[sh] & (keys[sh] != -1))[0]
                dur.flusher.flush_rows(
                    up.name, keys[sh][idx], ts[sh][idx],
                    jax.tree.map(lambda v: v[sh][idx], vals), up.ttl)
            new_tables[up.name] = tbl.SlateTable(
                keys=t.keys, ts=t.ts, dirty=jnp.zeros_like(t.dirty),
                vals=t.vals, dropped=t.dropped)
        state = dict(state)
        state["tables"] = new_tables
        dur.record_frontier(tick)
        return state, tick

    def run(self, state, source_fn, n_ticks: int, *, start_tick: int = 0,
            handle=None):
        """Uniform host driver (same shape as ``Engine.run``):
        ``source_fn(tick, max_events) -> dict[stream, EventBatch]`` with
        [n_shards, B]-leading batches; ``max_events`` is always ``None``
        here (per-shard backpressure is the exchange/queue bound, not a
        host-side ingest limit).  With durability attached, sources are
        write-ahead logged per shard and flush boundaries fire per the
        flush policy — the ``run_durable`` path.  ``handle`` (a
        :class:`~repro.core.engine.StateHandle`) is republished every
        tick.  Returns ``(state, outputs)`` with one output dict per
        source tick; the post-run tick cursor (drain ticks included) is
        left on ``self.tick_cursor`` for durable drivers that resume."""
        outputs = []
        t = start_tick
        for _ in range(n_ticks):
            srcs = source_fn(t, None)
            if self.dur is not None:
                self.append_sources(t, srcs)
            state, outs = self.step(state, srcs)
            outputs.append(outs)
            t += 1
            if self.dur is not None and self.dur.due(t, state["tables"]):
                state, t = self._flush_boundary(state, t)
            if handle is not None:
                handle.state = state
        self.tick_cursor = t
        return state, outputs

    def drain(self, state, max_ticks: int = 64):
        """Run source-less ticks until every shard's queues are empty
        (or ``max_ticks``).  Returns ``(state, ticks_run)``."""
        return self._drain_queues(state, max_ticks)

    def run_durable(self, state, source_fn, n_ticks: int, *,
                    start_tick: int = 0):
        """Host driver: per-tick step with write-ahead logging and
        policy-driven flush boundaries.  ``source_fn(tick)`` returns
        [n_shards, B]-leading source batches.  Returns
        ``(state, next_tick)`` (drain ticks included).  Thin wrapper
        over :meth:`run` — one durable drive loop to maintain."""
        assert self.dur is not None, "attach_durability first"
        state, _ = self.run(state, lambda t, _mx: source_fn(t), n_ticks,
                            start_tick=start_tick)
        return state, self.tick_cursor

    def recover(self, *, frontier=None):
        """Rebuild sharded state after losing any subset of machines:
        flushed slates are re-inserted on whatever shard the *current*
        ring routes them to (so a dead shard's keys land on survivors —
        the elastic-restore move of ``distributed/checkpoint.py``:
        host rows -> ``device_put`` with the target sharding), then each
        shard's WAL suffix replays through the shard_map tick, which
        re-routes every replayed event with the current ring."""
        dur = self.dur
        assert dur is not None, "attach_durability first"
        frontier = frontier or dur.frontier
        f_tick = int(frontier.tick)
        offs = list(frontier.wal_offset) \
            if isinstance(frontier.wal_offset, (list, tuple)) \
            else [frontier.wal_offset] * self.n_shards

        state = jax.device_get(self.init_state())
        state["tick"] = np.full((self.n_shards,), f_tick, np.int32)
        rh, rs = self.ring.table()
        for up in self.wf.updaters():
            recs = dur.store.scan_records(
                up.name, now=f_tick if up.ttl else None)
            if not recs:
                continue
            ks = np.asarray(sorted(recs), np.int32)
            shard_of = np.asarray(jax.device_get(
                route(jnp.asarray(ks), _salt(up.name), rh, rs)))
            t = state["tables"][up.name]
            per_shard = []
            for sh in range(self.n_shards):
                local = jax.tree.map(lambda x: jnp.asarray(x[sh]), t)
                sel = np.nonzero(shard_of == sh)[0]
                if len(sel):
                    ts = np.asarray([recs[int(k)][0] for k in ks[sel]],
                                    np.int32)
                    slates = jax.tree.map(
                        lambda *r: np.stack(r),
                        *[recs[int(k)][1] for k in ks[sel]])
                    local = flush_mod.restore_into(local, ks[sel],
                                                   slates, ts)
                per_shard.append(jax.device_get(local))
            state["tables"][up.name] = jax.tree.map(
                lambda *xs: np.stack(xs), *per_shard)
        state = jax.tree.map(jnp.asarray, state,
                             is_leaf=lambda x: isinstance(x, np.ndarray))
        state = jax.device_put(state, self._shard_tree(state))

        cur = f_tick
        for tk, by_shard in merge_replay_ticks(dur.wals, offs):
            if tk < f_tick:
                continue
            while cur < tk:
                state = self._step_empty(state)
                cur += 1
            state, _ = self.step(state, self._stack_shard_sources(
                by_shard))
            cur += 1
        return state

    def _stack_shard_sources(self, by_shard: Dict[int, Dict[str, Any]]
                             ) -> Dict[str, EventBatch]:
        """Per-shard replay records -> [n_shards, B] source batches
        (missing shards/streams become all-invalid rows)."""
        caps: Dict[str, int] = {}
        tmpl: Dict[str, EventBatch] = {}
        for src in by_shard.values():
            for s, b in src.items():
                if s not in caps or b.capacity > caps[s]:
                    caps[s], tmpl[s] = b.capacity, b

        def one(sh, s):
            b = by_shard.get(sh, {}).get(s)
            if b is None:
                t = tmpl[s]
                return EventBatch.empty(
                    caps[s], jax.tree.map(
                        lambda a: (a.shape[1:], a.dtype), t.value))
            return EventBatch(sid=jnp.asarray(b.sid),
                              ts=jnp.asarray(b.ts),
                              key=jnp.asarray(b.key),
                              value=jax.tree.map(jnp.asarray, b.value),
                              valid=jnp.asarray(b.valid)).pad_to(caps[s])

        return {s: jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[one(sh, s) for sh in range(self.n_shards)])
            for s in tmpl}

    def close(self):
        if self.dur is not None:
            self.dur.close()

    # ---- failure / elasticity (host side; master of section 4.3) ----
    def fail_shard(self, state, shard: int):
        """Machine crash: re-route ring; the dead shard's unflushed slates
        and queued events are lost (paper semantics)."""
        self.ring.fail(shard)
        self._step = None  # ring arrays change shape only on rebuild size
        self._chunk = None
        self._empty_step = None

        def zap(leaf):
            if hasattr(leaf, "ndim") and leaf.ndim >= 1 and \
                    leaf.shape[0] == self.n_shards:
                return leaf.at[shard].set(jnp.zeros_like(leaf[shard]))
            return leaf

        state = dict(state)
        state["queues"] = jax.tree.map(zap, state["queues"])
        # tables: mark every slot empty on the dead shard
        new_tables = {}
        for name, t in state["tables"].items():
            keys = t.keys.at[shard].set(
                jnp.full_like(t.keys[shard], tbl.EMPTY))
            dirty = t.dirty.at[shard].set(
                jnp.zeros_like(t.dirty[shard]))
            new_tables[name] = tbl.SlateTable(
                keys=keys, ts=t.ts, dirty=dirty, vals=t.vals,
                dropped=t.dropped)
        state["tables"] = new_tables
        return state

    def stats(self, state):
        g = lambda x: np.asarray(jax.device_get(x))
        return {
            "tick": int(g(state["tick"]).max()),
            "exchange_dropped": int(g(state["exchange_dropped"]).sum()),
            "throttle_hits": int(g(state["throttle_hits"]).sum()),
            "processed": {k: int(g(v).sum())
                          for k, v in state["processed"].items()},
            "queue_dropped": {k: int(g(q.dropped).sum())
                              for k, q in state["queues"].items()},
            "table_occupancy": {k: int(g(t.occupancy()).sum())
                                for k, t in state["tables"].items()},
        }

    def read_slate(self, state, updater: str, key: int, *, merge=None):
        """Read a slate by key; with two-choice enabled, merges the (<=2)
        partial aggregates (primary + secondary shard)."""
        rh, rs = self.ring.table()
        karr = jnp.asarray([key], jnp.int32)
        shards = [int(route(karr, _salt(updater), rh, rs)[0])]
        if self.cfg.two_choice_threshold:
            shards.append(int(route_secondary(karr, _salt(updater),
                                              rh, rs)[0]))
        vals = []
        t = state["tables"][updater]
        for s in dict.fromkeys(shards):
            local = jax.tree.map(lambda x: x[s], t)
            slot, found = tbl.lookup(local, karr)
            if bool(found[0]):
                vals.append(jax.tree.map(
                    lambda v: jax.device_get(v[int(slot[0])]), local.vals))
        if not vals:
            return None
        if len(vals) == 1:
            return vals[0]
        # merge the two partial aggregates via the updater's combine
        op = self.wf.by_name[updater]
        combine = merge or op.combine
        out = vals[0]
        for v in vals[1:]:
            out = combine(jax.tree.map(np.asarray, out),
                          jax.tree.map(np.asarray, v))
        return out
