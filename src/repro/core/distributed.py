"""Distributed MapUpdate engine: the single-shard tick under shard_map.

Muppet's data path — workers hash events to peers and write directly into
their queues — becomes one ``all_to_all`` per workflow hop: each shard
buckets its outgoing events by destination shard (ring lookup), the
collective delivers every bucket, and the receiving shard enqueues.  No
master is on the data path; the ring is a runtime *array* input, so
failure re-routes and elastic joins swap rings without recompiling.

Two-choice dispatch (Muppet 2.0 dual queues): for associative updaters,
per-key load beyond ``two_choice_threshold`` in a tick spills to the
key's secondary shard; each shard then holds a *partial* aggregate and
``read_slate`` merges the (at most two) partials — the same <=2-contender
bound the paper proves acceptable in production.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field, replace
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import apply as apply_mod
from repro.core import queues as q_mod
from repro.core.engine import EngineConfig
from repro.core.event import EventBatch, concat
from repro.core.hashing import HashRing, route, route_secondary
from repro.core.operators import (AssociativeUpdater, Mapper,
                                  SequentialUpdater, Updater)
from repro.core.queues import OverflowPolicy
from repro.core.workflow import Workflow
from repro.slates import table as tbl


def _axis_size(axis_names) -> int:
    """Static size of the (possibly multi-) mesh axis we're mapped over.
    ``jax.lax.axis_size`` only exists on newer jax; ``psum(1, axes)``
    constant-folds to a python int on every version we support."""
    if hasattr(jax.lax, "axis_size"):
        return int(jax.lax.axis_size(axis_names))
    return int(jax.lax.psum(1, axis_names))


def _salt(name: str) -> int:
    h = 2166136261
    for c in name.encode():
        h = ((h ^ c) * 16777619) & 0xFFFFFFFF
    return h


def exchange(batch: EventBatch, dest, axis_names, cap_per_dest: int
             ) -> Tuple[EventBatch, jnp.ndarray]:
    """Route events to destination shards with one all_to_all.

    Per-destination buckets have static capacity; excess events are
    dropped and counted (bounded queues, paper section 4.3).  Returns the
    received local batch [n*cap] and the local overflow count.
    """
    n = _axis_size(axis_names)
    B = batch.capacity
    dest = jnp.where(batch.valid, dest, n)              # invalid -> sink
    order = jnp.argsort(dest, stable=True)
    sb = batch.take(order)
    sdest = dest[order]
    pos = jnp.arange(B, dtype=jnp.int32) - jnp.searchsorted(
        sdest, sdest, side="left").astype(jnp.int32)
    ok = sb.valid & (sdest < n) & (pos < cap_per_dest)
    slot = jnp.where(ok, sdest * cap_per_dest + pos, n * cap_per_dest)
    dropped = jnp.sum((sb.valid & (sdest < n) & ~ok).astype(jnp.int32))

    buckets = EventBatch.empty(
        n * cap_per_dest,
        jax.tree.map(lambda a: (a.shape[1:], a.dtype), sb.value))

    def put(dst, src):
        return dst.at[slot].set(src, mode="drop")

    buckets = EventBatch(
        sid=put(buckets.sid, sb.sid), ts=put(buckets.ts, sb.ts),
        key=put(buckets.key, sb.key),
        value=jax.tree.map(put, buckets.value, sb.value),
        valid=put(buckets.valid, ok))

    def a2a(x):
        return jax.lax.all_to_all(
            x.reshape((n, cap_per_dest) + x.shape[1:]), axis_names,
            split_axis=0, concat_axis=0).reshape((n * cap_per_dest,)
                                                 + x.shape[1:])

    received = EventBatch(
        sid=a2a(buckets.sid), ts=a2a(buckets.ts), key=a2a(buckets.key),
        value=jax.tree.map(a2a, buckets.value), valid=a2a(buckets.valid))
    return received, dropped


@dataclass
class DistConfig(EngineConfig):
    exchange_slack: float = 2.0   # per-dest bucket capacity multiplier
    two_choice_threshold: int = 0  # 0 = off; else per-key spill point
    axis_names: Tuple[str, ...] = ("data",)


class DistributedEngine:
    """Global state lives sharded on dim 0 (= shard axis) of every leaf."""

    def __init__(self, workflow: Workflow, mesh: Mesh,
                 config: Optional[DistConfig] = None):
        self.wf = workflow
        self.mesh = mesh
        self.cfg = config or DistConfig()
        self.axes = self.cfg.axis_names
        self.n_shards = int(np.prod([mesh.shape[a] for a in self.axes]))
        self.ring = HashRing(self.n_shards)
        self._sharding = NamedSharding(mesh, P(self.axes))
        self._replicated = NamedSharding(mesh, P())
        cap = int(self.cfg.batch_size * self.cfg.exchange_slack
                  / self.n_shards)
        self.cap_per_dest = max(8, cap)
        self._step = None
        self._chunk = None

    # ---- state ----
    def init_state(self):
        def per_shard(make):
            one = make()
            return jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x[None], (self.n_shards,) + x.shape).copy(), one)

        queues = {op.name: per_shard(partial(
            q_mod.make_queue, self.cfg.queue_capacity, op.in_value_spec))
            for op in self.wf.operators}
        tables = {up.name: per_shard(partial(
            tbl.make_table, up.table_capacity, up.slate_spec()))
            for up in self.wf.updaters()}
        z = lambda: jnp.zeros((self.n_shards,), jnp.int32)
        state = {
            "queues": queues, "tables": tables,
            "tick": z(),
            "exchange_dropped": z(),
            "throttle_hits": z(),
            "processed": {op.name: z() for op in self.wf.operators},
        }
        state = jax.tree.map(lambda x: jnp.array(x, copy=True), state)
        return jax.device_put(state, self._shard_tree(state))

    def _shard_tree(self, state):
        def spec(path_unused, leaf):
            if leaf.ndim >= 1 and leaf.shape[0] == self.n_shards:
                return self._sharding
            return self._replicated
        return jax.tree_util.tree_map_with_path(spec, state)

    # ---- the per-shard tick ----
    def _local_tick(self, state, sources, ring_hashes, ring_shards):
        cfg, wf = self.cfg, self.wf
        queues = {k: jax.tree.map(lambda x: x[0], v)
                  for k, v in state["queues"].items()}
        tables = {k: jax.tree.map(lambda x: x[0], v)
                  for k, v in state["tables"].items()}
        processed = {k: v[0] for k, v in state["processed"].items()}
        exchange_dropped = state["exchange_dropped"][0]
        throttle_hits = state["throttle_hits"][0]
        tick = state["tick"][0]
        sources = {k: jax.tree.map(lambda x: x[0], v)
                   for k, v in sources.items()}
        outputs: Dict[str, List[EventBatch]] = {}

        def deliver_all(items):
            nonlocal throttle_hits, exchange_dropped
            work = deque(items)
            for _ in range(len(work) + 64):
                if not work:
                    return
                stream, batch = work.popleft()
                subs = wf.dests_of(stream)
                if not subs:
                    outputs.setdefault(stream, []).append(batch)
                    continue
                for dest_op in subs:
                    op = wf.by_name[dest_op]
                    dshard = route(batch.key, _salt(dest_op), ring_hashes,
                                   ring_shards)
                    if (cfg.two_choice_threshold
                            and isinstance(op, AssociativeUpdater)):
                        dshard = self._two_choice(batch, dshard, dest_op,
                                                  ring_hashes, ring_shards)
                    recv, dropped = exchange(batch, dshard, self.axes,
                                             self.cap_per_dest)
                    exchange_dropped = exchange_dropped + dropped
                    nq, ovf = q_mod.enqueue(queues[dest_op], recv)
                    pol = cfg.policy_for(dest_op)
                    if pol is OverflowPolicy.DROP:
                        nq = q_mod.count_drop(nq, ovf)
                    elif pol is OverflowPolicy.OVERFLOW_STREAM:
                        work.append((cfg.overflow_stream[dest_op], ovf))
                    elif pol is OverflowPolicy.THROTTLE:
                        throttle_hits = throttle_hits + ovf.count()
                        nq = q_mod.count_drop(nq, ovf)
                    queues[dest_op] = nq
            raise RuntimeError("overflow-stream routing did not converge")

        deliver_all(list(sources.items()))
        emitted_now: List[Tuple[str, EventBatch]] = []

        for op in wf.operators:
            queues[op.name], batch = q_mod.dequeue(queues[op.name],
                                                   cfg.batch_size)
            if isinstance(op, Mapper):
                outs = op.map_batch(batch)
                for s, b in outs.items():
                    emitted_now.append((s, b.mask(batch.valid & b.valid)))
                processed[op.name] = processed[op.name] + batch.count()
            elif isinstance(op, AssociativeUpdater):
                tables[op.name], ems, n = apply_mod.apply_associative(
                    op, tables[op.name], batch, tick, impl=cfg.fused)
                emitted_now.extend(ems.items())
                processed[op.name] = processed[op.name] + n
            elif isinstance(op, SequentialUpdater):
                tables[op.name], ems, deferred, n = \
                    apply_mod.apply_sequential(op, tables[op.name], batch,
                                               tick)
                emitted_now.extend(ems.items())
                nq, ovf = q_mod.enqueue(queues[op.name], deferred)
                queues[op.name] = q_mod.count_drop(nq, ovf)
                processed[op.name] = processed[op.name] + n

        for up in wf.updaters():
            if up.ttl:
                tables[up.name] = tbl.expire_ttl(tables[up.name], tick,
                                                 up.ttl)

        deliver_all(emitted_now)

        out_batches = {s: concat(bs) if len(bs) > 1 else bs[0]
                       for s, bs in outputs.items()}
        lift = lambda t: jax.tree.map(lambda x: x[None], t)
        new_state = {
            "queues": {k: lift(v) for k, v in queues.items()},
            "tables": {k: lift(v) for k, v in tables.items()},
            "tick": (tick + 1)[None],
            "exchange_dropped": exchange_dropped[None],
            "throttle_hits": throttle_hits[None],
            "processed": {k: v[None] for k, v in processed.items()},
        }
        return new_state, {k: lift(v) for k, v in out_batches.items()}

    def _two_choice(self, batch, primary, dest_op, ring_hashes,
                    ring_shards):
        """Spill a key's per-tick excess to its secondary shard."""
        secondary = route_secondary(batch.key, _salt(dest_op), ring_hashes,
                                    ring_shards)
        key_sink = jnp.where(batch.valid, batch.key, jnp.int32(2**31 - 1))
        order = jnp.argsort(key_sink, stable=True)
        sk = key_sink[order]
        rank_sorted = jnp.arange(batch.capacity, dtype=jnp.int32) - \
            jnp.searchsorted(sk, sk, side="left").astype(jnp.int32)
        rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)
        spill = rank >= self.cfg.two_choice_threshold
        return jnp.where(spill, secondary, primary)

    # ---- jit plumbing ----
    def _spec_like(self, tree):
        """Leading-dim-n_shards leaves are sharded, the rest replicated."""
        sharded, rep = P(self.axes), P()
        return jax.tree.map(
            lambda x: sharded
            if (hasattr(x, "ndim") and x.ndim >= 1
                and x.shape[0] == self.n_shards) else rep, tree)

    def step(self, state, sources: Dict[str, EventBatch]):
        """sources: global batches with leading dim n_shards*B_loc or
        [n_shards, B_loc] — pass [n_shards, B_loc] (leading shard axis)."""
        from jax.experimental.shard_map import shard_map
        if self._step is None:
            sharded, rep = P(self.axes), P()
            state_specs = self._spec_like(state)
            src_specs = jax.tree.map(lambda _: sharded, sources)

            def run(st, src, rh, rs):
                fn = shard_map(self._local_tick, mesh=self.mesh,
                               in_specs=(state_specs, src_specs, rep, rep),
                               out_specs=sharded,
                               check_rep=False)
                return fn(st, src, rh, rs)

            self._step = jax.jit(run, donate_argnums=(0,))
        rh, rs = self.ring.table()
        return self._step(state, sources, rh, rs)

    def run_chunk(self, state, stacked_sources: Dict[str, EventBatch]):
        """T device-resident ticks in one dispatch (DESIGN.md 2.2).

        ``stacked_sources`` leaves are [T, n_shards, B, ...] — tick axis
        leading (scanned), shard axis second (split by shard_map).
        Returns ``(state, stacked_outputs, info)``; output leaves are
        [T, n_shards, ...] and ``info['throttle_hits']`` is the
        [T, n_shards] on-device per-tick trace, so the host syncs once
        per chunk for the backpressure signal.
        """
        from jax.experimental.shard_map import shard_map
        if self._chunk is None:
            stacked = P(None, self.axes)
            rep = P()
            state_specs = self._spec_like(state)
            src_specs = jax.tree.map(lambda _: stacked, stacked_sources)

            def local_chunk(st, src, rh, rs):
                def body(s, x):
                    s2, outs = self._local_tick(s, x, rh, rs)
                    return s2, (outs, s2["throttle_hits"])
                final, (outs, hits) = jax.lax.scan(body, st, src)
                return final, outs, hits

            def run(st, src, rh, rs):
                fn = shard_map(local_chunk, mesh=self.mesh,
                               in_specs=(state_specs, src_specs, rep, rep),
                               out_specs=(state_specs, stacked, stacked),
                               check_rep=False)
                return fn(st, src, rh, rs)

            self._chunk = jax.jit(run, donate_argnums=(0,))
        rh, rs = self.ring.table()
        state, outs, hits = self._chunk(state, stacked_sources, rh, rs)
        return state, outs, {"throttle_hits": hits}

    # ---- failure / elasticity (host side; master of section 4.3) ----
    def fail_shard(self, state, shard: int):
        """Machine crash: re-route ring; the dead shard's unflushed slates
        and queued events are lost (paper semantics)."""
        self.ring.fail(shard)
        self._step = None  # ring arrays change shape only on rebuild size
        self._chunk = None

        def zap(leaf):
            if hasattr(leaf, "ndim") and leaf.ndim >= 1 and \
                    leaf.shape[0] == self.n_shards:
                return leaf.at[shard].set(jnp.zeros_like(leaf[shard]))
            return leaf

        state = dict(state)
        state["queues"] = jax.tree.map(zap, state["queues"])
        # tables: mark every slot empty on the dead shard
        new_tables = {}
        for name, t in state["tables"].items():
            keys = t.keys.at[shard].set(
                jnp.full_like(t.keys[shard], tbl.EMPTY))
            dirty = t.dirty.at[shard].set(
                jnp.zeros_like(t.dirty[shard]))
            new_tables[name] = tbl.SlateTable(
                keys=keys, ts=t.ts, dirty=dirty, vals=t.vals,
                dropped=t.dropped)
        state["tables"] = new_tables
        return state

    def stats(self, state):
        g = lambda x: np.asarray(jax.device_get(x))
        return {
            "tick": int(g(state["tick"]).max()),
            "exchange_dropped": int(g(state["exchange_dropped"]).sum()),
            "throttle_hits": int(g(state["throttle_hits"]).sum()),
            "processed": {k: int(g(v).sum())
                          for k, v in state["processed"].items()},
            "queue_dropped": {k: int(g(q.dropped).sum())
                              for k, q in state["queues"].items()},
            "table_occupancy": {k: int(g(t.occupancy()).sum())
                                for k, t in state["tables"].items()},
        }

    def read_slate(self, state, updater: str, key: int, *, merge=None):
        """Read a slate by key; with two-choice enabled, merges the (<=2)
        partial aggregates (primary + secondary shard)."""
        rh, rs = self.ring.table()
        karr = jnp.asarray([key], jnp.int32)
        shards = [int(route(karr, _salt(updater), rh, rs)[0])]
        if self.cfg.two_choice_threshold:
            shards.append(int(route_secondary(karr, _salt(updater),
                                              rh, rs)[0]))
        vals = []
        t = state["tables"][updater]
        for s in dict.fromkeys(shards):
            local = jax.tree.map(lambda x: x[s], t)
            slot, found = tbl.lookup(local, karr)
            if bool(found[0]):
                vals.append(jax.tree.map(
                    lambda v: jax.device_get(v[int(slot[0])]), local.vals))
        if not vals:
            return None
        if len(vals) == 1:
            return vals[0]
        # merge the two partial aggregates via the updater's combine
        op = self.wf.by_name[updater]
        combine = merge or op.combine
        out = vals[0]
        for v in vals[1:]:
            out = combine(jax.tree.map(np.asarray, out),
                          jax.tree.map(np.asarray, v))
        return out
