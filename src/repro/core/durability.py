"""Durability layer threaded through the engine drivers (DESIGN.md 10).

Muppet keeps slates recoverable by continuously flushing them to
Cassandra and restoring on restart (paper sections 4.2-4.3); event
replay is the paper's stated future work.  This module wires both into
one runtime:

- every ingested source batch is appended to a per-shard
  :class:`~repro.slates.wal.WriteAheadLog` *before* the tick that
  consumes it (write-ahead);
- per :class:`~repro.slates.flush.FlushPolicy`, every updater's
  :class:`~repro.slates.table.SlateTable` is flushed to the
  :class:`~repro.slates.kvstore.KVStore` and a
  :class:`~repro.slates.flush.FlushFrontier` ``(tick, wal_offset)`` is
  recorded atomically once the writes are durable;
- recovery = restore flushed slates + replay the WAL suffix from the
  frontier through the same jitted tick path.

Guarantees (see DESIGN.md section 10 for the full table): with the
default drain **barrier** the pipeline is empty at every frontier, so
replay applies each surviving event exactly once — bitwise-identical
slates for associative updaters.  With ``barrier=False`` the frontier is
set ``replay_slack`` ticks behind the flush, which re-applies in-flight
events already captured by the snapshot: *at-least-once*, acceptable for
idempotent sequential updaters (e.g. last-value), wrong for counters.
"""
from __future__ import annotations

import functools
import os
import queue as pyqueue
import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.slates.flush import FlushConfig, Flusher, FlushFrontier
from repro.slates.kvstore import KVStore
from repro.slates.wal import WriteAheadLog


@dataclass
class DurabilityConfig:
    """Pure configuration (paths + knobs) — runtime handles live in
    :class:`EngineDurability` so configs stay copyable/shareable."""

    dir: str                          # root: wal(s), store, FRONTIER.json
    flush: FlushConfig = field(default_factory=FlushConfig)
    # drain in-flight queues before each flush: exactly-once replay.
    # False skips the drain ticks and backdates the frontier by
    # replay_slack: at-least-once replay (see module docstring).
    barrier: bool = True
    drain_ticks_max: int = 64
    replay_slack: Optional[int] = None   # None = auto from workflow shape
    truncate_wal: bool = False        # compact the log at each frontier
    sync_wal: bool = False            # fsync every append
    # KV store replication (1 replica: plain local dir; >1 simulates the
    # paper's Cassandra quorum cluster)
    replicas: int = 1
    write_quorum: int = 1
    read_quorum: int = 1
    # retain flushed rows host-side (Flusher.track_deltas) so an
    # attached SlateReplica can refresh incrementally from the flush
    # stream instead of re-scanning the store (DESIGN.md section 15)
    track_flush_deltas: bool = False

    def store_root(self) -> str:
        return os.path.join(self.dir, "store")

    def wal_path(self, shard: Optional[int] = None) -> str:
        if shard is None:
            return os.path.join(self.dir, "wal.log")
        return os.path.join(self.dir, f"shard_{shard:03d}", "wal.log")

    def frontier_path(self) -> str:
        return os.path.join(self.dir, "FRONTIER.json")

    def make_store(self) -> KVStore:
        return KVStore(self.store_root(), replicas=self.replicas,
                       write_quorum=self.write_quorum,
                       read_quorum=self.read_quorum)


class WALAppendError(RuntimeError):
    """One or more background WAL appends failed; ``.errors`` holds the
    underlying exceptions in arrival order.  Raised at the next fence —
    a frontier must never advance past a failed append."""

    def __init__(self, errors):
        self.errors = list(errors)
        super().__init__(
            f"{len(self.errors)} WAL append(s) failed: "
            f"{self.errors[0]!r}")


def auto_replay_slack(workflow, queue_capacity: int,
                      batch_size: int) -> int:
    """Sound residence bound for barrier-less frontiers: an event sits at
    most ceil(Q/B) ticks per hop (bounded FIFO draining B per tick), for
    at most graph-depth hops.  Sustained hotspot deferral past this bound
    voids the guarantee — use the barrier (DESIGN.md 10.3)."""
    depth = max(1, len(workflow.operators))
    per_hop = -(-queue_capacity // max(1, batch_size))   # ceil
    return depth * (1 + per_hop) + 1


class EngineDurability:
    """Runtime durability state for one engine (or one shard group).

    Owns the WAL(s), the KV store + background flusher, and the frontier
    file.  ``n_shards=None`` is the single-shard engine (one WAL);
    an int opens one WAL per shard sharing a single store + frontier
    barrier (each shard's offset tracked independently).
    """

    def __init__(self, cfg: DurabilityConfig, workflow,
                 queue_capacity: int, batch_size: int,
                 n_shards: Optional[int] = None):
        self.cfg = cfg
        self.wf = workflow
        self.n_shards = n_shards
        os.makedirs(cfg.dir, exist_ok=True)
        self.store = cfg.make_store()
        self.flusher = Flusher(self.store, cfg.flush,
                               track_deltas=cfg.track_flush_deltas)
        if n_shards is None:
            self.wals = [WriteAheadLog(cfg.wal_path(), sync=cfg.sync_wal)]
        else:
            self.wals = [WriteAheadLog(cfg.wal_path(s), sync=cfg.sync_wal)
                         for s in range(n_shards)]
        self.frontier = FlushFrontier.load(cfg.frontier_path()) or \
            FlushFrontier(tick=0, wal_offset=self._offsets())
        self.slack = cfg.replay_slack if cfg.replay_slack is not None \
            else auto_replay_slack(workflow, queue_capacity, batch_size)
        # tick -> per-wal offsets *before* that tick's appends; needed to
        # backdate barrier-less frontiers.  Pruned against the frontier.
        # Touched only by the writer thread and by post-fence frontier
        # code (the fence empties the queue first), so no lock is needed.
        self._tick_offsets: Dict[int, List[int]] = {}
        # Async appender (DESIGN.md 17): the driver enqueues append
        # thunks and returns immediately; file I/O (+ any deferred
        # device_get the distributed driver wraps in the thunk) runs
        # here, off the tick critical path.  Bounded so a slow disk
        # exerts backpressure instead of growing an unbounded backlog.
        self._wq: pyqueue.Queue = pyqueue.Queue(maxsize=64)
        self._werrs: list = []
        self._wthread = threading.Thread(target=self._writer_loop,
                                         daemon=True)
        self._wthread.start()

    @property
    def wal(self) -> WriteAheadLog:
        assert self.n_shards is None, "per-shard WALs: use .wals[s]"
        return self.wals[0]

    def _offsets(self) -> List[int]:
        return [w.offset for w in self.wals]

    # ---- write-ahead ----
    def _writer_loop(self):
        while True:
            job = self._wq.get()
            if job is None:
                self._wq.task_done()
                return
            try:
                job()
            except Exception as e:
                self._werrs.append(e)
            finally:
                self._wq.task_done()

    def _do_append(self, tick: int, sources, shard: int):
        # writer-thread body: the original synchronous append
        if not self.cfg.barrier:
            # barrier-less frontiers backdate by replay_slack ticks, so
            # only a sliding window of pre-append offsets is needed
            self._tick_offsets.setdefault(tick, self._offsets())
            for t in [t for t in self._tick_offsets
                      if t < tick - 2 * self.slack]:
                del self._tick_offsets[t]
        if sources:
            self.wals[shard].append(tick, sources)

    def append(self, tick: int, sources, shard: Optional[int] = None):
        """Log one tick's sources (single-shard) or one shard's slice.

        Asynchronous: the append is handed to the background writer and
        this call returns immediately — the write-ahead invariant is
        restored at :meth:`begin_frontier`, whose fence guarantees every
        append at or before the frontier tick is on disk before the
        frontier can cover it (DESIGN.md 17).  Blocks only when the
        bounded writer queue is full (slow-disk backpressure)."""
        self._wq.put(functools.partial(
            self._do_append, int(tick), sources,
            0 if shard is None else int(shard)))

    def append_deferred(self, fn: Callable[[], None]):
        """Enqueue an arbitrary thunk on the writer thread — the
        distributed driver uses this to move the device_get of the
        per-shard source slices off the dispatch path; the thunk calls
        :meth:`_do_append` per shard itself.  Ordering with respect to
        plain :meth:`append` calls is FIFO (one queue, one writer)."""
        self._wq.put(fn)

    def fence(self):
        """Epoch fence: wait until every enqueued append has hit the
        WAL, then re-raise any writer error as :class:`WALAppendError`.
        After the fence the writer queue is empty, so ``_tick_offsets``
        and the WAL offsets may be read from the driver thread."""
        self._wq.join()
        if self._werrs:
            errs, self._werrs = self._werrs, []
            raise WALAppendError(errs)

    # ---- frontier ----
    def due(self, tick: int, tables=None) -> bool:
        """Flush decision at a chunk boundary.  EVERY_K fires when the
        boundary crossed a multiple of k since the last frontier."""
        from repro.slates.flush import FlushPolicy
        p = self.cfg.flush.policy
        if p is FlushPolicy.IMMEDIATE:
            return tick > self.frontier.tick
        if p is FlushPolicy.EVERY_K:
            k = self.cfg.flush.every_k
            return tick // k > self.frontier.tick // k
        if tables is None:
            return False
        return any(self.flusher.should_flush(tick, t)
                   for t in tables.values())

    def begin_frontier(self, tick: int):
        """Phase one of a frontier advance: fence the async writer (so
        every append the new frontier must cover is on disk and the
        offset maps are stable), then capture the replay point.  Returns
        an opaque token for :meth:`commit_frontier`.

        The capture MUST happen here, not at commit: the driver overlaps
        the commit with the next chunk, whose appends land between begin
        and commit — offsets read at commit time would let the frontier
        cover ticks the flushed snapshot never saw."""
        self.fence()
        if self.cfg.barrier:
            f_tick, f_offs = int(tick), self._offsets()
        else:
            f_tick = max(self.frontier.tick, int(tick) - self.slack)
            cands = [offs for t, offs in self._tick_offsets.items()
                     if t >= f_tick]
            f_offs = [min(c[i] for c in cands) if cands
                      else self.wals[i].offset
                      for i in range(len(self.wals))]
        self._tick_offsets = {t: o for t, o in self._tick_offsets.items()
                              if t >= f_tick}
        return (f_tick, f_offs)

    def commit_frontier(self, token, meta: Optional[dict] = None):
        """Phase two: drain the flusher (re-raises on store failure),
        then persist the frontier captured by :meth:`begin_frontier`.
        Blocking — the driver calls this after dispatching the next
        chunk so the drain overlaps device compute.  ``meta`` is an
        opaque driver cursor stored alongside (None keeps the previous
        one)."""
        f_tick, f_offs = token
        self.flusher.drain()
        self.frontier = FlushFrontier(
            tick=f_tick,
            wal_offset=f_offs[0] if self.n_shards is None else f_offs,
            meta=meta if meta is not None else self.frontier.meta)
        self.frontier.save(self.cfg.frontier_path())
        if self.cfg.truncate_wal:
            for w, off in zip(self.wals, f_offs):
                w.truncate_before(off)

    def record_frontier(self, tick: int, meta: Optional[dict] = None):
        """Synchronous frontier advance: fence + capture + drain + save
        in one call (checkpoint/drain/recovery paths; the pipelined hot
        loop uses begin/commit directly).  With the barrier the pipeline
        is empty, so the frontier is exactly ``tick``; without it the
        frontier is backdated by ``replay_slack`` ticks."""
        self.commit_frontier(self.begin_frontier(tick), meta=meta)

    def frontier_offsets(self) -> List[int]:
        off = self.frontier.wal_offset
        return list(off) if isinstance(off, (list, tuple)) else [off]

    def resize(self, n_shards: int):
        """Live elasticity (DESIGN.md sections 12/14): match the
        per-shard WAL set to the new physical shard count and re-record
        the frontier with the adjusted offset list.  Called at a scale
        boundary right after a flush barrier, so every shard's frontier
        offset is current: growth appends WALs starting at their
        (empty) head; a compaction shrink closes the WALs of the
        dropped slots — sound only behind the barrier, which
        guarantees those files hold no records past the frontier
        (replay re-routes every event by key, so WAL-slot identity
        never matters).  Deactivated-but-not-compacted shards keep
        their WAL — it simply receives nothing until the slot
        rejoins."""
        assert self.n_shards is not None, \
            "resize() is for per-shard durability (DistributedEngine)"
        self.fence()   # the writer must not touch WALs we close/append
        offs = self.frontier_offsets()
        if n_shards < len(self.wals):
            for w in self.wals[n_shards:]:
                w.close()
            del self.wals[n_shards:]
            offs = offs[:n_shards]
        for s in range(len(self.wals), n_shards):
            self.wals.append(WriteAheadLog(self.cfg.wal_path(s),
                                           sync=self.cfg.sync_wal))
            offs.append(self.wals[s].offset)
        self.n_shards = n_shards
        self.frontier = FlushFrontier(tick=self.frontier.tick,
                                      wal_offset=offs,
                                      meta=self.frontier.meta)
        self.frontier.save(self.cfg.frontier_path())

    def close(self):
        try:
            self._wq.join()
            self._wq.put(None)
            self._wthread.join(timeout=5)
        finally:
            try:
                self.flusher.close()
            finally:
                for w in self.wals:
                    w.close()


def merge_replay_ticks(wals: List[WriteAheadLog], offsets: List[int]):
    """Merge per-shard WAL suffixes into a sorted per-tick stream:
    yields ``(tick, {shard: {stream: EventBatch}})``."""
    by_tick: Dict[int, Dict[int, dict]] = {}
    for s, (w, off) in enumerate(zip(wals, offsets)):
        for t, src in w.replay(from_offset=off):
            by_tick.setdefault(int(t), {})[s] = src
    for t in sorted(by_tick):
        yield t, by_tick[t]
