"""Single-shard MapUpdate engine: one jitted tick over the whole workflow.

Execution model (DESIGN.md section 2): every tick each operator dequeues up
to ``batch_size`` events, applies its (vectorized) function, and emitted
events are enqueued at their subscribers for the next tick.  End-to-end
latency = graph depth x tick latency, mirroring Muppet's pipeline; there is
no master on the data path.

Two dispatch granularities (DESIGN.md section 2.2):
  - ``step``: one jitted tick per host call (lowest latency to observe
    state, one host<->device round-trip per tick);
  - ``run_chunk``: N ticks rolled into a single ``jax.lax.scan`` over
    pre-staged (stacked) sources — state, outputs, and the throttle
    signal stay device-resident for the whole chunk, so the host pays
    one dispatch + one sync per N ticks instead of per tick.

The distributed engine (``core/distributed.py``) runs this same tick
per-shard under ``shard_map`` with an all_to_all key-routing exchange in
front of every enqueue.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from contextlib import nullcontext
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import apply as apply_mod
from repro.core import queues as q_mod
from repro.core.durability import DurabilityConfig, EngineDurability
from repro.core.event import EventBatch, concat
from repro.core.operators import (AssociativeUpdater, Mapper,
                                  SequentialUpdater, Updater)
from repro.core.queues import OverflowPolicy
from repro.core.workflow import Workflow
from repro.slates import flush as flush_mod
from repro.slates import table as tbl
from repro.telemetry import latency as lat_mod
from repro.telemetry import sketch as sk_mod
from repro.telemetry.metrics import MetricsRegistry, TelemetryConfig
from repro.telemetry.trace import Tracer, null_span


@dataclass
class EngineConfig:
    batch_size: int = 256
    queue_capacity: int = 1024
    overflow: Dict[str, OverflowPolicy] = field(default_factory=dict)
    overflow_stream: Dict[str, str] = field(default_factory=dict)
    default_policy: OverflowPolicy = OverflowPolicy.DROP
    # fused slate-update backend for sum_mergeable updaters:
    # "auto" (Pallas on TPU, generic path elsewhere), "pallas",
    # "interpret", "jnp", "ref", or "off" (always the generic path).
    # See core/apply.apply_associative.
    fused: str = "auto"
    # key plane width, end-to-end: "int32" (default) or "int64".
    # int64 widens tables, queues, the sketch sample ring, WAL frames
    # and every kernel entry point, and requires jax_enable_x64 (the
    # engine refuses to construct otherwise — JAX silently demotes
    # int64 arrays without it).  Under int64 the hotspot split window
    # covers the whole 32-bit band (DESIGN.md 12.5 closed).
    key_dtype: str = "int32"
    # ticks per device-resident scan in run(); 1 = per-tick dispatch
    chunk_size: int = 8
    # durable runtime (WAL + slate flush + crash recovery, DESIGN.md 10);
    # None = fast-but-amnesiac (the seed behavior)
    durability: Optional[DurabilityConfig] = None
    # device-side telemetry (DESIGN.md 13): a count-min key-heat sketch
    # updated inside the jitted tick + a windowed metrics registry read
    # at chunk boundaries.  None = no sketch state, no readings.
    telemetry: Optional[TelemetryConfig] = None

    def policy_for(self, op_name: str) -> OverflowPolicy:
        return self.overflow.get(op_name, self.default_policy)


def stack_sources(per_tick: Sequence[Dict[str, "EventBatch"]]
                  ) -> Dict[str, "EventBatch"]:
    """Stack T per-tick source dicts into one dict of EventBatches with
    a leading tick axis [T, B, ...] — the pre-staged input format of
    ``run_chunk`` (scanned over axis 0 on device).

    Ticks may feed different stream subsets (including ``{}``) and
    different batch capacities: missing streams are padded with
    all-invalid batches and smaller batches are padded to the chunk's
    max capacity, so a bursty ``source_fn`` stacks the same way it
    would step.
    """
    assert per_tick, "need at least one tick of sources"
    caps: Dict[str, int] = {}
    templates: Dict[str, "EventBatch"] = {}
    for d in per_tick:
        for s, b in d.items():
            if s not in caps or b.capacity > caps[s]:
                caps[s], templates[s] = b.capacity, b

    def get(d, s):
        if s in d:
            return d[s].pad_to(caps[s])
        tmpl = templates[s]
        return tmpl.mask(jnp.zeros_like(tmpl.valid))

    return {s: jax.tree.map(lambda *xs: jnp.stack(xs),
                            *[get(d, s) for d in per_tick])
            for s in templates}


def _limit_ingest(batch: "EventBatch", ingest) -> "EventBatch":
    """Keep only the first ``ingest`` valid events (device-side source
    throttling inside a chunk)."""
    rank = jnp.cumsum(batch.valid.astype(jnp.int32)) - 1
    return batch.mask(rank < ingest)


def resolve_key_dtype(name) -> np.dtype:
    """Validate an ``EngineConfig.key_dtype`` / ``DistConfig`` key plane
    request: int32 or int64, with int64 demanding ``jax_enable_x64``
    up front (JAX silently demotes int64 arrays without it, which would
    corrupt keys instead of failing)."""
    dt = np.dtype(name)
    if dt not in (np.dtype(np.int32), np.dtype(np.int64)):
        raise ValueError(f"key_dtype must be int32 or int64, got {name!r}")
    if dt.itemsize > 4 and not jax.config.jax_enable_x64:
        raise RuntimeError(
            "key_dtype=int64 requires jax_enable_x64: set "
            "JAX_ENABLE_X64=1 (or jax.config.update('jax_enable_x64', "
            "True)) before building the engine")
    return dt


@partial(jax.jit, static_argnames=("impl",))
def _batched_lookup(table_keys, table_vals, query, *, impl: str):
    """One fused device program for a [Q] read batch: probe-walk +
    per-leaf row gather (kernels/slate_lookup.lookup_tree)."""
    from repro.kernels.slate_lookup import ops as lk_ops
    return lk_ops.lookup_tree(table_keys, table_vals, query, impl=impl)


class StateHandle:
    """Live view of ``(engine, state)`` for concurrent readers.

    The engine is functional — ``run()``/``step()`` thread an immutable
    state value — but live slate reads (paper section 4.4: the HTTP
    slate server answers *while the stream flows*) need the *current*
    state.  Drivers used to hand the server a mutable
    ``box = {"state": state}`` and rebind it every tick; instead,
    ``Engine.run(..., handle=h)`` republishes ``h.state`` after every
    chunk, and the server binds ``h.read_slate`` / ``h.stats`` directly.
    Works for :class:`~repro.core.distributed.DistributedEngine` too
    (same ``read_slate(state, ...)`` / ``stats(state)`` shape).
    """

    def __init__(self, engine, state=None, cache=None):
        self.engine = engine
        self.state = state
        # optional slates.replica.HotKeyCache: consulted before touching
        # device state, warmed from telemetry heavy hitters, invalidated
        # whenever the flush frontier advances (DESIGN.md section 15)
        self.cache = cache

    def _lock(self):
        return getattr(self.engine, "read_lock", None) or nullcontext()

    def read_slate(self, updater: str, key: int):
        c = self.cache
        if c is not None:
            hit, val = c.get(updater, key)
            if hit:
                return val
        with self._lock():
            val = self.engine.read_slate(self.state, updater, key)
        if c is not None and val is not None:
            c.put(updater, key, val)
        return val

    def read_slates(self, updater: str, keys):
        """Batched point reads (one device dispatch); list aligned with
        ``keys``, ``None`` for missing."""
        with self._lock():
            return self.engine.read_slates(self.state, updater, keys)

    def stats(self) -> Dict[str, Any]:
        with self._lock():
            return self.engine.stats(self.state)

    # -- driver hooks (Engine.run calls these at chunk boundaries) --
    def on_telemetry(self, report):
        if self.cache is not None and report is not None:
            self.cache.warm([k for k, _, _ in report.heavy_hitters])

    def on_frontier_advance(self):
        """Flush frontier moved: cached rows may now disagree with the
        durable snapshot the replica tier serves — drop them."""
        if self.cache is not None:
            self.cache.invalidate()

    def metrics_text(self) -> str:
        """Prometheus text exposition of the engine's current counters,
        latest telemetry window, and cumulative latency histograms —
        rendered from snapshots the registry already holds plus one
        ``stats()`` read (no hot-path cost beyond that)."""
        from repro.telemetry.prom import render_prometheus
        reg = getattr(self.engine, "telemetry", None)
        return render_prometheus(
            stats=self.stats(),
            report=reg.last if reg is not None else None,
            hist=reg.hist_cum if reg is not None else None,
            n_buckets=(reg.cfg.latency_buckets
                       if reg is not None else lat_mod.N_BUCKETS))

    def serve(self, port: int = 0):
        """Start an HTTP slate server bound to this handle."""
        from repro.slates.http import SlateServer
        return SlateServer(read_fn=self.read_slate, stats_fn=self.stats,
                           read_many_fn=self.read_slates,
                           metrics_fn=self.metrics_text, port=port)


class Engine:
    """Host-side wrapper owning the jitted tick."""

    def __init__(self, workflow: Workflow, config: EngineConfig = None):
        self.wf = workflow
        self.cfg = config or EngineConfig()
        self.key_dtype = resolve_key_dtype(self.cfg.key_dtype)
        # serializes concurrent readers against the donating dispatches
        # in run(): donated state buffers are deleted the moment a chunk
        # is dispatched, so a read racing the chunk would touch freed
        # arrays.  RLock: read_split_slate holds it across its sub-key
        # loop while read_slate re-acquires.
        self.read_lock = threading.RLock()
        self._step = jax.jit(self._tick, donate_argnums=(0,))
        self._chunk = jax.jit(self._chunk_impl, donate_argnums=(0,),
                              static_argnames=("n_ticks", "adapt",
                                               "throttle_floor"))
        self.dur: Optional[EngineDurability] = None
        if self.cfg.durability is not None:
            self.dur = EngineDurability(self.cfg.durability, workflow,
                                        self.cfg.queue_capacity,
                                        self.cfg.batch_size)
        self.telemetry: Optional[MetricsRegistry] = None
        self.tracer: Optional[Tracer] = None
        if self.cfg.telemetry is not None:
            self.telemetry = MetricsRegistry(
                self.cfg.telemetry, batch_size=self.cfg.batch_size)
            self._salts = self.telemetry.salts
            if self.cfg.telemetry.trace:
                self.tracer = Tracer()

    def _span(self, name: str, **args):
        """Tracer span when tracing is on, else a free no-op."""
        return self.tracer.span(name, **args) if self.tracer \
            else null_span(**args)

    @property
    def key_bits(self) -> int:
        return int(self.key_dtype.itemsize) * 8

    # ---- state ----
    def init_state(self) -> Dict[str, Any]:
        kd = self.key_dtype
        queues = {}
        for op in self.wf.operators:
            queues[op.name] = q_mod.make_queue(self.cfg.queue_capacity,
                                               op.in_value_spec,
                                               key_dtype=kd)
        tables = {}
        for up in self.wf.updaters():
            tables[up.name] = tbl.make_table(up.table_capacity,
                                             up.slate_spec(),
                                             key_dtype=kd)
        z = jnp.zeros((), jnp.int32)
        state = {
            "queues": queues,
            "tables": tables,
            "tick": z,
            "throttle_hits": z,
            "deferred": z,
            "processed": {op.name: z for op in self.wf.operators},
        }
        if self.cfg.telemetry is not None:
            tc = self.cfg.telemetry
            state["sketch"] = sk_mod.make_sketch(tc.depth, tc.width,
                                                 tc.sample, key_dtype=kd)
            if tc.latency_buckets > 0:
                state["lat_hist"] = lat_mod.make_hist(
                    [u.name for u in self.wf.updaters()],
                    tc.latency_buckets)
        # constants are interned by XLA; donation needs distinct buffers
        return jax.tree.map(lambda x: jnp.array(x, copy=True), state)

    # ---- one tick (jit) ----
    def _tick(self, state, sources: Dict[str, EventBatch]):
        cfg, wf = self.cfg, self.wf
        queues = dict(state["queues"])
        tables = dict(state["tables"])
        processed = dict(state["processed"])
        throttle_hits = state["throttle_hits"]
        deferred_total = state["deferred"]
        tick = state["tick"]
        sketch = state.get("sketch")
        lat_hist = dict(state["lat_hist"]) if "lat_hist" in state \
            else None
        outputs: Dict[str, List[EventBatch]] = {}

        def deliver_all(items: List[Tuple[str, EventBatch]]):
            """Route batches to subscriber queues; overflow-stream policy
            may chain (bounded — cycles are a config error)."""
            nonlocal throttle_hits
            work = deque(items)
            for _ in range(len(work) + 64):
                if not work:
                    return
                stream, batch = work.popleft()
                subs = wf.dests_of(stream)
                if not subs:
                    outputs.setdefault(stream, []).append(batch)
                    continue
                for dest in subs:
                    nq, ovf = q_mod.enqueue(queues[dest], batch)
                    pol = cfg.policy_for(dest)
                    if pol is OverflowPolicy.DROP:
                        nq = q_mod.count_drop(nq, ovf)
                    elif pol is OverflowPolicy.OVERFLOW_STREAM:
                        work.append((cfg.overflow_stream[dest], ovf))
                    elif pol is OverflowPolicy.THROTTLE:
                        throttle_hits = throttle_hits + ovf.count()
                        nq = q_mod.count_drop(nq, ovf)
                    queues[dest] = nq
            raise RuntimeError("overflow-stream routing did not converge "
                               "(cycle in overflow_stream config?)")

        # 1. deliver sources (visible to operators this tick; operator
        #    emissions become visible next tick — pipelined execution).
        deliver_all(list(sources.items()))
        emitted_now: List[Tuple[str, EventBatch]] = []

        # 2. apply operators on their queues
        for op in wf.operators:
            queues[op.name], batch = q_mod.dequeue(queues[op.name],
                                                   cfg.batch_size)
            if sketch is not None and isinstance(op, Updater):
                # key-heat telemetry: observe the keys each updater
                # actually processes (post-routing) — pure extra state,
                # never read by the tick itself (parity contract)
                sketch = sk_mod.sketch_update(
                    sketch, batch.key, batch.valid, self._salts,
                    impl=cfg.telemetry.impl)
            if lat_hist is not None and isinstance(op, Updater):
                # event-latency telemetry (DESIGN.md 18): the event's
                # age at dequeue, binned into this arc's power-of-two
                # histogram — same parity contract as the sketch
                lat_hist[op.name] = lat_mod.hist_update(
                    lat_hist[op.name], tick, batch.ts, batch.valid,
                    n_buckets=cfg.telemetry.latency_buckets,
                    impl=cfg.telemetry.impl)
            if isinstance(op, Mapper):
                outs = op.map_batch(batch)
                for s, b in outs.items():
                    emitted_now.append((s, b.mask(batch.valid & b.valid)))
                processed[op.name] = processed[op.name] + batch.count()
            elif isinstance(op, AssociativeUpdater):
                tables[op.name], ems, n = apply_mod.apply_associative(
                    op, tables[op.name], batch, tick, impl=cfg.fused)
                emitted_now.extend(ems.items())
                processed[op.name] = processed[op.name] + n
            elif isinstance(op, SequentialUpdater):
                tables[op.name], ems, deferred, n = \
                    apply_mod.apply_sequential(op, tables[op.name], batch,
                                               tick)
                emitted_now.extend(ems.items())
                # hotspot backpressure: re-queue over-budget run tails
                deferred_total = deferred_total + deferred.count()
                nq, ovf = q_mod.enqueue(queues[op.name], deferred)
                queues[op.name] = q_mod.count_drop(nq, ovf)
                processed[op.name] = processed[op.name] + n
            else:
                raise TypeError(f"unknown operator type {type(op)}")

        # 3. TTL sweeps
        for up in wf.updaters():
            if up.ttl:
                tables[up.name] = tbl.expire_ttl(tables[up.name], tick,
                                                 up.ttl)

        # 4. route this tick's emissions (visible next tick)
        deliver_all(emitted_now)

        out_batches = {s: concat(bs) if len(bs) > 1 else bs[0]
                       for s, bs in outputs.items()}
        new_state = {
            "queues": queues,
            "tables": tables,
            "tick": tick + 1,
            "throttle_hits": throttle_hits,
            "deferred": deferred_total,
            "processed": processed,
        }
        if sketch is not None:
            new_state["sketch"] = sketch
        if lat_hist is not None:
            new_state["lat_hist"] = lat_hist
        return new_state, out_batches

    # ---- multi-tick chunk (jit: one dispatch, one sync per chunk) ----
    def _chunk_impl(self, state, stacked_sources, ingest, *,
                    n_ticks: int, adapt: bool, throttle_floor: int):
        """Roll the tick over a [T, ...] stack of sources with lax.scan.

        carry = (state, ingest).  With ``adapt`` the sources of each
        tick are masked down to the first ``ingest`` valid events and
        ingest halves/doubles *on device* from the tick's throttle-hits
        delta — the device-resident version of ``run``'s source
        throttling (paper section 5).  Without it the body is exactly
        ``_tick``, so a chunk is bitwise-identical to T ``step`` calls.
        """
        ing_max = jnp.maximum(ingest, jnp.int32(self.cfg.batch_size))

        def body(carry, src):
            st, ing = carry
            hits0 = st["throttle_hits"]
            if adapt:
                src = {s: _limit_ingest(b, ing) for s, b in src.items()}
            st, outs = self._tick(st, src)
            if adapt:
                delta = st["throttle_hits"] - hits0
                # halve under pressure; double back toward the ceiling
                # (the caller's initial limit, at least batch_size)
                ing = jnp.where(
                    delta > 0,
                    jnp.maximum(jnp.int32(throttle_floor), ing // 2),
                    jnp.minimum(ing_max, ing * 2))
            return (st, ing), (outs, st["throttle_hits"])

        (state, ingest), (outs, hits) = jax.lax.scan(
            body, (state, ingest), stacked_sources, length=n_ticks)
        return state, outs, {"throttle_hits": hits, "ingest": ingest}

    # ---- host API ----
    def step(self, state, sources: Dict[str, EventBatch]):
        return self._step(state, sources)

    def run_chunk(self, state, stacked_sources: Dict[str, EventBatch],
                  n_ticks: Optional[int] = None, *,
                  ingest: Optional[int] = None, throttle_floor: int = 8):
        """Run T ticks in one device-resident dispatch.

        ``stacked_sources``: dict of EventBatches with a leading tick
        axis [T, B, ...] (see ``stack_sources``).  Returns
        ``(state, stacked_outputs, info)`` where ``stacked_outputs``
        leaves have leading dim T and ``info`` holds the on-device
        per-tick ``throttle_hits`` trace plus the final ``ingest``.

        With ``ingest=None`` the chunk is bitwise-identical to T
        sequential ``step`` calls; passing an int enables on-device
        source throttling (events beyond the running ingest limit are
        masked before delivery).  An empty ``stacked_sources`` runs
        ``n_ticks`` source-less (drain) ticks.
        """
        lead = {s: jax.tree.leaves(b)[0].shape[0]
                for s, b in stacked_sources.items()}
        t_dim = next(iter(lead.values())) if lead else n_ticks
        if t_dim is None:
            raise ValueError("empty stacked_sources needs an explicit "
                             "n_ticks")
        if n_ticks is not None and lead and t_dim != n_ticks:
            raise ValueError(f"stacked sources have {t_dim} ticks, "
                             f"caller asked for {n_ticks}")
        adapt = ingest is not None
        ing0 = jnp.asarray(ingest if adapt else self.cfg.batch_size,
                           jnp.int32)
        return self._chunk(state, stacked_sources, ing0, n_ticks=t_dim,
                           adapt=adapt, throttle_floor=throttle_floor)

    def run(self, state, source_fn, n_ticks: int, *,
            throttle_floor: int = 8, chunk_size: Optional[int] = None,
            source_offset: int = 0,
            handle: Optional[StateHandle] = None):
        """Drive the engine; applies *source throttling* (paper section 5):
        if throttle hits grow, halve the ingest batch until queues drain.
        ``source_fn(tick, max_events) -> dict[stream, EventBatch]``.

        Ticks run in device-resident chunks of ``chunk_size`` (default
        ``cfg.chunk_size``); the host reads the throttle signal once per
        chunk — one sync per chunk, not per tick — and replays the
        per-tick halve/double rule over the on-device hits trace, so the
        ingest limit handed to ``source_fn`` reacts at chunk boundaries.
        ``chunk_size=1`` recovers exact per-tick backpressure.

        With ``cfg.durability`` set, every per-tick source dict is
        appended to the WAL *before* the chunk that consumes it, and at
        chunk boundaries the flush policy may trigger a durable slate
        flush + frontier advance (DESIGN.md section 10).  Durability
        drain ticks advance the engine tick counter, so ``source_fn``'s
        tick argument (the source index) and ``stats()['tick']`` diverge
        by the number of drain ticks.

        ``source_offset`` resumes an interrupted source stream:
        ``source_fn`` is called with absolute indices ``offset ..
        offset+n_ticks`` and chunk grouping stays aligned to the absolute
        index, so a recovered run flushes (and drains) at the same
        boundaries as the uninterrupted run — the bitwise-parity
        contract of ``recover()``.

        ``handle``: a :class:`StateHandle` republished with the current
        state after every chunk, so concurrent readers (the HTTP slate
        server) see live slates without the driver threading state.
        """
        chunk = chunk_size or self.cfg.chunk_size
        outputs = []
        ingest = None
        obs_mark = source_offset    # telemetry window cursor
        # throttle_hits is cumulative: resuming from prior state (second
        # run() call, or a recovered state) must not read old hits as a
        # fresh backpressure signal
        last_hits = int(jax.device_get(state["throttle_hits"]))
        t = source_offset
        end = source_offset + n_ticks
        eng_tick = int(jax.device_get(state["tick"])) if self.dur else 0
        # pipelined write path (DESIGN.md section 17): boundary work
        # splits into a cheap *begin* at the boundary (snapshot copies,
        # WAL epoch fence) and a blocking *commit* resolved right after
        # the NEXT chunk is dispatched, so store writes and telemetry
        # transfers overlap device compute instead of serializing the
        # tick path.
        pending_flush = None    # in-flight flush epoch (begin'd, not committed)
        pending_obs = None      # in-flight telemetry transfer
        while t < end:
            n = min(chunk - t % chunk, end - t)
            per_tick = [source_fn(t + i, ingest) for i in range(n)]
            if self.dur:
                for i, srcs in enumerate(per_tick):
                    self.dur.append(eng_tick + i, srcs)  # async writer
            # the chunk dispatch donates (deletes) the buffers a handle
            # reader may be touching; hold the read lock from dispatch
            # until the fresh state is republished
            with self.read_lock:
                with self._span("chunk_dispatch", tick=t, n_ticks=n):
                    state, outs, info = self.run_chunk(
                        state, stack_sources(per_tick), n)
                # chunk is in flight: resolve the previous boundary's
                # deferred work while the device computes
                if pending_flush is not None:
                    with self._span("flush_commit"):
                        self._flush_commit(pending_flush)
                    pending_flush = None
                    if handle is not None:
                        handle.on_frontier_advance()
                if pending_obs is not None:
                    with self._span("observe_finish"):
                        report = self.telemetry.finish_observe(
                            pending_obs)
                    pending_obs = None
                    if handle is not None:
                        handle.on_telemetry(report)
                for i in range(n):
                    outputs.append(jax.tree.map(lambda x, i=i: x[i],
                                                outs))
                hits_trace = jax.device_get(
                    info["throttle_hits"])  # 1 sync
                for hits in (int(h) for h in hits_trace):
                    if hits > last_hits:     # backpressure signal
                        cur = (ingest if ingest is not None
                               else self.cfg.batch_size)
                        ingest = max(throttle_floor, cur // 2)
                    elif ingest is not None:
                        ingest = min(self.cfg.batch_size, ingest * 2)
                        if ingest == self.cfg.batch_size:
                            ingest = None
                    last_hits = hits
                t += n
                eng_tick += n
                if self.dur and self.dur.due(eng_tick, state["tables"]):
                    with self._span("flush_begin", tick=t):
                        state, eng_tick, pending_flush = \
                            self._flush_begin(state, eng_tick,
                                              meta={"source_tick": t})
                if (self.telemetry is not None
                        and t - obs_mark >= self.cfg.telemetry.window):
                    # start the boundary transfer; the report resolves
                    # after the next chunk's dispatch (one-chunk lag)
                    with self._span("observe_begin", tick=t):
                        pending_obs = self.telemetry.begin_observe(
                            self, state)
                    state = dict(state)
                    state["sketch"] = sk_mod.decay(
                        state["sketch"], self.cfg.telemetry.decay)
                    obs_mark = t
                if handle is not None:
                    handle.state = state
        # trailing deferred work: the run must not return with an
        # uncommitted frontier or an unresolved report
        if pending_flush is not None:
            with self._span("flush_commit"):
                self._flush_commit(pending_flush)
            if handle is not None:
                handle.on_frontier_advance()
        if pending_obs is not None:
            with self._span("observe_finish"):
                report = self.telemetry.finish_observe(pending_obs)
            if handle is not None:
                handle.on_telemetry(report)
        if self.dur:
            # run() is a durable unit: every source batch it consumed is
            # on disk (and append errors surface) before control returns
            with self._span("wal_fence"):
                self.dur.fence()
        return state, outputs

    def drain(self, state, max_ticks: int = 64):
        """Run source-less ticks until every queue is empty (or
        ``max_ticks``) — flushes in-flight events through the remaining
        pipeline hops.  Returns ``(state, ticks_run)``."""
        return self._drain_queues(state, max_ticks)

    # ---- durability (DESIGN.md section 10) ----
    def _drain_queues(self, state, max_ticks: int):
        """Run source-less ticks until every queue is empty — the flush
        barrier.  Each probe costs one host sync; barriers are rare
        (flush boundaries only).  Returns (state, ticks_run)."""
        d = 0
        while d < max_ticks:
            sizes = jax.device_get({k: q.size
                                    for k, q in state["queues"].items()})
            if all(int(v) == 0 for v in sizes.values()):
                break
            state, _ = self._step(state, {})
            d += 1
        return state, d

    def _flush_begin(self, state, eng_tick: int, meta=None):
        """First half of a flush boundary: drain (per config), start the
        device->host snapshot of every dirty table (tables come back
        marked clean immediately), and fence the WAL writer to pin the
        frontier's replay point *before* any later tick appends.  The
        blocking store-side work lives in :meth:`_flush_commit`, which
        the driver calls after the next chunk's dispatch so it overlaps
        device compute.  Returns ``(state, eng_tick, pending)``."""
        dur = self.dur
        if dur.cfg.barrier:
            state, d = self._drain_queues(state, dur.cfg.drain_ticks_max)
            eng_tick += d
        state = dict(state)
        tables = dict(state["tables"])
        snaps = []
        for up in self.wf.updaters():
            token, cleared = flush_mod.begin_dirty_snapshot(
                tables[up.name])
            tables[up.name] = cleared
            snaps.append((up.name, up.ttl, token))
        state["tables"] = tables
        f_token = dur.begin_frontier(eng_tick)
        return state, eng_tick, (snaps, f_token, meta)

    def _flush_commit(self, pending):
        """Second half: resolve the snapshots to host rows, hand them to
        the flusher, and commit the frontier once the store writes are
        durable (raises :class:`FlushError` without saving otherwise).
        ``meta`` is the driver cursor stored with the frontier (run()
        records the source index so a --recover driver can resume its
        stream even after full WAL truncation)."""
        snaps, f_token, meta = pending
        dur = self.dur
        for name, ttl, token in snaps:
            keys, ts, vals = flush_mod.finish_dirty_snapshot(token)
            dur.flusher.flush_rows(name, keys, ts, vals, ttl=ttl)
        dur.commit_frontier(f_token, meta=meta)

    def _flush_boundary(self, state, eng_tick: int, meta=None):
        """Synchronous flush boundary (checkpoint / shutdown / tests):
        begin + commit back to back — no overlap, identical durability
        semantics."""
        state, eng_tick, pending = self._flush_begin(state, eng_tick,
                                                     meta=meta)
        self._flush_commit(pending)
        return state, eng_tick

    def checkpoint(self, state):
        """Force a flush boundary now (shutdown / test hook); returns the
        new state (flushed tables are marked clean)."""
        assert self.dur is not None, "engine has no durability config"
        eng_tick = int(jax.device_get(state["tick"]))
        state, _ = self._flush_boundary(state, eng_tick)
        return state

    def recover(self, store=None, wal=None, *, frontier=None):
        """Rebuild engine state after a crash: restore flushed slates
        from the KV store, then replay the WAL suffix from the flush
        frontier through the jitted chunk path (DESIGN.md section 10).

        ``store`` / ``wal`` / ``frontier`` default to the engine's own
        durability runtime (``cfg.durability.dir``).  Returns the
        recovered state, positioned at the last WAL tick; resume with
        ``run()``/``step()`` as usual.  Stats counters (processed,
        drops) restart at the frontier — only slates and the tick
        counter are recovered state.
        """
        dur = self.dur
        store = store if store is not None else (dur and dur.store)
        wal = wal if wal is not None else (dur and dur.wal)
        if frontier is None:
            frontier = dur.frontier if dur else flush_mod.FlushFrontier()
        assert store is not None and wal is not None, \
            "recover() needs a store + wal (or cfg.durability)"
        f_tick = int(frontier.tick)
        f_off = frontier.wal_offset
        f_off = f_off[0] if isinstance(f_off, (list, tuple)) else f_off

        t_recover = time.perf_counter()
        state = self.init_state()
        state["tick"] = jnp.asarray(f_tick, jnp.int32)
        with self._span("recover_restore", frontier=f_tick):
            for up in self.wf.updaters():
                recs = store.scan_records(
                    up.name, now=f_tick if up.ttl else None)
                if not recs:
                    continue
                ks = np.asarray(sorted(recs), self.key_dtype)
                ts = np.asarray([recs[int(k)][0] for k in ks], np.int32)
                slates = jax.tree.map(
                    lambda *rows: np.stack(rows),
                    *[recs[int(k)][1] for k in ks])
                state["tables"][up.name] = flush_mod.restore_into(
                    state["tables"][up.name], ks, slates, ts)

        # replay, preserving the per-tick batch structure (gaps in the
        # log — drain ticks, empty-source ticks — replay as empty ticks)
        chunk = self.cfg.chunk_size
        pending: List[Dict[str, EventBatch]] = []
        replayed = 0

        def flush_pending():
            nonlocal state, pending, replayed
            while pending:
                group, pending = pending[:chunk], pending[chunk:]
                state, _, _ = self.run_chunk(
                    state, stack_sources(group), len(group))
                replayed += len(group)

        with self._span("recover_replay", frontier=f_tick) as sp:
            cur = f_tick
            for tk, srcs in wal.replay(from_offset=f_off):
                if tk < f_tick:
                    continue
                while cur < tk:
                    pending.append({})
                    cur += 1
                pending.append(srcs)
                cur += 1
                if len(pending) >= 4 * chunk:
                    flush_pending()
            flush_pending()
            sp["replayed_ticks"] = replayed
        # the migration path measures pause_s around _reconfigure; the
        # crash path surfaces its restore+replay wall time the same way
        if self.telemetry is not None:
            self.telemetry.note_recovery(time.perf_counter() - t_recover)
        return state

    def close(self):
        if self.dur is not None:
            self.dur.close()

    # ---- introspection (paper section 4.4: reading slates live) ----
    def read_slate(self, state, updater: str, key: int):
        """Fetch one slate from the device table (the HTTP slate-read
        path reuses this)."""
        table = state["tables"][updater]
        slot, found = tbl.lookup(table,
                                 jnp.asarray([key], self.key_dtype))
        if not bool(found[0]):
            return None
        s = int(slot[0])
        return jax.tree.map(lambda v: jax.device_get(v[s]), table.vals)

    def read_slates(self, state, updater: str, keys, *,
                    impl: str = "auto"):
        """Batched point reads: one device dispatch + one host sync for
        a whole [Q] key vector, bitwise identical to Q ``read_slate``
        calls.  Returns a list aligned with ``keys`` of per-key slate
        dicts (``None`` for missing keys).  ``impl`` picks the lookup
        backend (kernels/slate_lookup: "auto"/"pallas"/"interpret"/
        "jnp")."""
        keys = np.asarray(keys, self.key_dtype).reshape(-1)
        if keys.size == 0:
            return []
        table = state["tables"][updater]
        found, rows = _batched_lookup(table.keys, table.vals,
                                      jnp.asarray(keys), impl=impl)
        found = np.asarray(jax.device_get(found))
        rows = jax.device_get(rows)
        return [jax.tree.map(lambda v, i=i: v[i], rows)
                if found[i] else None for i in range(keys.size)]

    def stats(self, state) -> Dict[str, Any]:
        g = jax.device_get
        return {
            "tick": int(g(state["tick"])),
            "throttle_hits": int(g(state["throttle_hits"])),
            "deferred": int(g(state["deferred"])),
            "processed": {k: int(g(v))
                          for k, v in state["processed"].items()},
            "queue_dropped": {k: int(g(q.dropped))
                              for k, q in state["queues"].items()},
            "queue_peak": {k: int(g(q.peak))
                           for k, q in state["queues"].items()},
            "queue_size": {k: int(g(q.size))
                           for k, q in state["queues"].items()},
            "table_occupancy": {k: int(g(t.occupancy()))
                                for k, t in state["tables"].items()},
            "table_dropped": {k: int(g(t.dropped))
                              for k, t in state["tables"].items()},
        }
