"""Single-shard MapUpdate engine: one jitted tick over the whole workflow.

Execution model (DESIGN.md section 2): every tick each operator dequeues up
to ``batch_size`` events, applies its (vectorized) function, and emitted
events are enqueued at their subscribers for the next tick.  End-to-end
latency = graph depth x tick latency, mirroring Muppet's pipeline; there is
no master on the data path.

The distributed engine (``core/distributed.py``) runs this same tick
per-shard under ``shard_map`` with an all_to_all key-routing exchange in
front of every enqueue.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import apply as apply_mod
from repro.core import queues as q_mod
from repro.core.event import EventBatch, concat
from repro.core.operators import (AssociativeUpdater, Mapper,
                                  SequentialUpdater, Updater)
from repro.core.queues import OverflowPolicy
from repro.core.workflow import Workflow
from repro.slates import table as tbl


@dataclass
class EngineConfig:
    batch_size: int = 256
    queue_capacity: int = 1024
    overflow: Dict[str, OverflowPolicy] = field(default_factory=dict)
    overflow_stream: Dict[str, str] = field(default_factory=dict)
    default_policy: OverflowPolicy = OverflowPolicy.DROP

    def policy_for(self, op_name: str) -> OverflowPolicy:
        return self.overflow.get(op_name, self.default_policy)


class Engine:
    """Host-side wrapper owning the jitted tick."""

    def __init__(self, workflow: Workflow, config: EngineConfig = None):
        self.wf = workflow
        self.cfg = config or EngineConfig()
        self._step = jax.jit(self._tick, donate_argnums=(0,))

    # ---- state ----
    def init_state(self) -> Dict[str, Any]:
        queues = {}
        for op in self.wf.operators:
            queues[op.name] = q_mod.make_queue(self.cfg.queue_capacity,
                                               op.in_value_spec)
        tables = {}
        for up in self.wf.updaters():
            tables[up.name] = tbl.make_table(up.table_capacity,
                                             up.slate_spec())
        z = jnp.zeros((), jnp.int32)
        state = {
            "queues": queues,
            "tables": tables,
            "tick": z,
            "throttle_hits": z,
            "processed": {op.name: z for op in self.wf.operators},
        }
        # constants are interned by XLA; donation needs distinct buffers
        return jax.tree.map(lambda x: jnp.array(x, copy=True), state)

    # ---- one tick (jit) ----
    def _tick(self, state, sources: Dict[str, EventBatch]):
        cfg, wf = self.cfg, self.wf
        queues = dict(state["queues"])
        tables = dict(state["tables"])
        processed = dict(state["processed"])
        throttle_hits = state["throttle_hits"]
        tick = state["tick"]
        outputs: Dict[str, List[EventBatch]] = {}

        def deliver_all(items: List[Tuple[str, EventBatch]]):
            """Route batches to subscriber queues; overflow-stream policy
            may chain (bounded — cycles are a config error)."""
            nonlocal throttle_hits
            work = list(items)
            for _ in range(len(work) + 64):
                if not work:
                    return
                stream, batch = work.pop(0)
                subs = wf.dests_of(stream)
                if not subs:
                    outputs.setdefault(stream, []).append(batch)
                    continue
                for dest in subs:
                    nq, ovf = q_mod.enqueue(queues[dest], batch)
                    pol = cfg.policy_for(dest)
                    if pol is OverflowPolicy.DROP:
                        nq = q_mod.count_drop(nq, ovf)
                    elif pol is OverflowPolicy.OVERFLOW_STREAM:
                        work.append((cfg.overflow_stream[dest], ovf))
                    elif pol is OverflowPolicy.THROTTLE:
                        throttle_hits = throttle_hits + ovf.count()
                        nq = q_mod.count_drop(nq, ovf)
                    queues[dest] = nq
            raise RuntimeError("overflow-stream routing did not converge "
                               "(cycle in overflow_stream config?)")

        # 1. deliver sources (visible to operators this tick; operator
        #    emissions become visible next tick — pipelined execution).
        deliver_all(list(sources.items()))
        emitted_now: List[Tuple[str, EventBatch]] = []

        # 2. apply operators on their queues
        for op in wf.operators:
            queues[op.name], batch = q_mod.dequeue(queues[op.name],
                                                   cfg.batch_size)
            if isinstance(op, Mapper):
                outs = op.map_batch(batch)
                for s, b in outs.items():
                    emitted_now.append((s, b.mask(batch.valid & b.valid)))
                processed[op.name] = processed[op.name] + batch.count()
            elif isinstance(op, AssociativeUpdater):
                tables[op.name], ems, n = apply_mod.apply_associative(
                    op, tables[op.name], batch, tick)
                emitted_now.extend(ems.items())
                processed[op.name] = processed[op.name] + n
            elif isinstance(op, SequentialUpdater):
                tables[op.name], ems, deferred, n = \
                    apply_mod.apply_sequential(op, tables[op.name], batch,
                                               tick)
                emitted_now.extend(ems.items())
                # hotspot backpressure: re-queue over-budget run tails
                nq, ovf = q_mod.enqueue(queues[op.name], deferred)
                queues[op.name] = q_mod.count_drop(nq, ovf)
                processed[op.name] = processed[op.name] + n
            else:
                raise TypeError(f"unknown operator type {type(op)}")

        # 3. TTL sweeps
        for up in wf.updaters():
            if up.ttl:
                tables[up.name] = tbl.expire_ttl(tables[up.name], tick,
                                                 up.ttl)

        # 4. route this tick's emissions (visible next tick)
        deliver_all(emitted_now)

        out_batches = {s: concat(bs) if len(bs) > 1 else bs[0]
                       for s, bs in outputs.items()}
        new_state = {
            "queues": queues,
            "tables": tables,
            "tick": tick + 1,
            "throttle_hits": throttle_hits,
            "processed": processed,
        }
        return new_state, out_batches

    # ---- host API ----
    def step(self, state, sources: Dict[str, EventBatch]):
        return self._step(state, sources)

    def run(self, state, source_fn, n_ticks: int, *,
            throttle_floor: int = 8):
        """Drive the engine; applies *source throttling* (paper section 5):
        if throttle hits grow, halve the ingest batch until queues drain.
        ``source_fn(tick, max_events) -> dict[stream, EventBatch]``."""
        outputs = []
        ingest = None
        last_hits = 0
        for t in range(n_ticks):
            sources = source_fn(t, ingest)
            state, outs = self.step(state, sources)
            outputs.append(outs)
            hits = int(state["throttle_hits"])
            if hits > last_hits:     # backpressure signal
                cur = ingest if ingest is not None else self.cfg.batch_size
                ingest = max(throttle_floor, cur // 2)
            elif ingest is not None:
                ingest = min(self.cfg.batch_size, ingest * 2)
                if ingest == self.cfg.batch_size:
                    ingest = None
            last_hits = hits
        return state, outputs

    # ---- introspection (paper section 4.4: reading slates live) ----
    def read_slate(self, state, updater: str, key: int):
        """Fetch one slate from the device table (the HTTP slate-read
        path reuses this)."""
        table = state["tables"][updater]
        slot, found = tbl.lookup(table, jnp.asarray([key], jnp.int32))
        if not bool(found[0]):
            return None
        s = int(slot[0])
        return jax.tree.map(lambda v: jax.device_get(v[s]), table.vals)

    def stats(self, state) -> Dict[str, Any]:
        g = jax.device_get
        return {
            "tick": int(g(state["tick"])),
            "throttle_hits": int(g(state["throttle_hits"])),
            "processed": {k: int(g(v))
                          for k, v in state["processed"].items()},
            "queue_dropped": {k: int(g(q.dropped))
                              for k, q in state["queues"].items()},
            "queue_peak": {k: int(g(q.peak))
                           for k, q in state["queues"].items()},
            "queue_size": {k: int(g(q.size))
                           for k, q in state["queues"].items()},
            "table_occupancy": {k: int(g(t.occupancy()))
                                for k, t in state["tables"].items()},
            "table_dropped": {k: int(g(t.dropped))
                              for k, t in state["tables"].items()},
        }
