"""Per-operator bounded event queues (ring buffers) + overflow policies.

Paper section 4.3 "Queue Overflow": when a worker's queue is full the
sender must invoke an overflow mechanism — drop (+count, +log), divert to
an overflow stream running degraded operators, or throttle the source.
Capacities are static here (SPMD), so the policy applies at enqueue time.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core.event import EventBatch, compact


class OverflowPolicy(enum.Enum):
    DROP = "drop"
    OVERFLOW_STREAM = "overflow_stream"
    THROTTLE = "throttle"


@jax.tree_util.register_dataclass
@dataclass
class QueueState:
    buf: EventBatch        # capacity Q
    head: jnp.ndarray      # int32 []
    size: jnp.ndarray      # int32 []
    dropped: jnp.ndarray   # int32 [] lifetime overflow count
    peak: jnp.ndarray      # int32 [] high-water mark

    @property
    def capacity(self) -> int:
        return self.buf.capacity


def make_queue(capacity: int, value_spec,
               key_dtype=jnp.int32) -> QueueState:
    z = jnp.zeros((), jnp.int32)
    return QueueState(buf=EventBatch.empty(capacity, value_spec,
                                           key_dtype=key_dtype),
                      head=z, size=z, dropped=z, peak=z)


def enqueue(q: QueueState, incoming: EventBatch
            ) -> Tuple[QueueState, EventBatch]:
    """Append valid events; returns (queue, overflowed_events).

    Overflowed events keep their validity so the engine can apply the
    operator's policy (drop-count / overflow stream / throttle signal).
    """
    inc = compact(incoming)
    B, Q = inc.capacity, q.capacity
    n = inc.count()
    space = jnp.maximum(Q - q.size, 0)
    ranks = jnp.arange(B, dtype=jnp.int32)
    accept = inc.valid & (ranks < space)
    pos = (q.head + q.size + ranks) % Q
    safe_pos = jnp.where(accept, pos, Q)   # OOB -> dropped scatter

    def put(dst, src):
        return dst.at[safe_pos].set(src, mode="drop")

    buf = EventBatch(
        sid=put(q.buf.sid, inc.sid),
        ts=put(q.buf.ts, inc.ts),
        key=put(q.buf.key, inc.key),
        value=jax.tree.map(put, q.buf.value, inc.value),
        valid=put(q.buf.valid, accept),
    )
    taken = jnp.minimum(n, space)
    size = q.size + taken
    overflowed = inc.mask(inc.valid & (ranks >= space))
    nq = QueueState(buf=buf, head=q.head, size=size,
                    dropped=q.dropped,
                    peak=jnp.maximum(q.peak, size))
    return nq, overflowed


def dequeue(q: QueueState, batch: int) -> Tuple[QueueState, EventBatch]:
    Q = q.capacity
    ranks = jnp.arange(batch, dtype=jnp.int32)
    take = ranks < jnp.minimum(q.size, batch)
    idx = (q.head + ranks) % Q
    out = EventBatch(
        sid=q.buf.sid[idx], ts=q.buf.ts[idx], key=q.buf.key[idx],
        value=jax.tree.map(lambda a: a[idx], q.buf.value),
        valid=q.buf.valid[idx] & take,
    )
    n_taken = jnp.sum(take, dtype=jnp.int32)  # pinned: x64-stable carry
    # clear validity of consumed slots (hygiene for debugging)
    cleared = q.buf.valid.at[jnp.where(take, idx, Q)].set(False, mode="drop")
    nq = QueueState(buf=EventBatch(q.buf.sid, q.buf.ts, q.buf.key,
                                   q.buf.value, cleared),
                    head=(q.head + n_taken) % Q,
                    size=q.size - n_taken,
                    dropped=q.dropped, peak=q.peak)
    return nq, out


def count_drop(q: QueueState, overflowed: EventBatch) -> QueueState:
    return QueueState(buf=q.buf, head=q.head, size=q.size,
                      dropped=q.dropped + overflowed.count(),
                      peak=q.peak)
