"""Hotspot mitigation: key splitting (paper section 5, Example 6).

"Instead of using just a single updater U, we can use a set of updaters,
each of which counts just a subset of Best Buy events" — for associative
+ commutative updates, a hot key k is rewritten to W sub-keys
``k*W + r`` by a splitting mapper; per-sub-key partial aggregates are
re-combined on read (or by a periodic re-aggregation updater).

``KeySplitMapper`` wraps any stream; ``read_split_slate`` merges the W
partials with the updater's own combine.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.event import EventBatch
from repro.core.hashing import hash_key
from repro.core.operators import AssociativeUpdater, Mapper


def split_keys(keys, ts, ways: int, nonce=None):
    """key -> key*W + r with r pseudo-random per event (salted by ts and
    a per-row nonce so a hot key's events spread across all W sub-keys
    even within one microbatch)."""
    if nonce is None:
        nonce = jnp.arange(keys.shape[0], dtype=jnp.int32)
    mixin = keys ^ (ts * jnp.int32(-1640531535)) ^ \
        (nonce * jnp.int32(40503))  # 2654435761 as signed int32
    r = (hash_key(mixin, salt=0x51717) % jnp.uint32(ways)).astype(
        jnp.int32)
    return keys * ways + r


def merge_keys(split, ways: int):
    return split // ways


class KeySplitMapper(Mapper):
    """Rewrites keys on ``in_stream`` to W-way sub-keys on ``out_stream``."""

    def __init__(self, in_stream: str, out_stream: str, value_spec,
                 ways: int = 8, name: str = "key_split"):
        self.name = name
        self.subscribes = (in_stream,)
        self.in_value_spec = value_spec
        self.out_streams = {out_stream: value_spec}
        self.ways = ways
        self._out = out_stream

    def map_batch(self, batch: EventBatch) -> Dict[str, EventBatch]:
        new_key = split_keys(batch.key, batch.ts, self.ways)
        return {self._out: EventBatch(sid=batch.sid, ts=batch.ts + 1,
                                      key=new_key, value=batch.value,
                                      valid=batch.valid)}


def read_split_slate(engine, state, updater: str, key: int, ways: int,
                     combine=None):
    """Merge the W partial slates of a split key (single-shard engine)."""
    op = engine.wf.by_name[updater]
    combine = combine or op.combine
    partials = []
    for r in range(ways):
        s = engine.read_slate(state, updater, key * ways + r)
        if s is not None:
            partials.append(s)
    if not partials:
        return None
    out = partials[0]
    for p in partials[1:]:
        out = combine(jax.tree.map(np.asarray, out),
                      jax.tree.map(np.asarray, p))
    return out
