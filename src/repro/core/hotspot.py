"""Hotspot mitigation: key splitting (paper section 5, Example 6).

"Instead of using just a single updater U, we can use a set of updaters,
each of which counts just a subset of Best Buy events" — for associative
+ commutative updates, a hot key k is rewritten to W sub-keys
``k*W + r`` by a splitting mapper; per-sub-key partial aggregates are
re-combined on read (or by a periodic re-aggregation updater).

Sub-key arithmetic is *windowed* so it never overflows int32: only keys
inside ``|k| < split_window(W) = 2**30 // W`` are split (their sub-keys
tile ``(-2**30, 2**30)`` exactly, wrap-free); keys outside the window
pass through unsplit, so the int32 extremes round-trip bit-exactly and
the old silent wrap collisions between *in-window-sized* keys are gone
(e.g. ``2**28`` and ``-2**28`` collided at ``W=8``).  The irreducible
cost — sub-keys carry log2(W) extra bits that a 32-bit key cannot
absorb — lands on the *mid band* ``split_window(W) <= |k| < 2**30``:
those pass-through keys land inside the split image, so a mid-band key
can share a slate row with an in-window key's sub-key (storage-level
collision), and the pure inverse ``merge_keys`` misattributes them to
``k // W``.  Keys at ``|k| >= 2**30`` are fully exact and
collision-free.  Hot-key workloads live in small or hashed-down key
spaces; pre-mask keys into the window if the mid band matters.

``KeySplitMapper`` wraps any stream; ``read_split_slate`` merges the W
partials with the updater's own combine — on the single-shard
``Engine`` *and* on ``DistributedEngine``, where each sub-key read
routes through the hash ring (and merges two-choice partials) via the
engine's own ``read_slate``.
"""
from __future__ import annotations

from contextlib import nullcontext
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.event import EventBatch
from repro.core.hashing import hash_key
from repro.core.operators import AssociativeUpdater, Mapper


class SplitSlateReadError(RuntimeError):
    """``read_split_slate`` was handed an engine it cannot read from
    (no ``read_slate``/workflow surface) or an unknown updater."""


def split_window(ways: int, bits: int = 32) -> int:
    """Largest ``L`` such that every ``|k| < L`` splits W ways with
    sub-keys confined to ``(-2**(bits-2), 2**(bits-2))`` — wrap-free in
    the key dtype.  Under ``bits=64`` the window covers the entire
    int32 band, so every 32-bit-valued key splits and merges exactly
    (the DESIGN 12.5 mid band is gone)."""
    if ways < 1:
        raise ValueError(f"ways must be >= 1, got {ways}")
    return (1 << (bits - 2)) // ways


def _key_bits(dtype) -> int:
    return np.dtype(dtype).itemsize * 8


def split_keys(keys, ts, ways: int, nonce=None):
    """key -> key*W + r with r pseudo-random per event (salted by ts and
    a per-row nonce so a hot key's events spread across all W sub-keys
    even within one microbatch).  Keys outside
    ``split_window(ways, bits)`` — bits taken from the key dtype — pass
    through unsplit (overflow-safe; see module docstring)."""
    kd = keys.dtype
    if nonce is None:
        nonce = jnp.arange(keys.shape[0], dtype=jnp.int32)
    mixin = keys ^ (ts * jnp.int32(-1640531535)) ^ \
        (nonce * jnp.int32(40503))  # 2654435761 as signed int32
    r = (hash_key(mixin, salt=0x51717) % jnp.uint32(ways)).astype(kd)
    w = jnp.asarray(split_window(ways, _key_bits(kd)), kd)
    # |k| < w without jnp.abs (abs of the dtype min wraps)
    in_window = (keys > -w) & (keys < w)
    return jnp.where(in_window, keys * jnp.asarray(ways, kd) + r, keys)


def merge_keys(split, ways: int):
    """Exact inverse of :func:`split_keys` for every key inside the
    split window and every ``|k| >= 2**(bits-2)`` (the dtype extremes);
    see the module docstring for the mid band."""
    kd = split.dtype
    # <= 2**(bits-2), no wrap
    bound = jnp.asarray(split_window(ways, _key_bits(kd)) * ways, kd)
    in_image = (split > -bound) & (split < bound)
    return jnp.where(in_image, split // jnp.asarray(ways, kd), split)


def subkeys_of(key: int, ways: int, bits: int = 32) -> List[int]:
    """The sub-keys a key's events may have been rewritten to (host
    side, for reads).  Mirrors :func:`split_keys` exactly."""
    if abs(int(key)) < split_window(ways, bits):
        return [int(key) * ways + r for r in range(ways)]
    return [int(key)]


class KeySplitMapper(Mapper):
    """Rewrites keys on ``in_stream`` to W-way sub-keys on ``out_stream``."""

    def __init__(self, in_stream: str, out_stream: str, value_spec,
                 ways: int = 8, name: str = "key_split"):
        self.name = name
        self.subscribes = (in_stream,)
        self.in_value_spec = value_spec
        self.out_streams = {out_stream: value_spec}
        self.ways = ways
        self._out = out_stream

    def map_batch(self, batch: EventBatch) -> Dict[str, EventBatch]:
        new_key = split_keys(batch.key, batch.ts, self.ways)
        return {self._out: EventBatch(sid=batch.sid, ts=batch.ts + 1,
                                      key=new_key, value=batch.value,
                                      valid=batch.valid)}


def read_split_slate(engine, state, updater: str, key: int, ways: int,
                     combine=None):
    """Merge the W partial slates of a split key.

    Works on both engines: each sub-key read goes through
    ``engine.read_slate``, which on :class:`DistributedEngine` routes
    the sub-key through the hash ring to its owner shard (and merges
    two-choice partials).  Raises :class:`SplitSlateReadError` for
    engines without that surface or unknown updaters.
    """
    wf = getattr(engine, "wf", None)
    read = getattr(engine, "read_slate", None)
    if wf is None or read is None:
        raise SplitSlateReadError(
            f"read_split_slate needs an engine exposing .wf and "
            f".read_slate; got {type(engine).__name__}")
    op = wf.by_name.get(updater)
    if op is None:
        raise SplitSlateReadError(
            f"unknown updater {updater!r}; workflow has "
            f"{sorted(wf.by_name)}")
    combine = combine or getattr(op, "combine", None)
    if combine is None:
        raise SplitSlateReadError(
            f"{updater!r} is a {type(op).__name__} with no combine — "
            f"split-slate reads need an associative updater")
    partials = []
    # all sub-key reads under one read_lock hold (re-entrant: the
    # engine's read_slate re-acquires) so a mid-loop reconfigure cannot
    # hand back a mix of pre- and post-migration partials
    lock = getattr(engine, "read_lock", None) or nullcontext()
    bits = int(getattr(engine, "key_bits", 32))
    with lock:
        for sub in subkeys_of(key, ways, bits):
            s = read(state, updater, sub)
            if s is not None:
                partials.append(s)
    if not partials:
        return None
    out = partials[0]
    for p in partials[1:]:
        out = combine(jax.tree.map(np.asarray, out),
                      jax.tree.map(np.asarray, p))
    return out
