"""Integer hashing + the Muppet hash ring, as pure jnp.

The ring is materialized as *runtime arrays* (sorted virtual-node hashes +
their shard ids).  Routing is therefore data, not code: failure re-routes
and elastic scale-ups swap in a new ring without recompiling the engine
step — the TPU analogue of Muppet's "master broadcasts the failure, all
workers update their hash ring" (paper section 4.3).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

U32 = jnp.uint32


def mix32(x):
    """splitmix-style avalanche over uint32 (jnp)."""
    x = x.astype(U32)
    x = (x ^ (x >> 16)) * U32(0x7FEB352D)
    x = (x ^ (x >> 15)) * U32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def hash_key(key, salt: int = 0):
    """Hash int32/uint32 keys (+salt) to uint32."""
    return mix32(key.astype(U32) ^ U32(salt & 0xFFFFFFFF))


def _mix32_np(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint32)
    x = (x ^ (x >> np.uint32(16))) * np.uint32(0x7FEB352D)
    x = (x ^ (x >> np.uint32(15))) * np.uint32(0x846CA68B)
    x = x ^ (x >> np.uint32(16))
    return x


class HashRing:
    """Consistent-hash ring with virtual nodes (host-built, device-queried).

    ``table()`` returns (ring_hashes [R] ascending uint32, ring_shards [R])
    to be fed to the jitted step; ``route`` runs on device.
    """

    def __init__(self, n_shards: int, *, vnodes: int = 64,
                 alive: Optional[np.ndarray] = None, seed: int = 0x5EED):
        self.n_shards = n_shards
        self.vnodes = vnodes
        self.seed = seed
        self.alive = (np.ones(n_shards, bool) if alive is None
                      else np.asarray(alive, bool).copy())
        self._build()

    def _build(self):
        shards = np.nonzero(self.alive)[0]
        if len(shards) == 0:
            raise RuntimeError("hash ring has no alive shards")
        ids = np.repeat(shards, self.vnodes).astype(np.uint32)
        vix = np.tile(np.arange(self.vnodes, dtype=np.uint32), len(shards))
        h = _mix32_np(ids * np.uint32(0x9E3779B9) ^ _mix32_np(
            vix + np.uint32(self.seed)))
        order = np.argsort(h, kind="stable")
        self.ring_hashes = h[order]
        self.ring_shards = ids[order].astype(np.int32)

    # ---- host-side membership changes (master broadcast) ----
    def fail(self, shard: int):
        self.alive[shard] = False
        self._build()

    def join(self, shard: int):
        if shard >= self.n_shards:
            grown = np.ones(shard + 1, bool)
            grown[:self.n_shards] = self.alive
            self.alive = grown
            self.n_shards = shard + 1
        self.alive[shard] = True
        self._build()

    def table(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return (jnp.asarray(self.ring_hashes), jnp.asarray(self.ring_shards))


def route(keys, dest_salt: int, ring_hashes, ring_shards):
    """Device-side ring lookup: shard id per key.

    Hash of (key, destination operator) walks clockwise to the next
    virtual node — Muppet's ``h(key, dest function) -> worker``.
    """
    h = hash_key(keys, salt=dest_salt)
    idx = jnp.searchsorted(ring_hashes, h, side="left")
    idx = jnp.where(idx == ring_hashes.shape[0], 0, idx)  # wrap
    return ring_shards[idx]


def route_secondary(keys, dest_salt: int, ring_hashes, ring_shards):
    """The *other* choice for two-choice dispatch: next distinct shard
    clockwise on the ring (Muppet 2.0's secondary queue)."""
    h = hash_key(keys, salt=dest_salt)
    R = ring_hashes.shape[0]
    idx = jnp.searchsorted(ring_hashes, h, side="left") % R
    primary = ring_shards[idx]
    # walk up to 8 vnodes ahead looking for a different shard
    best = primary
    found = jnp.zeros(keys.shape, bool)
    for step in range(1, 9):
        cand = ring_shards[(idx + step) % R]
        take = (~found) & (cand != primary)
        best = jnp.where(take, cand, best)
        found = found | take
    return best
