"""Integer hashing + the Muppet hash ring, as pure jnp.

The ring is materialized as *runtime arrays* (sorted virtual-node hashes +
their shard ids).  Routing is therefore data, not code: failure re-routes
and elastic scale-ups swap in a new ring without recompiling the engine
step — the TPU analogue of Muppet's "master broadcasts the failure, all
workers update their hash ring" (paper section 4.3).

Two properties make live elasticity cheap (DESIGN.md section 12):

- **Fixed-shape tables.**  ``table()`` always returns arrays of length
  ``n_shards * vnodes``, padded at the top with ``0xFFFFFFFF`` entries
  that alias the wrap target (the first real virtual node's shard).
  Membership changes (``fail``/``join``) and weight changes therefore
  swap ring *contents*, never ring *shapes* — no jit recompilation on
  the hot path.  Only growing the physical shard count changes shapes.
- **Weighted virtual nodes.**  Each alive shard owns a contiguous block
  of vnode indices ``0..c_i-1`` with ``c_i`` proportional to its weight
  (sum fixed at ``alive_count * vnodes``).  Raising a weight *adds*
  high-index vnodes (stealing arcs); lowering it *removes* them
  (releasing arcs) — consistent-hashing minimal movement for load-aware
  rebalancing, and bit-identical to the classic equal-vnode ring when
  all weights are 1.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

U32 = jnp.uint32
_PAD_HASH = np.uint32(0xFFFFFFFF)


def mix32(x):
    """splitmix-style avalanche over uint32 (jnp)."""
    x = x.astype(U32)
    x = (x ^ (x >> 16)) * U32(0x7FEB352D)
    x = (x ^ (x >> 15)) * U32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def fold_u32(key):
    """Fold a key array to uint32: xor-fold for 64-bit keys, identity
    cast otherwise — 32-bit hashing stays bit-identical."""
    if np.dtype(key.dtype).itemsize > 4:
        u = key.astype(jnp.uint64)
        return (u ^ (u >> jnp.uint64(32))).astype(U32)
    return key.astype(U32)


def hash_key(key, salt: int = 0):
    """Hash integer keys (+salt) to uint32; 64-bit keys are xor-folded
    first so every hash consumer sees the full key band."""
    return mix32(fold_u32(key) ^ U32(salt & 0xFFFFFFFF))


def fold_u32_np(x: np.ndarray) -> np.ndarray:
    """Host mirror of :func:`fold_u32`."""
    if x.dtype.itemsize > 4:
        u = x.astype(np.uint64)
        return (u ^ (u >> np.uint64(32))).astype(np.uint32)
    return x.astype(np.uint32)


def _mix32_np(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint32)
    x = (x ^ (x >> np.uint32(16))) * np.uint32(0x7FEB352D)
    x = (x ^ (x >> np.uint32(15))) * np.uint32(0x846CA68B)
    x = x ^ (x >> np.uint32(16))
    return x


class HashRing:
    """Consistent-hash ring with weighted virtual nodes (host-built,
    device-queried).

    ``table()`` returns (ring_hashes [R] ascending uint32, ring_shards
    [R]) with R = ``n_shards * vnodes`` fixed, to be fed to the jitted
    step; ``route`` runs on device.
    """

    def __init__(self, n_shards: int, *, vnodes: int = 64,
                 alive: Optional[np.ndarray] = None,
                 weights: Optional[np.ndarray] = None, seed: int = 0x5EED):
        self.n_shards = n_shards
        self.vnodes = vnodes
        self.seed = seed
        self.alive = (np.ones(n_shards, bool) if alive is None
                      else np.asarray(alive, bool).copy())
        self.weights = (np.ones(n_shards, np.float64) if weights is None
                        else np.clip(np.asarray(weights, np.float64), 0.0,
                                     None).copy())
        self._build()

    def vnode_counts(self) -> np.ndarray:
        """Per-shard vnode allocation: proportional to weight over the
        alive set, every alive positive-weight shard gets >= 1, total
        fixed at ``alive_count * vnodes``."""
        return self.counts_for(self.weights)

    def counts_for(self, weights: np.ndarray) -> np.ndarray:
        """The vnode allocation a candidate weight vector would yield
        (pure — lets callers detect no-op reweights without a ring
        rebuild)."""
        w = np.where(self.alive, np.clip(weights, 0.0, None), 0.0)
        total = float(w.sum())
        alive_n = int(self.alive.sum())
        if alive_n == 0 or total <= 0.0:
            raise RuntimeError("hash ring has no alive shards with "
                               "positive weight")
        budget = alive_n * self.vnodes
        raw = budget * w / total
        counts = np.floor(raw).astype(np.int64)
        counts = np.where((w > 0) & (counts == 0), 1, counts)
        # largest-remainder: settle to the exact budget
        frac = raw - np.floor(raw)
        order = [int(i) for i in np.argsort(-frac, kind="stable")
                 if w[i] > 0]
        i = 0
        while counts.sum() < budget:
            counts[order[i % len(order)]] += 1
            i += 1
        donors = [int(i) for i in np.argsort(frac, kind="stable")
                  if w[i] > 0]
        i = 0
        while counts.sum() > budget:
            d = donors[i % len(donors)]
            if counts[d] > 1:
                counts[d] -= 1
            i += 1
        return counts.astype(np.int64)

    def _build(self):
        counts = self.vnode_counts()
        ids = np.repeat(np.arange(self.n_shards, dtype=np.uint32),
                        counts)
        vix = np.concatenate([np.arange(c, dtype=np.uint32)
                              for c in counts]) if len(ids) else \
            np.zeros(0, np.uint32)
        h = _mix32_np(ids * np.uint32(0x9E3779B9) ^ _mix32_np(
            vix + np.uint32(self.seed)))
        order = np.argsort(h, kind="stable")
        real_h = h[order]
        real_s = ids[order].astype(np.int32)
        # pad to the fixed physical shape.  All pad hashes tie at the
        # max value, so searchsorted(side="left") only ever *lands* on
        # the first pad entry — it aliases the wrap target (the first
        # real vnode's shard), keeping route() exact.  The remaining
        # pad entries cycle through the real ring so route_secondary's
        # bounded clockwise walk still meets distinct shards when it
        # crosses the pad region (a single-shard pad would collapse the
        # two-choice secondary to the primary near the ring top).
        R = self.n_shards * self.vnodes
        pad = R - len(real_h)
        self.real_size = len(real_h)
        self.ring_hashes = np.concatenate(
            [real_h, np.full(pad, _PAD_HASH, np.uint32)])
        self.ring_shards = np.concatenate(
            [real_s, real_s[np.arange(pad) % len(real_s)]])
        self._table_cache = None    # device copy, rebuilt lazily

    # ---- host-side membership / weight changes (master broadcast) ----
    def fail(self, shard: int):
        self.alive[shard] = False
        self._build()

    def join(self, shard: int):
        """(Re)activate a slot.  Its weight resets to neutral — a
        joining shard has fresh, empty state; any pre-leave load skew
        no longer describes it."""
        if shard >= self.n_shards:
            self.grow(shard + 1)
        self.alive[shard] = True
        self.weights[shard] = 1.0
        self._build()

    def grow(self, new_n_shards: int):
        """Extend the physical shard count (ring shape changes — the one
        elastic move that recompiles; see DistributedEngine.scale)."""
        if new_n_shards < self.n_shards:
            raise ValueError("grow() cannot shrink; use fail()/leave "
                             "to deactivate shards")
        grown = np.ones(new_n_shards, bool)
        grown[:self.n_shards] = self.alive
        w = np.ones(new_n_shards, np.float64)
        w[:self.n_shards] = self.weights
        self.alive, self.weights = grown, w
        self.n_shards = new_n_shards
        self._build()

    def set_weights(self, weights: np.ndarray):
        """Load-aware reweighting: a hot shard (low weight) sheds arcs.
        Same-shape swap — no recompilation."""
        w = np.clip(np.asarray(weights, np.float64), 0.0, None)
        if w.shape != (self.n_shards,):
            raise ValueError(f"weights must have shape "
                             f"({self.n_shards},), got {w.shape}")
        self.weights = w.copy()
        self._build()

    def table(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Device copy of the ring arrays.  Cached until the next
        ``_build`` — ``table()`` feeds every jitted tick *and* the
        device migration owner lookup, so re-uploading two host arrays
        per call would put a host->device transfer on the hot path."""
        if self._table_cache is None:
            self._table_cache = (jnp.asarray(self.ring_hashes),
                                 jnp.asarray(self.ring_shards))
        return self._table_cache

    def owners(self, keys: np.ndarray, dest_salt: int) -> np.ndarray:
        """Host-side routing (migration planning): shard id per key.
        Arrays keep their key width (int64 keys route on the folded
        hash); bare sequences default to int32."""
        rh, rs = self.table()
        k = keys if hasattr(keys, "dtype") \
            else np.asarray(keys, np.int32)
        return np.asarray(jax.device_get(
            route(jnp.asarray(k), dest_salt, rh, rs)))


def route(keys, dest_salt: int, ring_hashes, ring_shards):
    """Device-side ring lookup: shard id per key.

    Hash of (key, destination operator) walks clockwise to the next
    virtual node — Muppet's ``h(key, dest function) -> worker``.
    """
    h = hash_key(keys, salt=dest_salt)
    idx = jnp.searchsorted(ring_hashes, h, side="left")
    idx = jnp.where(idx == ring_hashes.shape[0], 0, idx)  # wrap
    return ring_shards[idx]


def route_secondary(keys, dest_salt: int, ring_hashes, ring_shards):
    """The *other* choice for two-choice dispatch: next distinct shard
    clockwise on the ring (Muppet 2.0's secondary queue)."""
    h = hash_key(keys, salt=dest_salt)
    R = ring_hashes.shape[0]
    idx = jnp.searchsorted(ring_hashes, h, side="left") % R
    primary = ring_shards[idx]
    # walk up to 8 vnodes ahead looking for a different shard
    best = primary
    found = jnp.zeros(keys.shape, bool)
    for step in range(1, 9):
        cand = ring_shards[(idx + step) % R]
        take = (~found) & (cand != primary)
        best = jnp.where(take, cand, best)
        found = found | take
    return best
