"""Streaming-ML subsystem (DESIGN.md section 16): model-backed stages
compiled into the unchanged MapUpdate engine.

- :class:`ModelMapper` — microbatched device inference as a mapper
  stage (``models/lm.py`` forward inside the jitted tick; params are
  device-resident constants uploaded once).
- :class:`SemanticTopK` / :class:`Personalization` — online updaters
  over the emitted embeddings.  ``SemanticTopK`` is an elementwise-max
  associative updater, so it rides the fused ``kernels/slate_update``
  path, stays durable, and remains hot-key-splittable.
- :mod:`repro.ml.serve_app` — the LM-serving loop as a MapUpdate app
  (admission source -> prefill/decode mapper -> per-request slate).
"""
from repro.ml.mapper import ModelMapper
from repro.ml.rankers import (Personalization, SemanticTopK,
                              personalization, semantic_topk)
from repro.ml.serve_app import (LMServeMapper, RequestSlate,
                                build_serve_app, request_source)

__all__ = [
    "ModelMapper",
    "SemanticTopK", "semantic_topk",
    "Personalization", "personalization",
    "LMServeMapper", "RequestSlate", "build_serve_app", "request_source",
]
