"""ModelMapper: microbatched device inference as a MapUpdate stage.

The paper's mappers are cheap field transforms; real fast-data apps
(Twitter's related-query pipeline, e-commerce ranking) run a *model*
per event.  ``ModelMapper`` is that stage: token events in, embeddings
or class scores out, with the full ``models/lm.py`` stack (attention /
MLA / MoE / mamba2 / xlstm per the config) executing inside the jitted
tick.

Three engine-facing properties (DESIGN.md section 16.1):

- **Param residency.**  Parameters are ``jax.device_put`` once at
  construction and closed over by ``map_batch`` — XLA interns them as
  device-resident constants of the compiled tick, so steady-state ticks
  move no weights (the ``StateHandle`` pattern applied to read-only
  state).
- **Bucket compilation.**  The event batch is padded to a multiple of
  ``bucket`` and inference runs as ``lax.map`` over ``[bucket, S]``
  microbatches: one compiled inference shape regardless of engine batch
  size, bounded peak activation memory.  Every per-event output depends
  only on its own row (attention mixes positions *within* a row, never
  across rows), so pad rows are exact no-ops and slicing back to the
  true capacity loses nothing — the bucket-padding parity test pins
  this.
- **Fusion cost tag.**  ``flop_heavy = True`` tells the planner's
  fusion pass this is not a cheap field map: the stage keeps its own
  queue hop so its backpressure stays visible to telemetry and overflow
  policies (DESIGN.md section 16.3).

Output specs are inferred by the planner's existing ``jax.eval_shape``
path (``trace_out_streams``); subclass-API users call :meth:`bind`.
"""
from __future__ import annotations

from typing import Dict, Sequence

import jax
import jax.numpy as jnp

from repro.core.event import EventBatch, spec_of
from repro.core.operators import Mapper
from repro.models import lm
from repro.models.context import Ctx


class ModelMapper(Mapper):
    """Run a ``models/lm.py`` model over a token field of each event.

    ``mode="embed"`` emits ``{"emb": [D] f32}`` — the masked mean of
    the final hidden states over non-pad positions (token 0 = padding).
    ``mode="classify"`` adds a linear head and emits
    ``{"cls": [] i32, "score": [] f32}`` (argmax class + its logit).
    Fields named in ``keep`` are passed through from the input event.
    """

    flop_heavy = True
    trace_out_streams = True

    def __init__(self, cfg, params=None, *, field: str = "tokens",
                 out: str = "scored", mode: str = "embed",
                 n_classes: int = 0, bucket: int = 8,
                 keep: Sequence[str] = (), name: str = "model_mapper",
                 seed: int = 0):
        if mode not in ("embed", "classify"):
            raise ValueError(f"unknown ModelMapper mode {mode!r}")
        if mode == "classify" and n_classes <= 0:
            raise ValueError("mode='classify' needs n_classes > 0")
        self.model = lm.build(cfg)
        self.cfg = cfg
        self.field = field
        self.out = out
        self.mode = mode
        self.bucket = int(bucket)
        self.keep = tuple(keep)
        self.name = name
        self.subscribes = ()
        self.out_streams = {}
        self.in_value_spec = {}
        key = jax.random.PRNGKey(seed)
        if params is None:
            params, _ = lm.init(self.model, key)
        # uploaded once; closed over below = compiled-in device constant
        self._params = jax.device_put(params)
        self._head = None
        if mode == "classify":
            hk = jax.random.fold_in(key, 1)
            w = jax.random.normal(hk, (cfg.d_model, n_classes),
                                  jnp.float32) / jnp.sqrt(cfg.d_model)
            self._head = jax.device_put(w)

    # ---- inference over one [bucket, S] microbatch ----
    def _infer(self, toks):
        # f32 compute: the stream engine's slates are f32 and the parity
        # contract (fused vs generic, pre vs post recovery) is bitwise
        ctx = Ctx(phase="train", positions=lm._positions(toks.shape),
                  cdtype=jnp.float32)
        hidden, _, _ = lm.forward(self.model, self._params, toks, ctx,
                                  remat=False)
        pad_mask = (toks != 0).astype(hidden.dtype)        # 0 = pad
        denom = jnp.maximum(pad_mask.sum(-1, keepdims=True), 1.0)
        return (hidden * pad_mask[..., None]).sum(axis=1) / denom

    def map_batch(self, batch: EventBatch) -> Dict[str, EventBatch]:
        toks = batch.value[self.field].astype(jnp.int32)   # [B, S]
        B, S = toks.shape
        nb = -(-B // self.bucket)
        padded = jnp.pad(toks, ((0, nb * self.bucket - B), (0, 0)))
        emb = jax.lax.map(self._infer, padded.reshape(nb, self.bucket, S))
        emb = emb.reshape(nb * self.bucket, -1)[:B]        # [B, D]
        if self.mode == "embed":
            value = {"emb": emb}
        else:
            logits = emb @ self._head                      # [B, n_cls]
            value = {"cls": jnp.argmax(logits, -1).astype(jnp.int32),
                     "score": jnp.max(logits, -1)}
        for f in self.keep:
            value[f] = batch.value[f]
        out = EventBatch(sid=batch.sid, ts=batch.ts + 1, key=batch.key,
                         value=value, valid=batch.valid)
        return {self.out: out}

    # ---- subclass-API spec binding (the planner does this itself via
    #      trace_out_streams for app.add()-wired instances) ----
    def bind(self, in_value_spec) -> "ModelMapper":
        from repro.api.planner import abstract_batch
        self.in_value_spec = in_value_spec
        res = jax.eval_shape(self.map_batch,
                             abstract_batch(in_value_spec))
        self.out_streams = {s: spec_of(b.value) for s, b in res.items()}
        return self
