"""The LM-serving loop as a MapUpdate application (DESIGN.md 16.4).

``launch/serve.py`` drives continuous-batching decode with a hand-rolled
host loop; this module expresses the same workload *through the stream
engine*: an admission source feeds request events, a FLOP-heavy mapper
runs prefill + greedy decode (one ``lm.prefill`` then a ``lax.scan`` of
``lm.decode_step`` per microbatch, same model fns and bf16 compute as
``ServingEngine``), and a per-request associative slate keeps the
generated tokens — durable, queryable over the slate HTTP server, and
visible to the telemetry registry like any other updater.

The request slate merges by elementwise max (``monoid="max"``): exactly
one event per request id ever reaches it, token ids are non-negative
and < vocab < 2**24, so the fused path applies, and idempotent max
makes at-least-once WAL replay after a crash bitwise-exact.

Requests pad their prompt to a static ``prompt_len``; pad positions sit
behind the causal mask at the last real position and past the decode
frontier afterwards, so they never influence a generated token — which
is what makes token-level parity with the direct ``ServingEngine`` loop
checkable (``examples/serve_lm.py``).
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.app import App
from repro.core.event import EventBatch, spec_of
from repro.core.operators import AssociativeUpdater, Mapper
from repro.models import lm
from repro.models.context import Ctx


class LMServeMapper(Mapper):
    """prefill + greedy decode for a whole request inside one tick.

    Consumes ``{"prompt": [S] i32 (0-padded), "len": [] i32}`` events
    keyed by request id; emits ``{"tokens": [max_new] i32}`` onto
    ``out``.  Microbatched like :class:`~repro.ml.mapper.ModelMapper`
    (``bucket`` requests per compiled inference shape)."""

    flop_heavy = True
    trace_out_streams = True

    def __init__(self, cfg, params=None, *, max_new: int = 16,
                 cache_len: int = 128, bucket: int = 4,
                 out: str = "generated", name: str = "lm_generate",
                 seed: int = 0):
        self.model = lm.build(cfg)
        self.cfg = cfg
        self.max_new = int(max_new)
        self.cache_len = int(cache_len)
        self.bucket = int(bucket)
        self.out = out
        self.name = name
        self.subscribes = ()
        self.out_streams = {}
        self.in_value_spec = {}
        if params is None:
            params, _ = lm.init(self.model, jax.random.PRNGKey(seed))
        self._params = jax.device_put(params)   # uploaded once

    def _generate(self, args):
        toks, length = args                     # [b, S], [b]
        b, S = toks.shape
        # bf16 compute — the same Ctx the ServingEngine's cells use, so
        # the parity smoke in examples/serve_lm.py compares like to like
        ctx = Ctx(cdtype=jnp.bfloat16)
        logits, states = lm.prefill(self.model, self._params,
                                    {"tokens": toks}, ctx,
                                    self.cache_len, full_logits=True)
        last = jnp.clip(length - 1, 0, S - 1)
        tok0 = jnp.argmax(logits[jnp.arange(b), last], -1)
        tok0 = tok0.astype(jnp.int32)
        cur = jnp.clip(length, 1, S).astype(jnp.int32)

        def dec(carry, _):
            t, st, ci = carry
            lg, st = lm.decode_step(self.model, self._params, t, st,
                                    ci, ctx)
            nxt = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)
            return (nxt[:, None], st, ci + 1), nxt

        _, rest = jax.lax.scan(dec, (tok0[:, None], states, cur), None,
                               length=self.max_new - 1)
        return jnp.concatenate([tok0[:, None], rest.T], 1)  # [b, max_new]

    def map_batch(self, batch: EventBatch) -> Dict[str, EventBatch]:
        toks = batch.value["prompt"].astype(jnp.int32)      # [B, S]
        length = batch.value["len"].astype(jnp.int32)       # [B]
        B, S = toks.shape
        nb = -(-B // self.bucket)
        pad = nb * self.bucket - B
        mb_toks = jnp.pad(toks, ((0, pad), (0, 0))) \
            .reshape(nb, self.bucket, S)
        mb_len = jnp.pad(length, (0, pad)).reshape(nb, self.bucket)
        gen = jax.lax.map(self._generate, (mb_toks, mb_len))
        gen = gen.reshape(nb * self.bucket, self.max_new)[:B]
        out = EventBatch(sid=batch.sid, ts=batch.ts + 1, key=batch.key,
                         value={"tokens": gen}, valid=batch.valid)
        return {self.out: out}

    def bind(self, in_value_spec) -> "LMServeMapper":
        from repro.api.planner import abstract_batch
        self.in_value_spec = in_value_spec
        res = jax.eval_shape(self.map_batch,
                             abstract_batch(in_value_spec))
        self.out_streams = {s: spec_of(b.value) for s, b in res.items()}
        return self


class RequestSlate(AssociativeUpdater):
    """One slate per request id: the generated token block.

    Elementwise-max mergeable (one event per rid, non-negative token
    ids < 2**24): rides the fused path and replays idempotently."""

    monoid = "max"

    def __init__(self, name: str = "requests", *, max_new: int,
                 table_capacity: int = 4096, ttl: int = 0):
        self.name = name
        self.max_new = int(max_new)
        self.table_capacity = table_capacity
        self.ttl = ttl
        self.subscribes = ()
        self.out_streams = {}

    def slate_spec(self):
        return {"tokens": ((self.max_new,), jnp.int32),
                "n": ((), jnp.int32)}

    def lift(self, batch):
        toks = batch.value["tokens"].astype(jnp.int32)
        return {"tokens": toks,
                "n": jnp.full(toks.shape[:1], self.max_new, jnp.int32)}

    def combine(self, a, b):
        return jax.tree.map(jnp.maximum, a, b)

    merge = combine


def build_serve_app(cfg, params=None, *, prompt_len: int = 32,
                    max_new: int = 16, cache_len: int = 128,
                    bucket: int = 4, name: str = "serve_lm",
                    table_capacity: int = 4096) -> App:
    """requests source -> LMServeMapper -> per-request slate, as an App.

    Drive with :func:`request_source` and ``App.run``; read results via
    ``app.read_slate("requests", rid)`` (or the HTTP slate server)."""
    app = App(name)
    app.source("requests", {"prompt": ((prompt_len,), jnp.int32),
                            "len": ((), jnp.int32)})
    app.add(LMServeMapper(cfg, params, max_new=max_new,
                          cache_len=cache_len, bucket=bucket),
            subscribes=("requests",))
    app.stream("generated").update(RequestSlate(
        "requests", max_new=max_new, table_capacity=table_capacity))
    return app


def request_source(requests: Sequence, *, prompt_len: int,
                   capacity: int, per_tick: int = 2):
    """Admission source: feeds up to ``per_tick`` queued requests per
    tick (respecting the engine's ingest limit — unconsumed requests
    wait, exactly like ``ServingEngine``'s bounded admission queue).
    ``requests`` is any sequence with ``.rid`` / ``.prompt`` attributes
    (e.g. ``launch.serve.Request``)."""
    pending: List = list(requests)
    cursor = [0]

    def source_fn(tick, max_events):
        n = per_tick if not max_events else min(per_tick, int(max_events))
        take = pending[cursor[0]:cursor[0] + n]
        cursor[0] += len(take)
        prompts = np.zeros((capacity, prompt_len), np.int32)
        lens = np.zeros((capacity,), np.int32)
        keys = np.zeros((capacity,), np.int32)
        valid = np.zeros((capacity,), bool)
        for i, r in enumerate(take):
            p = np.asarray(r.prompt, np.int32)[:prompt_len]
            prompts[i, :p.shape[0]] = p
            lens[i] = p.shape[0]
            keys[i] = r.rid
            valid[i] = True
        return {"requests": EventBatch.of(
            key=keys, value={"prompt": prompts, "len": lens},
            ts=np.full(capacity, tick, np.int32), valid=valid)}

    return source_fn
