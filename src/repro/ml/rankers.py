"""Online rankers over streamed model outputs (DESIGN.md section 16.2).

``SemanticTopK`` — per-key top-k by model score as an *associative*
updater with a real elementwise-max combine, so it rides the fused
``kernels/slate_update`` path (packed f32 lanes, in-place scatter),
stays durable through the flush/WAL machinery unchanged, and remains
hot-key-splittable (max is commutative, associative, and idempotent —
partial merges and at-least-once replay are exact, not approximate).

The slate is a slotted max-sketch: item ids hash to one of ``n_slots``
columns; each column holds one f32 word packing
``quantized_score * 2^ITEM_BITS + (item mod 2^ITEM_BITS)`` — score in
the high bits so elementwise max keeps, per column, the best-scoring
item seen.  SCORE_BITS + ITEM_BITS <= 24 keeps every word exact in a
f32 lane (the packing contract, ``core/packing.py``).  Two items
hashing to one column keep only the better one — sketch semantics, the
price of an O(1)-merge top-k; scores are quantized to SCORE_BITS by
construction.  Because f32 max is order-independent, fused vs generic
execution is *bitwise* identical (the parity contract tier-1 tests pin).

``Personalization`` — per-user EMA embedding + re-scored candidate
slate.  Order-sensitive (the EMA and the rescoring depend on arrival
order), so it runs on the sequential padded-run path; its slate carries
a wide ``[k, D]`` float leaf — the wide-value case the packing/flush
layers must round-trip.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.operators import AssociativeUpdater, SequentialUpdater

SCORE_BITS = 14   # score quantization levels (high bits)
ITEM_BITS = 10    # item id space per packed word (low bits)
# SCORE_BITS + ITEM_BITS <= 24: packed words stay exact in f32 lanes


def pack_word(score, item):
    """(score in [0,1), item id) -> nonneg f32-exact word; elementwise
    max over words ranks by quantized score, tie-broken by item id."""
    q = jnp.clip(jnp.floor(score * (1 << SCORE_BITS)), 0.0,
                 float((1 << SCORE_BITS) - 1))
    low = (item & ((1 << ITEM_BITS) - 1)).astype(jnp.float32)
    return q * (1 << ITEM_BITS) + low


def unpack_word(word: float) -> Tuple[int, float]:
    """Packed word -> (item id mod 2^ITEM_BITS, quantized score)."""
    w = int(word)
    return w & ((1 << ITEM_BITS) - 1), (w >> ITEM_BITS) / (1 << SCORE_BITS)


class SemanticTopK(AssociativeUpdater):
    """Per-key top-k (item, model score) as an elementwise-max slate.

    Score per event, in ranking priority: ``score_fn(value) -> [B]``,
    else ``value[score_field]``, else the default embedding score
    ``sigmoid(mean(value[emb_field]))`` — all expected in [0, 1).
    Item ids must be positive (0 marks an empty column on read).
    """

    monoid = "max"

    def __init__(self, name: str = "semantic_topk", *, k: int = 8,
                 n_slots: int = 32, item_field: str = "item",
                 emb_field: str = "emb",
                 score_field: Optional[str] = None, score_fn=None,
                 table_capacity: int = 4096, ttl: int = 0):
        if k > n_slots:
            raise ValueError(f"k={k} > n_slots={n_slots}")
        self.name = name
        self.k = int(k)
        self.n_slots = int(n_slots)
        self.item_field = item_field
        self.emb_field = emb_field
        self.score_field = score_field
        self.score_fn = score_fn
        self.table_capacity = table_capacity
        self.ttl = ttl
        self.subscribes = ()
        self.out_streams = {}

    def slate_spec(self):
        return {"cells": ((self.n_slots,), jnp.float32)}

    def _scores(self, value):
        if self.score_fn is not None:
            return self.score_fn(value)
        if self.score_field is not None:
            return value[self.score_field].astype(jnp.float32)
        return jax.nn.sigmoid(
            jnp.mean(value[self.emb_field].astype(jnp.float32), axis=-1))

    def lift(self, batch):
        item = batch.value[self.item_field].astype(jnp.int32)
        word = pack_word(self._scores(batch.value), item)   # [B]
        col = jnp.mod(item, self.n_slots)
        hot = col[:, None] == jnp.arange(self.n_slots,
                                         dtype=jnp.int32)[None, :]
        return {"cells": jnp.where(hot, word[:, None], 0.0)}

    def combine(self, a, b):
        return {"cells": jnp.maximum(a["cells"], b["cells"])}

    merge = combine

    # ---- host-side read path ----
    def top(self, slate, k: Optional[int] = None
            ) -> List[Tuple[int, float]]:
        """Slate row -> [(item, score)] best-first (item ids are modulo
        2^ITEM_BITS; empty columns are skipped)."""
        cells = np.asarray(slate["cells"])
        out = []
        for w in sorted(cells, reverse=True)[:(k or self.k)]:
            if w <= 0:
                break
            out.append(unpack_word(w))
        return out


class Personalization(SequentialUpdater):
    """Per-user slate: EMA user embedding + re-scored candidate items.

    Each event carries an item id (> 0) and its model embedding
    ``[D]``.  The step folds the embedding into the user's EMA profile,
    then re-scores the stored candidates *plus* the new item against
    the updated profile (dot product) and keeps the top ``k`` — so
    earlier candidates are re-ranked as the user's taste drifts.
    Duplicate item arrivals replace their old entry.
    """

    def __init__(self, name: str = "personalization", *, d: int,
                 k: int = 4, alpha: float = 0.2,
                 item_field: str = "item", emb_field: str = "emb",
                 table_capacity: int = 4096, ttl: int = 0,
                 max_run: int = 32):
        self.name = name
        self.d = int(d)
        self.k = int(k)
        self.alpha = float(alpha)
        self.item_field = item_field
        self.emb_field = emb_field
        self.table_capacity = table_capacity
        self.ttl = ttl
        self.max_run = max_run
        self.subscribes = ()
        self.out_streams = {}

    def slate_spec(self):
        return {"user": ((self.d,), jnp.float32),
                "items": ((self.k,), jnp.int32),
                "cand": ((self.k, self.d), jnp.float32),   # wide leaf
                "scores": ((self.k,), jnp.float32),
                "n": ((), jnp.int32)}

    def step(self, slate, ev):
        emb = ev["value"][self.emb_field].astype(jnp.float32)   # [D]
        item = ev["value"][self.item_field].astype(jnp.int32)
        first = slate["n"] == 0
        user = jnp.where(first, emb,
                         (1.0 - self.alpha) * slate["user"]
                         + self.alpha * emb)
        cand = jnp.concatenate([slate["cand"], emb[None]], 0)  # [k+1, D]
        items = jnp.concatenate([slate["items"], item[None]])  # [k+1]
        live = items > 0
        # a re-seen item drops its stored copy in favor of the new one
        live = live & ~((items == item)
                        & (jnp.arange(self.k + 1) < self.k))
        scores = jnp.where(live, cand @ user, -jnp.inf)
        order = jnp.argsort(-scores)[:self.k]
        sel = jnp.isfinite(scores[order])
        new = {
            "user": user,
            "items": jnp.where(sel, items[order], 0),
            "cand": jnp.where(sel[:, None], cand[order], 0.0),
            "scores": jnp.where(sel, scores[order], 0.0),
            "n": slate["n"] + 1,
        }
        return new, {}

    # ---- host-side read path ----
    def ranked(self, slate) -> List[Tuple[int, float]]:
        items = np.asarray(slate["items"])
        scores = np.asarray(slate["scores"])
        return [(int(i), float(s)) for i, s in zip(items, scores)
                if i > 0]


def semantic_topk(name: str = "semantic_topk", **kw) -> SemanticTopK:
    return SemanticTopK(name, **kw)


def personalization(name: str = "personalization", **kw
                    ) -> Personalization:
    return Personalization(name, **kw)
