"""Pure-jnp oracle for (flash) attention.

Chunked over query blocks with a ``lax.scan`` so the S x S score matrix is
never fully materialized — this is also the GSPMD path lowered in the
multi-pod dry-run, so its HLO is representative of the flash kernel's
HBM traffic (scores stay transient at [B, H, chunk, Skv]).

Supports: GQA (kv-head repeat), causal masking with query offset, sliding
windows, different K/V head dims (for MLA), bidirectional (encoder) mode.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _repeat_kv(x, rep: int):
    if rep == 1:
        return x
    b, s, h, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, h, rep, d)).reshape(
        b, s, h * rep, d)


def _block_attend(qc, k, v, rows, cols, *, causal, window, scale):
    """One query block. qc: [B,C,H,Dh]; k,v: [B,Skv,H,D*]."""
    s = jnp.einsum("bqhd,bkhd->bhqk", qc.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = jnp.ones(s.shape[-2:], dtype=bool)
    if causal:
        mask &= cols[None, :] <= rows[:, None]
    if window:
        mask &= cols[None, :] > rows[:, None] - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows (pad) produce uniform p; the caller slices them off
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))


@partial(jax.jit, static_argnames=("causal", "window", "q_offset", "chunk"))
def mha(q, k, v, *, causal: bool = True, window: int = 0, q_offset: int = 0,
        chunk: int = 512):
    """q: [B,Sq,H,Dh]; k: [B,Skv,Hkv,Dh]; v: [B,Skv,Hkv,Dv] -> [B,Sq,H,Dv].

    ``q_offset``: absolute position of q row 0 minus kv row 0 (chunked
    prefill / self-extension); standard full self-attention uses 0 with
    Sq == Skv.
    """
    B, Sq, H, Dh = q.shape
    _, Skv, Hkv, Dv = v.shape
    rep = H // Hkv
    k = _repeat_kv(k, rep)
    v = _repeat_kv(v, rep)
    scale = Dh ** -0.5
    cols = jnp.arange(Skv)

    if Sq <= chunk:
        rows = jnp.arange(Sq) + q_offset
        out = _block_attend(q, k, v, rows, cols, causal=causal,
                            window=window, scale=scale)
        return out.astype(q.dtype)

    pad = (-Sq) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (Sq + pad) // chunk
    q_blocks = q.reshape(B, nc, chunk, H, Dh).transpose(1, 0, 2, 3, 4)
    row_blocks = (jnp.arange(nc * chunk) + q_offset).reshape(nc, chunk)

    def body(_, xs):
        qc, rows = xs
        out = _block_attend(qc, k, v, rows, cols, causal=causal,
                            window=window, scale=scale)
        return None, out

    _, ys = jax.lax.scan(jax.checkpoint(body), None, (q_blocks, row_blocks))
    out = ys.transpose(1, 0, 2, 3, 4).reshape(B, nc * chunk, H, Dv)
    return out[:, :Sq].astype(q.dtype)
