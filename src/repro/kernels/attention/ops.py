"""Dispatching wrapper: flash-attention Pallas kernel on TPU, ref elsewhere.

The dry-run / CPU tests always take the ref path (Pallas does not target
CPU); on a real TPU backend ``impl="auto"`` resolves to the Pallas kernel
when the shape is supported (head_dim multiple of 128 tiling etc.).
"""
from __future__ import annotations

import jax

from repro.kernels.attention import ref as _ref


def _tpu_available() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def mha(q, k, v, *, causal: bool = True, window: int = 0, q_offset: int = 0,
        chunk: int = 512, impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if _tpu_available() else "ref"
    if impl == "pallas":
        from repro.kernels.flash_attention import kernel as _k
        if _k.supported(q, k, v, causal=causal, window=window):
            return _k.flash_attention(q, k, v, causal=causal, window=window,
                                      q_offset=q_offset)
        impl = "ref"
    return _ref.mha(q, k, v, causal=causal, window=window,
                    q_offset=q_offset, chunk=chunk)
