"""Chunked SSD linear recurrence (Mamba-2 / mLSTM) — Pallas TPU kernel.

Grid (B*H, S/L): the chunk axis is innermost/sequential, carrying the
[N, P] recurrent state in VMEM scratch across chunks — the inter-chunk
recurrence never round-trips HBM (the jnp ref pays an HBM-resident carry
per lax.scan step).  Per chunk the kernel fuses: within-chunk gate cumsum,
the [L, L] decay-masked score matmul, the state-input contraction and the
state update, in one VMEM-resident pass (~L*L + 2*L*(N+P) f32 ~ 0.9 MB at
L=256, N=P=64).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(q_ref, k_ref, v_ref, la_ref, y_ref, fin_ref, state_scr, *,
                L: int, nc: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    q = q_ref[0].astype(jnp.float32)          # [L, N]
    k = k_ref[0].astype(jnp.float32)          # [L, N]
    v = v_ref[0].astype(jnp.float32)          # [L, P]
    la = la_ref[0].astype(jnp.float32)        # [L]
    cum = jnp.cumsum(la)                      # [L] inclusive

    # intra-chunk
    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [L, L]
    dmat = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    decay = jnp.where(tri, jnp.exp(dmat), 0.0)
    y = jax.lax.dot_general(scores * decay, v, (((1,), (0,)), ((), ())))

    # inter-chunk (carried state)
    state = state_scr[...]                    # [N, P]
    y += jax.lax.dot_general(q * jnp.exp(cum)[:, None], state,
                             (((1,), (0,)), ((), ())))
    y_ref[0] = y.astype(y_ref.dtype)

    # state update
    end_decay = jnp.exp(cum[L - 1] - cum)     # [L]
    s_chunk = jax.lax.dot_general(k * end_decay[:, None], v,
                                  (((0,), (0,)), ((), ())))  # [N, P]
    state_scr[...] = jnp.exp(cum[L - 1]) * state + s_chunk

    @pl.when(ci == nc - 1)
    def _final():
        fin_ref[0] = state_scr[...]


def supported(q, k, v) -> bool:
    B, S, H, N = q.shape
    P = v.shape[-1]
    return N % 8 == 0 and P % 8 == 0


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(q, k, v, log_a, *, chunk: int = 256, initial_state=None,
             interpret: bool = False):
    """Same contract as kernels.ssd.ref.ssd (initial_state must be None —
    the serving path uses ssd_step for incremental state)."""
    assert initial_state is None, "kernel path starts from zero state"
    B, S, H, N = q.shape
    P = v.shape[-1]
    L = min(chunk, S)
    pad = (-S) % L
    zp = lambda x: jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] *
                           (x.ndim - 2)) if pad else x
    q, k, v, log_a = zp(q), zp(k), zp(v), zp(log_a)
    nc = (S + pad) // L

    def flat(x):  # [B,S,H,*] -> [B*H, S, *]
        return x.transpose(0, 2, 1, 3).reshape((B * x.shape[2], S + pad)
                                               + x.shape[3:])

    qf, kf, vf = flat(q), flat(k), flat(v)
    laf = log_a.transpose(0, 2, 1).reshape(B * H, S + pad)

    kernel = functools.partial(_ssd_kernel, L=L, nc=nc)
    y, fin = pl.pallas_call(
        kernel,
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, L, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, L, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, L, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, L), lambda b, c: (b, c)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, N, P), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S + pad, P), v.dtype),
            jax.ShapeDtypeStruct((B * H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf, laf)
    y = y.reshape(B, H, S + pad, P).transpose(0, 2, 1, 3)[:, :S]
    fin = fin.reshape(B, H, N, P)
    return y, fin
