"""Pure-jnp oracle: chunked linear recurrence (Mamba-2 SSD / mLSTM).

Recurrent definition (per batch b, head h):
    S_t = exp(log_a_t) * S_{t-1} + k_t^T v_t        # state [N, P]
    y_t = q_t . S_t                                  # contract N

Both Mamba-2's state-space dual and xLSTM's mLSTM reduce to this after
gate/discretization preprocessing (see models/layers).  The chunked
algorithm processes L-step blocks with intra-chunk quadratic attention and
an inter-chunk sequential state pass — the same decomposition the Pallas
``ssd_scan`` kernel tiles into VMEM.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def ssd_step(state, q, k, v, log_a):
    """Single decode step.  state: [B,H,N,P]; q,k: [B,H,N]; v: [B,H,P];
    log_a: [B,H].  Returns (new_state, y [B,H,P])."""
    a = jnp.exp(log_a.astype(jnp.float32))[..., None, None]
    new_state = a * state.astype(jnp.float32) + (
        k.astype(jnp.float32)[..., :, None] * v.astype(jnp.float32)[..., None, :])
    y = jnp.einsum("bhn,bhnp->bhp", q.astype(jnp.float32), new_state)
    return new_state.astype(state.dtype), y.astype(v.dtype)


@partial(jax.jit, static_argnames=("chunk",))
def ssd(q, k, v, log_a, *, chunk: int = 256, initial_state=None):
    """q,k: [B,S,H,N]; v: [B,S,H,P]; log_a: [B,S,H] (<= 0).

    Returns (y [B,S,H,P], final_state [B,H,N,P]).
    """
    B, S, H, N = q.shape
    P = v.shape[-1]
    pad = (-S) % chunk
    if pad:
        zp = lambda x: jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2))
        q, k, v, log_a = zp(q), zp(k), zp(v), zp(log_a)
    L = chunk
    nc = (S + pad) // L

    def to_chunks(x):
        return x.reshape((B, nc, L) + x.shape[2:]).swapaxes(0, 1)

    qc, kc, vc, lac = map(to_chunks, (q, k, v, log_a))  # leading axis nc

    if initial_state is None:
        initial_state = jnp.zeros((B, H, N, P), jnp.float32)
    else:
        initial_state = initial_state.astype(jnp.float32)

    tri = jnp.tril(jnp.ones((L, L), bool))  # l >= m (inclusive of diagonal)

    def body(S_prev, xs):
        qb, kb, vb, lab = xs                       # [B,L,H,*]
        labf = lab.astype(jnp.float32)
        cum = jnp.cumsum(labf, axis=1)             # [B,L,H] inclusive
        # --- intra-chunk (quadratic within L) ---
        scores = jnp.einsum("blhn,bmhn->bhlm", qb.astype(jnp.float32),
                            kb.astype(jnp.float32))
        dmat = cum.transpose(0, 2, 1)[:, :, :, None] - \
            cum.transpose(0, 2, 1)[:, :, None, :]  # [B,H,L,M] = cum_l - cum_m
        decay = jnp.where(tri[None, None], jnp.exp(dmat), 0.0)
        y_intra = jnp.einsum("bhlm,bmhp->blhp", scores * decay,
                             vb.astype(jnp.float32))
        # --- inter-chunk (carry state) ---
        y_inter = jnp.einsum("blhn,bhnp->blhp",
                             qb.astype(jnp.float32) *
                             jnp.exp(cum)[..., None], S_prev)
        # --- state update ---
        end_decay = jnp.exp(cum[:, -1:, :] - cum)  # [B,L,H] decay u -> end
        S_chunk = jnp.einsum("blhn,blhp->bhnp",
                             kb.astype(jnp.float32) * end_decay[..., None],
                             vb.astype(jnp.float32))
        S_new = jnp.exp(cum[:, -1, :])[..., None, None] * S_prev + S_chunk
        return S_new, (y_intra + y_inter)

    final_state, ys = jax.lax.scan(jax.checkpoint(body), initial_state,
                                   (qc, kc, vc, lac))
    y = ys.swapaxes(0, 1).reshape(B, nc * L, H, P)[:, :S]
    return y.astype(v.dtype), final_state
