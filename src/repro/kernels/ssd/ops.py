"""Dispatching wrapper for the chunked SSD linear recurrence."""
from __future__ import annotations

import jax

from repro.kernels.ssd import ref as _ref

ssd_step = _ref.ssd_step


def ssd(q, k, v, log_a, *, chunk: int = 256, initial_state=None,
        impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        from repro.kernels.ssd_scan import kernel as _k
        if _k.supported(q, k, v):
            return _k.ssd_scan(q, k, v, log_a, chunk=chunk,
                               initial_state=initial_state)
        impl = "ref"
    return _ref.ssd(q, k, v, log_a, chunk=chunk, initial_state=initial_state)
