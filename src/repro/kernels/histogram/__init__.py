from repro.kernels.histogram.ops import histogram_update

__all__ = ["histogram_update"]
