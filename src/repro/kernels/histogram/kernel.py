"""Latency-histogram update — the observability hot-path kernel.

One invocation folds a microbatch of power-of-two latency buckets into
the [rows, width] histogram held in VMEM: each row's bucket indices
are expanded to a [B, width] one-hot mask and reduced over B — the
same VPU-friendly shape as the count-min kernel (no scalar scatter in
the inner loop).  The histogram is aliased in/out so the update is
in-place; *bucketizing* (the clz-based power-of-two binning) stays
outside the kernel, plain jnp on the already-resident latencies,
mirroring how ``countmin_update`` receives pre-hashed columns.

Masked-out events are folded into a sink column (``width``, which no
iota lane matches) before the call, so the kernel carries no validity
plumbing.  rows is 1 in practice (one histogram per updater arc) and
width a lane-aligned multiple of 128 — the logical power-of-two
buckets occupy a prefix and the padded tail is never hit because the
bucket index saturates below it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(cols_ref, counts_in_ref, counts_ref, *,
                 rows: int, B: int, width: int):
    for r in range(rows):                       # static, tiny
        cols = cols_ref[:, r:r + 1]             # [B, 1]
        iota = jax.lax.broadcasted_iota(jnp.int32, (B, width), 1)
        hit = (iota == cols).astype(jnp.int32)  # sink column never hits
        counts_ref[r:r + 1, :] = counts_ref[r:r + 1, :] + \
            jnp.sum(hit, axis=0, keepdims=True)


def supported(counts, cols) -> bool:
    return (counts.ndim == 2 and cols.ndim == 2
            and counts.shape[1] % 128 == 0
            and cols.shape[0] == counts.shape[0])


@functools.partial(jax.jit, static_argnames=("interpret",))
def histogram_update(counts, cols, add, *, interpret: bool = False):
    """counts: [rows, width] int32 (aliased in/out); cols: [rows, B]
    int32 bucket indices; add: [B] int32 0/1 increment per event.
    Returns the updated histogram."""
    rows, width = counts.shape
    B = cols.shape[1]
    # fold the increment mask into a sink column and transpose to
    # [B, rows] so the kernel stays rank-2 throughout
    cols_t = jnp.where(add[None, :] > 0, cols,
                       jnp.int32(width)).T.astype(jnp.int32)
    kernel = functools.partial(_hist_kernel, rows=rows, B=B, width=width)
    return pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec((B, rows), lambda: (0, 0)),      # cols (T)
            pl.BlockSpec((rows, width), lambda: (0, 0)),  # hist alias
        ],
        out_specs=pl.BlockSpec((rows, width), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct(counts.shape, counts.dtype),
        input_output_aliases={1: 0},
        interpret=interpret,
    )(cols_t, counts)
