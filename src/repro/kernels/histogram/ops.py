"""Dispatching wrapper for the latency-histogram update.

``impl`` (the same backend vocabulary as ``kernels/countmin``):
  - "auto":      Pallas on TPU, jnp oracle elsewhere
  - "pallas":    force the kernel (falls back to ref if unsupported)
  - "interpret": Pallas body in interpreter mode (CPU-testable)
  - "jnp" / "ref": pure-jnp scatter-add oracle

All backends are exact integer adds, so they agree bitwise.  ``add``
is the per-event 0/1 increment vector (invalid rows = 0) — the kernel
folds zeros into a sink column, the oracle scatter-adds them as-is.
"""
from __future__ import annotations

import jax

from repro.kernels.histogram import ref as _ref


def histogram_update(counts, cols, add, *, impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl in ("pallas", "interpret"):
        from repro.kernels.histogram import kernel as _k
        if _k.supported(counts, cols):
            return _k.histogram_update(counts, cols, add,
                                       interpret=(impl == "interpret"))
        impl = "ref"
    if impl not in ("ref", "jnp"):
        raise ValueError(f"unknown histogram impl {impl!r}")
    return _ref.histogram_update(counts, cols, add)
