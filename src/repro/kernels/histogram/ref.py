"""Pure-jnp oracle for the latency-histogram update.

Like the count-min oracle, the whole backend is one exact integer
scatter-add, so every impl agrees bitwise — the histogram is
telemetry, but a nondeterministic one would break the "histogram on
vs off" parity contract (DESIGN.md section 18).
"""
from __future__ import annotations

import jax.numpy as jnp


def histogram_update(counts, cols, add):
    """counts: [rows, width] int32; cols: [rows, B] int32 bucket per
    row; add: [B] int32 increment per event (0 for invalid rows).
    Returns counts with every (row, bucket) bumped by its event's
    increment — duplicate buckets accumulate.  Same flat 1D ravelled
    scatter as the count-min oracle (the scatter is the whole cost)."""
    rows, width = counts.shape
    flat = (cols
            + (jnp.arange(rows, dtype=jnp.int32) * width)[:, None])
    amt = jnp.broadcast_to(add.astype(counts.dtype)[None, :], cols.shape)
    return counts.ravel().at[flat.ravel()].add(
        amt.ravel()).reshape(rows, width)
