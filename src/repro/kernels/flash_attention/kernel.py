"""Flash attention forward — Pallas TPU kernel.

Tiling: grid (batch, q_heads, Sq/block_q, Skv/block_k); the innermost
(kv) grid dim is sequential on TPU, so the online-softmax running max /
denominator / accumulator live in VMEM scratch carried across kv steps.
Block shapes keep the MXU busy (block_q x d and block_k x d tiles,
d = head_dim 64..256 is lane-aligned); the VMEM working set is
~ block_q*(Dh+Dv)*2B + block_q*block_k*4B ~ 1.5 MB at the defaults.

Causal + sliding-window blocks are *skipped* (pl.when on block indices),
so local layers do O(S*window) work — the asymptotics gemma3's 5-of-6
local layers rely on.

GQA: kv blocks are indexed by h // (H/Hkv) — no materialized kv repeat
(the jnp ref pays that broadcast; the kernel reads the shared head
directly from HBM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                scale: float, block_q: int, block_k: int, causal: bool,
                window: int, q_offset: int, nk: int, kv_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # block-level skip decision
    q_block_end = qi * block_q + block_q - 1 + q_offset
    k_block_start = ki * block_k
    needed = k_block_start < kv_len
    if causal:
        needed &= k_block_start <= q_block_end
    if window:
        k_block_end = ki * block_k + block_k - 1
        needed &= k_block_end > qi * block_q + q_offset - window

    @pl.when(needed)
    def _compute():
        rows = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0) + q_offset
        cols = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        q = q_ref[0, 0].astype(jnp.float32) * scale        # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)                # [bk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
        mask = cols < kv_len
        if causal:
            mask &= cols <= rows
        if window:
            mask &= cols > rows - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        v = v_ref[0, 0].astype(jnp.float32)                # [bk, dv]
        acc_scr[...] = acc_scr[...] * corr[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        l_scr[...] = l_prev * corr + p.sum(axis=1)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def supported(q, k, v, *, causal: bool = True, window: int = 0) -> bool:
    B, Sq, H, Dh = q.shape
    _, Skv, Hkv, Dv = v.shape
    return (H % Hkv == 0 and Dh % 8 == 0 and Dv % 8 == 0
            and Sq >= 8 and Skv >= 8)


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, block_q: int = 512,
                    block_k: int = 512, interpret: bool = False):
    """q: [B,Sq,H,Dh]; k: [B,Skv,Hkv,Dh]; v: [B,Skv,Hkv,Dv]."""
    B, Sq, H, Dh = q.shape
    _, Skv, Hkv, Dv = v.shape
    rep = H // Hkv
    scale = Dh ** -0.5
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)

    qt = q.transpose(0, 2, 1, 3)   # [B, H, S, D]
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    pad_q = (-Sq) % block_q
    pad_k = (-Skv) % block_k
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = (Sq + pad_q) // block_q
    nk = (Skv + pad_k) // block_k

    kernel = functools.partial(
        _fwd_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, window=window, q_offset=q_offset, nk=nk,
        kv_len=Skv)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, Dh),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, Dh),
                         lambda b, h, i, j: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, block_k, Dv),
                         lambda b, h, i, j: (b, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dv),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq + pad_q, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out[:, :, :Sq].transpose(0, 2, 1, 3)
