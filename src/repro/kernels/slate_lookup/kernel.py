"""Muppet read-path hot loop — batched slate point-lookup.

One kernel invocation answers a [Q] vector of point reads against the
open-addressing slate table: per query, walk the (precomputed) probe
chain until the key matches, then DMA that slate row out of HBM — the
same row-at-a-time access pattern the write kernel's scatter uses, in
reverse.  The probe *candidates* are computed outside the kernel with
the table's own double-hash sequence, so the hash math exists in
exactly one place and the kernel is pure pointer-chasing: SMEM holds
the small int vectors (queries, candidate slots, results), the table
stays in HBM (``ANY``) and only hit rows cross into registers.

Serving shape, not throughput shape: Q is a request batch (<= ~2K),
so the whole walk is a scalar loop — the win over the host path is
collapsing Q round-trips into one dispatch, not FLOPs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

MAX_Q = 2048      # SMEM budget for the per-query scalar vectors


def _lookup_kernel(query_ref, cand_ref, tkeys_ref, vals_ref,
                   slot_ref, found_ref, rows_ref, *, P: int, Q: int,
                   D: int):
    def body(qi, _):
        def probe(p, carry):
            slot, found = carry
            c = cand_ref[p, qi]
            k = pl.load(tkeys_ref, (pl.dslice(c, 1),))[0]
            hit = k == query_ref[qi]
            # first hit wins (matches table.lookup's first_true)
            slot = jnp.where(hit & ~found, c, slot)
            return slot, found | hit

        slot, found = jax.lax.fori_loop(
            0, P, probe, (jnp.int32(-1), jnp.bool_(False)))
        slot_ref[qi] = slot
        found_ref[qi] = found.astype(jnp.int32)

        @pl.when(found)
        def _():
            row = pl.load(vals_ref, (pl.dslice(slot, 1), slice(None)))
            pl.store(rows_ref, (pl.dslice(qi, 1), slice(None)), row)

        @pl.when(~found)
        def _():
            pl.store(rows_ref, (pl.dslice(qi, 1), slice(None)),
                     jnp.zeros((1, D), vals_ref.dtype))

        return 0

    jax.lax.fori_loop(0, Q, body, 0)


def _lookup_kernel_wide(qlo_ref, qhi_ref, cand_ref, klo_ref, khi_ref,
                        vals_ref, slot_ref, found_ref, rows_ref, *,
                        P: int, Q: int, D: int):
    """64-bit-key variant: TPU SMEM scalars are 32-bit, so wide keys
    arrive pre-split into (lo, hi) int32 planes and a hit is equality
    on both planes — bit-exact int64 comparison without int64 in the
    kernel."""
    def body(qi, _):
        def probe(p, carry):
            slot, found = carry
            c = cand_ref[p, qi]
            klo = pl.load(klo_ref, (pl.dslice(c, 1),))[0]
            khi = pl.load(khi_ref, (pl.dslice(c, 1),))[0]
            hit = (klo == qlo_ref[qi]) & (khi == qhi_ref[qi])
            # first hit wins (matches table.lookup's first_true)
            slot = jnp.where(hit & ~found, c, slot)
            return slot, found | hit

        slot, found = jax.lax.fori_loop(
            0, P, probe, (jnp.int32(-1), jnp.bool_(False)))
        slot_ref[qi] = slot
        found_ref[qi] = found.astype(jnp.int32)

        @pl.when(found)
        def _():
            row = pl.load(vals_ref, (pl.dslice(slot, 1), slice(None)))
            pl.store(rows_ref, (pl.dslice(qi, 1), slice(None)), row)

        @pl.when(~found)
        def _():
            pl.store(rows_ref, (pl.dslice(qi, 1), slice(None)),
                     jnp.zeros((1, D), vals_ref.dtype))

        return 0

    jax.lax.fori_loop(0, Q, body, 0)


def supported(table_vals, query) -> bool:
    return (table_vals.ndim == 2 and table_vals.shape[1] % 8 == 0
            and query.shape[0] <= MAX_Q)


def _split_planes(a):
    """Integer [N] -> (lo, hi) int32 bit planes (exact for 64-bit)."""
    u = a.astype(jnp.uint64)
    lo = u.astype(jnp.uint32).astype(jnp.int32)
    hi = (u >> jnp.uint64(32)).astype(jnp.uint32).astype(jnp.int32)
    return lo, hi


@functools.partial(jax.jit, static_argnames=("interpret",))
def slate_lookup(table_keys, query, cand, table_vals, *,
                 interpret: bool = False):
    """``table_keys``: int32 [C]; ``query``: int32 [Q]; ``cand``:
    int32 [P, Q] probe candidates (``table._probe_seq``); ``table_vals``:
    [C, D].  Returns ``(slot [Q], found [Q] bool, rows [Q, D])`` with
    rows of missing keys zeroed."""
    Q = query.shape[0]
    P = cand.shape[0]
    D = table_vals.shape[1]
    kernel = functools.partial(_lookup_kernel, P=P, Q=Q, D=D)
    slot, found, rows = pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),       # query
            pl.BlockSpec(memory_space=pltpu.SMEM),       # cand
            pl.BlockSpec(memory_space=pltpu.ANY),        # table keys
            pl.BlockSpec(memory_space=pltpu.ANY),        # table vals
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q,), jnp.int32),
            jax.ShapeDtypeStruct((Q,), jnp.int32),
            jax.ShapeDtypeStruct((Q, D), table_vals.dtype),
        ],
        interpret=interpret,
    )(query.astype(jnp.int32), cand.astype(jnp.int32), table_keys,
      table_vals)
    return slot, found.astype(bool), rows


@functools.partial(jax.jit, static_argnames=("interpret",))
def slate_lookup_wide(table_keys, query, cand, table_vals, *,
                      interpret: bool = False):
    """64-bit-key entry: like :func:`slate_lookup` but ``table_keys`` /
    ``query`` are int64, compared inside the kernel as (lo, hi) int32
    bit planes."""
    Q = query.shape[0]
    P = cand.shape[0]
    D = table_vals.shape[1]
    qlo, qhi = _split_planes(query)
    klo, khi = _split_planes(table_keys)
    kernel = functools.partial(_lookup_kernel_wide, P=P, Q=Q, D=D)
    slot, found, rows = pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),       # query lo
            pl.BlockSpec(memory_space=pltpu.SMEM),       # query hi
            pl.BlockSpec(memory_space=pltpu.SMEM),       # cand
            pl.BlockSpec(memory_space=pltpu.ANY),        # table keys lo
            pl.BlockSpec(memory_space=pltpu.ANY),        # table keys hi
            pl.BlockSpec(memory_space=pltpu.ANY),        # table vals
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q,), jnp.int32),
            jax.ShapeDtypeStruct((Q,), jnp.int32),
            jax.ShapeDtypeStruct((Q, D), table_vals.dtype),
        ],
        interpret=interpret,
    )(qlo, qhi, cand.astype(jnp.int32), klo, khi, table_vals)
    return slot, found.astype(bool), rows
