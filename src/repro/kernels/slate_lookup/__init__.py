from repro.kernels.slate_lookup.ops import lookup_slots, slate_lookup

__all__ = ["slate_lookup", "lookup_slots"]
