"""Pure-jnp oracle for the batched slate point-lookup.

The read-side twin of ``slate_update``'s oracle: walk the probe chain
of every query key over the open-addressing table and gather the hit
rows.  The probe math is imported from ``slates.table`` — the lookup
contract is *bitwise* agreement with the looped host ``read_slate``
(which goes through ``table.lookup``), so there is exactly one copy of
the double-hashing sequence in the tree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.slates.table import _probe_seq


def lookup_slots(table_keys, query):
    """``table_keys``: int32 [C] (EMPTY = -1 = free); ``query``: int32
    [Q].  Returns ``(slot [Q], found [Q])`` — the first probe position
    holding the key, or -1.  Unlike ``table.lookup`` this never
    reports an insertion point: a read has no use for one, and -1
    keeps the downstream gather's clip branch-free.  ``found`` is
    bitwise ``table.lookup``'s (all PROBES positions are checked, so
    rows parked behind TTL holes stay visible)."""
    cand = _probe_seq(query, int(table_keys.shape[0]))     # [P, Q]
    hit = table_keys[cand] == query[None]
    found = jnp.any(hit, axis=0)
    idx = jnp.argmax(hit, axis=0)
    slot = jnp.where(found,
                     jnp.take_along_axis(cand, idx[None], axis=0)[0],
                     jnp.int32(-1))
    return slot, found


def gather_rows(vals, slot, found):
    """Gather one pytree of [C, ...] value leaves at ``slot`` ([Q]);
    missing keys ([Q] ``~found``) read as zeros."""
    safe = jnp.clip(slot, 0, None)

    def pick(v):
        rows = v[safe]
        mask = found.reshape(found.shape + (1,) * (rows.ndim - 1))
        return jnp.where(mask, rows, jnp.zeros_like(rows))

    return jax.tree.map(pick, vals)


def slate_lookup(table_keys, query, table_vals):
    """Fused oracle: probe walk + row gather.  ``table_vals``: [C, D].
    Returns ``(slot [Q], found [Q], rows [Q, D])``."""
    slot, found = lookup_slots(table_keys, query)
    return slot, found, gather_rows(table_vals, slot, found)
