"""Dispatching wrapper for the batched slate point-lookup.

``impl``:
  - "auto":      Pallas on TPU, jnp oracle elsewhere
  - "pallas":    force the kernel (falls back to the oracle if the
                 value layout is unsupported)
  - "interpret": Pallas body in interpreter mode (CPU-testable)
  - "jnp" / "ref": the pure-jnp probe-walk oracle
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.slate_lookup import ref as _ref


def lookup_slots(table_keys, query):
    """Probe-walk only: ``(slot [Q], found [Q])``.  Always the jnp
    oracle — the walk is a [P, Q] gather-compare, already one fused
    XLA op; the kernel earns its keep on the row gather."""
    return _ref.lookup_slots(table_keys, query)


def slate_lookup(table_keys, query, table_vals, *, impl: str = "auto"):
    """Fused probe walk + row gather over one [C, D] value matrix.
    Returns ``(slot [Q], found [Q], rows [Q, D])`` with missing rows
    zeroed; bitwise identical across every backend."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if impl in ("pallas", "interpret"):
        from repro.kernels.slate_lookup import kernel as _k
        if _k.supported(table_vals, query):
            from repro.slates.table import _probe_seq
            cand = _probe_seq(query, int(table_keys.shape[0]))
            # 64-bit keys enter the plane-split variant (SMEM scalars
            # are 32-bit); same probe chain, bit-exact comparison
            if jnp.dtype(query.dtype).itemsize > 4:
                return _k.slate_lookup_wide(
                    table_keys, query, cand, table_vals,
                    interpret=(impl == "interpret"))
            return _k.slate_lookup(table_keys, query, cand, table_vals,
                                   interpret=(impl == "interpret"))
        impl = "jnp"
    if impl not in ("jnp", "ref"):
        raise ValueError(f"unknown slate_lookup impl {impl!r}")
    return _ref.slate_lookup(table_keys, query, table_vals)


def lookup_tree(table_keys, table_vals, query, *, impl: str = "auto"):
    """Batched lookup over a whole slate-value *pytree*: the kernel path
    engages when the tree is a single kernel-eligible [C, D] leaf,
    otherwise the probe walk runs once and each leaf is gathered with
    the jnp oracle (still one fused XLA program).  Returns
    ``(found [Q], rows)`` with ``rows`` leaves [Q, ...], missing keys
    zeroed — the shared core of ``Engine.read_slates`` and the
    distributed per-shard read."""
    leaves, treedef = jax.tree.flatten(table_vals)
    if (impl in ("auto", "pallas", "interpret") and len(leaves) == 1):
        from repro.kernels.slate_lookup import kernel as _k
        if _k.supported(leaves[0], query):
            _, found, rows = slate_lookup(table_keys, query, leaves[0],
                                          impl=impl)
            return found, jax.tree.unflatten(treedef, [rows])
    slot, found = lookup_slots(table_keys, query)
    return found, _ref.gather_rows(table_vals, slot, found)
