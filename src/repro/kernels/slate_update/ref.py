"""Pure-jnp oracle for the fused slate update."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def run_totals(keys_sorted, deltas):
    """[B] sorted keys + [B, D] deltas -> [B, D] f32 where every row
    holds its run's total (shared by the oracle below and the fused
    jnp backend in core/apply.py)."""
    seg_start = jnp.concatenate([
        jnp.ones((1,), bool), keys_sorted[1:] != keys_sorted[:-1]])
    seg_ids = jnp.cumsum(seg_start.astype(jnp.int32)) - 1
    totals = jax.ops.segment_sum(deltas.astype(jnp.float32), seg_ids,
                                 num_segments=keys_sorted.shape[0])
    return totals[seg_ids]


def slate_update(keys_sorted, deltas, slots, table_vals):
    """Segment totals of sorted (key, delta) runs added into
    table_vals[slot] for run-last rows (slot >= 0)."""
    totals = run_totals(keys_sorted, deltas)
    ok = slots >= 0
    safe = jnp.where(ok, slots, table_vals.shape[0])
    return table_vals.at[safe].add(
        jnp.where(ok[:, None], totals, 0.0).astype(table_vals.dtype),
        mode="drop")
