"""Pure-jnp oracle for the fused slate update."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def slate_update(keys_sorted, deltas, slots, table_vals):
    """Segment totals of sorted (key, delta) runs added into
    table_vals[slot] for run-last rows (slot >= 0)."""
    B = keys_sorted.shape[0]
    seg_start = jnp.concatenate([
        jnp.ones((1,), bool), keys_sorted[1:] != keys_sorted[:-1]])
    seg_ids = jnp.cumsum(seg_start.astype(jnp.int32)) - 1
    totals = jax.ops.segment_sum(deltas.astype(jnp.float32), seg_ids,
                                 num_segments=B)
    run_totals = totals[seg_ids]                        # total at every row
    ok = slots >= 0
    safe = jnp.where(ok, slots, table_vals.shape[0])
    return table_vals.at[safe].add(
        jnp.where(ok[:, None], run_totals, 0.0).astype(table_vals.dtype),
        mode="drop")
