"""Pure-jnp oracle for the fused slate update."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def run_totals(keys_sorted, deltas, *, op: str = "sum"):
    """[B] sorted keys + [B, D] deltas -> [B, D] f32 where every row
    holds its run's total (shared by the oracle below and the fused
    jnp backend in core/apply.py).  ``op`` picks the elementwise
    monoid: "sum" (segment sum) or "max" (segment max over the
    non-negative domain, so empty-segment fill never leaks)."""
    seg_start = jnp.concatenate([
        jnp.ones((1,), bool), keys_sorted[1:] != keys_sorted[:-1]])
    seg_ids = jnp.cumsum(seg_start.astype(jnp.int32)) - 1
    if op == "max":
        totals = jax.ops.segment_max(deltas.astype(jnp.float32), seg_ids,
                                     num_segments=keys_sorted.shape[0])
        totals = jnp.maximum(totals, 0.0)   # unused segments fill -inf
    elif op == "sum":
        totals = jax.ops.segment_sum(deltas.astype(jnp.float32), seg_ids,
                                     num_segments=keys_sorted.shape[0])
    else:
        raise ValueError(f"unknown run_totals op {op!r}")
    return totals[seg_ids]


def slate_update(keys_sorted, deltas, slots, table_vals, *,
                 op: str = "sum"):
    """Segment totals of sorted (key, delta) runs merged into
    table_vals[slot] for run-last rows (slot >= 0): added for op="sum",
    elementwise-maxed for op="max"."""
    totals = run_totals(keys_sorted, deltas, op=op)
    ok = slots >= 0
    safe = jnp.where(ok, slots, table_vals.shape[0])
    masked = jnp.where(ok[:, None], totals, 0.0).astype(table_vals.dtype)
    if op == "max":
        return table_vals.at[safe].max(masked, mode="drop")
    return table_vals.at[safe].add(masked, mode="drop")
