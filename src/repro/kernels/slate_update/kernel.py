"""Muppet updater hot loop — fused segment-combine + slate scatter.

One kernel invocation applies one microbatch of *sorted* (key, delta)
events to the slate table: a log-depth segmented prefix-sum combines every
key's deltas in VMEM, then run-last rows read-modify-write their slate row
in HBM (the innermost loop is a row-wise DMA scatter — the same access
pattern Cassandra-backed Muppet pays per updated slate, minus the network).
The table buffer is aliased in/out so the update is in-place.

Covers sum-mergeable (counter-style) associative updaters — the flagship
Muppet workload (Examples 1/2/4/5 are all counters).  General combine fns
keep the jnp path (core/apply.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _slate_kernel(keys_ref, deltas_ref, slots_ref, table_in_ref,
                  table_ref, *, B: int, steps: int, op: str):
    keys = keys_ref[...]                        # [B] sorted, sink=int32max
    vals = deltas_ref[...].astype(jnp.float32)  # [B, D]

    # segmented inclusive prefix combine (doubling): vals[i] accumulates
    # the run prefix ending at i.  For "max" the masked-out lanes inject
    # 0.0, the identity on the kernel's non-negative max domain.
    for d in range(steps):
        sh = 1 << d
        rolled = pltpu.roll(vals, sh, 0)
        same = keys == pltpu.roll(keys, sh, 0)
        idx = jax.lax.broadcasted_iota(jnp.int32, (B,), 0)
        ok = (idx >= sh) & same
        contrib = jnp.where(ok[:, None], rolled, 0.0)
        vals = jnp.maximum(vals, contrib) if op == "max" \
            else vals + contrib

    # scatter run totals into slate rows (read-modify-write)
    # slice indices must share one dtype with the literal starts the
    # slice(None) dims produce — the canonical int: int32 on TPU, int64
    # when interpret runs under x64
    idt = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32

    def body(i, _):
        i = jnp.asarray(i, idt)
        slot = jnp.asarray(slots_ref[i], idt)

        @pl.when(slot >= 0)
        def _():
            row = pl.load(table_ref, (pl.dslice(slot, 1), slice(None)))
            total = jax.lax.dynamic_slice_in_dim(vals, i, 1, 0)
            total = total.astype(table_ref.dtype)
            merged = jnp.maximum(row, total) if op == "max" \
                else row + total
            pl.store(table_ref, (pl.dslice(slot, 1), slice(None)),
                     merged)
        return 0

    jax.lax.fori_loop(0, B, body, 0)


def supported(deltas) -> bool:
    return deltas.ndim == 2 and deltas.shape[1] % 8 == 0


@functools.partial(jax.jit, static_argnames=("interpret", "op"))
def slate_update(keys_sorted, deltas, slots, table_vals, *,
                 interpret: bool = False, op: str = "sum"):
    """keys_sorted: [B] int32 (invalid rows = int32.max, sorted);
    deltas: [B, D]; slots: [B] int32 (slate row for run-LAST rows, -1
    elsewhere); table_vals: [C, D].  ``op`` is the elementwise combine
    monoid: "sum" or "max" (non-negative domain — 0 is the identity
    injected for masked lanes).  Returns updated table_vals."""
    if op not in ("sum", "max"):
        raise ValueError(f"unknown slate_update op {op!r}")
    B, D = deltas.shape
    steps = max((B - 1).bit_length(), 1)
    kernel = functools.partial(_slate_kernel, B=B, steps=steps, op=op)
    return pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),          # keys
            pl.BlockSpec((B, D), lambda: (0, 0)),           # deltas
            pl.BlockSpec(memory_space=pltpu.SMEM),          # slots
            pl.BlockSpec(memory_space=pltpu.ANY),           # table (alias)
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=jax.ShapeDtypeStruct(table_vals.shape, table_vals.dtype),
        input_output_aliases={3: 0},
        interpret=interpret,
    )(keys_sorted.astype(jnp.int32), deltas, slots.astype(jnp.int32),
      table_vals)
