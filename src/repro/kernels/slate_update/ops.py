"""Dispatching wrapper for the fused slate update.

``impl``:
  - "auto":      Pallas on TPU, jnp oracle elsewhere
  - "pallas":    force the kernel (falls back to ref if unsupported)
  - "interpret": Pallas body in interpreter mode (CPU-testable)
  - "ref":       pure-jnp segment-sum oracle
"""
from __future__ import annotations

import jax

from repro.kernels.slate_update import ref as _ref


def slate_update(keys_sorted, deltas, slots, table_vals, *,
                 impl: str = "auto", op: str = "sum"):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl in ("pallas", "interpret"):
        from repro.kernels.slate_update import kernel as _k
        if _k.supported(deltas):
            return _k.slate_update(keys_sorted, deltas, slots, table_vals,
                                   interpret=(impl == "interpret"), op=op)
        impl = "ref"
    if impl != "ref":
        raise ValueError(f"unknown slate_update impl {impl!r}")
    return _ref.slate_update(keys_sorted, deltas, slots, table_vals, op=op)
