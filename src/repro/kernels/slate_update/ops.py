"""Dispatching wrapper for the fused slate update.

``impl``:
  - "auto":      Pallas on TPU, jnp oracle elsewhere
  - "pallas":    force the kernel (falls back to ref if unsupported)
  - "interpret": Pallas body in interpreter mode (CPU-testable)
  - "ref":       pure-jnp segment-sum oracle
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.slate_update import ref as _ref


def _segment_ids(keys_sorted):
    """Map sorted wide keys to int32 segment ids.  The kernel consumes
    keys only through adjacent-equality (run boundaries), which segment
    ids over a sorted vector preserve exactly — so int64 keys ride the
    int32 kernel losslessly."""
    boundary = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32),
         (keys_sorted[1:] != keys_sorted[:-1]).astype(jnp.int32)])
    return jnp.cumsum(boundary)


def slate_update(keys_sorted, deltas, slots, table_vals, *,
                 impl: str = "auto", op: str = "sum"):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl in ("pallas", "interpret"):
        from repro.kernels.slate_update import kernel as _k
        if _k.supported(deltas):
            ks = keys_sorted
            if jnp.dtype(ks.dtype).itemsize > 4:
                ks = _segment_ids(ks)
            return _k.slate_update(ks, deltas, slots, table_vals,
                                   interpret=(impl == "interpret"), op=op)
        impl = "ref"
    if impl != "ref":
        raise ValueError(f"unknown slate_update impl {impl!r}")
    return _ref.slate_update(keys_sorted, deltas, slots, table_vals, op=op)
