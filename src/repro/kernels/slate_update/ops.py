"""Dispatching wrapper for the fused slate update."""
from __future__ import annotations

import jax

from repro.kernels.slate_update import ref as _ref


def slate_update(keys_sorted, deltas, slots, table_vals, *,
                 impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        from repro.kernels.slate_update import kernel as _k
        if _k.supported(deltas):
            return _k.slate_update(keys_sorted, deltas, slots, table_vals)
        impl = "ref"
    return _ref.slate_update(keys_sorted, deltas, slots, table_vals)
