"""Dispatching wrapper for fused RMSNorm."""
from __future__ import annotations

import jax

from repro.kernels.rmsnorm import ref as _ref


def rmsnorm(x, w, *, eps: float = 1e-6, scale_offset: bool = False,
            impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        from repro.kernels.rmsnorm import kernel as _k
        if _k.supported(x):
            return _k.rmsnorm(x, w, eps=eps, scale_offset=scale_offset)
        impl = "ref"
    return _ref.rmsnorm(x, w, eps=eps, scale_offset=scale_offset)
