"""Fused RMSNorm — Pallas TPU kernel (memory-bound: one HBM pass)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rms_kernel(x_ref, w_ref, o_ref, *, eps: float, scale_offset: bool):
    x = x_ref[...].astype(jnp.float32)            # [rows, D]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    w = w_ref[...].astype(jnp.float32)
    if scale_offset:
        w = 1.0 + w
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w).astype(o_ref.dtype)


def supported(x) -> bool:
    return x.shape[-1] % 8 == 0


@functools.partial(jax.jit, static_argnames=("eps", "scale_offset",
                                             "block_rows", "interpret"))
def rmsnorm(x, w, *, eps: float = 1e-6, scale_offset: bool = False,
            block_rows: int = 256, interpret: bool = False):
    shape = x.shape
    D = shape[-1]
    rows = 1
    for d in shape[:-1]:
        rows *= d
    x2 = x.reshape(rows, D)
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    nb = (rows + pad) // block_rows
    kernel = functools.partial(_rms_kernel, eps=eps,
                               scale_offset=scale_offset)
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, w)
    return out[:rows].reshape(shape)
