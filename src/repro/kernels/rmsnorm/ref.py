"""Pure-jnp oracle for fused RMSNorm (same math as models.layers.norms)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm(x, w, *, eps: float = 1e-6, scale_offset: bool = False):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    wf = w.astype(jnp.float32)
    if scale_offset:
        wf = 1.0 + wf
    return (xf * jax.lax.rsqrt(var + eps) * wf).astype(x.dtype)
