from repro.kernels.countmin.ops import countmin_update

__all__ = ["countmin_update"]
