"""Pure-jnp oracle for the count-min sketch update.

All backends are exact integer scatter-adds, so they agree bitwise —
the sketch is telemetry, but a nondeterministic one would break the
"telemetry on vs off" parity contract (DESIGN.md section 13).
"""
from __future__ import annotations

import jax.numpy as jnp


def countmin_update(counts, cols, add):
    """counts: [depth, width] int32; cols: [depth, B] int32 hashed
    column per row; add: [B] int32 increment per event (0 for invalid
    rows).  Returns counts with every (row, col) bumped by its event's
    increment — duplicate columns accumulate.

    One flat 1D scatter over the ravelled sketch: measurably cheaper
    than the 2D advanced-index form on CPU, and the scatter is the
    whole cost of the jnp backend."""
    depth, width = counts.shape
    flat = (cols
            + (jnp.arange(depth, dtype=jnp.int32) * width)[:, None])
    amt = jnp.broadcast_to(add.astype(counts.dtype)[None, :], cols.shape)
    return counts.ravel().at[flat.ravel()].add(
        amt.ravel()).reshape(depth, width)
