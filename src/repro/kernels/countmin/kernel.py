"""Count-min sketch update — the telemetry hot-path kernel.

One invocation folds a microbatch of hashed event keys into the
[depth, width] sketch held in VMEM: for each hash row the batch's
columns are expanded to a [B, width] one-hot mask and reduced over B —
a VPU-friendly histogram (no scalar scatter in the inner loop, unlike
the slate kernel whose rows are too wide to one-hot).  The sketch is
aliased in/out so the update is in-place; column hashing stays outside
the kernel (plain jnp on the already-resident keys), mirroring how
``slate_update`` receives pre-computed slots.

Everything inside the kernel is rank-2 (TPU-native layouts): columns
arrive transposed as [B, depth] so each row's slice is a natural
[B, 1] block, and masked-out events are folded into a sink column
(``width``, which no iota lane matches) before the call — the kernel
itself carries no validity plumbing.

depth is small (2-8) and width a multiple of 128 (lane-aligned), so
the whole sketch is ~16 KB — it lives in VMEM for the duration of the
call and costs the tick no HBM traffic beyond the aliased buffer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cm_kernel(cols_ref, counts_in_ref, counts_ref, *,
               depth: int, B: int, width: int):
    for d in range(depth):                      # static, small
        cols = cols_ref[:, d:d + 1]             # [B, 1]
        iota = jax.lax.broadcasted_iota(jnp.int32, (B, width), 1)
        hit = (iota == cols).astype(jnp.int32)  # sink column never hits
        counts_ref[d:d + 1, :] = counts_ref[d:d + 1, :] + \
            jnp.sum(hit, axis=0, keepdims=True)


def supported(counts, cols) -> bool:
    return (counts.ndim == 2 and cols.ndim == 2
            and counts.shape[1] % 128 == 0
            and cols.shape[0] == counts.shape[0])


@functools.partial(jax.jit, static_argnames=("interpret",))
def countmin_update(counts, cols, add, *, interpret: bool = False):
    """counts: [depth, width] int32 (aliased in/out); cols: [depth, B]
    int32 hashed columns; add: [B] int32 0/1 increment per event.
    Returns the updated sketch."""
    depth, width = counts.shape
    B = cols.shape[1]
    # fold the increment mask into a sink column and transpose to
    # [B, depth] so the kernel stays rank-2 throughout
    cols_t = jnp.where(add[None, :] > 0, cols,
                       jnp.int32(width)).T.astype(jnp.int32)
    kernel = functools.partial(_cm_kernel, depth=depth, B=B, width=width)
    return pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec((B, depth), lambda: (0, 0)),      # cols (T)
            pl.BlockSpec((depth, width), lambda: (0, 0)),  # sketch alias
        ],
        out_specs=pl.BlockSpec((depth, width), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct(counts.shape, counts.dtype),
        input_output_aliases={1: 0},
        interpret=interpret,
    )(cols_t, counts)
