"""Dispatching wrapper for decode attention (flash-decoding on TPU)."""
from __future__ import annotations

import jax

from repro.kernels.decode_attention import ref as _ref


def decode_attend(q, k_cache, v_cache, lengths, *, window: int = 0,
                  impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        from repro.kernels.decode_attention import kernel as _k
        if _k.supported(q, k_cache, v_cache):
            return _k.decode_attention(q, k_cache, v_cache, lengths,
                                       window=window)
        impl = "ref"
    return _ref.decode_attend(q, k_cache, v_cache, lengths, window=window)
