"""Pure-jnp oracle for single-token (decode) attention over a KV cache.

q attends over a fixed-capacity cache with per-request valid lengths —
the Muppet serving layer stores these caches as slates keyed by request.

The cache is consumed in its storage dtype (accumulation forced to f32
via ``preferred_element_type``) — casting a multi-GB cache to f32 would
double decode HBM traffic, which is exactly what the Pallas kernel
avoids by streaming bf16 tiles.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _rep(x, rep):
    if rep == 1:
        return x
    b, s, h, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, h, rep, d)
                            ).reshape(b, s, h * rep, d)


@partial(jax.jit, static_argnames=("window",))
def decode_attend(q, k_cache, v_cache, lengths, *, window: int = 0):
    """q: [B,Sq,H,Dh] (Sq small); caches: [B,S,Hkv,D*];
    lengths: [B] number of valid cache entries (the new token's k/v must
    already be written at position lengths-1).  Returns [B,Sq,H,Dv].
    """
    B, Sq, H, Dh = q.shape
    _, S, Hkv, Dv = v_cache.shape
    rep = H // Hkv
    scale = Dh ** -0.5

    s = jnp.einsum("bqhd,bkhd->bhqk", q, _rep(k_cache, rep),
                   preferred_element_type=jnp.float32) * scale
    cols = jnp.arange(S)[None, None, None, :]
    valid = cols < lengths[:, None, None, None]
    if window:
        valid &= cols >= lengths[:, None, None, None] - window
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v_cache.dtype),
                     _rep(v_cache, rep),
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)
