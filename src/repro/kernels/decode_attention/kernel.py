"""Flash-decoding (split-K) — Pallas TPU kernel.

Decode reads an [S, Dh] KV cache per head to produce one token: pure HBM
bandwidth.  The grid splits the cache into block_k tiles (innermost,
sequential) with the online-softmax running (m, l, acc) in VMEM scratch;
per-request valid lengths live in SMEM.  Blocks beyond a request's length
(or outside its sliding window) are skipped entirely, so short requests
in a continuous batch don't pay for the longest one — the serving engine
relies on this for mixed-age slates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale: float, block_k: int, window: int,
                   nk: int):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    length = len_ref[b]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = ki * block_k
    needed = k_start < length
    if window:
        needed &= (k_start + block_k) > length - window

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # [sq, d]
        k = k_ref[0, 0].astype(jnp.float32)               # [bk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
        cols = k_start + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        mask = cols < length
        if window:
            mask &= cols >= length - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * corr[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        l_scr[...] = l_prev * corr + p.sum(axis=1)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def supported(q, k_cache, v_cache) -> bool:
    B, Sq, H, Dh = q.shape
    _, S, Hkv, Dv = v_cache.shape
    return H % Hkv == 0 and Dh % 8 == 0 and Dv % 8 == 0 and S >= 8


@functools.partial(jax.jit, static_argnames=("window", "block_k",
                                             "interpret"))
def decode_attention(q, k_cache, v_cache, lengths, *, window: int = 0,
                     block_k: int = 1024, interpret: bool = False):
    """q: [B,Sq,H,Dh] (Sq small); caches: [B,S,Hkv,D*]; lengths: [B]."""
    B, Sq, H, Dh = q.shape
    _, S, Hkv, Dv = v_cache.shape
    rep = H // Hkv
    scale = Dh ** -0.5
    block_k = min(block_k, S)

    qt = q.transpose(0, 2, 1, 3)
    kt = k_cache.transpose(0, 2, 1, 3)
    vt = v_cache.transpose(0, 2, 1, 3)
    pad_k = (-S) % block_k
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nk = (S + pad_k) // block_k

    kernel = functools.partial(_decode_kernel, scale=scale,
                               block_k=block_k, window=window, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # lengths
            pl.BlockSpec((1, 1, Sq, Dh), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, Dh),
                         lambda b, h, j: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, block_k, Dv),
                         lambda b, h, j: (b, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Sq, Dv), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Sq,), jnp.float32),
            pltpu.VMEM((Sq,), jnp.float32),
            pltpu.VMEM((Sq, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
