"""Pallas TPU kernels.

Layout: ``kernels/<name>/{kernel.py, ops.py, ref.py}``
  - ``kernel.py``  pl.pallas_call + BlockSpec VMEM tiling (TPU target)
  - ``ops.py``     jit'd dispatching wrapper (ref on CPU, pallas on TPU)
  - ``ref.py``     pure-jnp oracle (also the GSPMD/dry-run path)

Hot spots covered (see DESIGN.md section 6): flash_attention (train/
prefill), decode_attention (split-K flash decoding), ssd_scan (Mamba-2 /
mLSTM chunked linear recurrence), slate_update (the Muppet updater hot
loop: fused segment-combine + open-addressing table scatter), rmsnorm.
"""
