"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.models.config import ModelConfig, MoEConfig, MLAConfig, SSMConfig, XLSTMConfig

from repro.configs import (deepseek_moe_16b, deepseek_v2_lite_16b, gemma3_1b,
                           gemma_7b, llama_3_2_vision_11b, qwen1_5_110b,
                           qwen2_0_5b, whisper_tiny, xlstm_350m, zamba2_1_2b)

_MODULES = (
    llama_3_2_vision_11b, qwen2_0_5b, qwen1_5_110b, gemma3_1b, gemma_7b,
    deepseek_moe_16b, deepseek_v2_lite_16b, zamba2_1_2b, whisper_tiny,
    xlstm_350m,
)

ARCHS: Dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}
REDUCED: Dict[str, ModelConfig] = {m.CONFIG.name: m.REDUCED for m in _MODULES}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def reduced_config(name: str) -> ModelConfig:
    """Smoke-test scale config of the same family (CPU-runnable)."""
    if name not in REDUCED:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REDUCED)}")
    return REDUCED[name]
