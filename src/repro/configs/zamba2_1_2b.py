"""zamba2-1.2b [hybrid] — 38 Mamba-2 layers d_model=2048, ssm_state=64,
plus one weight-SHARED attention block (32H kv=32, d_ff=8192) applied
every 6th layer [arXiv:2411.15242]."""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,                # the shared attention block's MLP
    vocab_size=32000,
    tie_embeddings=True,
    shared_attn_every=6,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, d_conv=4, chunk=256),
)

REDUCED = CONFIG.replace(
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=512, shared_attn_every=3,
    ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, d_conv=4, chunk=32),
)
