"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (kv=16) vocab=102400;
fine-grained MoE: 2 shared + 64 routed experts, top-6, expert hidden 1408
(the spec's ``d_ff``); the single leading dense layer uses the paper's
10944 FFN [arXiv:2401.06066]."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,               # dense layer 0 only; experts use moe.d_expert
    vocab_size=102400,
    tie_embeddings=False,
    moe=MoEConfig(
        n_routed_experts=64,
        n_shared_experts=2,
        top_k=6,
        d_expert=1408,
        n_dense_layers=1,
    ),
)

REDUCED = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160,
    vocab_size=512,
    moe=MoEConfig(n_routed_experts=8, n_shared_experts=1, top_k=2,
                  d_expert=32, n_dense_layers=1,
                  capacity_factor=4.0),  # drop-free at smoke scale
)
