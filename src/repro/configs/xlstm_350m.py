"""xlstm-350m [ssm] — 24L d_model=1024 4H d_ff=0 vocab=50304; alternating
mLSTM (matrix memory, chunked-parallel) and sLSTM (scalar memory,
recurrent) blocks [arXiv:2405.04517]."""
from repro.models.config import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                   # blocks carry their own projections
    vocab_size=50304,
    tie_embeddings=True,
    xlstm=XLSTMConfig(mlstm_expand=2, slstm_proj=4.0 / 3.0, conv_width=4,
                      chunk=256),
)

REDUCED = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=4, vocab_size=512,
    xlstm=XLSTMConfig(mlstm_expand=2, slstm_proj=4.0 / 3.0, conv_width=4,
                      chunk=16),
)
