"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H vocab=102400;
MLA (kv_lora=512, rope 64 / nope 128 / v 128), 2 shared + 64 routed
top-6 experts (machine-readable spec field; see DESIGN.md section 9 on the
"160" comment discrepancy) [arXiv:2405.04434]."""
from repro.models.config import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,               # dense layer 0 only
    vocab_size=102400,
    tie_embeddings=False,
    moe=MoEConfig(
        n_routed_experts=64,
        n_shared_experts=2,
        top_k=6,
        d_expert=1408,
        n_dense_layers=1,
    ),
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=0,
        rope_head_dim=64,
        nope_head_dim=128,
        v_head_dim=128,
    ),
)

REDUCED = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160,
    vocab_size=512,
    moe=MoEConfig(n_routed_experts=8, n_shared_experts=1, top_k=2,
                  d_expert=32, n_dense_layers=1,
                  capacity_factor=4.0),  # drop-free at smoke scale
    mla=MLAConfig(kv_lora_rank=32, q_lora_rank=0, rope_head_dim=8,
                  nope_head_dim=16, v_head_dim=16),
)
