"""whisper-tiny [audio] — enc-dec, 4+4L d_model=384 6H d_ff=1536
vocab=51865; conv frontend is a STUB (``input_specs`` provides
precomputed frame embeddings) [arXiv:2212.04356]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    act="gelu",
    tie_embeddings=True,
    encdec=True,
    n_enc_layers=4,
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=512, n_enc_layers=2,
)
