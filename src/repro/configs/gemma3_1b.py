"""gemma3-1b [dense] — 26L d_model=1152 4H (GQA kv=1) d_ff=6912
vocab=262144; 5:1 local:global attention, 512-token window, dual rope
theta (1M global / 10k local) [hf:google/gemma-3-1b-pt]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_ff=6912,
    vocab_size=262144,
    head_dim=256,
    act="gelu",
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    sliding_window=512,
    global_every=6,
    embed_scale=True,
    norm_scale_offset=True,
    tie_embeddings=True,
)

REDUCED = CONFIG.replace(
    n_layers=7, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
    vocab_size=512, head_dim=16, sliding_window=8, global_every=3,
)
