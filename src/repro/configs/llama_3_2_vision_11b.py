"""llama-3.2-vision-11b [vlm] — 40L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=128256; cross-attn image layers every 5th layer
[hf:meta-llama/Llama-3.2-11B-Vision].  Vision frontend is a stub:
``input_specs`` provides precomputed patch embeddings."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500_000.0,
    tie_embeddings=False,
    cross_attn_every=5,
    n_image_tokens=1600,
)

REDUCED = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, cross_attn_every=2, n_image_tokens=16,
)
