"""Collective helpers: int8 error-feedback gradient compression.

Beyond-paper distributed-optimization trick for the DP axis: gradients
are quantized to int8 with per-block scales before the data-parallel
all-reduce (8x less ICI traffic on the dominant training collective);
the quantization error is carried in an *error-feedback* buffer and
added back next step, which keeps SGD/Adam convergence (Karimireddy et
al., 2019).  Exposed as a shard_map-based ``compressed_psum`` plus
pytree-level helpers used by ``launch/train.py --grad-compress``.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, BLOCK), pad


def quantize_int8(x) -> Tuple[jnp.ndarray, jnp.ndarray, int]:
    """x: any-shape f32 -> (int8 blocks [N,BLOCK], scales [N,1], pad)."""
    blocks, pad = _pad_to_block(x.astype(jnp.float32))
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, pad


def dequantize_int8(q, scale, pad, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def compress_decompress(x):
    """Round-trip quantization (what the wire sees); returns (xhat, err)."""
    q, s, pad = quantize_int8(x)
    xhat = dequantize_int8(q, s, pad, x.shape)
    return xhat, x - xhat


def compressed_psum_tree(grads, err_buf, axis_name: str):
    """Inside shard_map: per-leaf int8 quantize (+error feedback), psum
    the int32-accumulated quanta, dequantize.  Returns (grads, new_err).

    Traffic: int8 payload + f32 per-256 scales ~= 0.258x of f32.
    """
    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, s, pad = quantize_int8(g)
        ghat_local = dequantize_int8(q, s, pad, g.shape)
        err = g - ghat_local                       # error feedback carry
        # the wire carries (int8 q, f32 per-256 scales); summing the
        # per-shard dequantizations is exactly the all-reduce of those
        # payloads (gather-then-sum semantics of compressed all-reduce)
        ghat = _psum_dequant(q, s, pad, g.shape, axis_name)
        return ghat, err
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_buf)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in outs]),
            jax.tree.unflatten(tdef, [o[1] for o in outs]))


def _psum_dequant(q, s, pad, shape, axis_name):
    """Sum of per-shard dequantized blocks — mathematically the all-reduce
    of the compressed payloads (scales ride along, 1/256 overhead)."""
    return jax.lax.psum(dequantize_int8(q, s, pad, shape), axis_name)


def global_batch_psum(x, axis_name: str):
    return jax.lax.psum(x, axis_name)
