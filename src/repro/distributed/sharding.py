"""Logical-axis sharding rules -> mesh PartitionSpecs.

The model stack annotates params with logical tuples ("fsdp", "tp", None)
and activations via ``ctx.constrain(x, ("act_batch", None, "heads"))``.
This module translates those to the physical mesh with *divisibility-
adaptive* fallback: a dim is sharded over its rule's axes only when the
dim size divides the axis product (e.g. qwen2's 14 heads vs model=16 ->
replicated heads, FSDP still applies).  That keeps one rule-set valid
across all 10 archs x 4 shapes x 2 meshes.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def rules_for(mesh: Mesh, *, phase: str = "train",
              long_context: bool = False,
              fsdp_params: bool = True) -> Dict[str, Tuple[str, ...]]:
    """Sharding rules per phase.

    KV caches shard their *sequence* dim over "model" in serving phases:
    several archs have kv_heads < model-axis size (gemma3 kv=1, qwen kv=2/
    8), so head-sharding cannot spread the cache; sequence sharding always
    divides (32k/512k caches) and decode attention tolerates it (softmax
    partials combine with a psum — flash-decoding's split-K, done by
    GSPMD).  long_500k (batch=1) additionally spreads over the data axes.
    """
    names = mesh.axis_names
    fsdp = tuple(a for a in ("pod", "data") if a in names)
    tp = ("model",) if "model" in names else ()
    if long_context:
        kv_seq = fsdp + tp
    elif phase in ("prefill", "decode"):
        kv_seq = tp
    else:
        kv_seq = ()
    return {
        # params
        "fsdp": fsdp if fsdp_params else (),
        "tp": tp,
        # activations
        "act_batch": fsdp,
        # Megatron-style sequence parallelism: the residual stream (and
        # hence the remat-saved per-layer carry) is sharded over "model"
        # between blocks; GSPMD inserts the all-gather before qkv/ffn and
        # the reduce-scatter after the out-projection.
        "act_seq": tp if phase in ("train", "prefill") else (),
        "heads": tp,
        "kv_heads": tp,
        "ffn": tp,
        "vocab": tp,
        "experts": tp,
        "kv_seq": kv_seq,
    }


def _axis_prod(mesh: Mesh, axes: Sequence[str]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


# when several dims of one tensor map to the same mesh axis (e.g. a KV
# cache with both kv_heads and kv_seq -> "model"), the higher-priority
# logical name keeps it and the other dim replicates
_PRIORITY = ("kv_heads", "heads", "vocab", "ffn", "experts", "tp",
             "fsdp", "act_batch", "act_seq", "kv_seq")


def to_pspec(logical: Sequence[Optional[str]], shape: Sequence[int],
             mesh: Mesh, rules: Dict[str, Tuple[str, ...]]) -> P:
    order = sorted(range(len(logical)),
                   key=lambda i: _PRIORITY.index(logical[i])
                   if logical[i] in _PRIORITY else len(_PRIORITY))
    parts: list = [None] * len(logical)
    used: set = set()
    for i in order:
        name, dim = logical[i], shape[i]
        axes = rules.get(name, ()) if name else ()
        axes = tuple(a for a in axes if a not in used)
        if axes and dim % _axis_prod(mesh, axes) == 0:
            parts[i] = axes if len(axes) > 1 else axes[0]
            used.update(axes)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def tree_shardings(specs_tree, shapes_tree, mesh: Mesh, rules
                   ) -> Any:
    """specs_tree: logical tuples; shapes_tree: matching
    ShapeDtypeStructs/arrays -> tree of NamedSharding."""
    def one(spec, shaped):
        return NamedSharding(mesh, to_pspec(spec, shaped.shape, mesh,
                                            rules))
    return jax.tree.map(one, specs_tree, shapes_tree,
                        is_leaf=lambda s: isinstance(s, tuple) and
                        all(isinstance(e, (str, type(None))) for e in s))


def make_constrainer(mesh: Mesh, rules):
    """ctx.constrain implementation for model blocks."""
    def constrain(x, logical):
        spec = to_pspec(logical, x.shape, mesh, rules)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh,
                                                                 spec))
    return constrain


def batch_shardings(batch_tree, mesh: Mesh, rules) -> Any:
    """Shard every model input on its leading (batch) dim."""
    def one(x):
        if x.ndim == 0:
            return NamedSharding(mesh, P())
        spec = to_pspec(("act_batch",) + (None,) * (x.ndim - 1), x.shape,
                        mesh, rules)
        return NamedSharding(mesh, spec)
    return jax.tree.map(one, batch_tree)


def state_shardings(model, states_abstract, mesh: Mesh, rules):
    """Decode-state (KV cache / SSM state) shardings from the logical
    specs recorded by ``stack.init_states`` (leaves carry .logical)."""
    # states_abstract leaves are ShapeDtypeStruct with an attached
    # ``logical`` attribute (set by launch.input_specs machinery).
    def one(x):
        logical = getattr(x, "logical", None) or (None,) * x.ndim
        return NamedSharding(mesh, to_pspec(logical, x.shape, mesh, rules))
    return jax.tree.map(one, states_abstract)
