"""Async sharded checkpoint / restore with elastic resharding.

Layout:  <dir>/step_<N>/
            manifest.json        (step, leaf paths, shapes, dtypes)
            <leaf-path>.npy      (one file per pytree leaf)
            COMMIT               (written last -> atomic visibility)

- ``save`` snapshots to host then writes on a background thread (training
  never blocks on disk — the slate-store flush pattern again).
- ``restore`` rebuilds the pytree and ``jax.device_put``s each leaf with
  the *target* sharding: restoring to a different mesh shape (elastic
  scale-up/down, failed-chip exclusion) is just a different sharding
  argument.  The same host-rows -> target-sharding remap is the *host
  tier* of the live migration kernel in
  ``DistributedEngine._reconfigure`` (DESIGN.md sections 12/14), which
  applies it to slate tables and queues *mid-run* whenever physical
  shapes change (grow, slot compaction); shape-preserving reconfigures
  skip the host round trip entirely and move rows with an on-device
  ``all_to_all`` instead.  This module stays the offline / arbitrary-
  reshape tier of that hierarchy.
- ``latest_step`` only trusts committed checkpoints, so a crash mid-write
  rolls back to the previous step (restart-safety).
"""
from __future__ import annotations

import json
import os
import queue as pyqueue
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _leaf_paths(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = leaf
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: pyqueue.Queue = pyqueue.Queue()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        self.errors: list = []

    # ---- save ----
    def save(self, step: int, tree, *, blocking: bool = False):
        host = {k: np.asarray(jax.device_get(v))
                for k, v in _leaf_paths(tree).items()}
        self._q.put((step, host))
        if blocking:
            self.wait()

    def _loop(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            step, host = item
            try:
                self._write(step, host)
            except Exception as e:  # pragma: no cover
                self.errors.append(e)
            finally:
                self._q.task_done()

    def _write(self, step: int, host: Dict[str, np.ndarray]):
        d = os.path.join(self.dir, f"step_{step:010d}")
        tmp = d + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": {}}
        for key, arr in host.items():
            fn = key.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"][key] = {
                "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "COMMIT"), "w") as f:
            f.write("ok")
        if os.path.exists(d):
            shutil.rmtree(d)
        os.replace(tmp, d)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    def wait(self):
        self._q.join()

    def close(self):
        self.wait()
        self._q.put(None)
        self._thread.join(timeout=10)

    # ---- restore ----
    def all_steps(self):
        out = []
        for fn in sorted(os.listdir(self.dir)):
            if fn.startswith("step_") and not fn.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.dir, fn, "COMMIT")):
                out.append(int(fn[5:]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree, shardings=None):
        """target_tree: pytree of arrays/ShapeDtypeStructs giving the
        structure; shardings: optional matching pytree of NamedSharding
        (elastic restore to a new mesh)."""
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves = _leaf_paths(target_tree)
        shard_leaves = _leaf_paths(shardings) if shardings is not None \
            else {k: None for k in leaves}
        out = {}
        for key in leaves:
            meta = manifest["leaves"].get(key)
            if meta is None:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = np.load(os.path.join(d, meta["file"]))
            sh = shard_leaves.get(key)
            out[key] = jax.device_put(arr, sh) if sh is not None \
                else jax.numpy.asarray(arr)
        # rebuild tree in original structure
        flat = jax.tree_util.tree_flatten_with_path(target_tree)
        vals = []
        for path, _ in flat[0]:
            key = "/".join(_path_str(p) for p in path)
            vals.append(out[key])
        return jax.tree_util.tree_unflatten(flat[1], vals)
