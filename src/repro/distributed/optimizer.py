"""Hand-rolled AdamW with global-norm clipping.

ZeRO-1 falls out of the sharding: m/v inherit the parameters' FSDP x TP
shardings, so each chip holds only its shard of the optimizer state and
the update is entirely local (no optimizer collectives).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    m: Any
    v: Any
    count: jnp.ndarray


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100


def init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params),
                    count=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _schedule(cfg: AdamWConfig, count):
    warm = jnp.minimum(1.0, (count + 1) / max(cfg.warmup_steps, 1))
    return cfg.lr * warm


def update(params, grads, opt: OptState, cfg: AdamWConfig):
    """Returns (new_params, new_opt, metrics)."""
    count = opt.count + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-12))
    lr = _schedule(cfg, opt.count)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (step + cfg.weight_decay * pf)
        return pf.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt.m)
    flat_v = jax.tree.leaves(opt.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, OptState(m=new_m, v=new_v, count=count), {
        "grad_norm": gnorm, "lr": lr}
