"""Synthetic fast-data sources.

- ``ZipfEventSource``: tweet/checkin-like events with Zipfian keys — the
  skew regime of paper section 5 ("the distribution of event keys can be
  strongly skewed") used by the hotspot benchmarks.
- ``TokenStream``: an endless tokenized text stream for LM training
  (synthetic Markovian corpus: deterministic, seedable, non-trivial
  next-token structure so training loss visibly falls).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.core.event import EventBatch


@dataclass
class ZipfEventSource:
    n_keys: int = 10_000
    alpha: float = 1.2            # zipf exponent (1.0 = heavy skew)
    payload_dim: int = 8
    seed: int = 0
    events_per_tick: int = 256

    def __post_init__(self):
        self.rng = np.random.default_rng(self.seed)
        ranks = np.arange(1, self.n_keys + 1, dtype=np.float64)
        p = ranks ** (-self.alpha)
        self.p = p / p.sum()
        self._tick = 0

    def next_batch(self, max_events: Optional[int] = None) -> EventBatch:
        n = self.events_per_tick
        take = min(max_events, n) if max_events else n
        keys = self.rng.choice(self.n_keys, size=n, p=self.p
                               ).astype(np.int32)
        vals = self.rng.normal(size=(n, self.payload_dim)
                               ).astype(np.float32)
        valid = np.arange(n) < take
        ts = np.full(n, self._tick, np.int32)
        self._tick += 1
        return EventBatch.of(key=keys, value={"x": vals}, ts=ts,
                             valid=valid)


class TokenStream:
    """Markov-chain token stream: P(next | cur) concentrated on a few
    successors, so an LM can learn structure.  Infinite iterator of
    (tokens, labels) [B, S]."""

    def __init__(self, vocab_size: int, batch: int, seq_len: int, *,
                 seed: int = 0, branching: int = 4):
        self.vocab = vocab_size
        self.batch = batch
        self.seq = seq_len
        rng = np.random.default_rng(seed)
        self.succ = rng.integers(0, vocab_size,
                                 size=(vocab_size, branching)
                                 ).astype(np.int32)
        self.rng = rng

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        B, S = self.batch, self.seq
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = self.rng.integers(0, self.vocab, size=B)
        choices = self.rng.integers(0, self.succ.shape[1], size=(B, S))
        # 10% noise tokens break determinism
        noise = self.rng.random((B, S)) < 0.1
        rand_tok = self.rng.integers(0, self.vocab, size=(B, S))
        for t in range(S):
            nxt = self.succ[toks[:, t], choices[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand_tok[:, t], nxt)
        return {"tokens": toks[:, :-1],
                "labels": toks[:, 1:].astype(np.int32)}


class Prefetcher:
    """Host-side double-buffered prefetch with bounded skip-ahead: if the
    consumer falls behind (straggler host), up to ``max_skip`` batches are
    dropped instead of stalling the step loop."""

    def __init__(self, it: Iterator, depth: int = 2, max_skip: int = 0):
        import queue
        import threading
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._max_skip = max_skip
        self._stop = False

        def worker():
            for item in it:
                if self._stop:
                    return
                try:
                    self._q.put(item, timeout=5.0)
                except queue.Full:
                    if self._max_skip > 0:
                        self._max_skip -= 1
                        continue
                    self._q.put(item)

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop = True
