"""MapUpdate on jax/Pallas — a reproduction of "Muppet: MapReduce-Style
Processing of Fast Data" grown toward production scale.

Curated public surface: application authors should need nothing beyond
``from repro import App, RuntimeConfig, EventBatch, ops`` — the
declarative builder compiles to the engine layer below, which stays
importable (``repro.core.*``, ``repro.slates.*``) for engine work.
"""
from repro.api import App, PlanError, RuntimeConfig, Stream, ops
from repro.core.distributed import (AutoscalePolicy, DistConfig,
                                    DistributedEngine, MigrationReport)
from repro.core.engine import Engine, EngineConfig, StateHandle
from repro.core.event import EventBatch
from repro.core.operators import (AssociativeUpdater, Mapper, Operator,
                                  SequentialUpdater, Updater)
from repro.core.queues import OverflowPolicy
from repro.core.workflow import Workflow
from repro.slates.http import SlateServer
from repro.telemetry import (LoadAutoscaler, TelemetryConfig,
                             TelemetryReport)

__all__ = [
    # declarative app layer (the front door)
    "App", "RuntimeConfig", "Stream", "ops", "PlanError",
    # events & operators (shared by both API styles)
    "EventBatch", "Operator", "Mapper", "Updater", "AssociativeUpdater",
    "SequentialUpdater",
    # engine layer (explicit control when the builder is not enough)
    "Workflow", "Engine", "EngineConfig", "StateHandle", "OverflowPolicy",
    "SlateServer",
    # live elasticity (DESIGN.md section 12)
    "AutoscalePolicy", "DistributedEngine", "DistConfig",
    "MigrationReport",
    # telemetry + the closed control loop (DESIGN.md section 13)
    "LoadAutoscaler", "TelemetryConfig", "TelemetryReport",
    # streaming-ML subsystem (DESIGN.md section 16) — lazy, see below
    "ml",
]


def __getattr__(name):
    # repro.ml pulls in the model stack; load it on first touch so
    # counting/ranking apps keep the light import path
    if name == "ml":
        import repro.ml as ml
        return ml
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | {"ml"})
