"""Post-SPMD HLO cost walker.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
empirically), which under-counts scan-over-layers models by ~n_layers x.
This walker parses ``compiled.as_text()`` (post-partitioning, per-device
shapes, collectives materialized) and:

  - multiplies while bodies by their trip count — XLA records it as
    ``backend_config={"known_trip_count":{"n":"N"}}``;
  - counts matmul FLOPs from dot shapes + contracting dims (fusion
    internals included — dots can live inside fusions);
  - sums collective bytes by kind (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute);
  - approximates HBM traffic as operand+output bytes of fusion-BOUNDARY
    ops only (fusion internals never touch HBM).

All numbers are PER DEVICE (the partitioned module).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "token": 0,
    "opaque": 0, "f8e3m4": 1, "f8e4m3": 1, "f8e8m0fnu": 1, "s2": 1, "u2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_COND_BODY_RE = re.compile(r"condition=%([\w.\-]+), body=%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_ENTRY_RE = re.compile(r"^ENTRY\s+%([\w.\-]+)")
_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->.*\{")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "iota", "partition-id",
               "replica-id"}

# ops that read/write only a window of a big operand: charging the full
# operand per while-iteration would overcount scan xs slicing by the trip
# count (verified on the xLSTM cell: 50x inflation)
_SLICING = {"dynamic-slice", "gather", "slice"}
_UPDATING = {"dynamic-update-slice", "scatter"}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _numel(type_str: str) -> int:
    dims = _shape_dims(type_str)
    if dims is None:
        return 0
    n = 1
    for d in dims:
        n *= d
    return n


def _shape_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.hbm_bytes += o.hbm_bytes
        for k, v in o.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) + v
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.hbm_bytes * f,
                    {k: v * f for k, v in self.collective_bytes.items()})

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def as_dict(self) -> Dict:
        return {"flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "collective_bytes": dict(self.collective_bytes),
                "collective_bytes_total": self.total_collective_bytes}


@dataclass
class _Op:
    name: str
    rest: str
    out_type: str
    opcode: str
    operands: List[str]
    is_root: bool = False


class _Computation:
    def __init__(self, name: str, lines: List[str]):
        self.name = name
        self.ops: List[_Op] = []
        self.types: Dict[str, str] = {}
        self.root: "_Op" = None
        for ln in lines:
            m = _DEF_RE.match(ln)
            if not m:
                continue
            is_root = ln.lstrip().startswith("ROOT")
            name_, rest = m.group(1), m.group(2)
            if rest.startswith("("):
                # tuple type: balanced-paren scan (types may contain
                # /*index=N*/ comments, which defeat regexes)
                depth = 0
                j = 0
                for j, ch in enumerate(rest):
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            break
                out_type = rest[:j + 1]
                om = re.match(r"\s*([\w\-]+)\(", rest[j + 1:])
                opcode = om.group(1) if om else ""
                opcode_pos = j + 1
            else:
                tm = re.match(r"([a-z0-9]+\[[0-9,]*\]\S*)\s+"
                              r"([\w\-]+)\(", rest)
                if tm:
                    out_type, opcode = tm.group(1), tm.group(2)
                    opcode_pos = tm.start(2)
                else:
                    parts = rest.split()
                    out_type = parts[0] if parts else ""
                    opcode = parts[1].split("(")[0] if len(parts) > 1 \
                        else ""
                    opcode_pos = 0
            lparen = rest.find("(", opcode_pos)
            args = ""
            if lparen >= 0:
                depth, j = 0, lparen
                for j in range(lparen, len(rest)):
                    if rest[j] == "(":
                        depth += 1
                    elif rest[j] == ")":
                        depth -= 1
                        if depth == 0:
                            break
                args = rest[lparen + 1:j]
            operands = _OPND_RE.findall(args)
            op = _Op(name=name_, rest=rest, out_type=out_type,
                     opcode=opcode, operands=operands, is_root=is_root)
            self.ops.append(op)
            if is_root:
                self.root = op
            self.types[name_] = out_type


def parse_module(text: str) -> Tuple[Dict[str, _Computation], str]:
    comps: Dict[str, _Computation] = {}
    entry = None
    cur_name, cur_lines = None, []
    for ln in text.splitlines():
        m = _HEAD_RE.match(ln)
        if m and cur_name is None:
            cur_name, cur_lines = m.group(1), []
            if _ENTRY_RE.match(ln):
                entry = cur_name
            continue
        if cur_name is not None:
            if ln.startswith("}"):
                comps[cur_name] = _Computation(cur_name, cur_lines)
                cur_name, cur_lines = None, []
            else:
                cur_lines.append(ln)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    return comps, entry


def _dot_flops(op: _Op, comp: _Computation) -> float:
    out_dims = _shape_dims(op.out_type) or []
    out_numel = 1
    for d in out_dims:
        out_numel *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    if not m or not op.operands:
        return 0.0
    lhs_dims = _shape_dims(comp.types.get(op.operands[0], "")) or []
    contracted = 1
    if m.group(1):
        for ax in m.group(1).split(","):
            ax = int(ax)
            if ax < len(lhs_dims):
                contracted *= lhs_dims[ax]
    return 2.0 * out_numel * contracted


def _conv_flops(op: _Op, comp: _Computation) -> float:
    out_dims = _shape_dims(op.out_type) or []
    out_numel = 1
    for d in out_dims:
        out_numel *= d
    if len(op.operands) < 2:
        return 0.0
    ker_dims = _shape_dims(comp.types.get(op.operands[1], "")) or []
    ker_numel = 1
    for d in ker_dims:
        ker_numel *= d
    return 2.0 * out_numel * ker_numel / max(ker_dims[-1] if ker_dims
                                             else 1, 1)


def _param_sliced_bytes(called: "_Computation", idx: int,
                        full_bytes: int) -> int:
    """If fused-computation parameter ``idx`` is consumed ONLY through
    slicing ops (optionally via bitcast/reshape/copy hops), its HBM read
    is the slice windows, not the full operand."""
    pname = None
    for o in called.ops:
        if o.opcode == "parameter" and f"parameter({idx})" in o.rest:
            pname = o.name
            break
    if pname is None:
        return full_bytes
    names = {pname}
    # follow pure-renaming hops
    for _ in range(3):
        for o in called.ops:
            if o.opcode in ("bitcast", "reshape", "copy") and \
                    o.operands and o.operands[0] in names:
                names.add(o.name)
    consumers = [o for o in called.ops
                 if any(x in names for x in o.operands)
                 and o.opcode not in ("bitcast", "reshape", "copy")]
    if consumers and all(c.opcode in _SLICING for c in consumers):
        return sum(_shape_bytes(c.out_type) for c in consumers)
    return full_bytes


def _op_hbm_bytes(op: "_Op", comp: "_Computation",
                  comps: Dict[str, "_Computation"]) -> int:
    oc = op.opcode
    if oc in _SLICING:
        return 2 * _shape_bytes(op.out_type)          # window read + write
    if oc in _UPDATING:
        upd = _shape_bytes(comp.types.get(op.operands[1], "")) \
            if len(op.operands) > 1 else 0
        return 2 * upd                                # window RMW
    cm = _CALLS_RE.search(op.rest) if oc == "fusion" else None
    called = comps.get(cm.group(1)) if cm else None
    if called is not None:
        # fusion computing an in-place window write: the root is a DUS,
        # possibly behind convert/bitcast hops — charge the window RMW,
        # not the aliased buffer
        dus = next((o for o in called.ops if o.opcode in _UPDATING
                    and _numel(o.out_type) == _numel(op.out_type)), None)
        if dus is not None:
            upd = _shape_bytes(called.types.get(dus.operands[1], "")) \
                if len(dus.operands) > 1 else 0
            out_b = _shape_bytes(op.out_type)
            small = sum(_shape_bytes(comp.types.get(o, ""))
                        for o in op.operands
                        if _shape_bytes(comp.types.get(o, "")) < out_b)
            return 2 * upd + small
    b = _shape_bytes(op.out_type)
    for i, o in enumerate(op.operands):
        full = _shape_bytes(comp.types.get(o, ""))
        if called is not None and full > 4 * _shape_bytes(op.out_type):
            full = _param_sliced_bytes(called, i, full)
        b += full
    return b


def analyze(text: str, breakdown: Optional[list] = None) -> Cost:
    """``breakdown``: optional list collecting (scaled_bytes, scaled_flops,
    op_name, opcode, out_type[:60]) tuples for the top-contributor report
    (scale = product of enclosing while trip counts)."""
    comps, entry = parse_module(text)
    memo: Dict[Tuple[str, bool], Cost] = {}
    scale_stack = [1.0]

    def cost_of(comp_name: str, count_bytes: bool) -> Cost:
        key = (comp_name, count_bytes)
        if breakdown is None and key in memo:
            return memo[key]
        comp = comps.get(comp_name)
        total = Cost()
        if breakdown is None:
            memo[key] = total
        if comp is None:
            return total
        for op in comp.ops:
            oc = op.opcode
            if oc == "while":
                m = _COND_BODY_RE.search(op.rest)
                tm = _TRIP_RE.search(op.rest)
                trip = int(tm.group(1)) if tm else 1
                if m:
                    scale_stack.append(scale_stack[-1] * trip)
                    inner = Cost()
                    inner += cost_of(m.group(1), count_bytes)
                    inner += cost_of(m.group(2), count_bytes)
                    scale_stack.pop()
                    total += inner.scaled(trip)
                continue
            if oc == "conditional":
                bm = _BRANCHES_RE.search(op.rest)
                if bm:
                    costs = [cost_of(b, count_bytes)
                             for b in _OPND_RE.findall(bm.group(1))]
                    if costs:
                        total += max(costs, key=lambda c: c.flops +
                                     c.hbm_bytes)
                continue

            cm = _CALLS_RE.search(op.rest)
            if cm:
                # fusion internals: flops + collectives yes, bytes no
                inner_bytes = oc in ("call", "async-start")
                total += cost_of(cm.group(1), count_bytes and inner_bytes)

            if oc == "dot":
                total.flops += _dot_flops(op, comp)
            elif oc == "convolution":
                total.flops += _conv_flops(op, comp)

            for kind in COLLECTIVE_KINDS:
                if oc == kind or oc == kind + "-start":
                    b = sum(_shape_bytes(comp.types.get(o, ""))
                            for o in op.operands)
                    if b == 0:
                        b = _shape_bytes(op.out_type)
                    total.collective_bytes[kind] = \
                        total.collective_bytes.get(kind, 0.0) + b
                    break

            if count_bytes and oc not in _SKIP_BYTES:
                b = _op_hbm_bytes(op, comp, comps)
                total.hbm_bytes += b
                if breakdown is not None and b > 0:
                    f = _dot_flops(op, comp) if oc == "dot" else 0.0
                    breakdown.append((b * scale_stack[-1],
                                      f * scale_stack[-1],
                                      f"{comp_name}/{op.name}", oc,
                                      op.out_type[:60]))
        return total

    return cost_of(entry, True)


def top_contributors(text: str, n: int = 20):
    """(bytes, flops, op, opcode, type) rows sorted by scaled HBM bytes."""
    rows: list = []
    analyze(text, breakdown=rows)
    rows.sort(reverse=True)
    return rows[:n]


def analyze_compiled(compiled) -> Cost:
    return analyze(compiled.as_text())
