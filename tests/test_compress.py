"""Self-describing compression frames: WAL / KV blobs must replay in an
environment with a different codec installed than the writer's."""
import pytest

from repro.slates import _compress


def test_roundtrip():
    c, d = _compress.Compressor(3), _compress.Decompressor()
    data = b"slate " * 100
    frame = c.compress(data)
    assert frame[:1] in (b"z", b"g")        # tagged
    assert d.decompress(frame) == data


def test_zlib_frame_decompresses_everywhere():
    """A zlib-tagged frame (written where zstandard was absent) must
    decompress regardless of the local codec preference."""
    import zlib
    frame = b"g" + zlib.compress(b"payload", 1)
    assert _compress.Decompressor().decompress(frame) == b"payload"


def test_unknown_tag_rejected():
    with pytest.raises(ValueError):
        _compress.Decompressor().decompress(b"?garbage")


def test_legacy_untagged_zlib_blob_sniffed():
    """Blobs written before the codec tag existed start with the raw
    codec header; the decompressor must still read them."""
    import zlib
    legacy = zlib.compress(b"old slate", 3)
    assert legacy[:1] == b"\x78"
    assert _compress.Decompressor().decompress(legacy) == b"old slate"


@pytest.mark.skipif(not _compress.HAVE_ZSTD, reason="needs zstandard")
def test_legacy_untagged_zstd_blob_sniffed():
    import zstandard
    legacy = zstandard.ZstdCompressor(3).compress(b"old slate")
    assert _compress.Decompressor().decompress(legacy) == b"old slate"


@pytest.mark.skipif(_compress.HAVE_ZSTD, reason="zstandard installed")
def test_zstd_frame_without_zstandard_errors_actionably():
    with pytest.raises(RuntimeError, match="zstandard"):
        _compress.Decompressor().decompress(b"z\x28\xb5\x2f\xfd")


def test_wal_replay_roundtrip(tmp_path):
    import numpy as np
    from repro.core.event import EventBatch
    from repro.slates.wal import WriteAheadLog
    p = str(tmp_path / "w.log")
    wal = WriteAheadLog(p)
    b = EventBatch.of(key=np.array([1, 2], np.int32),
                      value={"x": np.ones(2, np.float32)})
    wal.append(0, {"S1": b})
    wal.close()
    wal2 = WriteAheadLog(p)
    ticks = list(wal2.replay())
    wal2.close()
    assert len(ticks) == 1 and ticks[0][0] == 0
    assert np.asarray(ticks[0][1]["S1"].key).tolist() == [1, 2]
