import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.distributed import collectives as coll
from repro.distributed import optimizer as adamw


def test_quantize_roundtrip_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 5
    xhat, err = coll.compress_decompress(x)
    # per-block max / 127 bounds the elementwise error
    assert float(jnp.abs(err).max()) <= float(jnp.abs(x).max()) / 127 + 1e-6
    assert np.allclose(np.asarray(xhat + err), np.asarray(x), atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 4096))
def test_quantize_any_length(n):
    x = jnp.linspace(-3, 7, n)
    xhat, err = coll.compress_decompress(x)
    assert xhat.shape == x.shape
    assert float(jnp.abs(err).max()) < 0.1


def test_error_feedback_unbiased_over_steps():
    """With error feedback, the *accumulated* compressed sum tracks the
    accumulated true sum (compression error does not accumulate)."""
    rng = jax.random.PRNGKey(1)
    err = jnp.zeros((257,))
    acc_hat = jnp.zeros((257,))
    acc_true = jnp.zeros((257,))
    for i in range(50):
        rng, k = jax.random.split(rng)
        g = jax.random.normal(k, (257,)) * 0.1 + 0.05
        acc_true = acc_true + g
        gc = g + err
        ghat, err = coll.compress_decompress(gc)
        acc_hat = acc_hat + ghat
    drift = float(jnp.abs(acc_hat - acc_true).max())
    # residual bounded by one step's quantization error, not 50 steps'
    assert drift < 0.02, drift


def test_compressed_psum_tree_single_device():
    """shard_map over a 1-device mesh: compressed psum == identity-ish."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(n_data=1, n_model=1)
    g = {"w": jax.random.normal(jax.random.PRNGKey(2), (64, 8))}
    e = {"w": jnp.zeros((64, 8))}

    def f(gs, es):
        return coll.compressed_psum_tree(gs, es, "data")

    out, err = jax.jit(shard_map(
        f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
        check_rep=False))(g, e)
    assert np.allclose(np.asarray(out["w"] + err["w"]),
                       np.asarray(g["w"]), atol=1e-6)


def test_adamw_minimizes_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    opt = adamw.init(params)
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1)
    for _ in range(200):
        grads = {"w": 2 * (params["w"] - target)}
        params, opt, m = adamw.update(params, grads, opt, cfg)
    assert np.allclose(np.asarray(params["w"]), np.asarray(target),
                       atol=0.05)
    assert int(opt.count) == 200


def test_grad_clip_caps_update():
    params = {"w": jnp.zeros(4)}
    opt = adamw.init(params)
    cfg = adamw.AdamWConfig(lr=1e-3, clip_norm=1.0, warmup_steps=1)
    grads = {"w": jnp.full((4,), 1e6)}
    _, _, metrics = adamw.update(params, grads, opt, cfg)
    assert float(metrics["grad_norm"]) > 1e6   # raw norm reported
