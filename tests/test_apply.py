import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import apply as apply_mod
from repro.slates import table as tbl
from tests.conftest import CountingUpdater, LastValueUpdater, make_batch


def brute_counts(keys, xs, valid):
    out = {}
    for k, x, v in zip(keys, xs, valid):
        if v:
            c, s = out.get(k, (0, 0.0))
            out[k] = (c + 1, s + x)
    return out


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 12), min_size=1, max_size=64), st.data())
def test_associative_matches_bruteforce(keys, data):
    xs = data.draw(st.lists(st.integers(-50, 50), min_size=len(keys),
                            max_size=len(keys)))
    valid = data.draw(st.lists(st.booleans(), min_size=len(keys),
                               max_size=len(keys)))
    up = CountingUpdater()
    table = tbl.make_table(256, up.slate_spec())
    batch = make_batch(keys, xs, valid=valid)
    table, _, n = apply_mod.apply_associative(up, table, batch, tick=0)
    want = brute_counts(keys, xs, valid)
    assert int(n) == sum(valid)
    for k, (c, s) in want.items():
        slot, found = tbl.lookup(table, jnp.asarray([k], jnp.int32))
        assert bool(found[0]), k
        assert int(table.vals["count"][int(slot[0])]) == c
        assert abs(float(table.vals["sum"][int(slot[0])]) - s) < 1e-4


def test_associative_accumulates_across_batches():
    up = CountingUpdater()
    table = tbl.make_table(128, up.slate_spec())
    for i in range(5):
        table, _, _ = apply_mod.apply_associative(
            up, table, make_batch([1, 2, 1]), tick=i)
    slot, found = tbl.lookup(table, jnp.asarray([1], jnp.int32))
    assert int(table.vals["count"][int(slot[0])]) == 10


def test_sequential_respects_ts_order():
    """slate['last'] must be the value of the max-ts event per key."""
    up = LastValueUpdater()
    table = tbl.make_table(128, up.slate_spec())
    keys = [5, 5, 5, 9, 9]
    xs = [10, 20, 30, 7, 8]
    ts = [2, 0, 1, 1, 0]     # key 5 order: 20,30,10 ; key 9 order: 8,7
    batch = make_batch(keys, xs, ts=ts)
    table, ems, deferred, n = apply_mod.apply_sequential(up, table, batch,
                                                         tick=0)
    assert int(n) == 5 and int(deferred.count()) == 0
    slot, _ = tbl.lookup(table, jnp.asarray([5, 9], jnp.int32))
    assert int(table.vals["last"][int(slot[0])]) == 10   # ts=2 last
    assert int(table.vals["last"][int(slot[1])]) == 7    # ts=1 last
    assert int(table.vals["n"][int(slot[0])]) == 3
    # emissions: one per processed event with running count
    em = ems["S3"]
    got = sorted(np.asarray(em.value["x"])[np.asarray(em.valid)].tolist())
    assert got == [1, 1, 2, 2, 3]


def test_sequential_defers_over_budget_runs():
    up = LastValueUpdater()   # max_run = 8
    table = tbl.make_table(128, up.slate_spec())
    batch = make_batch([3] * 20, list(range(20)),
                       ts=list(range(20)))
    table, _, deferred, n = apply_mod.apply_sequential(up, table, batch,
                                                       tick=0)
    assert int(n) == 8
    assert int(deferred.count()) == 12
    slot, _ = tbl.lookup(table, jnp.asarray([3], jnp.int32))
    assert int(table.vals["n"][int(slot[0])]) == 8
