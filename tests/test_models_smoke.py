"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs, plus
prefill->decode consistency (bf16-cache tolerance)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced_config
from repro.models import lm
from repro.models.config import SHAPE_BY_NAME, cell_is_applicable
from repro.models.context import Ctx

# minutes of compile time across all architectures: tier-1 runs the
# stream engine + durability suites; these run in the CI `slow` job
pytestmark = pytest.mark.slow

ALL_ARCHS = sorted(ARCHS)


def _batch_for(cfg, B, S, key):
    batch = {"tokens": jax.random.randint(key, (B, S), 0,
                                          cfg.vocab_size)}
    batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
    if cfg.encdec:
        batch["enc_frames"] = jax.random.normal(
            key, (B, S, cfg.d_model), jnp.float32)
    if cfg.cross_attn_every:
        batch["image_embeds"] = jax.random.normal(
            key, (B, cfg.n_image_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_smoke(arch):
    cfg = reduced_config(arch)
    model = lm.build(cfg)
    params, _ = lm.init(model, jax.random.PRNGKey(0))
    batch = _batch_for(cfg, 2, 16, jax.random.PRNGKey(1))
    ctx = Ctx(cdtype=jnp.float32)
    loss = lm.train_loss(model, params, batch, ctx)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    # random-init loss should be near ln(vocab)
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 1.0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes(arch):
    cfg = reduced_config(arch)
    model = lm.build(cfg)
    params, _ = lm.init(model, jax.random.PRNGKey(0))
    B, S = 2, 12
    batch = _batch_for(cfg, B, S, jax.random.PRNGKey(1))
    ctx = Ctx(cdtype=jnp.float32, phase="train",
              positions=jnp.broadcast_to(jnp.arange(S)[None], (B, S)))
    if cfg.encdec:
        ctx = ctx.replace(enc_memory=lm.encode(model, params,
                                               batch["enc_frames"], ctx))
    if cfg.cross_attn_every:
        ctx = ctx.replace(image_embeds=batch["image_embeds"])
    hidden, _, _ = lm.forward(model, params, batch["tokens"], ctx)
    assert hidden.shape == (B, S, cfg.d_model)
    assert np.isfinite(np.asarray(hidden)).all()
    logits = lm.logits_for(model, params, hidden, ctx)
    assert logits.shape == (B, S, cfg.vocab_size)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_consistency(arch):
    """decode at position S must match prefill over S+1 tokens (up to the
    bf16 cache quantization)."""
    cfg = reduced_config(arch)
    model = lm.build(cfg)
    params, _ = lm.init(model, jax.random.PRNGKey(0))
    B, S, CACHE = 2, 8, 24
    batch = _batch_for(cfg, B, S, jax.random.PRNGKey(1))
    batch.pop("labels")
    ctx = Ctx(cdtype=jnp.float32)
    logits, states = lm.prefill(model, params, batch, ctx, CACHE)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    cur = jnp.full((B,), S, jnp.int32)
    lg_dec, _ = lm.decode_step(model, params, tok, states, cur, ctx)

    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], tok], axis=1)
    # enc_frames stay identical: the encoder memory must not change
    # between the two runs (decoder length is independent of it)
    lg_ref, _ = lm.prefill(model, params, batch2, ctx, CACHE)
    a = np.asarray(lg_dec[:, 0], np.float32)
    b = np.asarray(lg_ref[:, 0], np.float32)
    denom = np.maximum(np.abs(b).max(), 1.0)
    rel = np.abs(a - b).max() / denom
    assert rel < 2e-2, f"decode/prefill mismatch rel={rel}"
    # argmax agreement on most rows (greedy path)
    agree = (a.argmax(-1) == b.argmax(-1)).mean()
    assert agree >= 0.5


@pytest.mark.parametrize("arch", ["zamba2-1.2b", "xlstm-350m",
                                  "deepseek-v2-lite-16b", "whisper-tiny",
                                  "llama-3.2-vision-11b"])
def test_grads_flow(arch):
    cfg = reduced_config(arch)
    model = lm.build(cfg)
    params, _ = lm.init(model, jax.random.PRNGKey(0))
    batch = _batch_for(cfg, 2, 12, jax.random.PRNGKey(1))
    ctx = Ctx(cdtype=jnp.float32)
    grads = jax.grad(lambda p: lm.train_loss(model, p, batch, ctx))(params)
    leaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in leaves)
    assert all(np.any(np.asarray(g) != 0) for g in leaves)


def test_full_configs_match_spec():
    """The FULL configs carry the exact assigned hyperparameters."""
    spec = {
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
    }
    for name, (L, D, H, Hkv, F, V) in spec.items():
        c = get_config(name)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
                c.d_ff, c.vocab_size) == (L, D, H, Hkv, F, V), name
    for name in ("deepseek-moe-16b", "deepseek-v2-lite-16b"):
        c = get_config(name)
        assert (c.d_model, c.n_heads, c.vocab_size) == (2048, 16, 102400)
        assert (c.moe.n_routed_experts, c.moe.top_k,
                c.moe.n_shared_experts, c.moe.d_expert) == (64, 6, 2, 1408)
    assert get_config("deepseek-v2-lite-16b").mla.kv_lora_rank == 512
    assert get_config("deepseek-v2-lite-16b").n_layers == 27
    assert get_config("deepseek-moe-16b").n_layers == 28


def test_long_500k_applicability():
    long = SHAPE_BY_NAME["long_500k"]
    runs = {a for a in ALL_ARCHS
            if cell_is_applicable(get_config(a), long)[0]}
    assert runs == {"zamba2-1.2b", "xlstm-350m"}
