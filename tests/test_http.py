import json
import urllib.request

from repro.core.engine import Engine, EngineConfig
from repro.core.workflow import Workflow
from repro.slates.http import SlateServer
from tests.conftest import CountingUpdater, PassThroughMapper, make_batch


def test_slate_http_reads():
    wf = Workflow([PassThroughMapper(), CountingUpdater()],
                  external_streams=("S1",))
    eng = Engine(wf, EngineConfig(batch_size=16, queue_capacity=64))
    state = eng.init_state()
    state, _ = eng.step(state, {"S1": make_batch([5, 5, 9])})
    state, _ = eng.step(state, {"S1": make_batch(
        [0], valid=[False], ts=[99])})

    box = {"state": state}
    srv = SlateServer(
        read_fn=lambda upd, key: eng.read_slate(box["state"], upd, key),
        stats_fn=lambda: eng.stats(box["state"]))
    try:
        url = f"http://127.0.0.1:{srv.port}"
        got = json.load(urllib.request.urlopen(f"{url}/slate/U1/5"))
        assert got["count"] == 2
        st = json.load(urllib.request.urlopen(f"{url}/status"))
        assert st["processed"]["U1"] == 3
        try:
            urllib.request.urlopen(f"{url}/slate/U1/12345")
            assert False, "expected 404"
        except urllib.error.HTTPError as e:
            assert e.code == 404
    finally:
        srv.close()
