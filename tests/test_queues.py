import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import queues as q_mod
from tests.conftest import VSPEC, make_batch


def test_fifo_roundtrip():
    q = q_mod.make_queue(16, VSPEC)
    q, ovf = q_mod.enqueue(q, make_batch([1, 2, 3]))
    assert int(ovf.count()) == 0
    q, out = q_mod.dequeue(q, 2)
    assert list(np.asarray(out.key)[np.asarray(out.valid)]) == [1, 2]
    q, out = q_mod.dequeue(q, 8)
    assert list(np.asarray(out.key)[np.asarray(out.valid)]) == [3]
    assert int(q.size) == 0


def test_overflow_returned():
    q = q_mod.make_queue(4, VSPEC)
    q, ovf = q_mod.enqueue(q, make_batch(list(range(10))))
    assert int(ovf.count()) == 6
    assert int(q.size) == 4
    q = q_mod.count_drop(q, ovf)
    assert int(q.dropped) == 6


def test_wraparound():
    q = q_mod.make_queue(4, VSPEC)
    for i in range(6):
        q, _ = q_mod.enqueue(q, make_batch([i]))
        q, out = q_mod.dequeue(q, 1)
        assert list(np.asarray(out.key)[np.asarray(out.valid)]) == [i]


@settings(max_examples=30, deadline=None)
@given(st.lists(st.lists(st.integers(0, 100), min_size=0, max_size=6),
                min_size=1, max_size=20))
def test_queue_preserves_order_and_counts(batches):
    """Property: dequeued stream == concatenation of enqueued (minus
    overflow), in order."""
    q = q_mod.make_queue(32, VSPEC)
    expect = []
    dropped = 0
    got = []
    for keys in batches:
        if keys:
            q, ovf = q_mod.enqueue(q, make_batch(keys))
            n_over = int(ovf.count())
            dropped += n_over
            expect.extend(keys[:len(keys) - n_over])
        q, out = q_mod.dequeue(q, 4)
        got.extend(np.asarray(out.key)[np.asarray(out.valid)].tolist())
    while int(q.size):
        q, out = q_mod.dequeue(q, 8)
        got.extend(np.asarray(out.key)[np.asarray(out.valid)].tolist())
    assert got == expect
