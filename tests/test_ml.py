"""Streaming-ML subsystem (repro/ml, DESIGN.md section 16).

Pins the three contracts the subsystem rests on:

- **Bucket-padding exactness**: ``ModelMapper.map_batch`` pads the
  event batch to the compiled microbatch size — outputs must be
  bitwise-identical to unbucketed inference for odd batch sizes, and
  empty ticks must flow through as all-invalid no-ops.
- **Fused-vs-unfused parity**: ``semantic_topk`` is an elementwise-max
  monoid, so the fused ``kernels/slate_update`` path ("jnp" and
  "interpret" backends) must agree *bitwise* with the generic
  scan/merge path (``fused="off"``).
- **Durable recovery**: a model-backed app (LM serving as a MapUpdate
  stream) crash-recovers from WAL replay to bitwise-identical slates.

Heavy model configs stay behind the ``slow`` marker; the tier-1 tests
use a 2-layer toy transformer.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import App, EventBatch, RuntimeConfig
from repro.api import ops
from repro.configs import get_config

TINY = get_config("qwen2-0.5b").replace(
    n_layers=2, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
    vocab_size=512, head_dim=32)


# ---------------------------------------------------------------------------
# ModelMapper: bucket padding is exact; empty ticks are no-ops
# ---------------------------------------------------------------------------

def test_model_mapper_bucket_padding_exact():
    """Padding to the microbatch bucket and slicing back must not
    perturb any real row: per-event outputs depend only on their own
    token row (attention never mixes across batch rows)."""
    mm = ops.model_mapper(TINY, field="tokens", out="o", bucket=8)
    rng = np.random.default_rng(0)
    whole = jax.jit(mm._infer)
    for B in (1, 5, 8, 13):
        toks = rng.integers(1, TINY.vocab_size, (B, 8)).astype(np.int32)
        batch = EventBatch.of(key=np.arange(1, B + 1, dtype=np.int32),
                              value={"tokens": toks})
        out = mm.map_batch(batch)["o"]
        # oracle: one unpadded, unbucketed forward over the true batch
        want = np.asarray(whole(jnp.asarray(toks)))
        np.testing.assert_array_equal(np.asarray(out.value["emb"]), want)
        np.testing.assert_array_equal(np.asarray(out.key),
                                      np.asarray(batch.key))
        np.testing.assert_array_equal(np.asarray(out.ts),
                                      np.asarray(batch.ts) + 1)


def test_model_mapper_empty_tick_passthrough():
    """An all-invalid batch (empty tick) must flow through with every
    row still invalid — no NaNs, no crashes, no spurious emissions."""
    mm = ops.model_mapper(TINY, field="tokens", out="o", bucket=4)
    B = 6
    batch = EventBatch.of(
        key=np.zeros(B, np.int32),
        value={"tokens": np.zeros((B, 8), np.int32)},
        valid=np.zeros(B, bool))
    out = mm.map_batch(batch)["o"]
    assert not bool(np.asarray(out.valid).any())
    assert np.isfinite(np.asarray(out.value["emb"])).all()


def test_model_mapper_keep_and_classify():
    mm = ops.model_mapper(TINY, field="tokens", out="o", mode="classify",
                          n_classes=3, bucket=4, keep=("item",))
    rng = np.random.default_rng(1)
    B = 5
    batch = EventBatch.of(
        key=np.arange(B, dtype=np.int32),
        value={"tokens": rng.integers(1, TINY.vocab_size,
                                      (B, 8)).astype(np.int32),
               "item": np.arange(10, 10 + B, dtype=np.int32)})
    out = mm.map_batch(batch)["o"]
    assert set(out.value) == {"cls", "score", "item"}
    cls = np.asarray(out.value["cls"])
    assert cls.shape == (B,) and (0 <= cls).all() and (cls < 3).all()
    np.testing.assert_array_equal(np.asarray(out.value["item"]),
                                  np.asarray(batch.value["item"]))


# ---------------------------------------------------------------------------
# semantic_topk: fused (jnp / interpret) vs generic — bitwise
# ---------------------------------------------------------------------------

def _run_topk(fused: str):
    app = App(f"topk_{fused}")
    app.source("ev", {"emb": ((4,), jnp.float32),
                      "item": ((), jnp.int32)})
    app.stream("ev").update(ops.semantic_topk(
        k=4, n_slots=16, table_capacity=64))
    rng = np.random.default_rng(7)

    def src(tick, max_events):
        B = 16
        return {"ev": EventBatch.of(
            key=rng.integers(0, 5, B).astype(np.int32),
            value={"emb": rng.normal(size=(B, 4)).astype(np.float32),
                   "item": rng.integers(1, 1000, B).astype(np.int32)},
            ts=np.full(B, tick, np.int32))}

    app.run(src, n_ticks=6,
            runtime=RuntimeConfig(batch_size=16, fused=fused), drain=True)
    cells = {}
    for key in range(5):
        slate = app.read_slate("semantic_topk", key)
        cells[key] = None if slate is None \
            else np.asarray(slate["cells"]).copy()
    app.close()
    return cells


def test_semantic_topk_fused_unfused_bitwise_parity():
    from repro.core.apply import fused_eligible, merge_monoid
    up = ops.semantic_topk()
    assert merge_monoid(up) == "max" and fused_eligible(up)
    base = _run_topk("off")                 # generic scan/merge path
    assert any(v is not None and (v > 0).any() for v in base.values())
    for impl in ("jnp", "interpret"):
        got = _run_topk(impl)
        for key, want in base.items():
            if want is None:
                assert got[key] is None
            else:
                np.testing.assert_array_equal(got[key], want,
                                              err_msg=f"key {key} {impl}")


def test_slate_update_max_kernel_matches_ref():
    """The op="max" branch of the fused kernel (interpret) against the
    jnp segment reference, on sorted keyed deltas."""
    from repro.kernels.slate_update import ops as su_ops
    rng = np.random.default_rng(3)
    B, C, N = 64, 8, 32
    keys = np.sort(rng.integers(0, 10, B)).astype(np.int32)
    valid = rng.random(B) > 0.2
    deltas = np.abs(rng.normal(size=(B, C))).astype(np.float32)
    deltas[~valid] = 0.0          # caller contract: invalid rows zeroed
    last = np.ones(B, bool)
    last[:-1] = keys[:-1] != keys[1:]
    slots = np.where(last, keys % N, -1).astype(np.int32)
    rows = np.abs(rng.normal(size=(N, C))).astype(np.float32)
    out_ref = su_ops.slate_update(
        jnp.asarray(keys), jnp.asarray(deltas), jnp.asarray(slots),
        jnp.asarray(rows), impl="ref", op="max")
    out_int = su_ops.slate_update(
        jnp.asarray(keys), jnp.asarray(deltas), jnp.asarray(slots),
        jnp.asarray(rows), impl="interpret", op="max")
    np.testing.assert_array_equal(np.asarray(out_ref),
                                  np.asarray(out_int))
    # hand oracle: per-slot elementwise max over valid deltas (0 = the
    # max identity on the non-negative domain)
    want = rows.copy()
    for i in range(B):
        if valid[i]:
            want[keys[i] % N] = np.maximum(want[keys[i] % N], deltas[i])
    np.testing.assert_array_equal(np.asarray(out_ref), want)


# ---------------------------------------------------------------------------
# personalization: engine sequential path == direct step replay
# ---------------------------------------------------------------------------

def test_personalization_matches_step_replay():
    D, K = 3, 2
    up = ops.personalization(d=D, k=K, alpha=0.5, table_capacity=32)
    rng = np.random.default_rng(5)
    n_ev = 5
    embs = rng.normal(size=(n_ev, D)).astype(np.float32)
    items = np.array([3, 7, 3, 9, 11], np.int32)

    app = App("pers")
    app.source("ev", {"emb": ((D,), jnp.float32),
                      "item": ((), jnp.int32)})
    app.stream("ev").update(up)

    def src(tick, max_events):
        return {"ev": EventBatch.of(
            key=np.ones(n_ev, np.int32),
            value={"emb": embs, "item": items},
            ts=np.arange(n_ev, dtype=np.int32))}

    app.run(src, n_ticks=1, runtime=RuntimeConfig(batch_size=8),
            drain=True)
    got = app.read_slate("personalization", 1)
    assert got is not None

    # oracle: apply `step` one event at a time, in ts order
    slate = {"user": jnp.zeros(D), "items": jnp.zeros(K, jnp.int32),
             "cand": jnp.zeros((K, D)), "scores": jnp.zeros(K),
             "n": jnp.zeros((), jnp.int32)}
    for i in range(n_ev):
        slate, _ = up.step(slate, {"value": {"emb": jnp.asarray(embs[i]),
                                             "item": jnp.asarray(items[i])},
                                   "ts": jnp.int32(i)})
    for leaf in slate:
        np.testing.assert_array_equal(np.asarray(got[leaf]),
                                      np.asarray(slate[leaf]),
                                      err_msg=leaf)
    ranked = up.ranked(got)
    assert 0 < len(ranked) <= K
    assert all(i > 0 for i, _ in ranked)
    app.close()


# ---------------------------------------------------------------------------
# durable recovery of a model-backed app — bitwise slates
# ---------------------------------------------------------------------------

def _mk_reqs(n, rng):
    from repro.launch.serve import Request
    return [Request(rid=i + 1,
                    prompt=rng.integers(1, TINY.vocab_size,
                                        int(rng.integers(3, 8))
                                        ).astype(np.int32),
                    max_new=4)
            for i in range(n)]


def test_serve_app_crash_recovery_bitwise(tmp_path):
    from repro.ml.serve_app import build_serve_app, request_source
    n_req = 6

    def runtime(d):
        # a flush boundary lands mid-run: recovery restores the earlier
        # requests' token slates from the store (wide-leaf round-trip)
        # and replays the rest of the WAL through the model mapper
        return RuntimeConfig(batch_size=4, chunk_size=2,
                             durable_dir=str(d), flush_every=2)

    def make():
        return build_serve_app(TINY, prompt_len=8, max_new=4,
                               cache_len=32, bucket=2)

    def source():
        return request_source(_mk_reqs(n_req, np.random.default_rng(9)),
                              prompt_len=8, capacity=4, per_tick=2)

    # uninterrupted durable run: all requests fed in 3 ticks
    app_a = make()
    app_a.run(source(), n_ticks=3, runtime=runtime(tmp_path / "a"),
              drain=True)
    base = {}
    for rid in range(1, n_req + 1):
        slate = app_a.read_slate("requests", rid)
        assert slate is not None, f"request {rid} missing"
        base[rid] = np.asarray(slate["tokens"]).copy()
    app_a.close()

    # same run, crashed before any drain: in-memory state dropped
    app_b = make()
    app_b.run(source(), n_ticks=3, runtime=runtime(tmp_path / "b"))
    assert app_b.engine.dur.frontier.tick > 0   # a flush boundary hit
    app_b.close()                            # the crash

    # recover on a fresh app (new process in real life) and drain
    app_c = make()
    app_c.run(lambda t, m: {}, n_ticks=0,
              runtime=runtime(tmp_path / "b"), recover=True, drain=True)
    for rid, want in base.items():
        slate = app_c.read_slate("requests", rid)
        assert slate is not None, f"request {rid} lost in recovery"
        np.testing.assert_array_equal(np.asarray(slate["tokens"]), want)
    app_c.close()


# ---------------------------------------------------------------------------
# heavy config behind `slow`
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_model_mapper_heavy_config_bucket_parity():
    cfg = get_config("qwen2-0.5b").replace(
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, d_ff=512,
        vocab_size=4096, head_dim=32)
    mm = ops.model_mapper(cfg, field="tokens", out="o", bucket=16)
    rng = np.random.default_rng(11)
    toks = rng.integers(1, cfg.vocab_size, (37, 16)).astype(np.int32)
    batch = EventBatch.of(key=np.arange(37, dtype=np.int32),
                          value={"tokens": toks})
    out = mm.map_batch(batch)["o"]
    want = np.asarray(jax.jit(mm._infer)(jnp.asarray(toks)))
    np.testing.assert_array_equal(np.asarray(out.value["emb"]), want)
