"""Multi-shard distributed-engine tests.

These run in SUBPROCESSES with XLA_FLAGS=--xla_force_host_platform_
device_count=8 so the main pytest process keeps the real single device
(per the dry-run guidance: never set the flag globally).
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str):
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.core.event import EventBatch
        from repro.core.operators import Mapper, AssociativeUpdater
        from repro.core.workflow import Workflow
        from repro.core.distributed import DistributedEngine, DistConfig

        VSPEC = {'x': ((), jnp.int32)}

        class Counter(AssociativeUpdater):
            name = 'U1'; subscribes = ('S1',); in_value_spec = VSPEC
            out_streams = {}; table_capacity = 512
            def slate_spec(self): return {'count': ((), jnp.int32)}
            def lift(self, b): return {'count': jnp.ones_like(b.key)}
            def combine(self, a, b): return {'count': a['count'] + b['count']}
            def merge(self, s, d): return {'count': s['count'] + d['count']}

        def feed(eng, state, keys, t):
            n_sh = keys.shape[0]; B = keys.shape[1]
            b = EventBatch(sid=jnp.zeros((n_sh, B), jnp.int32),
                           ts=jnp.full((n_sh, B), t, jnp.int32),
                           key=jnp.asarray(keys),
                           value={'x': jnp.asarray(keys)},
                           valid=jnp.ones((n_sh, B), bool))
            state, _ = eng.step(state, {'S1': b})
            return state

        def drain(eng, state, ticks=4):
            for t in range(ticks):
                z = jnp.zeros((8, 16), jnp.int32)
                b = EventBatch(sid=z, ts=z + 900 + t, key=z,
                               value={'x': z}, valid=jnp.zeros((8, 16), bool))
                state, _ = eng.step(state, {'S1': b})
            return state
    """) + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", code],
                       capture_output=True, text=True,
                       env={**os.environ, "PYTHONPATH":
                            os.path.join(ROOT, "src")},
                       timeout=560)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_distributed_counting_exact():
    out = run_sub("""
        mesh = Mesh(np.array(jax.devices()).reshape(8), ('data',))
        wf = Workflow([Counter()], external_streams=('S1',))
        eng = DistributedEngine(wf, mesh, DistConfig(batch_size=64,
                                                     queue_capacity=512))
        state = eng.init_state()
        rng = np.random.default_rng(0)
        truth = np.zeros(64, np.int64)
        for t in range(12):
            keys = rng.integers(0, 64, size=(8, 16)).astype(np.int32)
            for k in keys.ravel(): truth[k] += 1
            state = feed(eng, state, keys, t)
        state = drain(eng, state)
        got = np.array([(eng.read_slate(state, 'U1', k) or
                        {'count': 0})['count'] for k in range(64)])
        assert (got == truth).all(), (got, truth)
        print('EXACT-OK')
    """)
    assert "EXACT-OK" in out


@pytest.mark.slow
def test_failover_reroutes_and_drops_dead_slates():
    out = run_sub("""
        mesh = Mesh(np.array(jax.devices()).reshape(8), ('data',))
        wf = Workflow([Counter()], external_streams=('S1',))
        eng = DistributedEngine(wf, mesh, DistConfig(batch_size=64,
                                                     queue_capacity=512))
        state = eng.init_state()
        rng = np.random.default_rng(1)
        for t in range(8):
            state = feed(eng, state,
                         rng.integers(0, 64, size=(8, 16)).astype(np.int32), t)
        state = drain(eng, state)
        occ_before = eng.stats(state)['table_occupancy']['U1']
        state = eng.fail_shard(state, 3)
        assert eng.stats(state)['table_occupancy']['U1'] <= occ_before
        for t in range(8, 16):
            state = feed(eng, state,
                         rng.integers(0, 64, size=(8, 16)).astype(np.int32), t)
        state = drain(eng, state)
        per_shard = [int(jax.device_get(
            (state['tables']['U1'].keys[i] != -1).sum())) for i in range(8)]
        assert per_shard[3] == 0, per_shard
        assert eng.stats(state)['exchange_dropped'] == 0
        print('FAILOVER-OK')
    """)
    assert "FAILOVER-OK" in out


@pytest.mark.slow
def test_two_choice_spills_hotspot():
    out = run_sub("""
        mesh = Mesh(np.array(jax.devices()).reshape(8), ('data',))
        wf = Workflow([Counter()], external_streams=('S1',))
        eng = DistributedEngine(wf, mesh, DistConfig(
            batch_size=256, queue_capacity=2048, exchange_slack=8.0,
            two_choice_threshold=4))
        state = eng.init_state()
        # hotspot: every event has key 7
        hot = np.full((8, 16), 7, np.int32)
        for t in range(10):
            state = feed(eng, state, hot, t)
        state = drain(eng, state, 6)
        total = eng.read_slate(state, 'U1', 7)['count']
        assert int(total) == 8 * 16 * 10, total
        # partials live on exactly two shards
        t_ = state['tables']['U1']
        shards_with_key = [i for i in range(8)
                          if int(jax.device_get((t_.keys[i] == 7).sum()))]
        assert len(shards_with_key) == 2, shards_with_key
        print('TWO-CHOICE-OK')
    """)
    assert "TWO-CHOICE-OK" in out


@pytest.mark.slow
def test_distributed_chunk_and_fused_path():
    """run_chunk under shard_map (stacked [T, n_shards, B] sources) and
    the fused sum_mergeable path produce exact counts."""
    out = run_sub("""
        class FusedCounter(Counter):
            sum_mergeable = True

        mesh = Mesh(np.array(jax.devices()).reshape(8), ('data',))
        rng = np.random.default_rng(7)
        all_keys = [rng.integers(0, 64, size=(8, 16)).astype(np.int32)
                    for _ in range(8)]
        truth = np.zeros(64, np.int64)
        for ks in all_keys:
            for k in ks.ravel(): truth[k] += 1

        def batch(keys, t, valid=True):
            n_sh, B = keys.shape
            return EventBatch(sid=jnp.zeros((n_sh, B), jnp.int32),
                              ts=jnp.full((n_sh, B), t, jnp.int32),
                              key=jnp.asarray(keys),
                              value={'x': jnp.asarray(keys)},
                              valid=jnp.full((n_sh, B), valid, bool))

        for fused in ('off', 'jnp', 'ref'):
            wf = Workflow([FusedCounter()], external_streams=('S1',))
            eng = DistributedEngine(wf, mesh, DistConfig(
                batch_size=64, queue_capacity=512, fused=fused))
            state = eng.init_state()
            stacked = {'S1': jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[batch(k, t) for t, k in enumerate(all_keys)])}
            state, outs, info = eng.run_chunk(state, stacked)
            assert info['throttle_hits'].shape == (8, 8)
            z = np.zeros((8, 16), np.int32)
            stacked_drain = {'S1': jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[batch(z, 900 + t, valid=False) for t in range(4)])}
            state, _, _ = eng.run_chunk(state, stacked_drain)
            got = np.array([(eng.read_slate(state, 'U1', k) or
                            {'count': 0})['count'] for k in range(64)])
            assert (got == truth).all(), (fused, got, truth)
        print('CHUNK-FUSED-OK')
    """)
    assert "CHUNK-FUSED-OK" in out


@pytest.mark.slow
def test_stream_engine_multipod_axes():
    """The stream engine shards over ('pod','data') — the multi-pod axes
    compose in the exchange collective."""
    out = run_sub("""
        mesh = jax.make_mesh((2, 4), ('pod', 'data'))
        wf = Workflow([Counter()], external_streams=('S1',))
        eng = DistributedEngine(wf, mesh, DistConfig(
            batch_size=64, queue_capacity=512,
            axis_names=('pod', 'data')))
        state = eng.init_state()
        rng = np.random.default_rng(3)
        truth = np.zeros(32, np.int64)
        for t in range(6):
            keys = rng.integers(0, 32, size=(8, 16)).astype(np.int32)
            for k in keys.ravel(): truth[k] += 1
            state = feed(eng, state, keys, t)
        state = drain(eng, state)
        got = np.array([(eng.read_slate(state, 'U1', k) or
                        {'count': 0})['count'] for k in range(32)])
        assert (got == truth).all()
        print('MULTIPOD-OK')
    """)
    assert "MULTIPOD-OK" in out
