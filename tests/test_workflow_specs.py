"""Build-time stream-spec validation in Workflow._validate: a
producer's out_streams value_spec must structurally match each
subscriber's in_value_spec — mismatches raise at construction with
operator/stream names instead of opaque shape errors inside jit."""
import jax.numpy as jnp
import pytest

from repro.core.event import spec_matches
from repro.core.workflow import Workflow
from tests.conftest import CountingUpdater, PassThroughMapper, VSPEC


def test_spec_matches_normalizes_dtypes():
    import numpy as np
    assert spec_matches({"x": ((), jnp.int32)}, {"x": ((), np.int32)})
    assert not spec_matches({"x": ((), jnp.int32)},
                            {"x": ((), jnp.float32)})
    assert not spec_matches({"x": ((2,), jnp.int32)},
                            {"x": ((3,), jnp.int32)})
    assert not spec_matches({"x": ((), jnp.int32)},
                            {"y": ((), jnp.int32)})


def test_matching_specs_build():
    Workflow([PassThroughMapper(), CountingUpdater()],
             external_streams=("S1",))


def test_dtype_mismatch_raises_with_names():
    class FloatMapper(PassThroughMapper):
        out_streams = {"S2": {"x": ((), jnp.float32)}}

    with pytest.raises(ValueError) as ei:
        Workflow([FloatMapper(), CountingUpdater()],
                 external_streams=("S1",))
    msg = str(ei.value)
    assert "S2" in msg and "M1" in msg and "U1" in msg


def test_shape_mismatch_raises():
    class WideMapper(PassThroughMapper):
        out_streams = {"S2": {"x": ((4,), jnp.int32)}}

    with pytest.raises(ValueError, match="S2"):
        Workflow([WideMapper(), CountingUpdater()],
                 external_streams=("S1",))


def test_structure_mismatch_raises():
    class RenamedMapper(PassThroughMapper):
        out_streams = {"S2": {"y": ((), jnp.int32)}}

    with pytest.raises(ValueError, match="S2"):
        Workflow([RenamedMapper(), CountingUpdater()],
                 external_streams=("S1",))


def test_multi_producer_each_checked():
    class GoodMapper(PassThroughMapper):
        name = "M2"

    class BadMapper(PassThroughMapper):
        name = "M3"
        out_streams = {"S2": {"x": ((), jnp.float32)}}

    with pytest.raises(ValueError, match="M3"):
        Workflow([PassThroughMapper(), GoodMapper(), BadMapper(),
                  CountingUpdater()], external_streams=("S1",))
