"""Fallback shim installed by conftest.py when ``hypothesis`` is absent.

Property tests decorated with ``@given`` skip gracefully instead of
breaking collection of their whole module; every example-based test in
the same file keeps running.  Install the real package from
``requirements-dev.txt`` to execute the property tests.
"""
import sys
import types

import pytest


def _strategy(*args, **kwargs):
    return None


def given(*_args, **_kwargs):
    def deco(fn):
        def skipper(*a, **k):
            pytest.skip("hypothesis not installed "
                        "(pip install -r requirements-dev.txt)")
        skipper.__name__ = fn.__name__
        skipper.__doc__ = fn.__doc__
        return skipper
    return deco


class settings:
    """Accepts any profile kwargs; as a decorator it is the identity."""

    def __init__(self, *args, **kwargs):
        pass

    def __call__(self, fn):
        return fn

    @staticmethod
    def register_profile(*args, **kwargs):
        pass

    @staticmethod
    def load_profile(*args, **kwargs):
        pass


def install():
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.assume = _strategy
    mod.note = _strategy
    mod.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None, filter_too_much=None)

    st = types.ModuleType("hypothesis.strategies")
    st.__getattr__ = lambda name: _strategy   # any strategy constructor

    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
