"""Device-resident multi-tick loop (``Engine.run_chunk``): bitwise
equivalence with sequential ``step``, stacked output plumbing, on-device
ingest throttling, and the chunked ``run`` driver."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import Engine, EngineConfig, stack_sources
from repro.core.queues import OverflowPolicy
from repro.core.workflow import Workflow
from tests.conftest import (CountingUpdater, LastValueUpdater,
                            PassThroughMapper, make_batch)


def counting_engine(**cfg):
    wf = Workflow([PassThroughMapper(), CountingUpdater()],
                  external_streams=("S1",))
    eng = Engine(wf, EngineConfig(**cfg))
    return eng, eng.init_state()


def random_ticks(rng, n_ticks, cap=32, n_keys=20):
    out = []
    for t in range(n_ticks):
        keys = rng.integers(0, n_keys, size=cap).astype(np.int32)
        xs = rng.integers(0, 9, size=cap).astype(np.int32)
        out.append({"S1": make_batch(keys, xs, ts=[t] * cap)})
    return out


def test_run_chunk_bitwise_identical_to_steps():
    """Acceptance: run_chunk(n_ticks=32) == 32 sequential step() calls,
    bitwise, on the counting workload."""
    rng = np.random.default_rng(0)
    ticks = random_ticks(rng, 32)

    eng_a, st_a = counting_engine(batch_size=32, queue_capacity=256)
    for src in ticks:
        st_a, _ = eng_a.step(st_a, src)

    eng_b, st_b = counting_engine(batch_size=32, queue_capacity=256)
    st_b, outs, info = eng_b.run_chunk(st_b, stack_sources(ticks), 32)

    la, lb = jax.tree.leaves(st_a), jax.tree.leaves(st_b)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            "chunked state diverged from sequential state"
    assert int(st_b["tick"]) == 32
    assert info["throttle_hits"].shape == (32,)


def test_run_chunk_stacks_outputs():
    """Engine outputs (streams nobody subscribes to) surface with a
    leading tick axis and match per-tick step outputs."""
    wf = Workflow([PassThroughMapper(), LastValueUpdater()],
                  external_streams=("S1",))
    eng = Engine(wf, EngineConfig(batch_size=16, queue_capacity=64))
    ticks = [{"S1": make_batch([4, 4, 5], [10, 20, 30], ts=[0, 1, 2])}]
    ticks += [{"S1": make_batch([0] * 3, valid=[False] * 3,
                                ts=[50 + t] * 3)} for t in range(3)]

    state = eng.init_state()
    st, outs, _ = eng.run_chunk(state, stack_sources(ticks))
    assert "S3" in outs
    em = outs["S3"]
    assert jax.tree.leaves(em)[0].shape[0] == 4      # tick axis
    valid = np.asarray(em.valid)
    xs = np.asarray(em.value["x"])[valid]
    assert sorted(xs.tolist()) == [1, 1, 2]

    # same emissions as per-tick stepping
    state2 = eng.init_state()
    got = []
    for src in ticks:
        state2, o = eng.step(state2, src)
        if "S3" in o:
            e = o["S3"]
            got.extend(np.asarray(e.value["x"])[np.asarray(e.valid)]
                       .tolist())
    assert sorted(got) == [1, 1, 2]


def test_run_chunk_validates_tick_count():
    eng, state = counting_engine(batch_size=8, queue_capacity=32)
    ticks = random_ticks(np.random.default_rng(1), 4, cap=8)
    with pytest.raises(ValueError):
        eng.run_chunk(state, stack_sources(ticks), 8)


def test_run_chunk_on_device_throttling():
    """With an ingest limit the chunk masks sources on device and the
    carried limit halves under throttle pressure."""
    eng, state = counting_engine(
        batch_size=4, queue_capacity=8,
        overflow={"M1": OverflowPolicy.THROTTLE})
    ticks = random_ticks(np.random.default_rng(2), 8, cap=16)
    st, outs, info = eng.run_chunk(state, stack_sources(ticks),
                                   ingest=16, throttle_floor=2)
    hits = np.asarray(info["throttle_hits"])
    assert hits[-1] > 0                      # pressure was signalled
    assert int(info["ingest"]) < 16          # and the limit backed off


def test_run_chunk_ingest_above_batch_size_survives_quiet_ticks():
    """An initial ingest limit above cfg.batch_size is the ceiling the
    doubling recovers to — a quiet tick must not collapse it."""
    eng, state = counting_engine(batch_size=8, queue_capacity=256,
                                 overflow={"M1": OverflowPolicy.THROTTLE})
    ticks = [{"S1": make_batch([k % 5 for k in range(16)],
                               ts=[t] * 16)} for t in range(4)]
    st, _, info = eng.run_chunk(state, stack_sources(ticks), ingest=64)
    assert np.asarray(info["throttle_hits"])[-1] == 0   # no pressure
    assert int(info["ingest"]) == 64                    # ceiling kept


def test_run_driver_chunked_backpressure():
    """The chunked run() still backs off ingest (one sync per chunk)."""
    eng, _ = counting_engine(batch_size=4, queue_capacity=8,
                             overflow={"M1": OverflowPolicy.THROTTLE})
    state = eng.init_state()
    sizes = []

    def source(t, max_events):
        n = 16
        take = min(max_events, n) if max_events else n
        sizes.append(take)
        return {"S1": make_batch(list(range(n)), ts=[t] * n,
                                 valid=[i < take for i in range(n)])}

    state, outputs = eng.run(state, source, 12, chunk_size=4)
    assert len(outputs) == 12
    assert min(sizes) < 16    # the loop backed off under pressure


def test_run_chunk_size_one_matches_legacy_per_tick():
    """chunk_size=1 reproduces the old per-tick driver: one step per
    tick, hits read every tick, same halve/double ingest schedule."""
    def make_source(sizes):
        def source(t, max_events):
            n = 16
            take = min(max_events, n) if max_events else n
            sizes.append(take)
            return {"S1": make_batch(list(range(n)), ts=[t] * n,
                                     valid=[i < take for i in range(n)])}
        return source

    # the pre-chunking driver, verbatim
    def legacy_run(eng, state, source_fn, n_ticks, throttle_floor=8):
        ingest = None
        last_hits = 0
        for t in range(n_ticks):
            state, _ = eng.step(state, source_fn(t, ingest))
            hits = int(state["throttle_hits"])
            if hits > last_hits:
                cur = (ingest if ingest is not None
                       else eng.cfg.batch_size)
                ingest = max(throttle_floor, cur // 2)
            elif ingest is not None:
                ingest = min(eng.cfg.batch_size, ingest * 2)
                if ingest == eng.cfg.batch_size:
                    ingest = None
            last_hits = hits
        return state

    cfg = dict(batch_size=4, queue_capacity=8,
               overflow={"M1": OverflowPolicy.THROTTLE})
    eng_a, st_a = counting_engine(**cfg)
    legacy_sizes = []
    st_a = legacy_run(eng_a, st_a, make_source(legacy_sizes), 10)

    eng_b, st_b = counting_engine(**cfg)
    new_sizes = []
    st_b, _ = eng_b.run(st_b, make_source(new_sizes), 10, chunk_size=1)

    assert new_sizes == legacy_sizes
    assert min(new_sizes) < 16      # backpressure engaged in both
    assert int(st_b["throttle_hits"]) == int(st_a["throttle_hits"])


def test_run_handles_bursty_source_streams():
    """source_fn may return different stream subsets per tick (e.g. {}
    once the input is exhausted) — the chunked driver pads instead of
    crashing, like the old per-tick loop."""
    eng, state = counting_engine(batch_size=8, queue_capacity=64)

    def source(t, max_events):
        if t < 2:
            return {"S1": make_batch([1, 2, 3], ts=[t] * 3)}
        return {}

    state, outputs = eng.run(state, source, 6, chunk_size=4)
    assert len(outputs) == 6
    for k, want in ((1, 2), (2, 2), (3, 2)):
        slate = eng.read_slate(state, "U1", k)
        assert slate is not None and int(slate["count"]) == want


def test_stack_sources_pads_missing_streams():
    ticks = [{"S1": make_batch([1, 2])}, {},
             {"S1": make_batch([3, 4])}]
    stacked = stack_sources(ticks)
    assert jax.tree.leaves(stacked["S1"])[0].shape[0] == 3
    valid = np.asarray(stacked["S1"].valid)
    assert valid[0].all() and not valid[1].any() and valid[2].all()


def test_run_handles_varying_batch_capacities():
    """source_fn may emit differently-sized batches per tick (e.g. a
    final partial batch); stack_sources pads to the chunk max."""
    eng, state = counting_engine(batch_size=8, queue_capacity=64)

    def source(t, max_events):
        n = 4 - t if t < 3 else 1        # capacities 4, 3, 2, 1, 1, ...
        return {"S1": make_batch([1] * n, ts=[t] * n)}

    state, outputs = eng.run(state, source, 6, chunk_size=3)
    assert len(outputs) == 6
    state, _ = eng.step(state, {"S1": make_batch(
        [0] * 4, valid=[False] * 4, ts=[99] * 4)})
    assert int(eng.read_slate(state, "U1", 1)["count"]) == 4 + 3 + 2 + 3
