"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see the real single
CPU device; multi-shard behaviour is exercised via subprocess tests
(test_multishard.py) so device-count init never leaks across suites."""
try:                               # property tests need hypothesis; a
    import hypothesis              # clean checkout without dev deps must
except ImportError:                # still collect and run everything else
    from tests import _hypothesis_stub
    _hypothesis_stub.install()

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: subprocess / multi-device tests (deselect with "
        "-m 'not slow')")

from repro.core.event import EventBatch
from repro.core.operators import AssociativeUpdater, Mapper, SequentialUpdater

VSPEC = {"x": ((), jnp.int32)}


class PassThroughMapper(Mapper):
    name = "M1"
    subscribes = ("S1",)
    in_value_spec = VSPEC
    out_streams = {"S2": VSPEC}

    def map_batch(self, batch):
        out = EventBatch(sid=batch.sid, ts=batch.ts + 1, key=batch.key,
                         value=batch.value, valid=batch.valid)
        return {"S2": out}


class CountingUpdater(AssociativeUpdater):
    name = "U1"
    subscribes = ("S2",)
    in_value_spec = VSPEC
    out_streams = {}
    table_capacity = 512

    def slate_spec(self):
        return {"count": ((), jnp.int32), "sum": ((), jnp.float32)}

    def lift(self, batch):
        return {"count": jnp.ones_like(batch.key),
                "sum": batch.value["x"].astype(jnp.float32)}

    def combine(self, a, b):
        return {"count": a["count"] + b["count"], "sum": a["sum"] + b["sum"]}

    def merge(self, slate, delta):
        return {"count": slate["count"] + delta["count"],
                "sum": slate["sum"] + delta["sum"]}


class LastValueUpdater(SequentialUpdater):
    """Order-sensitive: slate keeps the last event value and a step count;
    emits the running count each event."""
    name = "U2"
    subscribes = ("S2",)
    in_value_spec = VSPEC
    out_streams = {"S3": VSPEC}
    table_capacity = 512
    max_run = 8

    def slate_spec(self):
        return {"last": ((), jnp.int32), "n": ((), jnp.int32)}

    def step(self, slate, ev):
        new = {"last": ev["value"]["x"], "n": slate["n"] + 1}
        emit = {"S3": {"key": ev["key"], "value": {"x": new["n"]},
                       "emit": jnp.bool_(True)}}
        return new, emit


def make_batch(keys, xs=None, ts=None, valid=None):
    keys = np.asarray(keys, np.int32)
    xs = np.asarray(xs if xs is not None else keys, np.int32)
    return EventBatch.of(key=keys, value={"x": xs}, ts=ts, valid=valid)


@pytest.fixture
def counting_workflow():
    from repro.core.workflow import Workflow
    return Workflow([PassThroughMapper(), CountingUpdater()],
                    external_streams=("S1",))
