"""End-to-end latency observability (DESIGN.md section 18).

Covers the PR-10 contract:

- device latency histograms: clz bucketize exactness at power-of-two
  edges, kernel-vs-oracle bitwise equality (including the saturating
  top bucket), and bitwise slate parity with histograms on vs off —
  telemetry state is pure-extra, the tick never reads it;
- host readout: quantile interpolation units, windowed report
  quantiles from a lagged feed;
- span tracing: Chrome-trace JSON schema, ring bounding, migration
  pause reconciliation lives in the distributed suite;
- exposition: /metrics scrape parses as Prometheus text 0.0.4 with
  counter + native histogram families;
- control: the LoadAutoscaler p99 watermark, and recovery timing
  (``recovery_replay_s``) on the report.
"""
import json
import re
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import Engine, EngineConfig, stack_sources
from repro.core.workflow import Workflow
from repro.telemetry import latency as lat
from repro.telemetry.metrics import TelemetryConfig, TelemetryReport
from tests.conftest import (CountingUpdater, PassThroughMapper,
                            make_batch)


def _wf():
    return Workflow([PassThroughMapper(), CountingUpdater()],
                    external_streams=("S1",))


# ---------------------------------------------------------------------------
# bucketize: exact power-of-two edges
# ---------------------------------------------------------------------------

def test_bucketize_exact_edges():
    """clz binning: bucket b is exactly [2^(b-1), 2^b) — no float-log2
    misplacement at the edges."""
    vals, want = [0, 1], [0, 1]
    for k in range(1, 30):
        vals += [(1 << k) - 1, 1 << k, (1 << k) + 1]
        want += [k, k + 1, k + 1]
    got = np.asarray(lat.bucketize(jnp.asarray(vals, jnp.int32), 32))
    assert got.tolist() == [min(w, 31) for w in want]


def test_bucketize_clamps_negative_and_saturates():
    got = np.asarray(lat.bucketize(
        jnp.asarray([-5, -1, 2**31 - 1, 1 << 20], jnp.int32), 8))
    assert got.tolist() == [0, 0, 7, 7]   # future-stamped -> bucket 0


# ---------------------------------------------------------------------------
# kernel vs oracle: bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_histogram_kernel_vs_oracle_bitwise(impl):
    from repro.kernels.histogram import histogram_update
    from repro.kernels.histogram.ref import histogram_update as oracle
    rng = np.random.default_rng(7)
    rows, B, width = 3, 64, 128        # width%128==0 keeps pallas viable
    counts = jnp.asarray(rng.integers(0, 50, (rows, width)), jnp.int32)
    cols = jnp.asarray(rng.integers(0, width, (rows, B)), jnp.int32)
    add = jnp.asarray(rng.integers(0, 2, B), jnp.int32)
    got = histogram_update(counts, cols, add, impl=impl)
    want = oracle(counts, cols, add)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_hist_update_edges_and_saturation(impl):
    """Latencies at bucket edges land in exactly the right device
    bucket; out-of-range mass saturates into the top bucket; invalid
    rows add nothing; ``sum`` is the exact masked total."""
    nb = 8
    h = lat.make_hist(["U1"], nb)["U1"]
    ts = jnp.zeros((6,), jnp.int32)
    tick = jnp.asarray(0, jnp.int32)
    lats = jnp.asarray([0, 1, 2, 3, 4, 1 << 20], jnp.int32)
    valid = jnp.asarray([True, True, True, True, True, True])
    got = lat.hist_update(h, tick + lats, ts * 0, valid,
                          n_buckets=nb, impl=impl)
    # per-row tick works too, but here each event gets its own latency
    # by feeding tick as a vector (tick - ts broadcast)
    counts = np.asarray(got["counts"]).ravel()[:nb]
    #            b0  b1  b2[2,4)  b3[4,8)           top (saturated)
    assert counts.tolist() == [1, 1, 2, 1, 0, 0, 0, 1]
    assert int(got["sum"]) == 0 + 1 + 2 + 3 + 4 + (1 << 20)
    # invalid rows: nothing moves
    got2 = lat.hist_update(got, tick + lats, ts * 0,
                           jnp.zeros_like(valid), n_buckets=nb,
                           impl=impl)
    assert np.array_equal(np.asarray(got2["counts"]),
                          np.asarray(got["counts"]))
    assert int(got2["sum"]) == int(got["sum"])


# ---------------------------------------------------------------------------
# quantile interpolation (host units)
# ---------------------------------------------------------------------------

def test_quantile_interpolation_units():
    nb = 8
    counts = np.zeros(nb)
    counts[2] = 100                    # all mass in [2, 4)
    q = lat.quantile(counts, 0.5, n_buckets=nb)
    assert isinstance(q, float) and not isinstance(q, np.floating)
    assert q == pytest.approx(3.0)     # lo + (hi-lo) * 0.5
    assert lat.quantile(counts, 0.0, n_buckets=nb) == pytest.approx(2.0)
    # mass split across buckets: rank walks the cumulative counts
    counts = np.zeros(nb)
    counts[1] = 50                     # {1}: [1, 2)
    counts[3] = 50                     # [4, 8)
    assert lat.quantile(counts, 0.25, n_buckets=nb) <= 2.0
    assert 4.0 <= lat.quantile(counts, 0.99, n_buckets=nb) < 8.0
    # saturating top bucket reports its lower edge (+Inf convention)
    counts = np.zeros(nb)
    counts[nb - 1] = 10
    assert lat.quantile(counts, 0.99, n_buckets=nb) \
        == float(lat.bucket_lo(nb - 1))
    assert lat.quantile(np.zeros(nb), 0.9, n_buckets=nb) == 0.0


# ---------------------------------------------------------------------------
# the parity contract: histograms are pure-extra state
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_chunk_parity_histograms_on_off(impl):
    """Tables / queues / outputs of the jitted chunk are bitwise
    identical with latency histograms on vs off — the tick updates
    telemetry state but never reads it."""
    rng = np.random.default_rng(3)
    srcs = [{"S1": make_batch(rng.integers(0, 40, 24),
                              rng.integers(0, 9, 24),
                              ts=np.full(24, t, np.int32))}
            for t in range(8)]

    def run(nb):
        eng = Engine(_wf(), EngineConfig(
            batch_size=32, queue_capacity=128,
            telemetry=TelemetryConfig(impl=impl, latency_buckets=nb)))
        state, outs, _ = eng.run_chunk(eng.init_state(),
                                       stack_sources(srcs), 8)
        return state, outs

    s0, o0 = run(0)
    s1, o1 = run(32)
    assert "lat_hist" not in s0 and "lat_hist" in s1
    for part in ("tables", "queues", "processed", "tick"):
        a, b = jax.device_get((s0[part], s1[part]))
        for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            assert np.array_equal(np.asarray(la), np.asarray(lb)), part
    for la, lb in zip(jax.tree.leaves(jax.device_get(o0)),
                      jax.tree.leaves(jax.device_get(o1))):
        assert np.array_equal(np.asarray(la), np.asarray(lb))


def test_hist_backends_agree_through_chunk():
    """The histogram state itself is backend-independent (bitwise)."""
    rng = np.random.default_rng(5)
    srcs = [{"S1": make_batch(rng.integers(0, 40, 24),
                              ts=np.full(24, t, np.int32))}
            for t in range(8)]

    def run(impl):
        eng = Engine(_wf(), EngineConfig(
            batch_size=32, queue_capacity=128,
            telemetry=TelemetryConfig(impl=impl)))
        state, _, _ = eng.run_chunk(eng.init_state(),
                                    stack_sources(srcs), 8)
        return jax.device_get(state["lat_hist"])

    a, b = run("ref"), run("interpret")
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert np.array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# windowed report quantiles, end to end
# ---------------------------------------------------------------------------

def test_report_quantiles_from_lagged_feed():
    """Sources stamped 3 ticks in the past -> the updater sees ~4-tick
    old events (one mapper hop re-stamps +1); the windowed report's
    pooled quantiles and per-arc p99 land in that band."""
    eng = Engine(_wf(), EngineConfig(
        batch_size=32, queue_capacity=128, chunk_size=4,
        telemetry=TelemetryConfig(window=4, impl="ref")))
    reports = []

    class H:
        state = None
        def on_telemetry(self, r): reports.append(r)
        def on_frontier_advance(self): pass

    def src(t, _mx):
        return {"S1": make_batch(np.arange(16) + t,
                                 ts=np.full(16, max(t - 3, 0), np.int32))}

    state, _ = eng.run(eng.init_state(), src, 16, handle=H())
    assert reports, "windowed observe never fired"
    rep = reports[-1]
    assert 0 < rep.event_latency_p50 <= rep.event_latency_p90 \
        <= rep.event_latency_p99
    assert rep.event_latency_p99 <= 8.0      # small fixed lag, no backlog
    assert rep.queue_delay_p99.get("U1", 0) > 0
    # report round-trips to JSON (no numpy scalars leak)
    json.dumps(rep.to_dict())


def test_recovery_replay_seconds_reported():
    """``recover()`` (restore + WAL replay) is timed into the next
    report's ``recovery_replay_s`` — the satellite bugfix: recovery
    previously ran unobserved."""
    from repro.core.durability import DurabilityConfig
    from repro.slates.flush import FlushConfig, FlushPolicy
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        def build():
            return Engine(_wf(), EngineConfig(
                batch_size=32, queue_capacity=128, chunk_size=4,
                telemetry=TelemetryConfig(window=4, impl="ref"),
                durability=DurabilityConfig(
                    dir=d, flush=FlushConfig(policy=FlushPolicy.EVERY_K,
                                             every_k=4))))

        eng = build()
        src = lambda t, _mx: {"S1": make_batch(
            np.arange(8) + t, ts=np.full(8, t, np.int32))}
        eng.run(eng.init_state(), src, 10)

        eng2 = build()
        state2 = eng2.recover()
        assert eng2.telemetry._recovery_s > 0
        rep = eng2.telemetry.observe(eng2, state2)
        assert rep.recovery_replay_s > 0
        if eng2.tracer is not None:       # trace off by default: None
            pass
        eng2.close()
        eng.close()


# ---------------------------------------------------------------------------
# span tracing
# ---------------------------------------------------------------------------

def test_trace_json_schema(tmp_path):
    """Exported trace is valid Chrome trace JSON: complete events with
    name/ph/ts/dur/pid/tid, JSON-safe args, ring-bounded."""
    from repro.telemetry.trace import Tracer
    tr = Tracer(capacity=8)
    for i in range(12):                  # overflow the ring
        with tr.span("tick", tick=np.int32(i),
                     arr=np.arange(2)) as sp:
            sp["outcome"] = np.float64(1.5)
    path = tr.export(str(tmp_path / "t.json"))
    doc = json.load(open(path))
    evs = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    assert len(evs) == 8                 # ring kept the newest 8
    for e in evs:
        assert {"name", "cat", "ph", "ts", "dur", "pid", "tid",
                "args"} <= set(e)
        assert e["ph"] == "X" and e["dur"] >= 0
        assert e["args"]["outcome"] == 1.5       # json-safe numpy
    assert [e["args"]["tick"] for e in evs] == list(range(4, 12))


def test_engine_run_emits_phase_spans():
    """A traced durable run records the split phases the drive loop
    already has — chunk dispatch, WAL fence, flush, observe."""
    from repro.core.durability import DurabilityConfig
    from repro.slates.flush import FlushConfig, FlushPolicy
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        eng = Engine(_wf(), EngineConfig(
            batch_size=32, queue_capacity=128, chunk_size=4,
            telemetry=TelemetryConfig(window=4, trace=True),
            durability=DurabilityConfig(
                dir=d, flush=FlushConfig(policy=FlushPolicy.EVERY_K,
                                         every_k=4))))
        src = lambda t, _mx: {"S1": make_batch(
            np.arange(8) + t, ts=np.full(8, t, np.int32))}
        eng.run(eng.init_state(), src, 8)
        names = {e["name"] for e in eng.tracer.events()}
        assert {"chunk_dispatch", "wal_fence", "flush_begin",
                "flush_commit"} <= names, names
        eng.close()


def test_control_log_jsonl(tmp_path):
    from repro.telemetry.trace import ControlLog
    p = tmp_path / "ctl.jsonl"
    log = ControlLog(str(p))
    log.log({"tick": 8, "action": None,
             "pressure": np.asarray([0.5, 0.25])})
    log.log({"tick": 16, "action": {"kind": "scale", "target": 4}})
    log.close()
    recs = [json.loads(l) for l in open(p)]
    assert [r["tick"] for r in recs] == [8, 16]
    assert recs[0]["pressure"] == [0.5, 0.25]
    assert recs[1]["action"]["kind"] == "scale"


# ---------------------------------------------------------------------------
# /metrics exposition
# ---------------------------------------------------------------------------

_SAMPLE = re.compile(
    r'^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? (\+Inf|-?[0-9.e+-]+)$')


def test_metrics_scrape_parses(tmp_path):
    """GET /metrics on the slate server returns Prometheus text 0.0.4:
    every sample line parses, counter and native histogram families are
    present, bucket series are cumulative and end at +Inf."""
    from repro.core.engine import StateHandle
    eng = Engine(_wf(), EngineConfig(
        batch_size=32, queue_capacity=128, chunk_size=4,
        telemetry=TelemetryConfig(window=4, impl="ref")))
    src = lambda t, _mx: {"S1": make_batch(
        np.arange(16) + t, ts=np.full(16, max(t - 2, 0), np.int32))}
    state, _ = eng.run(eng.init_state(), src, 8)
    h = StateHandle(eng, state)
    srv = h.serve()
    try:
        with urllib.request.urlopen(
                f"http://127.0.0.1:{srv.port}/metrics") as r:
            assert r.status == 200
            assert r.headers["Content-Type"].startswith("text/plain")
            text = r.read().decode()
    finally:
        srv.close()

    kinds = {}
    for line in text.strip().splitlines():
        if line.startswith("# TYPE"):
            _, _, name, kind = line.split()
            kinds[name] = kind
        elif not line.startswith("#"):
            assert _SAMPLE.match(line), f"unparseable sample: {line!r}"
    assert kinds.get("muppet_processed_total") == "counter"
    assert kinds.get("muppet_event_latency_ticks") == "gauge"
    assert kinds.get("muppet_event_latency_ticks_hist") == "histogram"

    # native histogram series: cumulative counts, +Inf last, _count
    # equals the +Inf bucket
    buckets = re.findall(
        r'muppet_event_latency_ticks_hist_bucket\{arc="U1",le="([^"]+)"\}'
        r' ([0-9.e+]+)', text)
    assert buckets and buckets[-1][0] == "+Inf"
    cums = [float(v) for _, v in buckets]
    assert cums == sorted(cums) and cums[-1] > 0
    count = re.search(
        r'muppet_event_latency_ticks_hist_count\{arc="U1"\} ([0-9.e+]+)',
        text)
    assert count and float(count.group(1)) == cums[-1]
    # integer-latency le edges: 2^b - 1 inclusive
    les = [b for b, _ in buckets[:-1]]
    assert les[:4] == ["0", "1", "3", "7"]


def test_render_prometheus_shapes():
    """Renderer unit: stats counters, report gauges with labels, and
    histogram families from synthetic inputs."""
    from repro.telemetry.prom import render_prometheus
    nb = 8
    counts = np.zeros((1, lat.pad_width(nb)), np.int32)
    counts[0, :4] = [2, 3, 0, 5]
    text = render_prometheus(
        stats={"tick": 7, "processed": {"M1": 10, "U1": 9},
               "queue_dropped": {"S2": 1}, "throttle_hits": 2},
        report=TelemetryReport(
            tick=7, ticks=4, n_shards=1, active=[0], window_s=0.1,
            events=np.asarray([32]), events_per_tick=np.asarray([8.0]),
            queue_depth=np.asarray([3]), queue_peak_delta=np.asarray([0]),
            dropped_delta=np.asarray([0]), occupancy=np.asarray([12]),
            pressure=np.asarray([0.5]), heavy_hitters=[],
            migration_pause_s=0.0,
            event_latency_p50=2.0, event_latency_p90=3.5,
            event_latency_p99=3.9, queue_delay_p99={"U1": 3.9}),
        hist={"U1": {"counts": counts, "sum": 17}}, n_buckets=nb)
    assert 'muppet_processed_total{op="M1"} 10' in text
    assert 'muppet_queue_dropped_total{queue="S2"} 1' in text
    assert 'muppet_throttle_hits_total 2' in text
    assert 'muppet_window_pressure{shard="0"} 0.5' in text
    assert 'muppet_event_latency_ticks{quantile="0.99"} 3.9' in text
    assert 'muppet_queue_delay_p99_ticks{arc="U1"} 3.9' in text
    assert 'muppet_event_latency_ticks_hist_sum{arc="U1"} 17' in text
    assert 'muppet_event_latency_ticks_hist_count{arc="U1"} 10' in text
    assert re.search(r'_bucket\{arc="U1",le="\+Inf"\} 10', text)


# ---------------------------------------------------------------------------
# control: the p99 watermark
# ---------------------------------------------------------------------------

def _report(pressure, p99):
    n = len(pressure)
    z = np.zeros(n)
    return TelemetryReport(
        tick=8, ticks=8, n_shards=n, active=list(range(n)),
        window_s=0.1, events=z, events_per_tick=np.asarray(pressure),
        queue_depth=z, queue_peak_delta=z, dropped_delta=z,
        occupancy=z, pressure=np.asarray(pressure, np.float64),
        heavy_hitters=[], migration_pause_s=0.0,
        event_latency_p99=p99)


def test_autoscaler_p99_watermark_scales_up():
    """With ``p99_high`` set, scale-up fires on tail latency even while
    mean pressure sits under the high watermark; a quiet p99 holds."""
    from repro.telemetry.controller import LoadAutoscaler
    pol = LoadAutoscaler(high=0.75, low=0.1, dwell=2, cooldown=1,
                         p99_high=5.0)
    r_hot = _report([0.3, 0.3], p99=12.0)      # mean well under high
    assert pol.decide(r_hot, n_active=2, limit=8) is None   # dwell 1/2
    act = pol.decide(r_hot, n_active=2, limit=8)
    assert act is not None and act.kind == "scale" and act.target == 4
    assert "p99" in act.reason

    pol.reset()
    r_cool = _report([0.3, 0.3], p99=2.0)
    for _ in range(4):
        assert pol.decide(r_cool, n_active=2, limit=8) is None


def test_autoscaler_p99_zero_keeps_pressure_trigger():
    from repro.telemetry.controller import LoadAutoscaler
    pol = LoadAutoscaler(high=0.75, low=0.1, dwell=1, cooldown=1)
    act = pol.decide(_report([0.9, 0.9], p99=0.0), n_active=2, limit=8)
    assert act is not None and act.kind == "scale"
