"""Parity tests for the fused slate-update path (ISSUE 1 tentpole):
Pallas kernel (interpret) vs jnp oracle vs the generic apply path, on
Zipf-skewed and all-duplicate-key batches, plus the ``supported()``
fallback and an engine-level fused run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import apply as apply_mod
from repro.core import packing
from repro.core.engine import Engine, EngineConfig
from repro.core.event import EventBatch
from repro.core.workflow import Workflow
from repro.slates import table as tbl
from tests.conftest import CountingUpdater, PassThroughMapper, make_batch


class FusedCountingUpdater(CountingUpdater):
    """Counter with the packed-path capability declared."""
    sum_mergeable = True


def zipf_keys(rng, n, n_keys=40, alpha=1.2):
    ranks = np.arange(1, n_keys + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    p /= p.sum()
    return rng.choice(n_keys, size=n, p=p).astype(np.int32)


def _table_state(impl, batch, capacity=256, n_batches=1, tick0=0):
    up = FusedCountingUpdater()
    table = tbl.make_table(capacity, up.slate_spec())
    for i in range(n_batches):
        table, ems, n = apply_mod.apply_associative(up, table, batch,
                                                    tick=tick0 + i,
                                                    impl=impl)
    return table, ems, n


@pytest.mark.parametrize("impl", ["jnp", "ref", "interpret"])
@pytest.mark.parametrize("case", ["zipf", "all_dup", "masked"])
def test_fused_matches_generic(impl, case):
    rng = np.random.default_rng(hash((impl, case)) % 2**31)
    if case == "zipf":
        keys = zipf_keys(rng, 96)
        valid = None
    elif case == "all_dup":
        keys = np.full(96, 7, np.int32)       # one giant run
        valid = None
    else:
        keys = zipf_keys(rng, 96)
        valid = (rng.random(96) > 0.3).tolist()
    xs = rng.integers(-40, 40, size=96).astype(np.int32)
    batch = make_batch(keys, xs, valid=valid)

    ref_t, ref_ems, ref_n = _table_state("off", batch, n_batches=3)
    got_t, got_ems, got_n = _table_state(impl, batch, n_batches=3)

    assert int(ref_n) == int(got_n)
    assert got_ems == {}
    assert np.array_equal(np.asarray(ref_t.keys), np.asarray(got_t.keys))
    assert np.array_equal(np.asarray(ref_t.vals["count"]),
                          np.asarray(got_t.vals["count"]))
    # f32 sums may differ in combine order, not value (ints here: exact)
    assert np.allclose(np.asarray(ref_t.vals["sum"]),
                       np.asarray(got_t.vals["sum"]), atol=1e-4)
    assert np.array_equal(np.asarray(ref_t.dirty), np.asarray(got_t.dirty))
    assert np.array_equal(np.asarray(ref_t.ts), np.asarray(got_t.ts))


def test_kernel_interpret_matches_ref_oracle():
    """kernel (interpret) vs kernels/slate_update/ref on a skewed batch,
    straight through the ops dispatcher."""
    from repro.kernels.slate_update import ops
    rng = np.random.default_rng(3)
    B, D, C = 128, 8, 256
    keys = np.sort(zipf_keys(rng, B)).astype(np.int32)
    deltas = rng.normal(size=(B, D)).astype(np.float32)
    run_last = np.concatenate([keys[1:] != keys[:-1], [True]])
    slots = np.where(run_last, (keys * 11 + 5) % C, -1).astype(np.int32)
    table = rng.normal(size=(C, D)).astype(np.float32)
    a = ops.slate_update(jnp.asarray(keys), jnp.asarray(deltas),
                         jnp.asarray(slots), jnp.asarray(table),
                         impl="interpret")
    b = ops.slate_update(jnp.asarray(keys), jnp.asarray(deltas),
                         jnp.asarray(slots), jnp.asarray(table),
                         impl="ref")
    assert np.abs(np.asarray(a) - np.asarray(b)).max() < 1e-4


def test_unsupported_width_falls_back_to_ref():
    """D not lane-aligned -> supported() is False and the dispatcher
    silently takes the oracle, even when Pallas is requested."""
    from repro.kernels.slate_update import kernel, ops
    rng = np.random.default_rng(4)
    B, D, C = 32, 5, 64                       # 5 % 8 != 0
    keys = np.sort(rng.integers(0, 10, B)).astype(np.int32)
    deltas = rng.normal(size=(B, D)).astype(np.float32)
    run_last = np.concatenate([keys[1:] != keys[:-1], [True]])
    slots = np.where(run_last, keys % C, -1).astype(np.int32)
    table = np.zeros((C, D), np.float32)
    assert not kernel.supported(jnp.asarray(deltas))
    out = ops.slate_update(jnp.asarray(keys), jnp.asarray(deltas),
                           jnp.asarray(slots), jnp.asarray(table),
                           impl="pallas")
    ref = ops.slate_update(jnp.asarray(keys), jnp.asarray(deltas),
                           jnp.asarray(slots), jnp.asarray(table),
                           impl="ref")
    assert np.allclose(np.asarray(out), np.asarray(ref))


def test_pack_unpack_roundtrip():
    spec = packing.pack_spec({"count": ((), jnp.int32),
                              "vec": ((3,), jnp.float32)})
    assert spec.width == 4 and spec.padded_width == 8
    rng = np.random.default_rng(5)
    tree = {"count": jnp.asarray(rng.integers(0, 1000, 17), jnp.int32),
            "vec": jnp.asarray(rng.normal(size=(17, 3)), jnp.float32)}
    buf = packing.pack(tree, spec)
    assert buf.shape == (17, 8) and buf.dtype == jnp.float32
    back = packing.unpack(buf, spec)
    assert np.array_equal(np.asarray(back["count"]),
                          np.asarray(tree["count"]))
    assert np.array_equal(np.asarray(back["vec"]), np.asarray(tree["vec"]))
    # unpadded pack serves the jnp backend
    assert packing.pack(tree, spec, pad=False).shape == (17, 4)


def test_fused_engine_counting_exact():
    """Engine-level: the fused path produces the same slates as the
    generic path over a multi-tick pipelined run."""
    rng = np.random.default_rng(6)
    ticks = [(zipf_keys(rng, 24),
              rng.integers(0, 9, 24).astype(np.int32)) for _ in range(6)]

    def final_state(fused):
        wf = Workflow([PassThroughMapper(), FusedCountingUpdater()],
                      external_streams=("S1",))
        eng = Engine(wf, EngineConfig(batch_size=32, queue_capacity=128,
                                      fused=fused))
        state = eng.init_state()
        for t, (keys, xs) in enumerate(ticks):
            state, _ = eng.step(state, {"S1": make_batch(
                keys, xs, ts=[t] * 24)})
        for t in range(3):   # drain
            state, _ = eng.step(state, {"S1": make_batch(
                [0] * 24, valid=[False] * 24, ts=[90 + t] * 24)})
        return eng, state

    eng_a, st_a = final_state("off")
    eng_b, st_b = final_state("jnp")
    truth = {}
    for keys, xs in ticks:
        for k, x in zip(keys, xs):
            c, s = truth.get(int(k), (0, 0))
            truth[int(k)] = (c + 1, s + int(x))
    for k, (c, s) in truth.items():
        for eng, st in ((eng_a, st_a), (eng_b, st_b)):
            slate = eng.read_slate(st, "U1", k)
            assert slate is not None and int(slate["count"]) == c
            assert abs(float(slate["sum"]) - s) < 1e-3


@pytest.mark.parametrize("impl", ["jnp", "ref", "interpret"])
def test_fused_zeroes_reused_slots_after_ttl_expiry(impl):
    """expire_ttl frees a slot but keeps the dead occupant's values;
    the additive path must not fold them into the new key's slate."""
    up = FusedCountingUpdater()
    batch = make_batch([7])

    def count_after_reuse(path):
        table = tbl.make_table(64, up.slate_spec())
        table, _, _ = apply_mod.apply_associative(up, table, batch,
                                                  tick=0, impl=path)
        table = tbl.expire_ttl(table, now=10, ttl=2)
        table, _, _ = apply_mod.apply_associative(up, table, batch,
                                                  tick=11, impl=path)
        slot, found = tbl.lookup(table, jnp.asarray([7], jnp.int32))
        assert bool(found[0])
        return int(table.vals["count"][int(slot[0])])

    assert count_after_reuse("off") == 1
    assert count_after_reuse(impl) == 1


def test_fused_requires_matching_lift_structure():
    class BadLift(FusedCountingUpdater):
        def lift(self, batch):
            return {"only_count": jnp.ones_like(batch.key)}

    up = BadLift()
    table = tbl.make_table(64, up.slate_spec())
    with pytest.raises(TypeError):
        apply_mod.apply_associative(up, table, make_batch([1, 2, 3]),
                                    tick=0, impl="jnp")


def test_generic_path_untouched_for_non_mergeable():
    """A plain AssociativeUpdater never routes through the packed path,
    whatever the impl knob says."""
    up = CountingUpdater()
    assert not apply_mod.fused_eligible(up)
    table = tbl.make_table(64, up.slate_spec())
    t2, ems, n = apply_mod.apply_associative(up, table,
                                             make_batch([5, 5, 6]),
                                             tick=0, impl="ref")
    slot, found = tbl.lookup(t2, jnp.asarray([5, 6], jnp.int32))
    assert bool(found[0]) and bool(found[1])
    assert int(t2.vals["count"][int(slot[0])]) == 2
