"""High-QPS slate read tier (DESIGN.md section 15).

Covers the batched device lookup (kernels/slate_lookup) against the
looped ``read_slate`` oracle — bitwise, on jnp and interpret backends,
including two-choice partials, active hot-key splits, and TTL-expired
rows — plus the off-engine tiers: ``SlateReplica`` staleness bounds
(through crash recovery) and the telemetry-admitted ``HotKeyCache``.

Multi-shard coverage runs in subprocesses (same pattern as
test_elasticity) so the main pytest process keeps the real single
device."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import Engine, EngineConfig, StateHandle
from repro.core.workflow import Workflow
from repro.slates import table as tbl
from tests.conftest import (CountingUpdater, PassThroughMapper, VSPEC,
                            make_batch)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# the lookup kernel against its oracle (tier-1, single device)
# ---------------------------------------------------------------------------

def _filled_table(n_rows=200, cap=512, d=8, seed=0):
    """Open-addressing table with one [C, D] value leaf (the layout the
    Pallas kernel accepts) holding ``n_rows`` random keys."""
    rng = np.random.default_rng(seed)
    keys = rng.choice(200_000, n_rows, replace=False).astype(np.int32)
    t = tbl.make_table(cap, {"v": ((d,), jnp.float32)})
    t, slot, _, placed = tbl.insert_or_find(
        t, jnp.asarray(keys), jnp.ones(n_rows, bool))
    vals = {"v": t.vals["v"].at[slot].set(
        rng.normal(size=(n_rows, d)).astype(np.float32))}
    t = tbl.SlateTable(keys=t.keys, ts=t.ts, dirty=t.dirty, vals=vals,
                       dropped=t.dropped)
    assert bool(np.asarray(placed).all())
    return t, keys


def test_lookup_kernel_interpret_matches_jnp_oracle():
    from repro.kernels.slate_lookup import ops as lk_ops
    t, keys = _filled_table()
    rng = np.random.default_rng(1)
    q = np.concatenate([rng.choice(keys, 64),
                        rng.integers(300_000, 400_000, 64)
                        ]).astype(np.int32)  # hits + guaranteed misses
    query = jnp.asarray(q)
    slot_r, found_r, rows_r = lk_ops.slate_lookup(
        t.keys, query, t.vals["v"], impl="jnp")
    slot_k, found_k, rows_k = lk_ops.slate_lookup(
        t.keys, query, t.vals["v"], impl="interpret")
    np.testing.assert_array_equal(np.asarray(found_r),
                                  np.asarray(found_k))
    np.testing.assert_array_equal(
        np.asarray(rows_r), np.asarray(rows_k))
    # found keys resolve to the exact live slot
    f = np.asarray(found_r)
    np.testing.assert_array_equal(
        np.asarray(t.keys)[np.asarray(slot_k)[f]], q[f])


def test_lookup_tree_multi_leaf_falls_back_bitwise():
    """Slate specs with several / scalar leaves can't use the kernel;
    lookup_tree must serve them through the jnp gather, same answers."""
    from repro.kernels.slate_lookup import ops as lk_ops
    from repro.kernels.slate_lookup import ref as lk_ref
    rng = np.random.default_rng(2)
    keys = rng.choice(10_000, 100, replace=False).astype(np.int32)
    t = tbl.make_table(256, {"count": ((), jnp.int32),
                             "sum": ((), jnp.float32)})
    t, slot, _, _ = tbl.insert_or_find(
        t, jnp.asarray(keys), jnp.ones(100, bool))
    vals = {"count": t.vals["count"].at[slot].set(
                jnp.arange(100, dtype=jnp.int32)),
            "sum": t.vals["sum"].at[slot].set(
                jnp.arange(100, dtype=jnp.float32) * 0.5)}
    q = np.concatenate([keys[:40],
                        np.arange(90_000, 90_024)]).astype(np.int32)
    found, rows = lk_ops.lookup_tree(t.keys, vals, jnp.asarray(q))
    slot_r, found_r = lk_ref.lookup_slots(t.keys, jnp.asarray(q))
    rows_r = lk_ref.gather_rows(vals, slot_r, found_r)
    np.testing.assert_array_equal(np.asarray(found),
                                  np.asarray(found_r))
    for k in rows:
        np.testing.assert_array_equal(np.asarray(rows[k]),
                                      np.asarray(rows_r[k]))


# ---------------------------------------------------------------------------
# engine.read_slates == looped read_slate (tier-1, single device)
# ---------------------------------------------------------------------------

class VecUpdater(CountingUpdater):
    """Single [8]-vector slate leaf: the layout the Pallas lookup
    kernel accepts, so impl="interpret" actually runs the kernel."""
    name = "UV"
    table_capacity = 256

    def slate_spec(self):
        return {"v": ((8,), jnp.float32)}

    def lift(self, batch):
        return {"v": jnp.broadcast_to(
            batch.value["x"].astype(jnp.float32)[:, None],
            (batch.key.shape[0], 8))}

    def combine(self, a, b):
        return {"v": a["v"] + b["v"]}

    def merge(self, s, d):
        return {"v": s["v"] + d["v"]}


def _run_engine(updaters, n_ticks=8, **cfg_kw):
    wf = Workflow([PassThroughMapper()] + updaters,
                  external_streams=("S1",))
    eng = Engine(wf, EngineConfig(batch_size=32, queue_capacity=256,
                                  **cfg_kw))
    state = eng.init_state()
    rng = np.random.default_rng(7)
    for t in range(n_ticks):
        keys = rng.integers(0, 60, 24).astype(np.int32)
        state, _ = eng.step(state, {"S1": make_batch(keys)})
    return eng, state


@pytest.mark.parametrize("impl", ["jnp", "interpret"])
def test_read_slates_bitwise_parity_with_looped(impl):
    eng, state = _run_engine([CountingUpdater(), VecUpdater()])
    keys = list(range(-4, 70))      # present, absent, negative
    for up in ("U1", "UV"):
        batched = eng.read_slates(state, up, keys, impl=impl)
        for k, b in zip(keys, batched):
            ref = eng.read_slate(state, up, k)
            if ref is None:
                assert b is None, (up, k)
            else:
                assert b is not None, (up, k)
                for leaf in ref:
                    np.testing.assert_array_equal(
                        np.asarray(ref[leaf]), np.asarray(b[leaf]))


def test_read_slates_ttl_expired_rows():
    """Rows past their TTL vanish from both read paths at the same
    tick; rows behind the freed slots stay visible (the probe-chain
    contract both paths share)."""
    class TTLCounter(CountingUpdater):
        ttl = 3

    eng, state = _run_engine([TTLCounter()], n_ticks=2)
    live = [k for k in range(60)
            if eng.read_slate(state, "U1", k) is not None]
    assert live
    # idle past the ttl: sweep evicts everything touched before
    for t in range(2, 8):
        state, _ = eng.step(
            state, {"S1": make_batch(np.asarray([500], np.int32))})
    batched = eng.read_slates(state, "U1", live)
    for k, b in zip(live, batched):
        assert eng.read_slate(state, "U1", k) is None
        assert b is None, k
    # the late key survives on both paths
    assert eng.read_slate(state, "U1", 500) is not None
    assert eng.read_slates(state, "U1", [500])[0] is not None


def test_read_slates_empty_and_unknown():
    eng, state = _run_engine([CountingUpdater()], n_ticks=1)
    assert eng.read_slates(state, "U1", []) == []
    with pytest.raises(KeyError):
        eng.read_slates(state, "nope", [1])


# ---------------------------------------------------------------------------
# hot-key cache (tier-1)
# ---------------------------------------------------------------------------

def test_hot_key_cache_admission_lru_ttl():
    from repro.slates.replica import HotKeyCache
    clock = [0.0]
    c = HotKeyCache(capacity=2, ttl_s=10.0, clock=lambda: clock[0])
    c.put("U1", 1, {"v": 1})            # not admitted -> dropped
    assert c.get("U1", 1) == (False, None)
    c.warm([1, 2, 3])
    c.put("U1", 1, {"v": 1})
    c.put("U1", 2, {"v": 2})
    assert c.get("U1", 1) == (True, {"v": 1})
    c.put("U1", 3, {"v": 3})            # evicts LRU (=2, 1 was touched)
    assert c.get("U1", 2) == (False, None)
    assert c.get("U1", 1) == (True, {"v": 1})
    clock[0] = 11.0                     # TTL expiry
    assert c.get("U1", 1) == (False, None)
    c.put("U1", 3, {"v": 3})
    c.invalidate()                      # frontier advanced
    assert len(c) == 0
    assert c.hot_keys() == [1, 2, 3]    # admission survives
    s = c.stats()
    assert s["invalidations"] == 1 and s["hits"] >= 2


def test_state_handle_serves_cached_hot_keys():
    from repro.slates.replica import HotKeyCache
    eng, state = _run_engine([CountingUpdater()])
    hot = next(k for k in range(60)
               if eng.read_slate(state, "U1", k) is not None)
    cache = HotKeyCache(capacity=8)
    cache.warm([hot])
    h = StateHandle(eng, state, cache=cache)
    first = h.read_slate("U1", hot)
    assert len(cache) == 1
    # cache now answers without touching the engine at all
    h.state = None
    assert h.read_slate("U1", hot) == first
    h.on_frontier_advance()             # invalidation hook
    assert len(cache) == 0


# ---------------------------------------------------------------------------
# replica tier: staleness bound through crash recovery (tier-1)
# ---------------------------------------------------------------------------

def test_replica_staleness_bound_across_crash_recovery(tmp_path):
    from repro.core.durability import DurabilityConfig
    from repro.slates.flush import FlushConfig, FlushPolicy
    from repro.slates.replica import SlateReplica, StaleReplicaError

    def build():
        wf = Workflow([PassThroughMapper(), CountingUpdater()],
                      external_streams=("S1",))
        return Engine(wf, EngineConfig(
            batch_size=32, queue_capacity=256,
            durability=DurabilityConfig(
                dir=str(tmp_path / "d"),
                flush=FlushConfig(policy=FlushPolicy.EVERY_K,
                                  every_k=4))))

    def src(t, ingest=None):
        rng = np.random.default_rng(300 + t)
        return {"S1": make_batch(
            rng.integers(0, 30, 24).astype(np.int32), ts=[t] * 24)}

    eng = build()
    state, _ = eng.run(eng.init_state(), src, 12)
    state = eng.checkpoint(state)
    rep = SlateReplica(eng.dur.store, eng.wf, max_staleness_ticks=8)
    with pytest.raises(StaleReplicaError):
        rep.read("U1", 0, now=0)        # never refreshed
    rep.refresh(eng.dur.frontier)
    tick = rep.snapshot_tick
    assert tick > 0
    # within the bound: snapshot values equal the live table
    live = [(k, eng.read_slate(state, "U1", k)) for k in range(30)]
    for k, lv in live:
        rv = rep.read("U1", k, now=tick)
        if lv is None:
            assert rv is None
        else:
            assert int(lv["count"]) == int(np.asarray(rv["count"]))
            assert float(lv["sum"]) == float(np.asarray(rv["sum"]))
    # beyond the bound: refused, not silently stale
    with pytest.raises(StaleReplicaError):
        rep.read("U1", 0, now=tick + 9)
    eng.close()

    # crash: memory gone.  A fresh engine recovers from the same store;
    # the replica keeps serving (its snapshot is the recovery source)
    eng2 = build()
    s2 = eng2.recover()
    rep2 = SlateReplica(eng2.dur.store, eng2.wf, max_staleness_ticks=8)
    rep2.refresh(eng2.dur.frontier)
    for k, lv in live:
        rv = rep2.read_many("U1", [k], now=rep2.snapshot_tick)[0]
        rlv = eng2.read_slate(s2, "U1", k)
        if rlv is None:
            assert rv is None
        else:
            assert int(np.asarray(rv["count"])) == int(rlv["count"])
    # the recovered engine runs on; the old snapshot ages out
    s2, _ = eng2.run(s2, src, 12, source_offset=12)
    s2 = eng2.checkpoint(s2)
    now = int(eng2.dur.frontier.tick)
    if now - rep2.snapshot_tick > 8:
        with pytest.raises(StaleReplicaError):
            rep2.read("U1", 0, now=now)
    rep2.refresh(eng2.dur.frontier)
    assert rep2.read("U1", 0, now=now) is not None or \
        eng2.read_slate(s2, "U1", 0) is None
    eng2.close()


def test_replica_incremental_refresh_matches_full_scan(tmp_path):
    """A delta-fed replica refreshed at every frontier must hold the
    same snapshot (keys, write ticks, values — bitwise) a fresh
    full-store scan at that frontier builds, including TTL pruning."""
    from repro.core.durability import DurabilityConfig
    from repro.slates.flush import FlushConfig, FlushPolicy
    from repro.slates.replica import SlateReplica

    class TtlCounting(CountingUpdater):
        name = "U2"
        ttl = 6

    wf = Workflow([PassThroughMapper(), CountingUpdater(), TtlCounting()],
                  external_streams=("S1",))
    eng = Engine(wf, EngineConfig(
        batch_size=32, queue_capacity=256,
        durability=DurabilityConfig(
            dir=str(tmp_path / "d"),
            flush=FlushConfig(policy=FlushPolicy.EVERY_K, every_k=4),
            track_flush_deltas=True)))

    def src(t, ingest=None):
        rng = np.random.default_rng(40 + t)
        return {"S1": make_batch(
            rng.integers(0, 50, 24).astype(np.int32), ts=[t] * 24)}

    state = eng.init_state()
    inc = SlateReplica(eng.dur.store, eng.wf, max_staleness_ticks=64,
                       flusher=eng.dur.flusher)
    for seg in range(3):
        state, _ = eng.run(state, src, 4, source_offset=seg * 4)
        state = eng.checkpoint(state)        # barrier: frontier advance
        inc.refresh(eng.dur.frontier)        # seg 0: scan; then deltas
        full = SlateReplica(eng.dur.store, eng.wf,
                            max_staleness_ticks=64)
        full.refresh(eng.dur.frontier)
        assert inc.snapshot_tick == full.snapshot_tick
        assert inc.stats()["rows"] == full.stats()["rows"]
        for up in ("U1", "U2"):
            for k in range(50):
                a = inc.read(up, k, now=inc.snapshot_tick)
                b = full.read(up, k, now=full.snapshot_tick)
                if b is None:
                    assert a is None, (up, k)
                else:
                    for leaf in b:
                        np.testing.assert_array_equal(
                            np.asarray(a[leaf]), np.asarray(b[leaf]))
    assert inc.stats()["rows"]["U1"] > 0
    eng.close()


# ---------------------------------------------------------------------------
# distributed batched reads (subprocess; slow)
# ---------------------------------------------------------------------------

PRELUDE = """
    import os
    os.environ["XLA_FLAGS"] = \
        "--xla_force_host_platform_device_count=%(devices)d"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.core.event import EventBatch
    from repro.core.operators import AssociativeUpdater
    from repro.core.workflow import Workflow
    from repro.core.distributed import DistConfig, DistributedEngine

    VSPEC = {'x': ((), jnp.float32)}

    class Counter(AssociativeUpdater):
        name = 'U1'; subscribes = ('S1',); in_value_spec = VSPEC
        out_streams = {}; table_capacity = 1024
        sum_mergeable = True
        def slate_spec(self):
            return {'count': ((), jnp.int32), 'sum': ((), jnp.float32)}
        def lift(self, b):
            return {'count': jnp.ones_like(b.key),
                    'sum': b.value['x']}
        def combine(self, a, b):
            return {'count': a['count'] + b['count'],
                    'sum': a['sum'] + b['sum']}
        def merge(self, s, d):
            return {'count': s['count'] + d['count'],
                    'sum': s['sum'] + d['sum']}

    class Vec(Counter):
        name = 'UV'
        def slate_spec(self):
            return {'v': ((8,), jnp.float32)}
        def lift(self, b):
            return {'v': jnp.broadcast_to(b.value['x'][:, None],
                                          (b.key.shape[0], 8))}
        def combine(self, a, b):
            return {'v': a['v'] + b['v']}
        def merge(self, s, d):
            return {'v': s['v'] + d['v']}

    def gb(keys, xs, t, n_sh):
        k = keys.reshape(n_sh, -1)
        return EventBatch(sid=jnp.zeros(k.shape, jnp.int32),
                          ts=jnp.full(k.shape, t, jnp.int32),
                          key=jnp.asarray(k),
                          value={'x': jnp.asarray(
                              xs.reshape(n_sh, -1))},
                          valid=jnp.ones(k.shape, bool))

    def check_parity(eng, state, updater, keys, impls):
        looped = [eng.read_slate(state, updater, int(k)) for k in keys]
        for impl in impls:
            batched = eng.read_slates(state, updater, keys, impl=impl)
            for k, a, b in zip(keys, looped, batched):
                assert (a is None) == (b is None), (impl, k, a, b)
                if a is None:
                    continue
                for leaf in a:
                    av, bv = np.asarray(a[leaf]), np.asarray(b[leaf])
                    assert np.array_equal(av, bv), (impl, k, leaf,
                                                    av, bv)
"""


def run_sub(body: str, devices: int = 4, timeout: int = 560):
    code = textwrap.dedent(PRELUDE % {"devices": devices}) + \
        textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", code],
                       capture_output=True, text=True,
                       env={**os.environ, "PYTHONPATH":
                            os.path.join(ROOT, "src")},
                       timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_distributed_read_slates_parity_plain_and_partials():
    """Batched sharded reads == looped ring reads, bitwise, on jnp and
    interpret — plain routing, two-choice partials, and a live hot-key
    entry (secondary-shard merge paths)."""
    out = run_sub("""
        def drive(cfg):
            mesh = Mesh(np.array(jax.devices()[:4]), ('data',))
            wf = Workflow([Counter(), Vec()], external_streams=('S1',))
            eng = DistributedEngine(wf, mesh, cfg)
            state = eng.init_state()
            rng = np.random.default_rng(11)
            for t in range(6):
                keys = rng.integers(0, 64, 32).astype(np.int32)
                xs = rng.integers(0, 99, 32).astype(np.float32)
                state, _ = eng.step(state, {'S1': gb(keys, xs, t, 4)})
            state, _ = eng.drain(state)
            return eng, state

        keys = np.arange(-4, 72, dtype=np.int32)   # hits + misses

        # plain primary-only routing
        eng, state = drive(DistConfig(batch_size=32,
                                      queue_capacity=256, fused='off'))
        check_parity(eng, state, 'U1', keys, ['jnp', 'interpret'])
        check_parity(eng, state, 'UV', keys, ['jnp', 'interpret'])

        # two-choice: hot keys spill partials onto a secondary shard
        eng2, state2 = drive(DistConfig(batch_size=32,
                                        queue_capacity=256, fused='off',
                                        two_choice_threshold=4))
        check_parity(eng2, state2, 'U1', keys, ['jnp', 'interpret'])
        check_parity(eng2, state2, 'UV', keys, ['jnp', 'interpret'])

        # hot-key split set entry flips the secondary merge on for one
        # key even without two-choice
        eng.read_slates.__self__  # noqa (keep eng alive)
        eng._hot_keys[0] = np.int32(7)
        eng._hot_valid[0] = True
        eng._read_fns.clear()     # with_sec changed for the read path
        check_parity(eng, state, 'U1', keys, ['jnp', 'interpret'])
        print('DIST-PARITY-OK')
    """)
    assert "DIST-PARITY-OK" in out


@pytest.mark.slow
def test_distributed_batched_reads_of_split_keys():
    """Active split_keys: every sub-key of a split hot key reads the
    same through the batched path as the looped path, and their merge
    equals read_split_slate."""
    out = run_sub("""
        from repro.core.hotspot import (KeySplitMapper, read_split_slate,
                                        subkeys_of)
        WAYS = 4
        mesh = Mesh(np.array(jax.devices()[:4]), ('data',))
        wf = Workflow([KeySplitMapper('S1', 'S2', VSPEC, ways=WAYS),
                       type('C', (Counter,), {'subscribes': ('S2',)})()],
                      external_streams=('S1',))
        eng = DistributedEngine(wf, mesh, DistConfig(
            batch_size=32, queue_capacity=512, fused='off'))
        state = eng.init_state()
        rng = np.random.default_rng(5)
        HOT = 9
        for t in range(8):
            keys = np.where(rng.random(32) < 0.5, HOT,
                            rng.integers(0, 40, 32)).astype(np.int32)
            xs = rng.integers(0, 99, 32).astype(np.float32)
            state, _ = eng.step(state, {'S1': gb(keys, xs, t, 4)})
        state, _ = eng.drain(state)

        subs = subkeys_of(HOT, WAYS)
        looped = [eng.read_slate(state, 'U1', s) for s in subs]
        present = [s for s, v in zip(subs, looped) if v is not None]
        assert len(present) >= 2, (subs, looped)   # key really split
        check_parity(eng, state, 'U1', np.asarray(subs, np.int32),
                     ['jnp', 'interpret'])
        merged = read_split_slate(eng, state, 'U1', HOT, WAYS)
        batched = eng.read_slates(state, 'U1', subs)
        total_c = sum(int(np.asarray(b['count']))
                      for b in batched if b is not None)
        total_s = sum(float(np.asarray(b['sum']))
                      for b in batched if b is not None)
        assert int(np.asarray(merged['count'])) == total_c
        assert abs(float(np.asarray(merged['sum'])) - total_s) < 1e-3
        print('SPLIT-READ-OK')
    """)
    assert "SPLIT-READ-OK" in out
