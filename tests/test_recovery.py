"""Crash-recovery integration tests (DESIGN.md section 10).

The headline property: a durable run that crashes (in-memory state
discarded), recovers from the KV store + WAL, and runs to completion
produces slates **bitwise equal** to an uninterrupted run of the same
durable configuration — exactly-once-by-merge for associative updaters.
Sequential updaters under ``barrier=False`` get the documented
at-least-once semantics instead.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.durability import DurabilityConfig, WALAppendError
from repro.core.engine import Engine, EngineConfig
from repro.core.event import EventBatch
from repro.core.operators import AssociativeUpdater
from repro.core.workflow import Workflow
from repro.slates.flush import (FlushConfig, FlushError, FlushFrontier,
                                FlushPolicy, Flusher, restore_into)
from repro.slates import table as tbl
from repro.slates.wal import WriteAheadLog
from tests.conftest import (LastValueUpdater, PassThroughMapper, VSPEC,
                            make_batch)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class SumCounter(AssociativeUpdater):
    """Counter eligible for the fused slate-update path."""
    name = "U1"
    subscribes = ("S2",)
    in_value_spec = VSPEC
    out_streams = {}
    table_capacity = 512
    sum_mergeable = True

    def slate_spec(self):
        return {"count": ((), jnp.int32), "sum": ((), jnp.float32)}

    def lift(self, batch):
        return {"count": jnp.ones_like(batch.key),
                "sum": batch.value["x"].astype(jnp.float32)}

    def combine(self, a, b):
        return {"count": a["count"] + b["count"], "sum": a["sum"] + b["sum"]}

    def merge(self, s, d):
        return {"count": s["count"] + d["count"], "sum": s["sum"] + d["sum"]}


def counting_source(t, ingest=None, n_keys=40, n=24):
    rng = np.random.default_rng(1000 + t)
    keys = rng.integers(0, n_keys, size=n).astype(np.int32)
    xs = rng.integers(0, 9, size=n).astype(np.int32)
    return {"S1": make_batch(keys, xs, ts=[t] * n)}


def table_dict(state, name):
    """{key: {leaf: np value}} for every occupied slot — slot-order
    independent (recovery re-inserts keys in a different order)."""
    t = state["tables"][name]
    keys = np.asarray(jax.device_get(t.keys))
    vals = jax.tree.map(lambda v: np.asarray(jax.device_get(v)), t.vals)
    out = {}
    for i, k in enumerate(keys):
        if k != -1:
            out[int(k)] = jax.tree.map(lambda v: v[i], vals)
    return out


def assert_tables_bitwise_equal(a, b):
    assert set(a) == set(b), (sorted(a), sorted(b))
    for k in a:
        la, lb = jax.tree.leaves(a[k]), jax.tree.leaves(b[k])
        for x, y in zip(la, lb):
            assert np.asarray(x).tobytes() == np.asarray(y).tobytes(), \
                (k, x, y)


def _counting_engine(d, fused, **dur_kw):
    wf = Workflow([PassThroughMapper(), SumCounter()],
                  external_streams=("S1",))
    dur_kw.setdefault("flush", FlushConfig(policy=FlushPolicy.EVERY_K,
                                           every_k=8))
    cfg = EngineConfig(batch_size=32, queue_capacity=128, chunk_size=4,
                       fused=fused,
                       durability=DurabilityConfig(dir=d, **dur_kw))
    return Engine(wf, cfg)


# ---------------------------------------------------------------------------
# the archetype headline: crash at tick k, recover, bitwise parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fused", ["jnp", "interpret"])
def test_crash_recover_bitwise_parity(tmp_path, fused):
    n_total, n_crash = 24, 12
    # uninterrupted durable run
    ea = _counting_engine(str(tmp_path / "a"), fused)
    sa, _ = ea.run(ea.init_state(), counting_source, n_total)
    base = table_dict(sa, "U1")
    base_tick = int(jax.device_get(sa["tick"]))
    ea.close()

    # durable run crashed at source tick k: every in-memory buffer dropped
    eb = _counting_engine(str(tmp_path / "b"), fused)
    sb, _ = eb.run(eb.init_state(), counting_source, n_crash)
    assert eb.dur.frontier.tick > 0          # a flush boundary happened
    del sb                                    # the crash
    eb.close()

    # recover on a fresh engine (new process in real life)
    eb2 = _counting_engine(str(tmp_path / "b"), fused)
    s2 = eb2.recover()
    s2, _ = eb2.run(s2, counting_source, n_total - n_crash,
                    source_offset=n_crash)
    rec = table_dict(s2, "U1")
    rec_tick = int(jax.device_get(s2["tick"]))
    eb2.close()

    assert base_tick == rec_tick             # drain ticks replay too
    assert_tables_bitwise_equal(base, rec)


def test_recover_uses_store_not_only_wal(tmp_path):
    """After WAL truncation at the frontier, pre-frontier events exist
    only as flushed slates — recovery must come from the store."""
    d = str(tmp_path / "t")
    ea = _counting_engine(d, "jnp", truncate_wal=True)
    sa, _ = ea.run(ea.init_state(), counting_source, 16)
    base = table_dict(sa, "U1")
    frontier = ea.dur.frontier
    assert frontier.tick > 0
    # log was compacted: nothing before the frontier survives
    first = next(iter(ea.dur.wal.replay()), None)
    if first is not None:
        assert first[0] >= frontier.tick
    ea.close()

    eb = _counting_engine(d, "jnp", truncate_wal=True)
    rec = table_dict(eb.recover(), "U1")
    eb.close()
    assert_tables_bitwise_equal(base, rec)


# ---------------------------------------------------------------------------
# sequential updaters: documented at-least-once under barrier=False
# ---------------------------------------------------------------------------

def _seq_source(t, ingest=None):
    rng = np.random.default_rng(7 + t)
    keys = rng.integers(0, 6, size=8).astype(np.int32)
    xs = rng.integers(0, 100, size=8).astype(np.int32)
    return {"S1": make_batch(keys, xs, ts=[t] * 8)}


def _seq_engine(d=None):
    wf = Workflow([PassThroughMapper(), LastValueUpdater()],
                  external_streams=("S1",))
    dur = None if d is None else DurabilityConfig(
        dir=d, barrier=False,
        flush=FlushConfig(policy=FlushPolicy.EVERY_K, every_k=4))
    return Engine(wf, EngineConfig(batch_size=16, queue_capacity=64,
                                   chunk_size=2, durability=dur))


def test_sequential_at_least_once(tmp_path):
    """barrier=False backdates the frontier by replay_slack: replay
    re-applies events already in the snapshot.  Nothing is lost (n >=
    baseline, some keys over-counted), and order-dependent state
    converges (`last` exact) — DESIGN.md 10.3."""
    e0 = _seq_engine()
    s0, _ = e0.run(e0.init_state(), _seq_source, 16)
    base = table_dict(s0, "U2")

    d = str(tmp_path / "seq")
    eb = _seq_engine(d)
    sb, _ = eb.run(eb.init_state(), _seq_source, 10)
    del sb
    eb.close()

    e2 = _seq_engine(d)
    s2 = e2.recover()
    s2, _ = e2.run(s2, _seq_source, 6, source_offset=10)
    rec = table_dict(s2, "U2")
    e2.close()

    assert set(rec) == set(base)
    duplicated = 0
    for k in base:
        assert int(rec[k]["last"]) == int(base[k]["last"])   # converges
        assert int(rec[k]["n"]) >= int(base[k]["n"])         # no loss
        duplicated += int(rec[k]["n"]) - int(base[k]["n"])
    assert duplicated > 0    # replay really re-applied in-flight events


# ---------------------------------------------------------------------------
# async WAL writer (DESIGN.md section 17): torn tails, surfaced errors,
# and the barrier=False frontier under deferred appends
# ---------------------------------------------------------------------------

def test_crash_during_async_append_trims_torn_tail(tmp_path):
    """Kill the writer mid-frame: the reopened WAL trims the torn tail
    to the last whole record, and resuming from the surviving prefix
    replays to bitwise parity with an uninterrupted run."""
    n_total = 24
    ea = _counting_engine(str(tmp_path / "a"), "jnp")
    sa, _ = ea.run(ea.init_state(), counting_source, n_total)
    base = table_dict(sa, "U1")
    ea.close()

    eb = _counting_engine(str(tmp_path / "b"), "jnp")
    sb, _ = eb.run(eb.init_state(), counting_source, 12)
    n_recs = len(list(eb.dur.wal.replay()))
    assert n_recs == 12                  # every source tick made it out
    assert eb.dur.frontier.tick > 0
    del sb                               # the crash
    eb.close()

    # simulate the writer thread dying mid-append: the tail frame is
    # half-written (cut inside the last record's payload)
    wal_path = os.path.join(str(tmp_path / "b"), "wal.log")
    with open(wal_path, "r+b") as f:
        f.truncate(os.path.getsize(wal_path) - 7)

    eb2 = _counting_engine(str(tmp_path / "b"), "jnp")
    recs = list(eb2.dur.wal.replay())
    assert len(recs) == n_recs - 1       # torn frame dropped, no garbage
    # records are FIFO per source tick (drain ticks append nothing), so
    # the surviving count IS the number of source ticks fully on disk
    m = len(recs)
    s2 = eb2.recover()
    s2, _ = eb2.run(s2, counting_source, n_total - m, source_offset=m)
    rec = table_dict(s2, "U1")
    eb2.close()
    assert_tables_bitwise_equal(base, rec)


def test_async_append_error_surfaces_at_fence(tmp_path):
    """A failed background append must fail the run at the next epoch
    fence — before any frontier advance could certify the lost tick."""
    eng = _counting_engine(str(tmp_path / "e"), "jnp")

    def broken(tick, sources):
        raise IOError("disk gone")

    eng.dur.wals[0].append = broken
    with pytest.raises(WALAppendError, match="disk gone"):
        eng.run(eng.init_state(), counting_source, 12)
    assert eng.dur.frontier.tick == 0    # never advanced past the loss
    eng.close()


def test_sequential_frontier_covers_async_tail(tmp_path):
    """barrier=False with the async writer: the backdated frontier must
    still point at-or-before every tick whose append was in flight, so
    replay-from-frontier re-covers the whole unflushed suffix
    (at-least-once, never at-most-once)."""
    d = str(tmp_path / "seqf")
    eng = _seq_engine(d)
    s, _ = eng.run(eng.init_state(), _seq_source, 12)
    frontier = eng.dur.frontier
    assert frontier.tick > 0
    all_ticks = [t for t, _ in eng.dur.wal.replay()]
    ticks = [t for t, _ in eng.dur.wal.replay(
        from_offset=frontier.wal_offset)]
    eng.close()
    # backdated frontier: replay starts at-or-before the frontier tick
    assert ticks and min(ticks) <= frontier.tick
    # ...and the suffix is the exact unbroken tail of the log: nothing
    # appended after the frontier offset was lost while queue-resident
    assert ticks == all_ticks[len(all_ticks) - len(ticks):]
    assert max(ticks) == max(all_ticks)


# ---------------------------------------------------------------------------
# satellite fixes: per-slot TTL restore, flusher error re-raise
# ---------------------------------------------------------------------------

class TTLCounter(SumCounter):
    ttl = 6


def _ttl_source(t, ingest=None):
    # key 7 appears only at tick 0; keys 0/1 every tick
    keys = [0, 1] if t else [0, 1, 7]
    return {"S1": make_batch(np.asarray(keys, np.int32),
                             ts=[t] * len(keys))}


def _ttl_engine(d):
    wf = Workflow([PassThroughMapper(), TTLCounter()],
                  external_streams=("S1",))
    cfg = EngineConfig(batch_size=16, queue_capacity=64, chunk_size=2,
                       durability=DurabilityConfig(
                           dir=d, flush=FlushConfig(
                               policy=FlushPolicy.EVERY_K, every_k=4)))
    return Engine(wf, cfg)


def test_ttl_expiry_after_recover(tmp_path):
    """Recovery restores per-slot `ts`, so TTL eviction after a crash
    follows the same schedule as the uninterrupted run (the old
    ``ts.max()`` restore kept idle keys alive too long)."""
    ea = _ttl_engine(str(tmp_path / "a"))
    sa, _ = ea.run(ea.init_state(), _ttl_source, 14)
    base = table_dict(sa, "U1")
    ea.close()
    assert 7 not in base and {0, 1} <= set(base)   # idle key expired

    eb = _ttl_engine(str(tmp_path / "b"))
    sb, _ = eb.run(eb.init_state(), _ttl_source, 5)   # key 7 still live
    assert 7 in table_dict(sb, "U1")
    del sb
    eb.close()

    eb2 = _ttl_engine(str(tmp_path / "b"))
    s2 = eb2.recover()
    s2, _ = eb2.run(s2, _ttl_source, 9, source_offset=5)
    rec = table_dict(s2, "U1")
    eb2.close()
    assert 7 not in rec
    assert_tables_bitwise_equal(base, rec)


def test_restore_into_preserves_per_slot_ts():
    spec = {"count": ((), jnp.int32)}
    t = tbl.make_table(32, spec)
    t = restore_into(t, np.asarray([3, 5], np.int32),
                     {"count": np.asarray([30, 50], np.int32)},
                     np.asarray([2, 9], np.int32))
    slot, found = tbl.lookup(t, jnp.asarray([3, 5], jnp.int32))
    assert bool(found.all())
    ts = np.asarray(jax.device_get(t.ts))[np.asarray(slot)]
    assert ts.tolist() == [2, 9]
    # TTL sweep sees the restored clocks: key 3 (idle since tick 2) dies
    t = tbl.expire_ttl(t, now=jnp.int32(10), ttl=5)
    _, found = tbl.lookup(t, jnp.asarray([3, 5], jnp.int32))
    assert found.tolist() == [False, True]


class _FailingStore:
    def put_many(self, *a, **k):
        raise IOError("store down")

    def flush(self):
        pass


def test_flusher_reraises_store_errors():
    fl = Flusher(_FailingStore(), FlushConfig(policy=FlushPolicy.IMMEDIATE))
    t = tbl.make_table(16, {"count": ((), jnp.int32)})
    t, slot, _, placed = tbl.insert_or_find(
        t, jnp.asarray([1], jnp.int32), jnp.ones(1, bool))
    t = tbl.write_slates(t, slot, placed,
                         {"count": jnp.asarray([5], jnp.int32)}, 1)
    fl.flush_table("U1", t)
    with pytest.raises(FlushError) as ei:
        fl.drain()
    assert isinstance(ei.value.errors[0], IOError)
    # errors were consumed; a clean drain passes and close() still
    # terminates the worker thread
    fl.drain()
    fl.close()
    assert not fl._thread.is_alive()


def test_frontier_never_advances_past_failed_flush(tmp_path):
    eng = _counting_engine(str(tmp_path / "f"), "jnp")
    eng.dur.flusher.store = _FailingStore()   # store dies mid-run
    with pytest.raises(FlushError):
        eng.run(eng.init_state(), counting_source, 12)
    assert eng.dur.frontier.tick == 0         # replay covers everything
    eng.dur.flusher.close()


# ---------------------------------------------------------------------------
# WAL compaction
# ---------------------------------------------------------------------------

def test_wal_truncate_before_keeps_offsets(tmp_path):
    wal = WriteAheadLog(str(tmp_path / "w.log"))
    offs = []
    for t in range(5):
        offs.append(wal.append(t, counting_source(t)))
    wal.truncate_before(offs[1])              # drop ticks 0..1
    assert [t for t, _ in wal.replay()] == [2, 3, 4]
    # logical offsets recorded before compaction stay valid
    assert [t for t, _ in wal.replay(from_offset=offs[2])] == [3, 4]
    assert wal.offset == offs[4]
    wal.close()
    wal2 = WriteAheadLog(str(tmp_path / "w.log"))   # survives reopen
    assert [t for t, _ in wal2.replay(from_offset=offs[2])] == [3, 4]
    wal2.close()


def test_frontier_file_roundtrip(tmp_path):
    p = str(tmp_path / "FRONTIER.json")
    assert FlushFrontier.load(p) is None
    FlushFrontier(tick=17, wal_offset=[3, 4]).save(p)
    f = FlushFrontier.load(p)
    assert f.tick == 17 and list(f.wal_offset) == [3, 4]


# ---------------------------------------------------------------------------
# >= 2-shard DistributedEngine: shard loss + re-routed recovery
# (subprocess for the 8-device host platform, like test_multishard)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_distributed_crash_recover_parity(tmp_path):
    code = textwrap.dedent("""
        import os, tempfile
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.core.event import EventBatch
        from repro.core.operators import AssociativeUpdater
        from repro.core.workflow import Workflow
        from repro.core.distributed import DistributedEngine, DistConfig
        from repro.core.durability import DurabilityConfig
        from repro.slates.flush import FlushConfig, FlushPolicy

        VSPEC = {'x': ((), jnp.int32)}

        class Counter(AssociativeUpdater):
            name = 'U1'; subscribes = ('S1',); in_value_spec = VSPEC
            out_streams = {}; table_capacity = 512
            def slate_spec(self):
                return {'count': ((), jnp.int32), 'sum': ((), jnp.int32)}
            def lift(self, b):
                return {'count': jnp.ones_like(b.key), 'sum': b.value['x']}
            def combine(self, a, b):
                return {'count': a['count'] + b['count'],
                        'sum': a['sum'] + b['sum']}
            def merge(self, s, d):
                return {'count': s['count'] + d['count'],
                        'sum': s['sum'] + d['sum']}

        mesh = Mesh(np.array(jax.devices()), ('data',))

        def src(t):
            rng = np.random.default_rng(50 + t)
            keys = rng.integers(0, 64, size=(8, 16)).astype(np.int32)
            return {'S1': EventBatch(
                sid=jnp.zeros((8, 16), jnp.int32),
                ts=jnp.full((8, 16), t, jnp.int32),
                key=jnp.asarray(keys),
                value={'x': jnp.asarray(keys % 7)},
                valid=jnp.ones((8, 16), bool))}

        def slates(eng, state):
            return {k: {lk: int(lv) for lk, lv in v.items()}
                    for k in range(64)
                    for v in [eng.read_slate(state, 'U1', k)]
                    if v is not None}

        def build(d):
            cfg = DistConfig(batch_size=32, queue_capacity=256,
                             durability=DurabilityConfig(
                                 dir=d, flush=FlushConfig(
                                     policy=FlushPolicy.EVERY_K,
                                     every_k=4)))
            wf = Workflow([Counter()], external_streams=('S1',))
            return DistributedEngine(wf, mesh, cfg)

        da, db = tempfile.mkdtemp(), tempfile.mkdtemp()
        ea = build(da)
        sa, _ = ea.run_durable(ea.init_state(), src, 12)
        base = slates(ea, sa)
        ea.dur.close()

        # crash at tick 10: store covers ticks < 8, WAL replay 8..9
        eb = build(db)
        sb, _ = eb.run_durable(eb.init_state(), src, 10)
        assert eb.dur.frontier.tick == 8
        del sb                              # crash: all shards lost
        eb.dur.close()

        eb2 = build(db)
        eb2.ring.fail(3)                    # machine 3 never comes back
        s2 = eb2.recover()
        tick2 = int(np.asarray(jax.device_get(s2['tick'])).max())
        assert tick2 == 10, tick2           # frontier 8 + 2 replayed
        s2, _ = eb2.run_durable(s2, src, 2, start_tick=tick2)
        rec = slates(eb2, s2)
        eb2.dur.close()

        assert set(base) == set(rec), (len(base), len(rec))
        bad = [k for k in base if base[k] != rec[k]]
        assert not bad, bad[:5]
        # the failed shard's keys really moved: its table is empty
        occ = np.asarray(jax.device_get(
            (s2['tables']['U1'].keys != -1).sum(axis=1)))
        assert occ[3] == 0 and occ.sum() == len(rec)
        print('DIST-RECOVERY-OK', len(rec))
    """)
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")},
        timeout=560)
    assert r.returncode == 0, r.stderr[-4000:]
    assert "DIST-RECOVERY-OK" in r.stdout


def test_resumed_run_does_not_rethrottle():
    """throttle_hits is cumulative: a second run() on carried-over state
    (the shape of every post-recover resume) must not read old hits as a
    fresh backpressure signal and spuriously halve the ingest limit."""
    from repro.core.queues import OverflowPolicy
    from tests.conftest import CountingUpdater

    wf = Workflow([PassThroughMapper(), CountingUpdater()],
                  external_streams=("S1",))
    cfg = EngineConfig(batch_size=16, queue_capacity=16, chunk_size=1,
                       overflow={"M1": OverflowPolicy.THROTTLE})
    eng = Engine(wf, cfg)

    def flood(t, ingest=None):     # 32 events into a 16-slot queue
        return {"S1": make_batch(np.arange(32, dtype=np.int32),
                                 ts=[t] * 32)}

    state, _ = eng.run(eng.init_state(), flood, 3)
    assert int(jax.device_get(state["throttle_hits"])) > 0

    seen = []

    def calm(t, ingest=None):      # 4 events: no overflow possible
        seen.append(ingest)
        return {"S1": make_batch(np.arange(4, dtype=np.int32),
                                 ts=[t] * 4)}

    state, _ = eng.run(state, calm, 4, source_offset=3)
    assert seen == [None] * 4, seen   # no spurious throttling
