import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.hashing import HashRing, hash_key, route, route_secondary


def test_route_targets_alive_shards_only():
    ring = HashRing(8)
    ring.fail(3)
    ring.fail(5)
    rh, rs = ring.table()
    keys = jnp.arange(10_000, dtype=jnp.int32)
    dest = np.asarray(route(keys, 0xABC, rh, rs))
    assert set(np.unique(dest)) <= {0, 1, 2, 4, 6, 7}


def test_consistent_hashing_minimal_movement():
    ring = HashRing(16)
    rh, rs = ring.table()
    keys = jnp.arange(50_000, dtype=jnp.int32)
    before = np.asarray(route(keys, 1, rh, rs))
    ring.fail(7)
    rh2, rs2 = ring.table()
    after = np.asarray(route(keys, 1, rh2, rs2))
    moved = (before != after)
    # only events owned by the dead shard move
    assert np.all(moved == (before == 7))
    assert not np.any(after == 7)


def test_secondary_differs_from_primary():
    ring = HashRing(8)
    rh, rs = ring.table()
    keys = jnp.arange(5_000, dtype=jnp.int32)
    p = np.asarray(route(keys, 42, rh, rs))
    s = np.asarray(route_secondary(keys, 42, rh, rs))
    assert np.mean(p != s) > 0.99     # virtually always a distinct shard


def test_salt_decorrelates_destinations():
    ring = HashRing(8)
    rh, rs = ring.table()
    keys = jnp.arange(20_000, dtype=jnp.int32)
    a = np.asarray(route(keys, 1, rh, rs))
    b = np.asarray(route(keys, 2, rh, rs))
    assert np.mean(a == b) < 0.4      # near 1/8 for independent hashing


def test_load_balance_roughly_uniform():
    ring = HashRing(8, vnodes=128)
    rh, rs = ring.table()
    keys = jnp.arange(80_000, dtype=jnp.int32)
    d = np.asarray(route(keys, 7, rh, rs))
    counts = np.bincount(d, minlength=8)
    assert counts.min() > 0.5 * counts.mean()
    assert counts.max() < 1.8 * counts.mean()


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 32), st.integers(0, 2**31 - 1))
def test_route_in_range(n_shards, key):
    ring = HashRing(n_shards)
    rh, rs = ring.table()
    d = int(route(jnp.asarray([key], jnp.int32), 9, rh, rs)[0])
    assert 0 <= d < n_shards
