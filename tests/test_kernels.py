"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret
mode executes the Pallas body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

TOL = {jnp.float32: 5e-5, jnp.bfloat16: 2e-2}


def _tol(dt):
    return TOL[dt]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Sq,Skv,H,Hkv,Dh,causal,window",
    [
        (2, 128, 128, 4, 2, 64, True, 0),
        (1, 256, 256, 2, 1, 128, True, 0),
        (1, 192, 192, 4, 4, 32, True, 48),
        (2, 96, 96, 2, 2, 64, False, 0),
        (1, 130, 130, 2, 1, 64, True, 0),       # pad path
    ])
def test_flash_attention_sweep(B, Sq, Skv, H, Hkv, Dh, causal, window,
                               dtype):
    from repro.kernels.attention.ref import mha
    from repro.kernels.flash_attention.kernel import flash_attention
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, Dh), dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, Dh), dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, Dh), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    ref = mha(q, k, v, causal=causal, window=window)
    err = np.abs(np.asarray(out, np.float32)
                 - np.asarray(ref, np.float32)).max()
    assert err < _tol(dtype), err


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,Hkv,Dh,window",
                         [(2, 256, 4, 2, 64, 0),
                          (3, 200, 4, 1, 32, 64),
                          (1, 512, 8, 8, 128, 0)])
def test_decode_attention_sweep(B, S, H, Hkv, Dh, window, dtype):
    from repro.kernels.decode_attention.kernel import decode_attention
    from repro.kernels.decode_attention.ref import decode_attend
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (B, 1, H, Dh), dtype)
    kc = jax.random.normal(ks[1], (B, S, Hkv, Dh), dtype)
    vc = jax.random.normal(ks[2], (B, S, Hkv, Dh), dtype)
    lens = jax.random.randint(ks[3], (B,), window + 1, S + 1)
    out = decode_attention(q, kc, vc, lens, window=window, block_k=64,
                           interpret=True)
    ref = decode_attend(q, kc, vc, lens, window=window)
    err = np.abs(np.asarray(out, np.float32)
                 - np.asarray(ref, np.float32)).max()
    assert err < _tol(dtype), err


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,N,P,chunk",
                         [(2, 128, 2, 16, 16, 32),
                          (1, 200, 3, 32, 16, 64),     # pad path
                          (2, 256, 1, 8, 64, 128)])
def test_ssd_scan_sweep(B, S, H, N, P, chunk, dtype):
    from repro.kernels.ssd.ref import ssd
    from repro.kernels.ssd_scan.kernel import ssd_scan
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = jax.random.normal(ks[0], (B, S, H, N), dtype)
    k = jax.random.normal(ks[1], (B, S, H, N), dtype) * 0.3
    v = jax.random.normal(ks[2], (B, S, H, P), dtype)
    la = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H))
                          ).astype(jnp.float32)
    y1, f1 = ssd_scan(q, k, v, la, chunk=chunk, interpret=True)
    y2, f2 = ssd(q, k, v, la, chunk=chunk)
    ey = np.abs(np.asarray(y1, np.float32)
                - np.asarray(y2, np.float32)).max()
    scale = np.abs(np.asarray(y2, np.float32)).max() + 1.0
    assert ey / scale < _tol(dtype), ey
    ef = np.abs(np.asarray(f1) - np.asarray(f2)).max()
    assert ef / (np.abs(np.asarray(f2)).max() + 1.0) < 5e-4


@pytest.mark.parametrize("B,D,C,n_keys",
                         [(64, 8, 128, 10), (256, 16, 512, 40),
                          (32, 8, 64, 1)])
def test_slate_update_sweep(B, D, C, n_keys):
    from repro.kernels.slate_update.kernel import slate_update as ker
    from repro.kernels.slate_update.ref import slate_update as ref
    rng = np.random.default_rng(B + D)
    keys = np.sort(rng.integers(0, n_keys, B)).astype(np.int32)
    deltas = rng.normal(size=(B, D)).astype(np.float32)
    run_last = np.concatenate([keys[1:] != keys[:-1], [True]])
    slots = np.where(run_last, (keys * 7 + 3) % C, -1).astype(np.int32)
    table = rng.normal(size=(C, D)).astype(np.float32)
    a = ker(jnp.asarray(keys), jnp.asarray(deltas), jnp.asarray(slots),
            jnp.asarray(table), interpret=True)
    b = ref(jnp.asarray(keys), jnp.asarray(deltas), jnp.asarray(slots),
            jnp.asarray(table))
    err = np.abs(np.asarray(a) - np.asarray(b)).max()
    assert err < 1e-4, err


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("rows,D,offset", [(64, 64, False), (100, 128, True),
                                           (256, 32, False)])
def test_rmsnorm_sweep(rows, D, offset, dtype):
    from repro.kernels.rmsnorm.kernel import rmsnorm as ker
    from repro.kernels.rmsnorm.ref import rmsnorm as ref
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    x = jax.random.normal(ks[0], (2, rows, D), dtype)
    w = jax.random.normal(ks[1], (D,), jnp.float32)
    a = ker(x, w, scale_offset=offset, block_rows=32, interpret=True)
    b = ref(x, w, scale_offset=offset)
    err = np.abs(np.asarray(a, np.float32)
                 - np.asarray(b, np.float32)).max()
    assert err < _tol(dtype), err


def test_ssd_step_matches_scan_tail():
    """Decode-step recurrence agrees with the chunked scan."""
    from repro.kernels.ssd.ref import ssd, ssd_step
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    B, S, H, N, P = 1, 33, 2, 8, 8
    q = jax.random.normal(ks[0], (B, S, H, N))
    k = jax.random.normal(ks[1], (B, S, H, N)) * 0.3
    v = jax.random.normal(ks[2], (B, S, H, P))
    la = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    y_all, state_all = ssd(q, k, v, la, chunk=16)
    # replay step-by-step
    state = jnp.zeros((B, H, N, P))
    for t in range(S):
        state, y_t = ssd_step(state, q[:, t].swapaxes(1, 1),
                              k[:, t], v[:, t], la[:, t])
    assert np.allclose(np.asarray(state), np.asarray(state_all),
                       atol=1e-4)
    assert np.allclose(np.asarray(y_t), np.asarray(y_all[:, -1]),
                       atol=1e-4)
