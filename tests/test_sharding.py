import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh


def mesh1():
    return make_host_mesh(n_data=1, n_model=1)


def test_divisibility_fallback():
    mesh = mesh1()
    rules = {"tp": ("model",), "fsdp": ("data",)}
    # both divisible by 1 -> sharded specs named
    spec = shd.to_pspec(("fsdp", "tp"), (8, 16), mesh, rules)
    assert spec == P("data", "model")
    # dims not divisible -> replicated
    rules2 = {"tp": ("model",), "fsdp": ("data",)}
    mesh_big = mesh  # 1-dev mesh: everything divides; simulate via prod
    spec2 = shd.to_pspec(("fsdp", None), (8, 16), mesh_big, rules2)
    assert spec2 == P("data")


def test_duplicate_axis_priority():
    mesh = mesh1()
    rules = {"kv_heads": ("model",), "kv_seq": ("model",),
             "act_batch": ("data",)}
    spec = shd.to_pspec(("act_batch", "kv_seq", "kv_heads", None),
                        (4, 128, 16, 64), mesh, rules)
    # kv_heads wins "model"; kv_seq falls back to replicated
    assert spec == P("data", None, "model")


def test_rules_phase_behaviour():
    mesh = make_host_mesh(n_data=1, n_model=1)
    train = shd.rules_for(mesh, phase="train")
    dec = shd.rules_for(mesh, phase="decode")
    lng = shd.rules_for(mesh, phase="decode", long_context=True)
    assert train["kv_seq"] == ()
    assert dec["kv_seq"] == ("model",)
    assert set(lng["kv_seq"]) >= {"model"}
    assert train["act_seq"] == ("model",)
    assert dec["act_seq"] == ()


def test_tree_shardings_on_model():
    from repro.configs import reduced_config
    from repro.models import lm
    cfg = reduced_config("qwen2-0.5b")
    model = lm.build(cfg)
    mesh = mesh1()
    rules = shd.rules_for(mesh, phase="train")
    shapes, specs = lm.param_specs(model)
    shardings = shd.tree_shardings(specs, shapes, mesh, rules)
    n = len(jax.tree.leaves(shardings,
                            is_leaf=lambda x: hasattr(x, "spec")))
    assert n == len(jax.tree.leaves(shapes))


def test_constrainer_identity_semantics():
    mesh = mesh1()
    rules = shd.rules_for(mesh, phase="train")
    constrain = shd.make_constrainer(mesh, rules)
    x = jnp.ones((4, 8, 16))

    @jax.jit
    def f(y):
        return constrain(y, ("act_batch", "act_seq", None))

    with mesh:
        out = f(x)
    assert np.allclose(np.asarray(out), 1.0)
