import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.event import EventBatch, compact, concat
from tests.conftest import make_batch


def test_sort_by_key_ts_orders_runs():
    b = make_batch([3, 1, 3, 2, 1], ts=[4, 2, 1, 0, 3])
    s = b.sort_by_key_ts()
    keys = np.asarray(s.key)
    ts = np.asarray(s.ts)
    assert list(keys) == [1, 1, 2, 3, 3]
    assert list(ts) == [2, 3, 0, 1, 4]


def test_sort_sinks_invalid():
    b = make_batch([5, 0, 7], valid=[True, False, True])
    s = b.sort_by_key_ts()
    assert list(np.asarray(s.valid)) == [True, True, False]
    assert np.asarray(s.key)[-1] == np.int32(2**31 - 1)


def test_compact_moves_valid_first():
    b = make_batch([1, 2, 3, 4], valid=[False, True, False, True])
    c = compact(b)
    assert list(np.asarray(c.valid)) == [True, True, False, False]
    assert list(np.asarray(c.key)[:2]) == [2, 4]


def test_concat_and_pad():
    a = make_batch([1, 2])
    b = make_batch([3])
    c = concat([a, b])
    assert c.capacity == 3
    p = c.pad_to(8)
    assert p.capacity == 8
    assert int(p.count()) == 3


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 20), min_size=1, max_size=40),
       st.data())
def test_sort_is_stable_permutation(keys, data):
    ts = data.draw(st.lists(st.integers(0, 10), min_size=len(keys),
                            max_size=len(keys)))
    b = make_batch(keys, ts=ts)
    s = b.sort_by_key_ts()
    # same multiset of (key, ts)
    got = sorted(zip(np.asarray(s.key).tolist(),
                     np.asarray(s.ts).tolist()))
    want = sorted(zip(keys, ts))
    assert got == want
    # nondecreasing lexicographic order
    pairs = list(zip(np.asarray(s.key).tolist(),
                     np.asarray(s.ts).tolist()))
    assert pairs == sorted(pairs)
