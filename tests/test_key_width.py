"""64-bit key plane, end-to-end (DESIGN.md 12.5 closure).

``EngineConfig(key_dtype="int64")`` widens event keys, slate tables,
the WAL frames, the sketch sample, and the kernel entry points behind
one switch.  Contracts under test:

- construction-time validation: int64 without ``jax_enable_x64`` is a
  hard error (silent demotion would corrupt keys), bad dtypes rejected;
- int32 behavior is bit-identical whether or not x64 is globally on
  (bare python key sequences must not widen);
- bitwise slate parity between ``key_dtype=int32`` and ``int64`` runs
  over the same in-band key stream, on jnp and interpret backends;
- keys beyond the int32 band (> 2**31) route, aggregate, flush,
  recover, and read back exactly;
- ``hotspot.split_window`` arithmetic is exact across the full 64-bit
  band (the documented 12.5 mid-band inexactness).

The x64-dependent tests skip unless ``JAX_ENABLE_X64=1`` (CI runs them
in the dedicated x64 lane).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import Engine, EngineConfig, resolve_key_dtype
from repro.core.event import EventBatch
from repro.core.workflow import Workflow
from tests.conftest import CountingUpdater, PassThroughMapper
from tests.test_recovery import table_dict, assert_tables_bitwise_equal

X64 = bool(jax.config.jax_enable_x64)
needs_x64 = pytest.mark.skipif(
    not X64, reason="int64 keys need JAX_ENABLE_X64=1 (x64 CI lane)")


def _wf():
    return Workflow([PassThroughMapper(), CountingUpdater()],
                    external_streams=("S1",))


def _engine(fused="jnp", key_dtype="int32", **kw):
    return Engine(_wf(), EngineConfig(batch_size=32, queue_capacity=128,
                                      chunk_size=4, fused=fused,
                                      key_dtype=key_dtype, **kw))


def _source(key_dtype, lift=0, until=None):
    """In-band random keys, optionally lifted beyond the int32 band.
    Ticks at/after ``until`` emit nothing (drain ticks, so queued
    mapper output reaches the updater before we scan the table)."""
    def src(t, ingest=None):
        n = 24 if until is None or t < until else 0
        rng = np.random.default_rng(300 + t)
        keys = rng.integers(0, 48, size=n).astype(key_dtype) + lift
        xs = rng.integers(0, 9, size=n).astype(np.int32)
        return {"S1": EventBatch.of(key=keys, value={"x": np.asarray(xs)},
                                    ts=np.full(n, t, np.int32))}
    return src


# ---------------------------------------------------------------------------
# construction-time validation
# ---------------------------------------------------------------------------

def test_key_dtype_rejected_without_x64():
    if X64:
        pytest.skip("x64 lane: demotion cannot happen here")
    with pytest.raises(RuntimeError, match="jax_enable_x64"):
        _engine(key_dtype="int64")


def test_key_dtype_rejects_non_integer():
    with pytest.raises(ValueError, match="int32 or int64"):
        resolve_key_dtype("float32")
    with pytest.raises(ValueError, match="int32 or int64"):
        _engine(key_dtype="uint8")


def test_int32_default_unchanged():
    eng = _engine()
    state = eng.init_state()
    assert state["tables"]["U1"].keys.dtype == jnp.int32
    assert state["queues"]["M1"].buf.key.dtype == jnp.int32
    assert eng.key_bits == 32


def test_bare_sequences_stay_int32():
    """Python-list keys must not widen under x64 — int32 runs stay
    bit-identical whether or not the flag is globally on."""
    b = EventBatch.of(key=[1, 2, 3], value={"x": np.zeros(3, np.int32)})
    assert b.key.dtype == jnp.int32
    assert b.ts.dtype == jnp.int32


# ---------------------------------------------------------------------------
# bitwise parity: int32 vs int64 over the same in-band stream
# ---------------------------------------------------------------------------

@needs_x64
@pytest.mark.parametrize("fused", ["jnp", "interpret"])
def test_bitwise_slate_parity_across_key_widths(fused):
    base = None
    for kd in ("int32", "int64"):
        eng = _engine(fused=fused, key_dtype=kd)
        state, _ = eng.run(eng.init_state(), _source(np.dtype(kd)), 12)
        tables = table_dict(state, "U1")
        if base is None:
            base = tables
        else:
            assert_tables_bitwise_equal(base, tables)


@needs_x64
@pytest.mark.parametrize("fused", ["jnp", "interpret"])
def test_wide_keys_beyond_int32_band(fused):
    """Keys above 2**31 aggregate and read back exactly — no fold
    collisions in-table, no silent truncation anywhere on the path."""
    lift = np.int64(3) << 32
    eng = _engine(fused=fused, key_dtype="int64")
    state, _ = eng.run(eng.init_state(),
                       _source(np.int64, lift=lift, until=12), 16)
    tables = table_dict(state, "U1")
    assert tables and all(int(k) >= int(lift) for k in tables)
    # per-key ground truth from the raw stream
    truth = {}
    for t in range(12):
        b = _source(np.int64, lift=lift)(t)["S1"]
        for k, x in zip(np.asarray(b.key), np.asarray(b.value["x"])):
            c, s = truth.get(int(k), (0, 0.0))
            truth[int(k)] = (c + 1, s + float(x))
    assert set(tables) == set(truth)
    for k, (c, s) in truth.items():
        assert int(tables[k]["count"]) == c
        assert float(tables[k]["sum"]) == s
    # the batched read path agrees with the table scan
    ks = sorted(tables)
    rows = eng.read_slates(state, "U1", np.asarray(ks, np.int64))
    for k, row in zip(ks, rows):
        assert row is not None
        assert int(row["count"]) == int(tables[k]["count"])


@needs_x64
def test_wide_key_durable_recovery_parity(tmp_path):
    """int64 keys survive the full durability loop: WAL frames keep the
    width, flushed slates restore, replay is bitwise exact."""
    from repro.core.durability import DurabilityConfig
    from repro.slates.flush import FlushConfig, FlushPolicy

    lift = np.int64(5) << 33

    def build(d):
        return Engine(_wf(), EngineConfig(
            batch_size=32, queue_capacity=128, chunk_size=4, fused="jnp",
            key_dtype="int64",
            durability=DurabilityConfig(dir=d, flush=FlushConfig(
                policy=FlushPolicy.EVERY_K, every_k=8))))

    src = _source(np.int64, lift=lift)
    ea = build(str(tmp_path / "a"))
    sa, _ = ea.run(ea.init_state(), src, 24)
    base = table_dict(sa, "U1")
    ea.close()

    eb = build(str(tmp_path / "b"))
    sb, _ = eb.run(eb.init_state(), src, 12)
    assert eb.dur.frontier.tick > 0
    del sb
    eb.close()

    eb2 = build(str(tmp_path / "b"))
    s2 = eb2.recover()
    s2, _ = eb2.run(s2, src, 12, source_offset=12)
    rec = table_dict(s2, "U1")
    eb2.close()
    assert_tables_bitwise_equal(base, rec)


# ---------------------------------------------------------------------------
# kernel entry points: interpret-mode wide lookup, segment-id update
# ---------------------------------------------------------------------------

@needs_x64
def test_slate_lookup_wide_interpret_matches_ref():
    from repro.kernels.slate_lookup import ops as lk_ops
    from repro.slates import table as tbl

    t = tbl.make_table(64, {"v": ((8,), jnp.float32)}, key_dtype=jnp.int64)
    keys = (jnp.arange(1, 9, dtype=jnp.int64) << 33) + 7
    t, slot, _, placed = tbl.insert_or_find(
        t, keys, jnp.ones((8,), bool))
    assert bool(placed.all())
    vals = {"v": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    t = tbl.write_slates(t, slot, placed, vals,
                         jnp.zeros((8,), jnp.int32))
    query = jnp.concatenate([keys[:4], keys[:4] + 1])   # 4 hits, 4 misses
    s_ref, f_ref, r_ref = lk_ops.slate_lookup(
        t.keys, query, t.vals["v"], impl="ref")
    s_k, f_k, r_k = lk_ops.slate_lookup(
        t.keys, query, t.vals["v"], impl="interpret")
    np.testing.assert_array_equal(np.asarray(f_ref), np.asarray(f_k))
    np.testing.assert_array_equal(np.asarray(s_ref), np.asarray(s_k))
    np.testing.assert_array_equal(np.asarray(r_ref), np.asarray(r_k))


@needs_x64
def test_segment_ids_preserve_wide_runs():
    """The fused update kernel sees sorted int64 keys as int32 segment
    ids; adjacent-equality (all the kernel uses) must be preserved even
    for keys whose low 32 bits collide."""
    from repro.kernels.slate_update.ops import _segment_ids
    keys = jnp.asarray([1, 1, 1 + (1 << 32), 1 + (1 << 32), 2 << 40],
                       jnp.int64)
    seg = np.asarray(_segment_ids(keys))
    assert seg.dtype == np.int32
    assert (seg[:-1] != seg[1:]).tolist() == \
        (np.asarray(keys[:-1]) != np.asarray(keys[1:])).tolist()


# ---------------------------------------------------------------------------
# hashing + hotspot arithmetic across the full band
# ---------------------------------------------------------------------------

@needs_x64
def test_fold_matches_int32_hash_in_band():
    """In-band keys hash identically at both widths, so int32 and int64
    runs route/probe/sketch the same — the parity tests' substrate."""
    from repro.core.hashing import hash_key
    ks32 = jnp.asarray([0, 1, 7, 2**31 - 1], jnp.int32)
    h32 = np.asarray(hash_key(ks32, salt=13))
    h64 = np.asarray(hash_key(ks32.astype(jnp.int64), salt=13))
    np.testing.assert_array_equal(h32, h64)


def test_split_window_exact_across_band():
    """DESIGN.md 12.5 closure: the split/merge window arithmetic is
    exact at 64-bit — pure int math, no x64 flag needed."""
    from repro.core.hotspot import split_window
    for ways in (2, 3, 4, 7):
        w32, w64 = split_window(ways, 32), split_window(ways, 64)
        assert w32 == (1 << 30) // ways
        assert w64 == (1 << 62) // ways
        # every in-window key splits below the next key's window start
        assert (w32 - 1) * ways + (ways - 1) < w32 * ways
        assert (w64 - 1) * ways + (ways - 1) < w64 * ways


@needs_x64
def test_split_merge_roundtrip_wide():
    from repro.core import hotspot
    keys = jnp.asarray([0, 5, 1 << 40, (1 << 60) // 3], jnp.int64)
    ts = jnp.arange(keys.shape[0], dtype=jnp.int32)
    for ways in (2, 4):
        sub = hotspot.split_keys(keys, ts, ways)
        assert sub.dtype == jnp.int64
        back = hotspot.merge_keys(sub, ways)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(keys))
