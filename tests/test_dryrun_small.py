"""Small-mesh dry-run smoke: build_cell + lower + compile + HLO-walk a
few representative cells on an 8-device host mesh (subprocess — device
count must be set before jax init)."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CASES = [
    ("qwen2-0.5b", "train_4k"),
    ("gemma3-1b", "decode_32k"),
    ("xlstm-350m", "long_500k"),
    ("whisper-tiny", "prefill_32k"),
]


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", CASES)
def test_cell_lowers_on_host_mesh(arch, shape):
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        from repro.launch.cells import build_cell, lower_cell
        from repro.analysis.hlo import analyze

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cell = build_cell({arch!r}, {shape!r}, mesh)
        compiled = lower_cell(cell).compile()
        cost = analyze(compiled.as_text())
        assert cost.flops > 0, "walker must see matmul flops"
        assert cost.hbm_bytes > 0
        assert cost.total_collective_bytes > 0, "model-sharded cells communicate"
        ma = compiled.memory_analysis()
        assert ma.temp_size_in_bytes > 0
        print("CELL-OK", cost.flops, cost.total_collective_bytes)
    """)
    r = subprocess.run([sys.executable, "-c", code],
                       capture_output=True, text=True,
                       env={**os.environ,
                            "PYTHONPATH": os.path.join(ROOT, "src")},
                       timeout=560)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr}"
    assert "CELL-OK" in r.stdout


@pytest.mark.slow
def test_walker_counts_scan_trip_counts():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.analysis.hlo import analyze

        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, None, length=16)
            return y

        mesh = jax.make_mesh((8,), ("data",))
        xs = jax.ShapeDtypeStruct((128, 256), jnp.float32,
                                  sharding=NamedSharding(mesh, P("data")))
        ws = jax.ShapeDtypeStruct((256, 256), jnp.float32,
                                  sharding=NamedSharding(mesh, P(None,
                                                                 "data")))
        cost = analyze(jax.jit(f).lower(xs, ws).compile().as_text())
        assert cost.flops == 16 * 2 * 16 * 256 * 256, cost.flops
        assert abs(cost.collective_bytes["all-gather"] - 256*32*4) < 1
        print("WALKER-OK")
    """)
    r = subprocess.run([sys.executable, "-c", code],
                       capture_output=True, text=True,
                       env={**os.environ,
                            "PYTHONPATH": os.path.join(ROOT, "src")},
                       timeout=300)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr}"
