"""Hotspot key-splitting: overflow-safe sub-key arithmetic (regression
for the int32 wrap collision) + ring-routed split-slate reads on both
engines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import Engine, EngineConfig
from repro.core.event import EventBatch
from repro.core.hotspot import (KeySplitMapper, SplitSlateReadError,
                                merge_keys, read_split_slate, split_keys,
                                split_window, subkeys_of)
from repro.core.workflow import Workflow
from tests.conftest import CountingUpdater, VSPEC

I32_MIN, I32_MAX = -(2 ** 31), 2 ** 31 - 1


def _roundtrip(keys, ways):
    karr = jnp.asarray(keys, jnp.int32)
    ts = jnp.arange(len(keys), dtype=jnp.int32)
    split = split_keys(karr, ts, ways)
    return np.asarray(merge_keys(split, ways)), np.asarray(split)


@pytest.mark.parametrize("ways", [2, 8, 64])
def test_split_merge_roundtrips_full_int32_range(ways):
    """Regression: the old ``key * ways + r`` wrapped in int32 for
    ``|key| >= 2**31 / ways`` — merge returned garbage at the extremes
    and distinct keys collided.  The windowed encoding round-trips every
    key in its exact domain (the split window plus everything at
    ``|k| >= 2**30``, which includes both int32 extremes)."""
    w = split_window(ways)
    keys = [0, 1, -1, 17, w - 1, -(w - 1),            # split, exact
            2 ** 30, -(2 ** 30), 2 ** 30 + 12345,     # passthrough, exact
            I32_MAX, I32_MIN, I32_MIN + 1]
    back, split = _roundtrip(keys, ways)
    assert np.array_equal(back, np.asarray(keys, np.int32)), \
        (keys, split.tolist(), back.tolist())


@pytest.mark.parametrize("ways", [8])
def test_old_wrap_collision_pair_no_longer_collides(ways):
    """With W=8 the old encoding mapped 2**28 and -(2**28) to the same
    wrapped sub-key (they differ by 2**32/W).  Now their sub-key sets
    are disjoint."""
    a = set(subkeys_of(2 ** 28, ways))
    b = set(subkeys_of(-(2 ** 28), ways))
    assert not (a & b)
    # and extremes never alias small split keys
    hot = set(subkeys_of(5, ways))
    for k in (I32_MAX, I32_MIN, 2 ** 30):
        assert not (hot & set(subkeys_of(k, ways)))


def test_split_spreads_hot_key_and_stays_in_window():
    ways = 8
    hot = jnp.full((64,), 7, jnp.int32)
    ts = jnp.zeros((64,), jnp.int32)
    split = np.asarray(split_keys(hot, ts, ways))
    assert len(np.unique(split)) >= 4           # spread across sub-keys
    assert set(split.tolist()) <= set(subkeys_of(7, ways))
    # extreme keys pass through unsplit (no wrap, no corruption)
    ext = jnp.asarray([I32_MAX, I32_MIN], jnp.int32)
    assert np.array_equal(
        np.asarray(split_keys(ext, ts[:2], ways)), np.asarray(ext))


def _split_workflow(ways):
    class SplitCounter(CountingUpdater):
        subscribes = ("S2",)
    split = KeySplitMapper("S1", "S2", VSPEC, ways=ways, name="M1")
    return Workflow([split, SplitCounter()], external_streams=("S1",))


def _feed(eng, state, keys, n_shards=None):
    ts = np.zeros(len(keys), np.int32)
    b = EventBatch.of(key=np.asarray(keys, np.int32),
                      value={"x": np.ones(len(keys), np.int32)}, ts=ts)
    if n_shards is not None:
        b = jax.tree.map(lambda x: x[None], b)
    state, _ = eng.step(state, {"S1": b})
    return state


def test_read_split_slate_single_engine():
    ways = 8
    eng = Engine(_split_workflow(ways),
                 EngineConfig(batch_size=64, queue_capacity=256))
    state = eng.init_state()
    keys = [7] * 40 + [I32_MAX] * 8 + [I32_MIN] * 8
    state = _feed(eng, state, keys)
    for _ in range(3):
        state, _ = eng.step(state, {})
    assert int(read_split_slate(eng, state, "U1", 7, ways)["count"]) == 40
    assert int(read_split_slate(
        eng, state, "U1", I32_MAX, ways)["count"]) == 8
    assert int(read_split_slate(
        eng, state, "U1", I32_MIN, ways)["count"]) == 8


def test_read_split_slate_distributed_routes_ring():
    """The distributed path: every sub-key read routes through the hash
    ring via DistributedEngine.read_slate (1-device mesh keeps this in
    tier-1; multi-shard coverage lives in test_elasticity)."""
    from jax.sharding import Mesh
    from repro.core.distributed import DistConfig, DistributedEngine
    ways = 8
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    eng = DistributedEngine(_split_workflow(ways), mesh,
                            DistConfig(batch_size=64, queue_capacity=256))
    state = eng.init_state()
    state = _feed(eng, state, [7] * 24 + [I32_MIN] * 4, n_shards=1)
    for _ in range(3):
        state = eng._step_empty(state)
    assert int(read_split_slate(eng, state, "U1", 7, ways)["count"]) == 24
    assert int(read_split_slate(
        eng, state, "U1", I32_MIN, ways)["count"]) == 4
    assert read_split_slate(eng, state, "U1", 12345, ways) is None


def test_read_split_slate_named_errors():
    ways = 4
    eng = Engine(_split_workflow(ways),
                 EngineConfig(batch_size=8, queue_capacity=32))
    state = eng.init_state()
    with pytest.raises(SplitSlateReadError, match="unknown updater"):
        read_split_slate(eng, state, "nope", 1, ways)
    with pytest.raises(SplitSlateReadError, match="read_slate"):
        read_split_slate(object(), state, "U1", 1, ways)
    with pytest.raises(SplitSlateReadError, match="no combine"):
        read_split_slate(eng, state, "M1", 1, ways)   # a mapper
