import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.slates import table as tbl

SPEC = {"v": ((), jnp.float32)}


def test_insert_lookup_roundtrip():
    t = tbl.make_table(64, SPEC)
    keys = jnp.asarray([7, 13, 99], jnp.int32)
    t, slot, found, placed = tbl.insert_or_find(t, keys,
                                                jnp.ones(3, bool))
    assert bool(placed.all()) and not bool(found.any())
    t = tbl.write_slates(t, slot, placed,
                         {"v": jnp.asarray([1., 2., 3.])}, 0)
    slot2, found2 = tbl.lookup(t, keys)
    assert bool(found2.all())
    assert np.allclose(np.asarray(t.vals["v"])[np.asarray(slot2)],
                       [1., 2., 3.])


def test_missing_key_gets_insertion_point():
    t = tbl.make_table(32, SPEC)
    slot, found = tbl.lookup(t, jnp.asarray([5], jnp.int32))
    assert not bool(found[0]) and int(slot[0]) >= 0


def test_ttl_expiry():
    t = tbl.make_table(32, SPEC)
    keys = jnp.asarray([1, 2], jnp.int32)
    t, slot, _, placed = tbl.insert_or_find(t, keys, jnp.ones(2, bool))
    t = tbl.write_slates(t, slot, placed, {"v": jnp.asarray([1., 2.])},
                         tick=0)
    # touch key 1 at tick 50
    t, slot1, _, p1 = tbl.insert_or_find(t, jnp.asarray([1], jnp.int32),
                                         jnp.ones(1, bool))
    t = tbl.write_slates(t, slot1, p1, {"v": jnp.asarray([9.])}, tick=50)
    t = tbl.expire_ttl(t, now=60, ttl=30)
    _, found = tbl.lookup(t, keys)
    assert bool(found[0]) and not bool(found[1])   # 2 expired, 1 alive


def test_read_slates_initializes_missing():
    t = tbl.make_table(32, SPEC)
    keys = jnp.asarray([4], jnp.int32)
    t, slot, found, placed = tbl.insert_or_find(t, keys, jnp.ones(1, bool))
    init = lambda n: {"v": jnp.full((n,), 7.0)}
    vals = tbl.read_slates(t, slot, found, init)
    assert float(vals["v"][0]) == 7.0   # fresh slate initialized


@settings(max_examples=25, deadline=None)
@given(st.sets(st.integers(0, 10_000), min_size=1, max_size=200))
def test_no_key_lost_under_load(keys):
    """Property: unique keys inserted below ~50% load factor all land."""
    cap = max(512, 4 * len(keys))
    t = tbl.make_table(cap, SPEC)
    karr = jnp.asarray(sorted(keys), jnp.int32)
    t, slot, found, placed = tbl.insert_or_find(
        t, karr, jnp.ones(len(keys), bool))
    assert bool(placed.all())
    assert int(t.dropped) == 0
    slot2, found2 = tbl.lookup(t, karr)
    assert bool(found2.all())
    # slots are unique
    assert len(np.unique(np.asarray(slot2))) == len(keys)


def test_dropped_counted_when_full():
    t = tbl.make_table(8, SPEC)  # tiny
    keys = jnp.arange(64, dtype=jnp.int32)
    t, slot, found, placed = tbl.insert_or_find(t, keys,
                                                jnp.ones(64, bool))
    assert int(t.dropped) > 0
    assert int(placed.sum()) <= 8
