import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.checkpoint import Checkpointer


def make_tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)),
                   "b": jnp.zeros((16,))},
        "opt": {"m": jnp.ones((8, 16)), "count": jnp.asarray(7)},
    }


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = make_tree()
    ck.save(10, tree, blocking=True)
    assert ck.latest_step() == 10
    restored = ck.restore(10, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert np.allclose(np.asarray(a), np.asarray(b))
    ck.close()


def test_uncommitted_checkpoints_ignored(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, make_tree(), blocking=True)
    # simulate a crash mid-write: directory without COMMIT
    os.makedirs(str(tmp_path / "step_0000000009"))
    assert ck.latest_step() == 5
    ck.close()


def test_keep_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, make_tree(), blocking=True)
    assert ck.all_steps() == [3, 4]
    ck.close()


def test_elastic_restore_new_sharding(tmp_path):
    """Restore with explicit shardings (the elastic-mesh path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    ck = Checkpointer(str(tmp_path))
    tree = make_tree()
    ck.save(1, tree, blocking=True)
    mesh = make_host_mesh(n_data=1, n_model=1)
    shardings = jax.tree.map(
        lambda x: NamedSharding(mesh, P()), tree)
    restored = ck.restore(1, tree, shardings)
    assert np.allclose(np.asarray(restored["params"]["w"]),
                       np.asarray(tree["params"]["w"]))
    ck.close()


@pytest.mark.slow
def test_trainer_restart_resumes(tmp_path):
    """Kill training mid-run; a fresh Trainer resumes from the last
    committed step with identical state."""
    from repro.configs import reduced_config
    from repro.data.synthetic import TokenStream
    from repro.launch.train import Trainer

    cfg = reduced_config("qwen2-0.5b")
    tr = Trainer(cfg, ckpt_dir=str(tmp_path), ckpt_every=5)
    params, opt = tr.init(0)
    stream = TokenStream(cfg.vocab_size, 4, 32, seed=0)
    with pytest.raises(RuntimeError, match="simulated node failure"):
        tr.run(params, opt, iter(stream), 100, fail_at=12)
    tr.ckpt.wait()
    assert tr.ckpt.latest_step() == 10    # last committed multiple of 5

    tr2 = Trainer(cfg, ckpt_dir=str(tmp_path), ckpt_every=5)
    p2, o2 = tr2.init(0)
    p2, o2 = tr2.maybe_restore(p2, o2)
    assert tr2.step == 10
    assert int(o2.count) == 10
    p2, o2, losses = tr2.run(p2, o2, iter(TokenStream(
        cfg.vocab_size, 4, 32, seed=1)), 13)
    assert tr2.step == 13
    tr.close()
    tr2.close()
