import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.event import EventBatch
from repro.slates import table as tbl
from repro.slates.flush import (Flusher, FlushConfig, FlushPolicy,
                                dirty_snapshot, restore_into)
from repro.slates.kvstore import KVStore
from repro.slates.wal import WriteAheadLog

SPEC = {"count": ((), jnp.int32)}


@pytest.fixture
def store(tmp_path):
    return KVStore(str(tmp_path / "kv"), replicas=3, write_quorum=2,
                   read_quorum=2)


def test_put_get_roundtrip(store):
    store.put("U1", 42, {"count": np.int32(7)}, ts=1)
    assert int(store.get("U1", 42)["count"]) == 7
    assert store.get("U1", 43) is None


def test_newest_ts_wins(store):
    store.put("U1", 1, {"count": np.int32(1)}, ts=5)
    store.put("U1", 1, {"count": np.int32(2)}, ts=9)
    assert int(store.get("U1", 1)["count"]) == 2


def test_quorum_survives_replica_failure(store):
    store.put("U1", 5, {"count": np.int32(3)}, ts=0)
    store.set_replica_down(1)
    assert int(store.get("U1", 5)["count"]) == 3
    store.put("U1", 6, {"count": np.int32(4)}, ts=1)   # still quorum-2
    assert int(store.get("U1", 6)["count"]) == 4


def test_write_quorum_failure_raises(store):
    store.set_replica_down(0)
    store.set_replica_down(1)
    with pytest.raises(IOError):
        store.put("U1", 7, {"count": np.int32(1)}, ts=0)
        store.flush()


def test_ttl_and_gc(store):
    store.put("U1", 9, {"count": np.int32(1)}, ts=0, ttl=5)
    assert store.get("U1", 9, now=3) is not None
    assert store.get("U1", 9, now=10) is None
    removed = store.gc("U1", now=10)
    assert removed >= 1


def test_scan_bulk_read(store):
    for k in range(20):
        store.put("U1", k, {"count": np.int32(k)}, ts=0)
    data = store.scan("U1")
    assert len(data) == 20
    assert int(data[13]["count"]) == 13


def test_flusher_and_crash_restore(store):
    t = tbl.make_table(64, SPEC)
    keys = jnp.asarray([3, 5], jnp.int32)
    t, slot, _, placed = tbl.insert_or_find(t, keys, jnp.ones(2, bool))
    t = tbl.write_slates(t, slot, placed,
                         {"count": jnp.asarray([30, 50], jnp.int32)}, 2)
    fl = Flusher(store, FlushConfig(policy=FlushPolicy.IMMEDIATE))
    t = fl.flush_table("U1", t)
    fl.drain()
    assert not fl.errors
    assert not bool(np.asarray(jax.device_get(t.dirty)).any())
    # crash -> empty table -> restore from store
    fresh = tbl.make_table(64, SPEC)
    data = store.scan("U1")
    ks = np.array(sorted(data), np.int32)
    vals = {"count": np.array([int(data[k]["count"]) for k in ks],
                              np.int32)}
    restored = restore_into(fresh, ks, vals, np.full(len(ks), 2))
    slot2, found = tbl.lookup(restored, keys)
    assert bool(found.all())
    got = np.asarray(jax.device_get(restored.vals["count"]))[
        np.asarray(slot2)]
    assert got.tolist() == [30, 50]
    fl.close()


def test_flush_policies():
    fl_cfg = FlushConfig(policy=FlushPolicy.EVERY_K, every_k=4)
    t = tbl.make_table(16, SPEC)

    class Dummy:
        cfg = fl_cfg
    f = Flusher.__new__(Flusher)
    f.cfg = fl_cfg
    assert f.should_flush(0, t) and f.should_flush(4, t)
    assert not f.should_flush(3, t)


def test_wal_append_replay(tmp_path):
    wal = WriteAheadLog(str(tmp_path / "wal.log"))
    b1 = EventBatch.of(key=np.asarray([1, 2], np.int32),
                       value={"x": np.asarray([5, 6], np.int32)})
    wal.append(0, {"S1": b1})
    wal.append(1, {"S1": b1})
    wal.append(2, {"S1": b1})
    wal.close()
    wal2 = WriteAheadLog(str(tmp_path / "wal.log"))
    records = list(wal2.replay(from_tick=1))
    assert [t for t, _ in records] == [1, 2]
    _, src = records[0]
    assert np.asarray(src["S1"].key).tolist() == [1, 2]
    assert np.asarray(src["S1"].value["x"]).tolist() == [5, 6]
    wal2.close()


def test_compression_on_disk(store, tmp_path):
    big = {"blob": np.zeros(4096, np.float32)}   # compressible
    store.put("U1", 1, big, ts=0)
    store.flush()
    total = 0
    for root, _, files in os.walk(str(tmp_path / "kv")):
        for fn in files:
            total += os.path.getsize(os.path.join(root, fn))
    assert total < 4096 * 4 * 3   # zstd beats raw x3 replicas easily
