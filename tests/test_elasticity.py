"""Live elasticity (DESIGN.md section 12): weighted fixed-shape ring,
runtime shard join/leave with loss-free slate + queue migration, and the
load-aware rebalance.

Multi-shard coverage runs in SUBPROCESSES (like test_multishard) so the
main pytest process keeps the real single device; one fast parity test
stays in tier-1 on a 1-device mesh (it exercises the full migration
kernel — drain, host remap, table rebuild, device_put)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hashing import HashRing, route

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# weighted fixed-shape ring (host-level, tier-1)
# ---------------------------------------------------------------------------

def test_ring_table_shape_is_fixed_across_membership_and_weights():
    ring = HashRing(8, vnodes=32)
    shape0 = ring.table()[0].shape
    ring.fail(3)
    assert ring.table()[0].shape == shape0
    ring.join(3)
    assert ring.table()[0].shape == shape0
    ring.set_weights(np.array([4.0, 1, 1, 1, 1, 1, 1, 0.25]))
    assert ring.table()[0].shape == shape0
    # pad entries alias the wrap target: routing still lands on active
    # shards only
    ring.fail(0)
    rh, rs = ring.table()
    dest = np.asarray(route(jnp.arange(20_000, dtype=jnp.int32), 5,
                            rh, rs))
    assert 0 not in set(np.unique(dest))


def test_ring_secondary_stays_distinct_across_pad_region():
    """Deactivating half the shards fills half the table with pad
    entries; the two-choice secondary walk must still find a distinct
    shard when it crosses them (pads cycle the real ring)."""
    from repro.core.hashing import route_secondary
    ring = HashRing(8, vnodes=64)
    for s in (4, 5, 6, 7):
        ring.fail(s)
    rh, rs = ring.table()
    keys = jnp.arange(100_000, dtype=jnp.int32)
    p = np.asarray(route(keys, 42, rh, rs))
    sec = np.asarray(route_secondary(keys, 42, rh, rs))
    assert (p == sec).mean() < 0.001
    assert set(np.unique(sec)) <= {0, 1, 2, 3}


def test_ring_vnode_budget_and_proportionality():
    ring = HashRing(8, vnodes=64)
    counts = ring.vnode_counts()
    assert counts.sum() == 8 * 64 and (counts == 64).all()
    ring.set_weights(np.array([2.0, 1, 1, 1, 1, 1, 1, 0.5]))
    counts = ring.vnode_counts()
    assert counts.sum() == 8 * 64           # fixed total budget
    assert counts[0] > 64 > counts[7] >= 1  # proportional, min 1
    ring.fail(2)
    counts = ring.vnode_counts()
    assert counts.sum() == 7 * 64 and counts[2] == 0


def test_ring_weight_shed_moves_arcs_directionally():
    keys = jnp.arange(60_000, dtype=jnp.int32)
    ring = HashRing(8, vnodes=64)
    before = np.asarray(route(keys, 9, *ring.table()))
    share0 = (before == 0).mean()
    ring.set_weights(np.array([0.25, 1, 1, 1, 1, 1, 1, 1]))
    after = np.asarray(route(keys, 9, *ring.table()))
    assert (after == 0).mean() < 0.5 * share0   # hot shard sheds arcs
    # the fixed vnode budget redistributes (others gain high-index
    # vnodes), so some third-party arcs move too — but the change stays
    # a rebalance, not a reshuffle
    moved = before != after
    assert moved.mean() < 0.35
    assert (after == 0).sum() < (before == 0).sum()


def test_ring_equal_weights_match_unweighted_construction():
    """All-alive equal-weight ring must be bit-identical to the classic
    per-shard-vnodes build (elasticity must not perturb existing
    routing)."""
    ring = HashRing(8, vnodes=64)
    real = ring.real_size
    assert real == 8 * 64                     # no padding when full
    ids = np.repeat(np.arange(8, dtype=np.uint32), 64)
    vix = np.tile(np.arange(64, dtype=np.uint32), 8)
    from repro.core.hashing import _mix32_np
    h = _mix32_np(ids * np.uint32(0x9E3779B9) ^ _mix32_np(
        vix + np.uint32(ring.seed)))
    order = np.argsort(h, kind="stable")
    assert np.array_equal(ring.ring_hashes, h[order])
    assert np.array_equal(ring.ring_shards, ids[order].astype(np.int32))


# ---------------------------------------------------------------------------
# fast tier-1 parity: the migration kernel end to end on a 1-device mesh
# ---------------------------------------------------------------------------

def test_migration_kernel_preserves_slates_bitwise():
    from jax.sharding import Mesh
    from repro.core.distributed import DistConfig, DistributedEngine
    from repro.core.event import EventBatch
    from repro.core.workflow import Workflow
    from tests.conftest import CountingUpdater

    class U(CountingUpdater):
        subscribes = ("S1",)

    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    wf = Workflow([U()], external_streams=("S1",))
    eng = DistributedEngine(wf, mesh, DistConfig(batch_size=32,
                                                 queue_capacity=128))
    state = eng.init_state()
    rng = np.random.default_rng(0)
    for t in range(4):
        keys = rng.integers(0, 40, 24).astype(np.int32)
        b = EventBatch.of(key=keys,
                          value={"x": rng.integers(0, 9, 24).astype(
                              np.int32)},
                          ts=np.full(24, t, np.int32))
        state, _ = eng.step(state, {"S1": jax.tree.map(
            lambda x: x[None], b)})
    state, _ = eng.drain(state)
    before = {k: eng.read_slate(state, "U1", k) for k in range(40)}
    # reweight forces the full reconfigure path: drain barrier, host
    # remap, per-shard table rebuild, device_put with target sharding
    state, rep = eng._reconfigure(state, weights=np.array([3.0]))
    assert rep.moved_rows["U1"] == 0
    after = {k: eng.read_slate(state, "U1", k) for k in range(40)}
    for k in range(40):
        if before[k] is None:
            assert after[k] is None
            continue
        assert int(before[k]["count"]) == int(after[k]["count"])
        assert np.float32(before[k]["sum"]).tobytes() == \
            np.float32(after[k]["sum"]).tobytes()   # bitwise


def test_host_grow_pads_only_shard_leaves():
    """_host_grow pads exactly the [old_n, ...] leaves: a non-shard
    leaf keeps its shape, the new slot's table starts empty, its queue
    starts drained, and the tick carries over."""
    from jax.sharding import Mesh
    from repro.core.distributed import DistConfig, DistributedEngine
    from repro.core.workflow import Workflow
    from tests.conftest import CountingUpdater

    class U(CountingUpdater):
        subscribes = ("S1",)

    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    wf = Workflow([U()], external_streams=("S1",))
    eng = DistributedEngine(wf, mesh, DistConfig(batch_size=16,
                                                 queue_capacity=64))
    state = eng.init_state()
    host = jax.device_get(state)
    host["aux"] = np.arange(15).reshape(3, 5)    # non-shard leaf
    eng.n_shards = 2                             # pad target (test rig)
    try:
        out = eng._host_grow(host, 1)
    finally:
        eng.n_shards = 1
    assert out["aux"].shape == (3, 5)            # untouched
    assert out["tick"].shape == (2,)
    assert int(out["tick"][1]) == int(np.asarray(host["tick"])[0])
    t = out["tables"]["U1"]
    assert t.keys.shape[0] == 2 and (t.keys[1] == -1).all()
    q = out["queues"]["U1"]
    assert q.size.shape == (2,) and int(q.size[1]) == 0


def test_durability_resize_shrink_closes_extra_wals(tmp_path):
    """Compaction's WAL shrink: resize down closes the dropped slots'
    logs and truncates the frontier offset list; resize back up appends
    fresh WALs at their (empty) head."""
    from repro.core.durability import DurabilityConfig, EngineDurability
    from repro.core.workflow import Workflow
    from repro.slates.flush import FlushConfig, FlushPolicy
    from tests.conftest import CountingUpdater

    class U(CountingUpdater):
        subscribes = ("S1",)

    wf = Workflow([U()], external_streams=("S1",))
    cfg = DurabilityConfig(dir=str(tmp_path),
                           flush=FlushConfig(policy=FlushPolicy.EVERY_K,
                                             every_k=4))
    dur = EngineDurability(cfg, wf, queue_capacity=64, batch_size=16,
                           n_shards=4)
    assert len(dur.wals) == 4
    dur.record_frontier(0)
    dur.resize(2)
    assert len(dur.wals) == 2
    assert len(dur.frontier_offsets()) == 2
    dur.resize(4)
    assert len(dur.wals) == 4 and len(dur.frontier_offsets()) == 4
    dur.close()


# ---------------------------------------------------------------------------
# multi-shard elasticity (subprocess; slow)
# ---------------------------------------------------------------------------

PRELUDE = """
    import os
    os.environ["XLA_FLAGS"] = \
        "--xla_force_host_platform_device_count=%(devices)d"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.core.event import EventBatch
    from repro.core.operators import AssociativeUpdater
    from repro.core.workflow import Workflow
    from repro.core.distributed import (AutoscalePolicy, DistConfig,
                                        DistributedEngine)

    VSPEC = {'x': ((), jnp.float32)}

    class Counter(AssociativeUpdater):
        name = 'U1'; subscribes = ('S1',); in_value_spec = VSPEC
        out_streams = {}; table_capacity = 1024
        sum_mergeable = True
        def slate_spec(self):
            return {'count': ((), jnp.int32), 'sum': ((), jnp.float32)}
        def lift(self, b):
            return {'count': jnp.ones_like(b.key),
                    'sum': b.value['x']}
        def combine(self, a, b):
            return {'count': a['count'] + b['count'],
                    'sum': a['sum'] + b['sum']}
        def merge(self, s, d):
            return {'count': s['count'] + d['count'],
                    'sum': s['sum'] + d['sum']}

    def gb(keys, xs, t, n_sh):
        k = keys.reshape(n_sh, -1)
        return EventBatch(sid=jnp.zeros(k.shape, jnp.int32),
                          ts=jnp.full(k.shape, t, jnp.int32),
                          key=jnp.asarray(k),
                          value={'x': jnp.asarray(xs.reshape(n_sh, -1))},
                          valid=jnp.ones(k.shape, bool))

    def slates(eng, state, n_keys):
        out = []
        for k in range(n_keys):
            s = eng.read_slate(state, 'U1', k)
            out.append((0, 0.0) if s is None else
                       (int(s['count']), float(s['sum'])))
        return out
"""


def run_sub(body: str, devices: int = 8, timeout: int = 560):
    code = textwrap.dedent(PRELUDE % {"devices": devices}) + \
        textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", code],
                       capture_output=True, text=True,
                       env={**os.environ, "PYTHONPATH":
                            os.path.join(ROOT, "src")},
                       timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_scale_2to4_parity_fast():
    """Small live scale-up mid-run == never-scaled run, slate for slate
    (the tier-1 smoke; the full 8->16 bitwise check is in the slow
    suite)."""
    out = run_sub("""
        def run(scale_to=None):
            mesh = Mesh(np.array(jax.devices()[:2]), ('data',))
            wf = Workflow([Counter()], external_streams=('S1',))
            eng = DistributedEngine(wf, mesh, DistConfig(
                batch_size=32, queue_capacity=256, fused='off'))
            state = eng.init_state()
            rng = np.random.default_rng(0)
            for t in range(6):
                keys = rng.integers(0, 32, 32).astype(np.int32)
                xs = rng.integers(0, 99, 32).astype(np.float32)
                if scale_to and t == 3:
                    state, rep = eng.scale(state, scale_to)
                    assert rep.recompiled and eng.n_shards == scale_to
                state, _ = eng.step(state, {'S1': gb(keys, xs, t,
                                                     eng.n_shards)})
            state, _ = eng.drain(state)
            return slates(eng, state, 32)
        a = run(); b = run(4)
        assert a == b, (a, b)
        print('FAST-PARITY-OK')
    """, devices=4)
    assert "FAST-PARITY-OK" in out


def test_device_migration_parity_fast():
    """The device tier (DESIGN.md 14.1): shape-preserving reconfigures
    move rows with on-device all_to_all and must match the host remap
    bitwise; reports carry the measured pause and payload, and
    heat_owners maps keys per updater salt."""
    out = run_sub("""
        class C2(Counter):
            name = 'U2'
        def run(mode, reconf):
            mesh = Mesh(np.array(jax.devices()[:4]), ('data',))
            wf = Workflow([Counter(), C2()], external_streams=('S1',))
            eng = DistributedEngine(wf, mesh, DistConfig(
                batch_size=32, queue_capacity=256, fused='off',
                device_migration=mode))
            state = eng.init_state()
            rng = np.random.default_rng(3)
            reps = []
            for t in range(6):
                keys = rng.integers(0, 48, 32).astype(np.int32)
                xs = rng.integers(0, 99, 32).astype(np.float32)
                if reconf and t == 2:
                    state, rep = eng.remove_shards(state, [3])
                    reps.append(rep)
                if reconf and t == 4:
                    state, rep = eng.scale(state, 4)    # rejoin
                    reps.append(rep)
                state, _ = eng.step(state, {'S1': gb(keys, xs, t, 4)})
            state, _ = eng.drain(state)
            return slates(eng, state, 48), reps, eng
        ref, _, _ = run('off', False)
        dev, dreps, eng = run('auto', True)
        host, hreps, _ = run('off', True)
        assert [r.path for r in dreps] == ['device', 'device'], dreps
        assert [r.path for r in hreps] == ['host', 'host']
        assert not any(r.recompiled for r in dreps)
        assert dev == ref and host == ref, (dev, host, ref)
        assert all(r.pause_s > 0 for r in dreps + hreps)
        assert sum(r.bytes_moved for r in dreps) > 0
        assert sum(dreps[0].moved_rows.values()) > 0
        # per-updater salted owner rows: [n_updaters, K], rows differ
        own = eng.heat_owners(np.arange(256, dtype=np.int32))
        assert own.shape == (2, 256)
        assert (own[0] != own[1]).any()
        print('DEVICE-PARITY-OK')
    """, devices=4)
    assert "DEVICE-PARITY-OK" in out


def test_grow_compact_grow_roundtrip_fast():
    """Physical grow -> auto-compaction -> grow again round-trips with
    exact counts and actually frees the parked slots' table HBM."""
    out = run_sub("""
        def tbytes(state):
            return sum(v.nbytes for v in jax.tree.leaves(state['tables']))
        mesh = Mesh(np.array(jax.devices()[:2]), ('data',))
        wf = Workflow([Counter()], external_streams=('S1',))
        eng = DistributedEngine(wf, mesh, DistConfig(
            batch_size=32, queue_capacity=256, fused='off',
            compact_threshold=0.5))
        state = eng.init_state()
        rng = np.random.default_rng(11)
        truth = np.zeros(48, np.int64)
        for t in range(9):
            keys = rng.integers(0, 48, 32).astype(np.int32)
            xs = np.ones(32, np.float32)
            for k in keys: truth[k] += 1
            if t == 2:
                state, rep = eng.scale(state, 4)        # physical grow
                assert rep.recompiled and eng.n_shards == 4
            if t == 5:
                big = tbytes(state)
                state, rep = eng.remove_shards(state, [2, 3])
                assert rep.recompiled and rep.path == 'host'
                assert eng.n_shards == 2                # auto-compacted
                assert tbytes(state) < big
            if t == 7:
                state, rep = eng.scale(state, 4)        # grow again
                assert rep.recompiled and eng.n_shards == 4
            state, _ = eng.step(state, {'S1': gb(keys, xs, t,
                                                 eng.n_shards)})
        state, _ = eng.drain(state)
        got = np.array([c for c, _ in slates(eng, state, 48)])
        assert (got == truth).all(), (got - truth)
        assert eng.stats(state)['exchange_dropped'] == 0
        print('ROUNDTRIP-OK')
    """, devices=4)
    assert "ROUNDTRIP-OK" in out


@pytest.mark.slow
@pytest.mark.parametrize("fused", ["jnp", "interpret"])
def test_device_path_scale_8to16_bitwise_parity(fused):
    """Acceptance bar for the device tier: on a pre-provisioned 16-slot
    mesh, activating 8 -> 16 moves rows via all_to_all (no recompile)
    with bitwise slate parity against both a never-scaled run and the
    host remap."""
    out = run_sub("""
        FUSED = %r
        def run(scale_to=None, mode='auto'):
            mesh = Mesh(np.array(jax.devices()[:16]), ('data',))
            wf = Workflow([Counter()], external_streams=('S1',))
            eng = DistributedEngine(wf, mesh, DistConfig(
                batch_size=64, queue_capacity=512, fused=FUSED,
                device_migration=mode, compact_threshold=0.0))
            state = eng.init_state()
            state, rep0 = eng.remove_shards(state, range(8, 16))
            assert not rep0.recompiled
            rng = np.random.default_rng(7)
            rep = None
            for t in range(12):
                keys = rng.integers(0, 96, 128).astype(np.int32)
                xs = rng.integers(0, 99, 128).astype(np.float32)
                if scale_to and t == 6:
                    state, rep = eng.scale(state, scale_to)
                    assert not rep.recompiled       # content-only swap
                state, _ = eng.step(state, {'S1': gb(keys, xs, t, 16)})
            state, _ = eng.drain(state)
            return slates(eng, state, 96), rep, eng, state
        a, _, _, _ = run()
        b, rep, eng, state = run(16)
        assert rep.path == 'device', rep
        assert rep.pause_s > 0 and rep.bytes_moved > 0
        assert sum(rep.moved_rows.values()) > 0
        for (ca, sa), (cb, sb) in zip(a, b):
            assert ca == cb
            assert np.float32(sa).tobytes() == np.float32(sb).tobytes()
        c, hrep, _, _ = run(16, mode='off')
        assert hrep.path == 'host' and b == c
        assert eng.stats(state)['exchange_dropped'] == 0
        rows16 = [int(jax.device_get(
            (state['tables']['U1'].keys[i] != -1).sum()))
            for i in range(16)]
        assert sum(1 for r in rows16[8:] if r > 0) >= 4, rows16
        print('DEVICE-8TO16-OK')
    """ % fused, devices=16)
    assert "DEVICE-8TO16-OK" in out


@pytest.mark.slow
def test_compaction_durable_recovery():
    """Compaction under durability: the WAL set shrinks with the mesh,
    counts stay exact through compact + continued feeding, and a crash
    after compaction recovers on the compacted layout."""
    out = run_sub("""
        import tempfile
        from repro.core.durability import DurabilityConfig
        from repro.slates.flush import FlushConfig, FlushPolicy
        def tbytes(state):
            return sum(v.nbytes for v in jax.tree.leaves(state['tables']))
        with tempfile.TemporaryDirectory() as d:
            def make(n):
                return DistributedEngine(
                    Workflow([Counter()], external_streams=('S1',)),
                    Mesh(np.array(jax.devices()[:n]), ('data',)),
                    DistConfig(batch_size=32, queue_capacity=256,
                               fused='off',
                               durability=DurabilityConfig(
                                   dir=d, flush=FlushConfig(
                                       policy=FlushPolicy.EVERY_K,
                                       every_k=2))))
            eng = make(8)
            state = eng.init_state()
            truth = np.zeros(64, np.int64)
            def src(t, _mx):
                r = np.random.default_rng(100 + t)
                ks = r.integers(0, 64, 64).astype(np.int32)
                for k in ks: truth[k] += 1
                return {'S1': gb(ks, np.ones(64, np.float32), t,
                                 eng.n_shards)}
            state, _ = eng.run(state, src, 6)
            b0 = tbytes(state)
            state, rep = eng.remove_shards(state, list(range(2, 8)))
            assert eng.n_shards == 2 and rep.recompiled
            assert rep.path == 'host' and rep.bytes_moved > 0
            assert len(eng.dur.wals) == 2           # WAL set compacted
            assert tbytes(state) < b0 / 3           # HBM actually freed
            state, _ = eng.drain(state)
            got = np.array([c for c, _ in slates(eng, state, 64)])
            assert (got == truth).all(), (got - truth)
            state, _ = eng.run(state, src, 2)       # keep feeding at 2
            state, _ = eng.drain(state)
            got = np.array([c for c, _ in slates(eng, state, 64)])
            assert (got == truth).all(), (got - truth)
            del state                               # crash
            eng2 = make(2)
            rec = eng2.recover()
            rec, _ = eng2.drain(rec)
            got2 = np.array([c for c, _ in slates(eng2, rec, 64)])
            assert (got2 == truth).all(), (got2 - truth)
            eng.close(); eng2.close()
        print('COMPACT-DURABLE-OK')
    """)
    assert "COMPACT-DURABLE-OK" in out


@pytest.mark.slow
def test_multiaxis_pod_data_growth():
    """Multi-axis growth: a ('pod','data') mesh scales 4 -> 8 along its
    trailing axis with exact counts; a target that is not a multiple of
    the leading axes' product is rejected."""
    out = run_sub("""
        mesh = Mesh(np.array(jax.devices()[:4]).reshape(2, 2),
                    ('pod', 'data'))
        eng = DistributedEngine(
            Workflow([Counter()], external_streams=('S1',)), mesh,
            DistConfig(batch_size=32, queue_capacity=256, fused='off',
                       axis_names=('pod', 'data')))
        state = eng.init_state()
        rng = np.random.default_rng(9)
        truth = np.zeros(48, np.int64)
        for t in range(8):
            keys = rng.integers(0, 48, 32).astype(np.int32)
            xs = np.ones(32, np.float32)
            for k in keys: truth[k] += 1
            if t == 4:
                state, rep = eng.scale(state, 8)
                assert rep.recompiled and eng.n_shards == 8
                assert tuple(eng.mesh.devices.shape) == (2, 4)
            state, _ = eng.step(state, {'S1': gb(keys, xs, t,
                                                 eng.n_shards)})
        state, _ = eng.drain(state)
        got = np.array([c for c, _ in slates(eng, state, 48)])
        assert (got == truth).all(), (got - truth)
        try:
            eng._grow_physical(9)
            raise SystemExit('expected ValueError')
        except ValueError as e:
            assert 'multiple' in str(e), e
        print('MULTIAXIS-OK')
    """)
    assert "MULTIAXIS-OK" in out


@pytest.mark.slow
@pytest.mark.parametrize("fused", ["jnp", "interpret"])
def test_live_scale_8to16_bitwise_parity(fused):
    """The acceptance bar: scale(8 -> 16) mid-run yields bitwise slate
    parity (int counts and f32 sums) with a never-scaled run — live
    migration is loss-free, unlike fail_shard."""
    out = run_sub("""
        FUSED = %r
        def run(scale_to=None):
            mesh = Mesh(np.array(jax.devices()[:8]), ('data',))
            wf = Workflow([Counter()], external_streams=('S1',))
            eng = DistributedEngine(wf, mesh, DistConfig(
                batch_size=64, queue_capacity=512, fused=FUSED))
            state = eng.init_state()
            rng = np.random.default_rng(7)
            for t in range(12):
                keys = rng.integers(0, 96, 128).astype(np.int32)
                xs = rng.integers(0, 99, 128).astype(np.float32)
                if scale_to and t == 6:
                    state, rep = eng.scale(state, scale_to)
                    assert rep.recompiled
                    assert sum(rep.moved_rows.values()) > 0
                state, _ = eng.step(state, {'S1': gb(keys, xs, t,
                                                     eng.n_shards)})
            state, _ = eng.drain(state)
            return slates(eng, state, 96), eng, state
        a, _, _ = run()
        b, eng, state = run(16)
        for (ca, sa), (cb, sb) in zip(a, b):
            assert ca == cb
            assert np.float32(sa).tobytes() == np.float32(sb).tobytes()
        assert eng.stats(state)['exchange_dropped'] == 0
        rows16 = [int(jax.device_get(
            (state['tables']['U1'].keys[i] != -1).sum()))
            for i in range(16)]
        assert sum(1 for r in rows16[8:] if r > 0) >= 4, rows16
        print('BITWISE-PARITY-OK')
    """ % fused, devices=16)
    assert "BITWISE-PARITY-OK" in out


@pytest.mark.slow
def test_remove_shards_loss_free_with_inflight_events():
    """Planned leave migrates slates AND events still queued on the
    leaving shards (drain_max=0 forces the in-flight path) — exact
    counts, zero drops; then the slots rejoin without recompilation."""
    out = run_sub("""
        mesh = Mesh(np.array(jax.devices()[:8]), ('data',))
        wf = Workflow([Counter()], external_streams=('S1',))
        eng = DistributedEngine(wf, mesh, DistConfig(
            batch_size=16, queue_capacity=512, exchange_slack=16.0))
        state = eng.init_state()
        rng = np.random.default_rng(1)
        feeds = [(rng.integers(0, 64, 128).astype(np.int32),
                  rng.integers(0, 99, 128).astype(np.float32))
                 for _ in range(10)]
        truth = np.zeros(64, np.int64)
        for ks, _ in feeds:
            for k in ks: truth[k] += 1
        for t in range(5):
            state, _ = eng.step(state, {'S1': gb(*feeds[t], t, 8)})
        backlog = {s: int(n) for s, n in enumerate(np.asarray(
            jax.device_get(state['queues']['U1'].size))) if int(n)}
        leave = sorted(backlog, key=backlog.get)[-2:]   # loaded shards
        state, rep = eng.remove_shards(state, leave, drain_max=0)
        assert sum(rep.moved_events.values()) > 0, (backlog, rep)
        for t in range(5, 10):
            state, _ = eng.step(state, {'S1': gb(*feeds[t], t, 8)})
        for _ in range(40):
            state = eng._step_empty(state)
        got = np.array([c for c, _ in slates(eng, state, 64)])
        assert (got == truth).all(), (got - truth)
        tb = state['tables']['U1']
        for s in leave:
            assert int(jax.device_get((tb.keys[s] != -1).sum())) == 0
        assert eng.stats(state)['exchange_dropped'] == 0
        # rejoin: content-only ring swap, compiled step object reused
        step_obj = eng._step
        state, rep = eng.scale(state, 8)
        assert not rep.recompiled and eng._step is step_obj
        print('REMOVE-REJOIN-OK')
    """)
    assert "REMOVE-REJOIN-OK" in out


@pytest.mark.slow
def test_rebalance_hot_ring_sheds_load():
    """The load-aware weighted ring: a shard running hot (queue peaks /
    drops) loses vnode arcs at the next rebalance, and counting stays
    exact through the reconfigure."""
    out = run_sub("""
        from repro.core.distributed import _salt
        mesh = Mesh(np.array(jax.devices()[:8]), ('data',))
        wf = Workflow([Counter()], external_streams=('S1',))
        eng = DistributedEngine(wf, mesh, DistConfig(
            batch_size=32, queue_capacity=2048, exchange_slack=16.0))
        state = eng.init_state()
        rng = np.random.default_rng(2)
        # hot traffic: one key -> one owner shard saturates
        hot_owner = int(eng.ring.owners(np.array([7], np.int32),
                                        _salt('U1'))[0])
        n_ticks = 6
        for t in range(n_ticks):
            keys = np.full(128, 7, np.int32)
            xs = np.ones(128, np.float32)
            state, _ = eng.step(state, {'S1': gb(keys, xs, t, 8)})
        counts0 = eng.ring.vnode_counts()
        state, rep = eng.rebalance(state)
        assert rep is not None
        counts1 = eng.ring.vnode_counts()
        assert counts1[hot_owner] < counts0[hot_owner], (hot_owner,
                                                         counts0, counts1)
        assert eng.ring.weights[hot_owner] < 1.0
        for _ in range(40):
            state = eng._step_empty(state)
        total = eng.read_slate(state, 'U1', 7)
        assert int(total['count']) == 128 * n_ticks, total
        print('REBALANCE-OK')
    """)
    assert "REBALANCE-OK" in out


@pytest.mark.slow
def test_autoscale_policy_through_run_and_durability():
    """The front-door path: cfg.autoscale drives scale boundaries inside
    DistributedEngine.run with durability attached; a crash after the
    scaled run recovers to the same slates (per-shard WAL/frontier set
    migrated with the shards)."""
    out = run_sub("""
        import tempfile
        from repro.core.durability import DurabilityConfig
        from repro.core.operators import Mapper
        from repro.slates.flush import FlushConfig, FlushPolicy

        class Fwd(Mapper):
            # an extra hop keeps events in flight at every reconfigure
            # boundary, forcing drain ticks there (the engine-tick vs
            # source-tick skew the WAL keying must survive)
            name = 'M1'; subscribes = ('S1',); in_value_spec = VSPEC
            out_streams = {'S2': VSPEC}
            def map_batch(self, b):
                return {'S2': EventBatch(sid=b.sid, ts=b.ts + 1,
                                         key=b.key, value=b.value,
                                         valid=b.valid)}

        class C2(Counter):
            subscribes = ('S2',)

        def make_wf():
            return Workflow([Fwd(), C2()], external_streams=('S1',))

        with tempfile.TemporaryDirectory() as d:
            reports = []
            cfg = DistConfig(batch_size=64, queue_capacity=512,
                             durability=DurabilityConfig(
                                 dir=d, flush=FlushConfig(
                                     policy=FlushPolicy.EVERY_K,
                                     every_k=4)),
                             autoscale=AutoscalePolicy(
                                 scale_at={4: 8}, rebalance_every=3,
                                 on_change=reports.append))
            eng = DistributedEngine(make_wf(), Mesh(
                np.array(jax.devices()[:4]), ('data',)), cfg)
            state = eng.init_state()
            fed = []
            def src(t, _mx):
                fed.append(t)
                r = np.random.default_rng(t)
                return {'S1': gb(r.integers(0, 32, 64).astype(np.int32),
                                 r.integers(0, 99, 64).astype(
                                     np.float32), t, eng.n_shards)}
            state, _ = eng.run(state, src, 8)
            state, _ = eng.drain(state)
            truth = np.zeros(32, np.int64)
            for t in fed:
                r = np.random.default_rng(t)
                for k in r.integers(0, 32, 64): truth[k] += 1
            assert any(r.recompiled for r in reports)
            assert eng.n_shards == 8
            # no duplicate WAL tick keys across any shard's log
            for w in eng.dur.wals:
                tks = [tk for tk, _ in w.replay(from_offset=0)]
                assert len(tks) == len(set(tks)), tks
            live = np.array([c for c, _ in slates(eng, state, 32)])
            assert (live == truth).all(), (live, truth)
            del state                      # crash
            def rebuild(n):
                c = DistConfig(batch_size=64, queue_capacity=512,
                               durability=DurabilityConfig(
                                   dir=d, flush=FlushConfig(
                                       policy=FlushPolicy.EVERY_K,
                                       every_k=4)))
                return DistributedEngine(
                    make_wf(),
                    Mesh(np.array(jax.devices()[:n]), ('data',)), c)
            eng2 = rebuild(8)
            rec = eng2.recover()
            rec, _ = eng2.drain(rec)
            got = np.array([c for c, _ in slates(eng2, rec, 32)])
            assert (got == truth).all(), (got, truth)
            # restart on the ORIGINAL 4-shard layout: the frontier's
            # 8-entry offset list outruns the engine — the extra
            # shards' WAL suffixes must fold into the replay, not be
            # silently dropped
            eng3 = rebuild(4)
            rec3 = eng3.recover()
            rec3, _ = eng3.drain(rec3)
            got3 = np.array([c for c, _ in slates(eng3, rec3, 32)])
            assert (got3 == truth).all(), (got3, truth)
            eng.close(); eng2.close(); eng3.close()
        print('AUTOSCALE-DURABLE-OK')
    """, devices=8)
    assert "AUTOSCALE-DURABLE-OK" in out


# ---------------------------------------------------------------------------
# front-door plumbing (tier-1)
# ---------------------------------------------------------------------------

def test_runtime_config_autoscale_front_door():
    from repro import AutoscalePolicy, RuntimeConfig
    pol = AutoscalePolicy(scale_at={24: 16}, rebalance_every=8)
    rt = RuntimeConfig(shards=2, autoscale=pol)
    assert rt.dist_config().autoscale is pol
    with pytest.raises(ValueError, match="distributed runtime"):
        RuntimeConfig(shards=1, autoscale=pol).engine_config()
    with pytest.raises(TypeError, match="AutoscalePolicy"):
        RuntimeConfig(shards=2, autoscale={"24": 16}).dist_config()


# ---------------------------------------------------------------------------
# read-tier edge cases against live elasticity (ISSUE 7)
# ---------------------------------------------------------------------------

def test_planned_leave_with_backlog_stays_on_device_path():
    """remove_shards(..., drain_max=0) with a queued backlog used to
    fall back to the host remap (exchange_rows only re-homed table
    rows).  exchange_queue now moves the backlog on device too: the
    device path must engage, report the same moved-event counts as the
    host migrator, and converge to bitwise-equal slates."""
    out = run_sub("""
        from repro.core.hashing import route
        from repro.core.distributed import _salt

        def total_dropped(eng, state):
            st = eng.stats(state)
            return (st['exchange_dropped'] +
                    sum(st['queue_dropped'].values()))

        def run(mode):
            mesh = Mesh(np.array(jax.devices()[:4]), ('data',))
            wf = Workflow([Counter()], external_streams=('S1',))
            eng = DistributedEngine(wf, mesh, DistConfig(
                batch_size=32, queue_capacity=2048, fused='off',
                device_migration=mode))
            state = eng.init_state()
            rng = np.random.default_rng(7)
            # keys the pre-leave ring homes on shard 3: hammer them so
            # the planned leave has a backlog exactly where it re-homes
            rh, rs = eng.ring.table()
            cand = jnp.arange(64, dtype=jnp.int32)
            owners = np.asarray(route(cand, _salt('U1'), rh, rs))
            hot = np.nonzero(owners == 3)[0][:4].astype(np.int32)
            assert len(hot) > 0
            reps = []
            for t in range(6):
                keys = np.where(rng.random(128) < 0.6,
                                rng.choice(hot, 128),
                                rng.integers(0, 24, 128)
                                ).astype(np.int32)
                xs = rng.integers(0, 99, 128).astype(np.float32)
                if t == 3:
                    sizes = jax.device_get({k: q.size for k, q in
                                            state['queues'].items()})
                    backlog = sum(int(np.asarray(v).sum())
                                  for v in sizes.values())
                    assert backlog > 0, 'no backlog built'
                    state, rep = eng.remove_shards(state, [3],
                                                   drain_max=0)
                    reps.append(rep)
                state, _ = eng.step(state, {'S1': gb(keys, xs, t, 4)})
            state, _ = eng.drain(state, max_ticks=256)
            return (slates(eng, state, 24), reps[0],
                    total_dropped(eng, state))

        dev, drep, ddrop = run('auto')
        host, hrep, hdrop = run('off')
        assert drep.path == 'device', drep
        assert hrep.path == 'host'
        assert drep.drain_ticks == 0 and hrep.drain_ticks == 0
        assert sum(drep.moved_events.values()) > 0, drep.moved_events
        assert drep.moved_events == hrep.moved_events, (
            drep.moved_events, hrep.moved_events)
        assert ddrop == hdrop, (ddrop, hdrop)  # feed overflow only
        assert dev == host, (dev, host)
        print('QEX-PARITY-OK')
    """, devices=4)
    assert "QEX-PARITY-OK" in out


def test_compaction_folds_lifetime_counters():
    """_compact_physical slices dead slots away; their lifetime
    counters (processed, drop tallies, count-min sketch mass) must fold
    into survivors so TelemetryReport lifetime counts stay exact."""
    out = run_sub("""
        from repro.telemetry.metrics import TelemetryConfig

        def lifetime(eng, state):
            st = eng.stats(state)
            out = {'processed': sum(st['processed'].values()),
                   'queue_dropped': sum(st['queue_dropped'].values()),
                   'exchange_dropped': st['exchange_dropped'],
                   'throttle_hits': st['throttle_hits']}
            sk = jax.device_get(state['sketch'])
            out['sk_total'] = int(np.asarray(sk['total']).sum())
            out['sk_counts'] = int(np.asarray(sk['counts']).sum())
            out['sk_sample_n'] = int(np.asarray(sk['sample_n']).sum())
            tdrop = jax.device_get({k: t.dropped for k, t in
                                    state['tables'].items()})
            out['table_dropped'] = sum(int(np.asarray(v).sum())
                                       for v in tdrop.values())
            return out

        mesh = Mesh(np.array(jax.devices()[:4]), ('data',))
        wf = Workflow([Counter()], external_streams=('S1',))
        eng = DistributedEngine(wf, mesh, DistConfig(
            batch_size=16, queue_capacity=32, fused='off',
            telemetry=TelemetryConfig(window=4, decay=0.5),
            compact_threshold=0.0))
        state = eng.init_state()
        rng = np.random.default_rng(1)
        for t in range(10):
            # heavy skew onto one key overflows a queue -> real drops
            keys = np.where(rng.random(64) < 0.5, 3,
                            rng.integers(0, 200, 64)).astype(np.int32)
            xs = rng.integers(0, 99, 64).astype(np.float32)
            state, _ = eng.step(state, {'S1': gb(keys, xs, t, 4)})
        state, _ = eng.drain(state, max_ticks=64)
        before = lifetime(eng, state)
        assert (before['queue_dropped'] > 0 or
                before['exchange_dropped'] > 0), before

        state, rep = eng.remove_shards(state, [2, 3])
        state, rep = eng.compact(state)
        assert rep.recompiled and eng.n_shards == 2, rep
        after = lifetime(eng, state)
        for k in before:
            assert before[k] == after[k], (k, before[k], after[k])
        r = eng.telemetry.observe(eng, state)
        assert r.n_shards == 2
        print('COMPACT-FOLD-OK')
    """, devices=4)
    assert "COMPACT-FOLD-OK" in out


@pytest.mark.slow
def test_concurrent_reads_during_live_scale():
    """Readers on a StateHandle race a 4->8 scale mid-run.  step() and
    _reconfigure donate the buffers a reader may hold; the read_lock +
    in-lock handle republish must keep every read either pre- or post-
    migration -- no deleted-buffer errors, no torn slates -- and the
    scaled run still matches a never-scaled run slate for slate."""
    out = run_sub("""
        import threading
        from repro.core.distributed import AutoscalePolicy
        from repro.core.engine import StateHandle

        def src(t, ingest=None):
            rng = np.random.default_rng(40 + t)
            keys = rng.integers(0, 48, 128).astype(np.int32)
            xs = rng.integers(0, 99, 128).astype(np.float32)
            return {'S1': gb(keys, xs, t, eng.n_shards)}

        def build(scale):
            pol = AutoscalePolicy(scale_at={6: 8}) if scale else None
            mesh = Mesh(np.array(jax.devices()[:4]), ('data',))
            wf = Workflow([Counter()], external_streams=('S1',))
            return DistributedEngine(wf, mesh, DistConfig(
                batch_size=32, queue_capacity=512, fused='off',
                autoscale=pol))

        eng = build(scale=True)
        state = eng.init_state()
        h = StateHandle(eng, state)
        errors, n_reads = [], [0]
        stop = threading.Event()

        def reader():
            rng = np.random.default_rng(99)
            while not stop.is_set():
                try:
                    k = int(rng.integers(0, 48))
                    s = h.read_slate('U1', k)
                    if s is not None:   # torn slate check
                        assert int(s['count']) >= 0
                    many = h.read_slates(
                        'U1', rng.integers(0, 48, 16).tolist())
                    assert len(many) == 16
                    n_reads[0] += 1
                except Exception as e:  # noqa: BLE001
                    errors.append(repr(e))
                    return

        threads = [threading.Thread(target=reader) for _ in range(3)]
        for th in threads:
            th.start()
        state, _ = eng.run(state, src, 12, handle=h)
        state, _ = eng.drain(state)
        h.state = state
        stop.set()
        for th in threads:
            th.join(timeout=60)
        assert not errors, errors
        assert n_reads[0] > 0
        assert eng.n_shards == 8
        scaled = slates(eng, state, 48)

        eng = build(scale=False)   # src() reads eng.n_shards
        s2 = eng.init_state()
        s2, _ = eng.run(s2, src, 12)
        s2, _ = eng.drain(s2)
        assert scaled == slates(eng, s2, 48)
        print('READ-RACE-OK')
    """, devices=8)
    assert "READ-RACE-OK" in out
