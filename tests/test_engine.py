import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import Engine, EngineConfig
from repro.core.event import EventBatch
from repro.core.operators import AssociativeUpdater, Mapper
from repro.core.queues import OverflowPolicy
from repro.core.workflow import Workflow
from tests.conftest import (CountingUpdater, LastValueUpdater,
                            PassThroughMapper, VSPEC, make_batch)


def drain(eng, state, ticks=6, cap=8):
    for t in range(ticks):
        state, _ = eng.step(state, {"S1": make_batch(
            [0] * cap, valid=[False] * cap, ts=[900 + t] * cap)})
    return state


def test_counting_exact(counting_workflow):
    eng = Engine(counting_workflow, EngineConfig(batch_size=32,
                                                 queue_capacity=128))
    state = eng.init_state()
    rng = np.random.default_rng(0)
    truth = {}
    for t in range(10):
        keys = rng.integers(0, 20, size=16).astype(np.int32)
        xs = rng.integers(0, 9, size=16).astype(np.int32)
        for k, x in zip(keys, xs):
            c, s = truth.get(int(k), (0, 0))
            truth[int(k)] = (c + 1, s + int(x))
        state, _ = eng.step(state, {"S1": make_batch(
            keys, xs, ts=[t] * 16)})
    state = drain(eng, state, cap=16)
    for k, (c, s) in truth.items():
        slate = eng.read_slate(state, "U1", k)
        assert slate is not None and int(slate["count"]) == c
        assert abs(float(slate["sum"]) - s) < 1e-3


def test_pipeline_latency_is_graph_depth(counting_workflow):
    """An event injected at tick t is visible in U1's slate after the
    mapper hop (tick t) + updater hop (tick t+1)."""
    eng = Engine(counting_workflow, EngineConfig(batch_size=8,
                                                 queue_capacity=64))
    state = eng.init_state()
    state, _ = eng.step(state, {"S1": make_batch([42])})
    assert eng.read_slate(state, "U1", 42) is None   # still in flight
    state = drain(eng, state, ticks=1)
    assert int(eng.read_slate(state, "U1", 42)["count"]) == 1


def test_overflow_drop_counts(counting_workflow):
    eng = Engine(counting_workflow, EngineConfig(batch_size=4,
                                                 queue_capacity=8))
    state = eng.init_state()
    state, _ = eng.step(state, {"S1": make_batch(list(range(32)))})
    stats = eng.stats(state)
    assert stats["queue_dropped"]["M1"] == 24


def test_overflow_stream_degraded_path():
    """OVERFLOW_STREAM diverts excess to a degraded updater (section
    4.3's 'slightly degraded service')."""
    class DegradedCounter(CountingUpdater):
        name = "U_degraded"
        subscribes = ("S_overflow",)

    class SecondMapper(PassThroughMapper):
        name = "M2"

    # two mappers fan S1 into S2: U1 receives 2x its drain rate
    wf = Workflow([PassThroughMapper(), SecondMapper(), CountingUpdater(),
                   DegradedCounter()],
                  external_streams=("S1", "S_overflow"))
    eng = Engine(wf, EngineConfig(
        batch_size=4, queue_capacity=8,
        overflow={"U1": OverflowPolicy.OVERFLOW_STREAM},
        overflow_stream={"U1": "S_overflow"}))
    state = eng.init_state()
    for t in range(6):
        state, _ = eng.step(state, {"S1": make_batch([1] * 4,
                                                     ts=[t] * 4)})
    state = drain(eng, state, ticks=12)
    main = eng.read_slate(state, "U1", 1)
    degraded = eng.read_slate(state, "U_degraded", 1)
    assert degraded is not None and int(degraded["count"]) > 0
    assert int(main["count"]) + int(degraded["count"]) == 48


def test_throttle_signal():
    wf = Workflow([PassThroughMapper(), CountingUpdater()],
                  external_streams=("S1",))
    eng = Engine(wf, EngineConfig(batch_size=4, queue_capacity=8,
                                  overflow={"M1": OverflowPolicy.THROTTLE}))
    state = eng.init_state()
    state, _ = eng.step(state, {"S1": make_batch(list(range(32)))})
    assert eng.stats(state)["throttle_hits"] > 0


def test_source_throttling_run_loop():
    wf = Workflow([PassThroughMapper(), CountingUpdater()],
                  external_streams=("S1",))
    eng = Engine(wf, EngineConfig(batch_size=4, queue_capacity=8,
                                  overflow={"M1": OverflowPolicy.THROTTLE}))
    state = eng.init_state()
    sizes = []

    def source(t, max_events):
        n = 16
        take = min(max_events, n) if max_events else n
        sizes.append(take)
        return {"S1": make_batch(list(range(n)), ts=[t] * n,
                                 valid=[i < take for i in range(n)])}

    state, _ = eng.run(state, source, 12)
    assert min(sizes) < 16    # the loop backed off under pressure


def test_ttl_expires_slates():
    class TTLCounter(CountingUpdater):
        ttl = 3

    wf = Workflow([PassThroughMapper(), TTLCounter()],
                  external_streams=("S1",))
    eng = Engine(wf, EngineConfig(batch_size=8, queue_capacity=64))
    state = eng.init_state()
    state, _ = eng.step(state, {"S1": make_batch([7])})
    state = drain(eng, state, ticks=1)
    assert eng.read_slate(state, "U1", 7) is not None
    state = drain(eng, state, ticks=6)   # > ttl idle ticks
    assert eng.read_slate(state, "U1", 7) is None


def test_sequential_updater_in_engine_emits():
    wf = Workflow([PassThroughMapper(), LastValueUpdater()],
                  external_streams=("S1",))
    eng = Engine(wf, EngineConfig(batch_size=16, queue_capacity=64))
    state = eng.init_state()
    outs = []
    state, o = eng.step(state, {"S1": make_batch([4, 4, 5],
                                                 [10, 20, 30],
                                                 ts=[0, 1, 2])})
    outs.append(o)
    for t in range(3):
        state, o = eng.step(state, {"S1": make_batch(
            [0], valid=[False], ts=[50 + t])})
        outs.append(o)
    emitted = [o["S3"] for o in outs if "S3" in o]
    assert emitted, "S3 events should surface as engine outputs"
    xs = np.concatenate([np.asarray(e.value["x"])[np.asarray(e.valid)]
                         for e in emitted])
    assert sorted(xs.tolist()) == [1, 1, 2]


def test_workflow_validation():
    with pytest.raises(ValueError):
        Workflow([PassThroughMapper()], external_streams=())  # S1 missing

    class BadMapper(PassThroughMapper):
        out_streams = {"S1": VSPEC}   # emits into external stream

    with pytest.raises(ValueError):
        Workflow([BadMapper(), CountingUpdater()],
                 external_streams=("S1",))


def test_overflow_stream_cycle_guard_raises():
    """A cyclic overflow_stream config (U_a spills to a stream feeding
    U_b, whose overflow spills back into U_a's stream) can never settle:
    deliver_all's bounded work loop must abort with the named
    RuntimeError at trace time instead of hanging."""
    class UA(CountingUpdater):
        name = "U_a"
        subscribes = ("S2",)

    class UB(CountingUpdater):
        name = "U_b"
        subscribes = ("S_ovf_a",)

    wf = Workflow([PassThroughMapper(), UA(), UB()],
                  external_streams=("S1", "S_ovf_a"))
    eng = Engine(wf, EngineConfig(
        batch_size=4, queue_capacity=8,
        overflow={"U_a": OverflowPolicy.OVERFLOW_STREAM,
                  "U_b": OverflowPolicy.OVERFLOW_STREAM},
        overflow_stream={"U_a": "S_ovf_a", "U_b": "S2"}))
    state = eng.init_state()
    with pytest.raises(RuntimeError,
                       match="overflow-stream routing did not converge"):
        eng.step(state, {"S1": make_batch(list(range(8)))})


def test_overflow_stream_full_degraded_queue_counts_drops():
    """OVERFLOW_STREAM re-enqueue when the degraded queue itself is
    full: the second-level overflow applies the degraded operator's own
    policy (DROP) — every event is either counted in a slate, still
    queued, or in a drop counter; none vanish and the step never
    cycles."""
    class SecondMapper(PassThroughMapper):
        name = "M2"

    class ThirdMapper(PassThroughMapper):
        name = "M3"

    class DegradedCounter(CountingUpdater):
        name = "U_degraded"
        subscribes = ("S_overflow",)

    # three mappers fan S1 into S2: U1 receives 3x its drain rate, so
    # its overflow stream outruns the degraded updater's drain too
    wf = Workflow([PassThroughMapper(), SecondMapper(), ThirdMapper(),
                   CountingUpdater(), DegradedCounter()],
                  external_streams=("S1", "S_overflow"))
    eng = Engine(wf, EngineConfig(
        batch_size=2, queue_capacity=4,
        overflow={"U1": OverflowPolicy.OVERFLOW_STREAM},
        overflow_stream={"U1": "S_overflow"}))
    state = eng.init_state()
    n_in = 0
    for t in range(10):
        state, _ = eng.step(state, {"S1": make_batch([1] * 8,
                                                     ts=[t] * 8)})
        n_in += 8
    state = drain(eng, state, ticks=16, cap=2)
    s = eng.stats(state)
    main = eng.read_slate(state, "U1", 1) or {"count": 0}
    deg = eng.read_slate(state, "U_degraded", 1) or {"count": 0}
    # every S2 event (one per processed mapper event) is counted in a
    # slate, still queued, or in the degraded DROP counter
    produced_s2 = (s["processed"]["M1"] + s["processed"]["M2"]
                   + s["processed"]["M3"])
    accounted = int(main["count"]) + int(deg["count"]) + \
        s["queue_size"]["U1"] + s["queue_size"]["U_degraded"] + \
        s["queue_dropped"]["U_degraded"]
    assert accounted == produced_s2, (accounted, produced_s2, s)
    assert int(deg["count"]) > 0            # degraded path engaged
    assert s["queue_dropped"]["U_degraded"] > 0   # and itself overflowed
