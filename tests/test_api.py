"""Declarative application API (DESIGN.md section 11): builder/subclass
parity, cyclic graphs, planner fusion, the run() front door."""
import importlib.util
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import (App, AssociativeUpdater, EventBatch, Engine,
                   EngineConfig, Mapper, PlanError, RuntimeConfig,
                   StateHandle, Workflow, ops)

VSPEC = {"retailer": ((), jnp.int32)}


def load_quickstart():
    path = (pathlib.Path(__file__).resolve().parent.parent
            / "examples" / "quickstart.py")
    spec = importlib.util.spec_from_file_location("quickstart_example",
                                                  path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---- the subclass-API quickstart (the seed's original spelling) ----

class RetailerMapper(Mapper):
    name = "M1"
    subscribes = ("checkins",)
    in_value_spec = VSPEC
    out_streams = {"S2": VSPEC}

    def map_batch(self, batch):
        rid = batch.value["retailer"]
        return {"S2": EventBatch(sid=batch.sid, ts=batch.ts + 1, key=rid,
                                 value={"retailer": rid},
                                 valid=batch.valid & (rid >= 0))}


class SubclassCounter(AssociativeUpdater):
    name = "U1"
    subscribes = ("S2",)
    in_value_spec = VSPEC
    out_streams = {}
    table_capacity = 256

    def slate_spec(self):
        return {"count": ((), jnp.int32)}

    def lift(self, batch):
        return {"count": jnp.ones_like(batch.key)}

    def combine(self, a, b):
        return {"count": a["count"] + b["count"]}

    def merge(self, slate, delta):
        return {"count": slate["count"] + delta["count"]}


def checkin_batches(n_ticks=10, B=64, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for t in range(n_ticks):
        rid = np.where(rng.random(B) < 0.3, rng.integers(0, 4, B),
                       -1).astype(np.int32)
        out.append(EventBatch.of(
            key=rng.integers(0, 1 << 30, B).astype(np.int32),
            value={"retailer": rid}, ts=np.full(B, t, np.int32)))
    return out


def drive(wf, batches, B=64):
    eng = Engine(wf, EngineConfig(batch_size=B, queue_capacity=4 * B))
    state = eng.init_state()
    for b in batches:
        state, _ = eng.step(state, {"checkins": b})
    state, _ = eng.drain(state)
    return eng, state


def assert_tree_bitwise(a, b):
    la, ta = jax.tree.flatten(a)
    lb, tb = jax.tree.flatten(b)
    assert ta == tb, f"{ta} != {tb}"
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_quickstart_builder_matches_subclass_bitwise():
    """The example's builder app compiles to the same workflow the
    subclass API hand-writes: identical operator/stream names, and
    bitwise-identical engine state (queues, tables, counters) after an
    identical feed."""
    mod = load_quickstart()
    wf_b = mod.app.build()
    wf_s = Workflow([RetailerMapper(), SubclassCounter()],
                    external_streams=("checkins",))
    assert [op.name for op in wf_b.operators] == \
        [op.name for op in wf_s.operators]
    assert wf_b.subscribers == wf_s.subscribers

    batches = checkin_batches()
    _, st_b = drive(wf_b, batches)
    _, st_s = drive(wf_s, batches)
    assert_tree_bitwise(st_b, st_s)


def test_quickstart_app_section_is_short():
    """Acceptance: the paper's Example 1 in <= 20 lines of app code."""
    path = (pathlib.Path(__file__).resolve().parent.parent
            / "examples" / "quickstart.py")
    text = path.read_text().splitlines()
    lo = next(i for i, l in enumerate(text) if "--- app" in l)
    hi = next(i for i, l in enumerate(text) if "--- end app" in l)
    body = [l for l in text[lo + 1:hi]
            if l.strip() and not l.strip().startswith("#")]
    assert len(body) <= 20, f"{len(body)} lines of app code:\n" + \
        "\n".join(body)


def test_run_front_door_and_read_slate():
    # the quickstart graph on a fresh App, via the fluent sugar
    app = App("front_door")
    checkins = app.source("checkins", VSPEC)

    @checkins.map(out="S2", name="M1")
    def at_retailer(batch):
        rid = batch.value["retailer"]
        return EventBatch(sid=batch.sid, ts=batch.ts + 1, key=rid,
                          value={"retailer": rid},
                          valid=batch.valid & (rid >= 0))

    at_retailer.update(ops.counter("U1", table_capacity=256))

    batches = checkin_batches()
    truth = {}
    for b in batches:
        rid = np.asarray(b.value["retailer"])
        for r in rid[rid >= 0]:
            truth[int(r)] = truth.get(int(r), 0) + 1

    it = iter(batches)
    app.run(lambda t, mx: {"checkins": next(it)}, len(batches),
            runtime=RuntimeConfig(batch_size=64), drain=True)
    for r, c in truth.items():
        assert int(app.read_slate("U1", r)["count"]) == c
    stats = app.stats()
    assert stats["processed"]["U1"] == sum(truth.values())
    app.close()


def test_cyclic_graph_via_forward_refs():
    """U1 emits into 'bounce'; M2 maps bounce back into U1's input
    stream — a cycle, expressed by subscribing to streams by name
    before their producers exist."""
    app = App("cyclic")
    src = app.source("src", {"x": ((), jnp.int32)})

    @app.mapper(src, out="loop", name="M1")
    def inject(b):
        return EventBatch(b.sid, b.ts + 1, b.key, {"x": b.value["x"]},
                          b.valid)

    # M2 subscribes to 'bounce' before U1 (its producer) is declared
    @app.mapper("bounce", out="loop", name="M2")
    def reinject(b):
        return EventBatch(b.sid, b.ts + 1, b.key, {"x": b.value["x"]},
                          b.valid & (b.key < 4))

    def cascade(keys, old, new, ts):
        crossed = (old["count"] < 3) & (new["count"] >= 3)
        return {"bounce": EventBatch(
            sid=jnp.zeros_like(keys), ts=ts + 1, key=keys + 1,
            value={"x": jnp.zeros_like(keys)}, valid=crossed)}

    @app.updater("loop", name="U1", merge="sum", emit=cascade,
                 slate={"count": ((), jnp.int32)})
    def lift(b):
        return {"count": jnp.ones_like(b.key)}

    wf = app.build()
    assert set(wf.subscribers["loop"]) == {"U1"}
    assert set(wf.subscribers["bounce"]) == {"M2"}

    # 9 events on key 0 -> count crosses 3 once -> one bounce to key 1
    def src_fn(t, mx):
        return {"src": EventBatch.of(key=np.zeros(3, np.int32),
                                     value={"x": np.zeros(3, np.int32)},
                                     ts=np.full(3, t, np.int32))}

    app.run(src_fn, 3, runtime=RuntimeConfig(batch_size=16), drain=True)
    assert int(app.read_slate("U1", 0)["count"]) == 9
    assert int(app.read_slate("U1", 1)["count"]) == 1
    app.close()


def _chain_app(fuse):
    app = App("chain")
    s1 = app.source("S1", {"x": ((), jnp.float32)})

    @app.mapper(s1, out="Sa")
    def m1(b):
        return EventBatch(b.sid, b.ts + 1, b.key,
                          {"x": b.value["x"] + 1.0}, b.valid)

    @app.mapper("Sa", out="Sb")
    def m2(b):
        return EventBatch(b.sid, b.ts + 1, b.key,
                          {"x": b.value["x"] * 2.0}, b.valid)

    @app.mapper("Sb", out="Sc")
    def m3(b):
        return EventBatch(b.sid, b.ts + 1, b.key * 2,
                          {"x": b.value["x"]}, b.valid)

    @app.updater("Sc", name="U1", merge="sum",
                 slate={"count": ((), jnp.int32), "sum": ((), jnp.float32)})
    def lift(b):
        return {"count": jnp.ones_like(b.key), "sum": b.value["x"]}

    wf = app.build(fuse=fuse)
    return app, wf


def test_planner_fuses_linear_mapper_chain():
    app_f, wf_f = _chain_app(True)
    app_u, wf_u = _chain_app(False)
    assert len(wf_u.operators) == 4
    assert len(wf_f.operators) == 2            # m1+m2+m3 fused, U1
    assert app_f.plan.fused_chains == [("m1", "m2", "m3")]
    fused = wf_f.operators[0]
    assert fused.subscribes == ("S1",)
    assert set(fused.out_streams) == {"Sc"}


@pytest.mark.parametrize("impl", ["jnp", "interpret"])
def test_fused_chain_matches_unfused(impl):
    """Fusion changes queue hops and tick alignment, not event->event
    semantics: final slate contents agree with the unfused build on
    both the portable and the kernel (interpret) slate-update
    backends."""
    rng = np.random.default_rng(3)
    keys = rng.integers(0, 40, 128).astype(np.int32)
    xs = rng.normal(size=128).astype(np.float32)
    batches = [EventBatch.of(key=keys, value={"x": xs},
                             ts=np.full(128, t, np.int32))
               for t in range(5)]

    slates = {}
    for fuse in (True, False):
        _, wf = _chain_app(fuse)
        eng = Engine(wf, EngineConfig(batch_size=128,
                                      queue_capacity=512, fused=impl))
        state = eng.init_state()
        for b in batches:
            state, _ = eng.step(state, {"S1": b})
        state, _ = eng.drain(state)
        slates[fuse] = {int(k): eng.read_slate(state, "U1", int(k) * 2)
                        for k in np.unique(keys)}
    for k in slates[True]:
        sf, su = slates[True][k], slates[False][k]
        assert sf is not None and su is not None
        assert int(sf["count"]) == int(su["count"])
        np.testing.assert_allclose(np.asarray(sf["sum"]),
                                   np.asarray(su["sum"]), rtol=1e-6)


def test_no_fusion_when_stream_has_two_subscribers():
    app = App("fanout")
    s1 = app.source("S1", {"x": ((), jnp.float32)})

    @app.mapper(s1, out="Sa")
    def m1(b):
        return EventBatch(b.sid, b.ts + 1, b.key, b.value, b.valid)

    @app.mapper("Sa", out="Sb")
    def m2(b):
        return EventBatch(b.sid, b.ts + 1, b.key, b.value, b.valid)

    app.stream("Sa").update(ops.counter("Ua"))   # second subscriber
    app.stream("Sb").update(ops.counter("Ub"))
    wf = app.build(fuse=True)
    assert len(wf.operators) == 4                # nothing fused
    assert app.plan.fused_chains == []


def test_ops_combinators():
    app = App("combinators")
    src = app.source("S1", {"x": ((), jnp.float32)})

    @app.mapper(src, out="S2")
    def fwd(b):
        return EventBatch(b.sid, b.ts + 1, b.key, b.value, b.valid)

    app.stream("S2").update(ops.topk(3, "x", "T1"))
    app.stream("S2").update(ops.ema(0.5, "x", "E1", max_run=64))

    rng = np.random.default_rng(7)
    xs = rng.normal(size=32).astype(np.float32)

    def src_fn(t, mx):
        return {"S1": EventBatch.of(key=np.zeros(32, np.int32),
                                    value={"x": xs},
                                    ts=np.arange(32, dtype=np.int32))}

    app.run(src_fn, 1, runtime=RuntimeConfig(batch_size=64), drain=True)
    top = np.asarray(app.read_slate("T1", 0)["top"])
    np.testing.assert_allclose(top, np.sort(xs)[::-1][:3], rtol=1e-6)

    ema = float(app.read_slate("E1", 0)["ema"])
    ref = xs[0]
    for x in xs[1:]:
        ref = 0.5 * ref + 0.5 * x
    assert abs(ema - ref) < 1e-4
    app.close()


# ---- planner validation errors (actionable, named) ----

def test_planner_unresolvable_cycle_names_streams():
    app = App("stuck")

    @app.mapper("c2", out="c1", name="Ma")
    def ma(b):
        return EventBatch(b.sid, b.ts, b.key, b.value, b.valid)

    @app.mapper("c1", out="c2", name="Mb")
    def mb(b):
        return EventBatch(b.sid, b.ts, b.key, b.value, b.valid)

    with pytest.raises(PlanError, match="app.stream"):
        app.build()
    # an explicit spec breaks the inference cycle
    app2 = App("unstuck")
    app2.stream("c2", {"x": ((), jnp.int32)})

    @app2.mapper("c2", out="c1", name="Ma")
    def ma2(b):
        return EventBatch(b.sid, b.ts, b.key, b.value, b.valid)

    @app2.mapper("c1", out="c2", name="Mb")
    def mb2(b):
        return EventBatch(b.sid, b.ts, b.key, b.value, b.valid)

    wf = app2.build()
    assert {op.name for op in wf.operators} == {"Ma", "Mb"}


def test_planner_rejects_unconsumed_source_and_ghost_stream():
    app = App("bad")
    app.source("S1", {"x": ((), jnp.int32)})
    with pytest.raises(PlanError, match="no subscribers"):
        app.build()

    app2 = App("ghost")
    s1 = app2.source("S1", {"x": ((), jnp.int32)})
    app2.stream("nowhere", {"x": ((), jnp.int32)})
    s1.update(ops.counter("U1"))
    with pytest.raises(PlanError, match="nowhere"):
        app2.build()


def test_planner_rejects_duplicate_names():
    app = App("dups")
    s1 = app.source("S1", {"x": ((), jnp.int32)})
    s1.update(ops.counter("U1"))
    with pytest.raises(PlanError, match="U1"):
        s1.update(ops.counter("U1"))


def test_graph_frozen_after_start():
    app = App("frozen")
    s1 = app.source("S1", {"x": ((), jnp.int32)})
    s1.update(ops.counter("U1"))
    app.start(RuntimeConfig(batch_size=8))
    with pytest.raises(RuntimeError, match="already running"):
        app.source("S2", {"x": ((), jnp.int32)})
    app.close()


# ---- state handle (the box-hack replacement) ----

def test_state_handle_live_during_run():
    app = App("handle")
    s1 = app.source("S1", {"x": ((), jnp.int32)})
    s1.update(ops.counter("U1"))
    h = app.start(RuntimeConfig(batch_size=16, chunk_size=2))
    seen = []

    def src(t, mx):
        # read through the handle mid-run: state must always be live
        if t > 0:
            seen.append(h.stats()["tick"])
        return {"S1": EventBatch.of(key=np.full(4, 7, np.int32),
                                    value={"x": np.ones(4, np.int32)},
                                    ts=np.full(4, t, np.int32))}

    app.run(src, 8, drain=True)
    assert seen and seen[-1] > seen[0]          # handle advanced mid-run
    assert int(app.read_slate("U1", 7)["count"]) == 32
    assert app.handle is h and isinstance(h, StateHandle)
    app.close()


# ---- front door: durability + distribution ----

def test_front_door_durable_recover(tmp_path):
    def build():
        app = App("durable")
        s1 = app.source("S1", {"x": ((), jnp.float32)})

        @app.mapper(s1, out="S2", name="M1")
        def fwd(b):
            return EventBatch(b.sid, b.ts + 1, b.key, b.value, b.valid)

        @app.updater("S2", name="U1", merge="sum",
                     slate={"count": ((), jnp.int32)})
        def lift(b):
            return {"count": jnp.ones_like(b.key)}
        return app

    rt = lambda: RuntimeConfig(batch_size=32, chunk_size=4,
                               durable_dir=str(tmp_path), flush_every=8)

    def src(t, mx):
        r = np.random.default_rng(t)
        return {"S1": EventBatch.of(
            key=r.integers(0, 10, 16).astype(np.int32),
            value={"x": r.normal(size=16).astype(np.float32)},
            ts=np.full(16, t, np.int32))}

    app = build()
    app.run(src, 16, runtime=rt(), drain=True)
    want = {k: app.read_slate("U1", k) for k in range(10)}
    del app   # crash: no close(), unflushed state dropped

    app2 = build()
    app2.start(rt(), recover=True)
    app2.run(src, 0, drain=True)
    for k, w in want.items():
        got = app2.read_slate("U1", k)
        if w is None:
            assert got is None
        else:
            assert int(got["count"]) == int(w["count"])
    app2.close()


def test_front_door_selects_distributed_engine():
    from jax.sharding import Mesh
    from repro.core.distributed import DistributedEngine
    app = App("dist")
    s1 = app.source("S1", {"x": ((), jnp.float32)})
    s1.update(ops.counter("U1", sum_mergeable=False))
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    app.start(RuntimeConfig(batch_size=16, mesh=mesh))
    assert isinstance(app.engine, DistributedEngine)

    def src(t, mx):   # [n_shards, B]-leading batches
        return {"S1": EventBatch.of(
            key=np.full(4, 3, np.int32),
            value={"x": np.ones(4, np.float32)},
            ts=np.full(4, t, np.int32))}

    stacked = lambda t, mx: {
        s: jax.tree.map(lambda x: x[None], b)
        for s, b in src(t, mx).items()}
    app.run(stacked, 4, drain=True)
    assert int(app.read_slate("U1", 3)["count"]) == 16
    app.close()


def test_public_surface():
    import repro
    assert set(repro.__all__) <= set(dir(repro))
    assert repro.App is App and repro.ops.counter is ops.counter
