"""Device-side telemetry + the closed control loop (DESIGN.md 13):
count-min sketch correctness and backend parity, the telemetry-on/off
bitwise parity contract of the chunk path, controller hysteresis, the
end-to-end closed-loop square wave, runtime hot-key splitting, and the
source-index / engine-tick decoupling in the distributed durable path.

Multi-shard coverage runs in subprocesses (the test_elasticity
pattern); the full 4 -> 8 -> 4 acceptance wave is in the slow suite
with a fast 2 -> 4 -> 2 twin in tier-1."""
import collections

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.countmin import countmin_update
from repro.telemetry import (LoadAutoscaler, TelemetryConfig,
                             TelemetryReport)
from repro.telemetry import controller as ctl_mod
from repro.telemetry import sketch as sk_mod
from tests.test_elasticity import run_sub


# ---------------------------------------------------------------------------
# count-min sketch: backends + bounds (tier-1, host-level)
# ---------------------------------------------------------------------------

def test_countmin_backends_agree_bitwise():
    """The interpret (kernel-body) backend must match the jnp oracle
    bit for bit — integer adds, no reassociation slack."""
    rng = np.random.default_rng(0)
    counts = jnp.asarray(rng.integers(0, 50, (4, 256)), jnp.int32)
    cols = jnp.asarray(rng.integers(0, 256, (4, 128)), jnp.int32)
    add = jnp.asarray(rng.integers(0, 2, 128), jnp.int32)
    a = countmin_update(counts, cols, add, impl="ref")
    b = countmin_update(counts, cols, add, impl="interpret")
    assert np.array_equal(np.asarray(a), np.asarray(b))
    # unsupported width falls back to ref instead of failing
    c = countmin_update(counts[:, :100], cols % 100, add, impl="pallas")
    d = countmin_update(counts[:, :100], cols % 100, add, impl="ref")
    assert np.array_equal(np.asarray(c), np.asarray(d))


def _true_counts(keys):
    return collections.Counter(int(k) for k in keys)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(-2**31 + 1, 2**31 - 1), min_size=1,
                max_size=200))
def test_sketch_estimate_never_underestimates(keys):
    """The one-sided count-min guarantee: estimate(k) >= true(k),
    always (collisions only ever inflate)."""
    salts = sk_mod.make_salts(4)
    s = sk_mod.make_sketch(4, 256, 64)
    s = sk_mod.sketch_update(s, jnp.asarray(keys, jnp.int32),
                             jnp.ones(len(keys), bool), salts,
                             impl="ref")
    true = _true_counts(keys)
    est = sk_mod.estimate(np.asarray(s["counts"]), list(true), salts)
    for (k, t), e in zip(true.items(), est):
        assert e >= t, (k, int(e), t)
    assert int(s["total"]) == len(keys)


def test_sketch_error_bound_example():
    """Stub-safe example twin: on a fixed workload the estimate error
    stays within the classic e*N/width bound and heavy_hitters ranks
    the planted hot keys first."""
    rng = np.random.default_rng(7)
    keys = np.concatenate([np.full(300, 77), np.full(150, -5),
                           rng.integers(0, 5000, 400)]).astype(np.int32)
    rng.shuffle(keys)
    salts = sk_mod.make_salts(4)
    s = sk_mod.make_sketch(4, 512, 256)
    for lo in range(0, len(keys), 128):     # batch-wise, like the tick
        chunk = np.zeros(128, np.int32)
        valid = np.zeros(128, bool)
        part = keys[lo:lo + 128]
        chunk[:len(part)], valid[:len(part)] = part, True
        s = sk_mod.sketch_update(s, jnp.asarray(chunk),
                                 jnp.asarray(valid), salts, impl="ref")
    true = _true_counts(keys)
    N = len(keys)
    bound = int(np.ceil(np.e * N / 512))
    est = sk_mod.estimate(np.asarray(s["counts"]), list(true), salts)
    for (k, t), e in zip(true.items(), est):
        assert t <= e <= t + bound, (k, int(e), t, bound)
    hh = sk_mod.heavy_hitters(np.asarray(s["counts"]),
                              np.asarray(s["sample"]),
                              int(s["sample_n"]), salts, k=2)
    assert [k for k, _ in hh] == [77, -5], hh
    # decay halves heat (floor), reset zeroes it
    dec = sk_mod.decay(s, 0.5)
    assert int(sk_mod.estimate(np.asarray(dec["counts"]), [77],
                               salts)[0]) <= (300 + bound) // 2 + 1
    assert not np.asarray(sk_mod.decay(s, 0.0)["counts"]).any()


# ---------------------------------------------------------------------------
# the parity contract: telemetry on vs off, chunk path, jnp + interpret
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_chunk_parity_telemetry_on_off(impl, counting_workflow):
    """With the sketch enabled, tables / queues / outputs of the jitted
    chunk path are bitwise identical to the untelemetered run — the
    sketch is pure extra state the tick never reads."""
    from repro.core.engine import Engine, EngineConfig, stack_sources
    from tests.conftest import make_batch

    rng = np.random.default_rng(3)
    srcs = [{"S1": make_batch(rng.integers(0, 40, 24),
                              rng.integers(0, 9, 24),
                              ts=np.full(24, t, np.int32))}
            for t in range(8)]

    def run(tc):
        eng = Engine(counting_workflow,
                     EngineConfig(batch_size=32, queue_capacity=128,
                                  telemetry=tc))
        state, outs, _ = eng.run_chunk(eng.init_state(),
                                       stack_sources(srcs), 8)
        return state, outs

    s0, o0 = run(None)
    s1, o1 = run(TelemetryConfig(width=256, impl=impl))
    for part in ("tables", "queues", "processed", "tick"):
        a, b = jax.device_get((s0[part], s1[part]))
        for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            assert np.array_equal(np.asarray(la), np.asarray(lb)), part
    for la, lb in zip(jax.tree.leaves(jax.device_get(o0)),
                      jax.tree.leaves(jax.device_get(o1))):
        assert np.array_equal(np.asarray(la), np.asarray(lb))


def test_chunk_sketch_backends_agree():
    """The sketch itself is backend-independent through the chunk."""
    from repro.core.engine import Engine, EngineConfig, stack_sources
    from repro.core.workflow import Workflow
    from tests.conftest import (CountingUpdater, PassThroughMapper,
                                make_batch)

    rng = np.random.default_rng(5)
    srcs = [{"S1": make_batch(rng.integers(0, 40, 24),
                              ts=np.full(24, t, np.int32))}
            for t in range(6)]
    sketches = []
    for impl in ("ref", "interpret"):
        wf = Workflow([PassThroughMapper(), CountingUpdater()],
                      external_streams=("S1",))
        eng = Engine(wf, EngineConfig(
            batch_size=32, queue_capacity=128,
            telemetry=TelemetryConfig(width=256, impl=impl)))
        state, _, _ = eng.run_chunk(eng.init_state(),
                                    stack_sources(srcs), 6)
        sketches.append(jax.device_get(state["sketch"]))
    a, b = sketches
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert np.array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# controller hysteresis (pure, tier-1)
# ---------------------------------------------------------------------------

def _rep(pressure, hh=()):
    p = np.asarray(pressure, np.float64)
    z = np.zeros_like(p)
    return TelemetryReport(
        tick=0, ticks=1, n_shards=len(p), active=list(range(len(p))),
        events=p * 32, events_per_tick=p * 32, queue_depth=z.copy(),
        queue_peak_delta=z.copy(), dropped_delta=z.copy(),
        occupancy=z.copy(), pressure=p, heavy_hitters=list(hh),
        migration_pause_s=0.0)


def test_controller_square_wave_does_not_flap():
    """A load square wave faster than the dwell produces zero actions:
    one-window spikes are noise by definition."""
    ctl = LoadAutoscaler(high=0.75, low=0.25, dwell=2, cooldown=2)
    acts = [ctl.decide(_rep([1.0, 1.0] if i % 2 == 0 else [0.05, 0.05]),
                       n_active=2, limit=8)
            for i in range(12)]
    assert all(a is None for a in acts), acts


def test_controller_scale_up_down_with_cooldown():
    ctl = LoadAutoscaler(high=0.75, low=0.25, dwell=2, cooldown=2,
                         min_shards=1)
    assert ctl.decide(_rep([1.0] * 2), n_active=2, limit=8) is None
    up = ctl.decide(_rep([1.0] * 2), n_active=2, limit=8)
    assert up is not None and up.kind == "scale" and up.target == 4
    # cooldown: two windows of silence even under sustained pressure
    assert ctl.decide(_rep([1.0] * 4), n_active=4, limit=8) is None
    assert ctl.decide(_rep([1.0] * 4), n_active=4, limit=8) is None
    up2 = ctl.decide(_rep([1.0] * 4), n_active=4, limit=8)
    assert up2 is not None and up2.target == 8
    # limit caps growth: no action when already at the ceiling
    ctl2 = LoadAutoscaler(high=0.75, dwell=1, cooldown=0)
    assert ctl2.decide(_rep([2.0] * 8), n_active=8, limit=8) is None
    # scale down needs the low watermark to *persist* too
    ctl3 = LoadAutoscaler(high=0.75, low=0.25, dwell=2, cooldown=0,
                          min_shards=2)
    assert ctl3.decide(_rep([0.05] * 4), n_active=4, limit=8) is None
    down = ctl3.decide(_rep([0.05] * 4), n_active=4, limit=8)
    assert down is not None and down.kind == "scale" and down.target == 2
    # min_shards floors the shrink
    ctl3.reset()
    for _ in range(4):
        a = ctl3.decide(_rep([0.01] * 2), n_active=2, limit=8)
        assert a is None


def test_controller_skew_prefers_split_and_heat_weights():
    """A single dominating key triggers split (scaling cannot shed
    it); heat_weights discounts the heavy hitter's irreducible mass."""
    ctl = LoadAutoscaler(high=0.5, dwell=1, cooldown=0, skew=0.5)
    rep = _rep([1.2, 0.1], hh=[(7, 100, 0.8)])
    a = ctl.decide(rep, n_active=2, limit=2)
    assert a is not None and a.kind == "split" and a.keys == (7,)
    # can_split=False (durable runs): the skew branch is skipped BEFORE
    # consuming streaks/cooldown, so scale still fires on pressure
    ctl2 = LoadAutoscaler(high=0.5, dwell=1, cooldown=0, skew=0.5)
    a2 = ctl2.decide(rep, n_active=2, limit=8, can_split=False)
    assert a2 is not None and a2.kind == "scale" and a2.target == 4
    # a key that is already split must not re-fire split forever —
    # sustained pressure escalates to scale instead
    ctl3 = LoadAutoscaler(high=0.5, dwell=1, cooldown=0, skew=0.5)
    a3 = ctl3.decide(rep, n_active=2, limit=8, already_split=(7,))
    assert a3 is not None and a3.kind == "scale", a3
    # heat weights: shard 0 hot purely from key 7 -> after discounting
    # it, both shards look alike and weights stay near-neutral
    rep2 = _rep([1.0, 1.0])
    rep2.events = np.array([132.0, 32.0])
    rep2.heavy_hitters = [(7, 100, 0.6)]
    w = ctl.heat_weights(rep2, owners=lambda ks: np.zeros(len(ks), int))
    assert abs(w[0] - w[1]) < 0.02, w
    # without the discount the hot shard would shed hard
    w2 = ctl.heat_weights(rep2, owners=None)
    assert w2[0] < w2[1], w2


def test_controller_rebalance_on_imbalance():
    ctl = LoadAutoscaler(high=5.0, low=0.0, dwell=1, cooldown=0,
                         rebalance_ratio=2.0)
    a = ctl.decide(_rep([1.0, 0.2, 0.2, 0.2]), n_active=4, limit=4)
    assert a is not None and a.kind == "rebalance", a


def test_controller_pause_sized_cooldown():
    """pause_factor stretches the post-action cooldown to cover the
    observed migration pause, measured in window wall-time units — a
    host-path migration that stalls the stream for 5 windows' worth of
    time earns a ~10-window sit-out at factor 2, while the device
    path's millisecond pauses keep the configured floor."""
    ctl = LoadAutoscaler(high=0.75, dwell=1, cooldown=1,
                         pause_factor=2.0)
    rep = _rep([1.0] * 2)
    rep.migration_pause_s = 5.0
    rep.window_s = 1.0
    a = ctl.decide(rep, n_active=2, limit=16)
    assert a is not None and a.kind == "scale"
    # ceil(2 * 5s / 1s) = 10 silent windows despite cooldown=1
    for _ in range(10):
        assert ctl.decide(rep, n_active=4, limit=16) is None
    a2 = ctl.decide(rep, n_active=4, limit=16)
    assert a2 is not None and a2.target == 8
    # a millisecond (device-path) pause keeps the configured floor
    ctl2 = LoadAutoscaler(high=0.75, dwell=1, cooldown=1,
                          pause_factor=2.0)
    rep2 = _rep([1.0] * 2)
    rep2.migration_pause_s = 0.001
    rep2.window_s = 1.0
    assert ctl2.decide(rep2, n_active=2, limit=16) is not None
    assert ctl2.decide(rep2, n_active=4, limit=16) is None
    assert ctl2.decide(rep2, n_active=4, limit=16) is not None


def test_registry_window_wall_clock_and_bytes_ema():
    """note_pause carries bytes alongside seconds, and observe_raw
    stamps the wall-clock span between readings (the denominator the
    controller sizes its pause cooldown with)."""
    import time as _time
    from repro.telemetry.metrics import MetricsRegistry
    reg = MetricsRegistry(TelemetryConfig(alpha=1.0), batch_size=32)
    kw = dict(queue_depth=[0.0], queue_peak=[0.0], dropped=[0.0],
              occupancy=[0.0], active=[0])
    rep0 = reg.observe_raw(tick=0, events=[0.0], **kw)
    assert rep0.window_s == 0.0              # no previous reading
    reg.note_pause(1.5, bytes_moved=4096)
    _time.sleep(0.02)
    rep1 = reg.observe_raw(tick=4, events=[64.0], **kw)
    assert rep1.window_s >= 0.02
    assert rep1.migration_pause_s == pytest.approx(1.5)
    assert rep1.migration_bytes_moved == pytest.approx(4096.0)
    assert rep1.to_dict()["migration_bytes_moved"] == \
        pytest.approx(4096.0)


def test_heat_weights_multi_updater_owner_rows():
    """heat_owners-shaped [n_updaters, K] owner maps: the sketch
    counted a hitter once per subscribing updater's dequeue, so its
    mass splits evenly across rows — two rows pinning key 7 to shard 0
    must discount exactly est, not 2*est."""
    ctl = LoadAutoscaler(skew=0.5)
    rep = _rep([1.0, 1.0])
    rep.events = np.array([132.0, 32.0])
    rep.heavy_hitters = [(7, 100, 0.6)]
    w = ctl.heat_weights(
        rep, owners=lambda ks: np.zeros((2, len(ks)), int))
    assert abs(w[0] - w[1]) < 0.02, w        # 132 - 2*(100/2) == 32
    # one row behaves exactly like the 1-D map
    w1 = ctl.heat_weights(
        rep, owners=lambda ks: np.zeros((1, len(ks)), int))
    w1d = ctl.heat_weights(
        rep, owners=lambda ks: np.zeros(len(ks), int))
    assert np.allclose(w1, w1d)


# ---------------------------------------------------------------------------
# front door (tier-1, single device)
# ---------------------------------------------------------------------------

def test_front_door_app_telemetry():
    from repro import (App, EventBatch, LoadAutoscaler, RuntimeConfig,
                       TelemetryConfig, ops)

    app = App("tele")
    s1 = app.source("S1", {"x": ((), jnp.int32)})
    s1.update(ops.counter("U1"))

    def src(t, _mx):
        keys = np.full(16, 3, np.int32)      # one hot key
        keys[:4] = np.arange(4)
        return {"S1": EventBatch.of(
            key=keys, value={"x": np.ones(16, np.int32)},
            ts=np.full(16, t, np.int32))}

    app.run(src, 8, runtime=RuntimeConfig(
        batch_size=16, chunk_size=2,
        telemetry=TelemetryConfig(width=256, window=2, impl="ref")))
    rep = app.telemetry()
    assert rep.events.sum() > 0
    assert rep.heavy_hitters and rep.heavy_hitters[0][0] == 3
    assert rep.pressure.shape == (1,)
    app.close()

    # config plumbing: LoadAutoscaler is distributed-only
    pol = LoadAutoscaler()
    assert RuntimeConfig(shards=2, autoscale=pol).dist_config() \
        .autoscale is pol
    with pytest.raises(ValueError, match="distributed"):
        RuntimeConfig(shards=1, autoscale=pol).engine_config()
    with pytest.raises(TypeError, match="TelemetryConfig"):
        RuntimeConfig(telemetry=object()).engine_config()


def test_registry_observe_raw_windows():
    """The engine-agnostic core: cumulative counters in, windowed
    deltas + EMA out; counter resets never read as negative load."""
    from repro.telemetry.metrics import MetricsRegistry
    reg = MetricsRegistry(TelemetryConfig(alpha=1.0), batch_size=32)
    kw = dict(queue_depth=[0.0], queue_peak=[0.0], dropped=[0.0],
              occupancy=[0.0], active=[0])
    reg.observe_raw(tick=0, events=[0.0], **kw)
    rep = reg.observe_raw(tick=4, events=[256.0], **kw)
    assert rep.ticks == 4 and rep.events[0] == 256.0
    assert rep.pressure[0] == pytest.approx(256 / 4 / 32)
    # a counter that went backwards (migration reset) clips to zero
    rep2 = reg.observe_raw(tick=8, events=[100.0], **kw)
    assert rep2.events[0] == 0.0 and rep2.pressure[0] == 0.0
    reg.note_pause(2.0)
    rep3 = reg.observe_raw(tick=12, events=[200.0], **kw)
    assert rep3.migration_pause_s > 0.0
    assert rep3.to_dict()["pressure"] == list(rep3.pressure)


# ---------------------------------------------------------------------------
# source-index / engine-tick decoupling in the distributed durable path
# ---------------------------------------------------------------------------

def test_run_span_decouples_source_index_from_engine_tick(tmp_path):
    """Flush-barrier drain ticks must not consume source indices: the
    two-hop workflow forces >= 1 drain tick per flush, yet source_fn
    sees exactly 0..n-1 and the frontier meta records the source
    cursor (the single-shard contract, ported)."""
    from jax.sharding import Mesh
    from repro.core.distributed import DistConfig, DistributedEngine
    from repro.core.durability import DurabilityConfig
    from repro.core.workflow import Workflow
    from repro.slates.flush import FlushConfig, FlushPolicy
    from tests.conftest import CountingUpdater, PassThroughMapper
    from tests.conftest import make_batch

    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    wf = Workflow([PassThroughMapper(), CountingUpdater()],
                  external_streams=("S1",))
    cfg = DistConfig(batch_size=32, queue_capacity=128,
                     durability=DurabilityConfig(
                         dir=str(tmp_path),
                         flush=FlushConfig(policy=FlushPolicy.EVERY_K,
                                           every_k=3)))
    eng = DistributedEngine(wf, mesh, cfg)
    fed = []

    def src(t, _mx):
        fed.append(t)
        b = make_batch(np.arange(8) + t, ts=np.full(8, t, np.int32))
        return {"S1": jax.tree.map(lambda x: x[None], b)}

    state, _ = eng.run(eng.init_state(), src, 9)
    assert fed == list(range(9)), fed
    assert eng.tick_cursor == 9
    eng_tick = int(np.asarray(jax.device_get(state["tick"])).max())
    assert eng_tick > 9          # drain ticks happened, engine-side only
    assert eng.dur.frontier.meta["source_tick"] in (6, 9)
    # WAL records keyed by engine tick: unique and gap-tolerant
    tks = [tk for tk, _ in eng.dur.wals[0].replay(from_offset=0)]
    assert len(tks) == len(set(tks)) == 9
    assert max(tks) > 8          # post-drain ticks keyed past the gap
    eng.close()


# ---------------------------------------------------------------------------
# multi-shard closed loop + actuators (subprocess)
# ---------------------------------------------------------------------------

def test_rebalance_window_rebase_back_to_back():
    """Controller-style back-to-back rebalance(): the first migrates,
    the second sees the rebased (empty) window and no-op skips."""
    out = run_sub("""
        mesh = Mesh(np.array(jax.devices()[:4]), ('data',))
        wf = Workflow([Counter()], external_streams=('S1',))
        eng = DistributedEngine(wf, mesh, DistConfig(
            batch_size=32, queue_capacity=1024, exchange_slack=16.0))
        state = eng.init_state()
        hot = np.full(128, 7, np.int32)
        for t in range(6):
            state, _ = eng.step(state, {'S1': gb(
                hot, np.ones(128, np.float32), t, 4)})
        state, rep1 = eng.rebalance(state)
        assert rep1 is not None
        counts = eng.ring.vnode_counts().copy()
        state, rep2 = eng.rebalance(state)
        assert rep2 is None, rep2
        assert np.array_equal(counts, eng.ring.vnode_counts())
        print('REBASE-OK')
    """, devices=4)
    assert "REBASE-OK" in out


def test_split_keys_runtime_exact_counts():
    """split_keys spreads a heavy hitter over primary + secondary,
    read_slate merges the partials exactly, and clear_split converges
    them back onto the owner — all without recompiling."""
    out = run_sub("""
        from repro.core.distributed import _salt
        from repro.core.hashing import route, route_secondary
        from repro.telemetry import TelemetryConfig
        mesh = Mesh(np.array(jax.devices()[:4]), ('data',))
        wf = Workflow([Counter()], external_streams=('S1',))
        eng = DistributedEngine(wf, mesh, DistConfig(
            batch_size=64, queue_capacity=2048, exchange_slack=16.0,
            hot_key_capacity=8, telemetry=TelemetryConfig(width=256)))
        state = eng.init_state()
        hot = np.full(64, 7, np.int32)
        xs = np.ones(64, np.float32)
        for t in range(3):
            state, _ = eng.step(state, {'S1': gb(hot, xs, t, 4)})
        step_obj = eng._step
        state, _ = eng.split_keys(state, [7])
        assert eng.split_key_set() == [7]
        for t in range(3, 9):
            state, _ = eng.step(state, {'S1': gb(hot, xs, t, 4)})
        assert eng._step is step_obj          # no recompilation
        for _ in range(20):
            state = eng._step_empty(state)
        rh, rs = eng.ring.table()
        k7 = jnp.asarray([7], jnp.int32)
        p = int(route(k7, _salt('U1'), rh, rs)[0])
        s = int(route_secondary(k7, _salt('U1'), rh, rs)[0])
        tb = state['tables']['U1']
        occ = [int(jax.device_get((tb.keys[i] != -1).sum()))
               for i in range(4)]
        assert p != s and occ[p] >= 1 and occ[s] >= 1, (p, s, occ)
        total = eng.read_slate(state, 'U1', 7)
        assert int(total['count']) == 64 * 9, total
        state, rep = eng.clear_split(state)
        assert not eng.split_key_set()
        total2 = eng.read_slate(state, 'U1', 7)
        assert int(total2['count']) == 64 * 9, total2
        occ2 = [int(jax.device_get(
            (state['tables']['U1'].keys[i] != -1).sum()))
            for i in range(4)]
        assert occ2[s] == 0, occ2             # partials converged
        print('SPLIT-OK')
    """, devices=4)
    assert "SPLIT-OK" in out


_CLOSED_LOOP = """
    from repro.telemetry import LoadAutoscaler, TelemetryConfig
    G = %(G)d                     # global events per tick
    LOW, HIGH = %(low)d, %(high)d  # active-shard band
    def feed(t):
        rng = np.random.default_rng(t)
        keys = rng.integers(0, 48, G).astype(np.int32)
        xs = rng.integers(0, 9, G).astype(np.float32)
        hi = (t // 15) %% 2 == 0   # square wave, period 30
        n = G if hi else G // 10
        return keys, xs, np.arange(G) < n
    def gbv(keys, xs, valid, t, n_sh):
        shp = lambda a: a.reshape(n_sh, -1)
        return EventBatch(sid=jnp.zeros(shp(keys).shape, jnp.int32),
                          ts=jnp.full(shp(keys).shape, t, jnp.int32),
                          key=jnp.asarray(shp(keys)),
                          value={'x': jnp.asarray(shp(xs))},
                          valid=jnp.asarray(shp(valid)))
    def run(ctl, shards, n_ticks=60):
        mesh = Mesh(np.array(jax.devices()[:shards]), ('data',))
        wf = Workflow([Counter()], external_streams=('S1',))
        eng = DistributedEngine(wf, mesh, DistConfig(
            batch_size=G // LOW, queue_capacity=4 * G,
            fused=%(fused)r, exchange_slack=8.0,
            telemetry=TelemetryConfig(width=256, alpha=1.0),
            autoscale=ctl))
        state = eng.init_state()
        trace = []
        def src(t, _mx):
            trace.append(len(eng.active_shards))
            return {'S1': gbv(*feed(t), t, eng.n_shards)}
        state, _ = eng.run(state, src, n_ticks)
        state, _ = eng.drain(state)
        return eng, state, trace
    ctl = LoadAutoscaler(high=0.75, low=0.25, window=3, dwell=2,
                         cooldown=1, min_shards=LOW, max_shards=HIGH)
    eng, state, trace = run(ctl, LOW)
    assert trace[0] == LOW and max(trace) == HIGH, trace
    assert trace[-1] == LOW, trace      # shrank back after the wave
    flips = sum(1 for a, b in zip(trace, trace[1:]) if a != b)
    assert flips <= 5, (flips, trace)   # hysteresis: no flapping
    # bitwise parity vs an untelemetered fixed-HIGH run
    engf, statef, _ = run(None, HIGH)
    for k in range(48):
        a = eng.read_slate(state, 'U1', k)
        b = engf.read_slate(statef, 'U1', k)
        assert (a is None) == (b is None), k
        if a is not None:
            assert int(a['count']) == int(b['count']), (k, a, b)
            assert np.float32(a['sum']).tobytes() == \\
                np.float32(b['sum']).tobytes(), k
    print('CLOSED-LOOP-OK', trace)
"""


def test_closed_loop_square_wave_2to4_fast():
    """Tier-1 twin of the acceptance wave: a square-wave load drives
    the LoadAutoscaler 2 -> 4 shards at the high watermark and back to
    2 after cooldown, with slate parity against a fixed-4 run."""
    out = run_sub(_CLOSED_LOOP % {"G": 64, "low": 2, "high": 4,
                                  "fused": "off"}, devices=4)
    assert "CLOSED-LOOP-OK" in out


@pytest.mark.slow
@pytest.mark.parametrize("fused", ["jnp", "interpret"])
def test_closed_loop_square_wave_4to8(fused):
    """The acceptance bar: square-wave load, 4 -> 8 shards at the high
    watermark, back to 4 after cooldown, bitwise slate parity with an
    untelemetered fixed-8 run — on both fused backends."""
    out = run_sub(_CLOSED_LOOP % {"G": 128, "low": 4, "high": 8,
                                  "fused": fused}, devices=8)
    assert "CLOSED-LOOP-OK" in out
