import numpy as np
import pytest

from repro.configs import reduced_config
from repro.launch.serve import Request, ServeConfig, ServingEngine

# LM build + prefill/decode jit: runs in the CI `slow` job
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def engine():
    cfg = reduced_config("qwen2-0.5b")
    return ServingEngine(cfg, ServeConfig(n_slots=4, cache_len=64,
                                          prompt_bucket=16,
                                          queue_capacity=8,
                                          admit_per_tick=2))


def _req(i, rng, max_new=6):
    return Request(rid=i, prompt=rng.integers(
        0, 512, size=int(rng.integers(3, 14))).astype(np.int32),
        max_new=max_new)


def test_all_requests_complete(engine):
    rng = np.random.default_rng(0)
    for i in range(6):
        assert engine.submit(_req(i, rng))
    engine.run(40)
    s = engine.stats()
    assert s["finished"] >= 6
    assert all(len(r.tokens_out) >= 1 for r in engine.finished)


def test_admission_queue_sheds_overload(engine):
    rng = np.random.default_rng(1)
    before = engine.stats()["shed"]
    ok = sum(engine.submit(_req(100 + i, rng)) for i in range(40))
    assert ok <= engine.scfg.queue_capacity
    assert engine.stats()["shed"] > before
    engine.run(120)
    assert engine.stats()["queued"] == 0


def test_continuous_batching_interleaves(engine):
    """A late-arriving request starts decoding while earlier ones are
    mid-generation (slots overlap in time)."""
    rng = np.random.default_rng(2)
    engine.submit(_req(200, rng, max_new=12))
    engine.run(3)
    engine.submit(_req(201, rng, max_new=4))
    engine.run(30)
    r200 = next(r for r in engine.finished if r.rid == 200)
    r201 = next(r for r in engine.finished if r.rid == 201)
    assert r201.done_tick < r200.done_tick  # shorter request finished first


def test_request_journal_recovery(tmp_path):
    """Crash the server mid-serve: the journal replays accepted-but-
    unfinished requests for re-submission (at-least-once serving)."""
    cfg = reduced_config("qwen2-0.5b")
    j = str(tmp_path / "requests.log")
    scfg = ServeConfig(n_slots=2, cache_len=64, prompt_bucket=16)
    eng = ServingEngine(cfg, scfg, journal=j)
    rng = np.random.default_rng(3)
    reqs = [_req(i, rng, max_new=4) for i in range(5)]
    for r in reqs:
        assert eng.submit(r)
    eng.run(6)                      # finishes some, not all
    finished = {r.rid for r in eng.finished}
    assert 0 < len(finished) < 5
    eng.journal.close()             # crash: slots + queue lost

    eng2 = ServingEngine(cfg, scfg, journal=j)
    pending = eng2.recover_requests()
    assert {r.rid for r in pending} == set(range(5)) - finished
    for r in pending:               # journaled prompts survive bit-exact
        orig = next(o for o in reqs if o.rid == r.rid)
        assert np.array_equal(r.prompt, orig.prompt)
        assert r.max_new == orig.max_new
        assert eng2.submit(r, journal=False)
    eng2.run(60)
    assert {r.rid for r in eng2.finished} == {r.rid for r in pending}
    # a second recovery sees everything completed
    assert eng2.recover_requests() == []
